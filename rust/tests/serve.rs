//! `dd serve` contracts, end to end:
//!
//! * concurrent identical submissions dedup onto ONE job and ONE
//!   execution (the cache-dedup story the daemon exists for),
//! * job lifecycles are deterministic — `Scheduled → Running → seed
//!   events in order → Done` — and `check::audit_serve` finds the
//!   history clean,
//! * a result served over HTTP is byte-identical to what the batch CLI
//!   computes for the same options (`report::flow_result_json` on both
//!   sides), even with the daemon's cache warm,
//! * malformed requests get structured 4xx errors, never a job,
//! * `POST /shutdown` drains the queue and the run ends audit-clean.
//!
//! The HTTP side talks to a real `Server` bound on an ephemeral port
//! through a raw `TcpStream` client — the same wire a `curl`-driven CI
//! smoke uses.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use double_duty::arch::ArchVariant;
use double_duty::bench_suites::{all_suites, BenchParams, Benchmark};
use double_duty::check::audit_serve;
use double_duty::flow::engine::{
    run_benchmark_cached, ArtifactCache, CellJob, JobEvent, JobState, PlanQueue,
};
use double_duty::flow::FlowOpts;
use double_duty::report::flow_result_json;
use double_duty::serve::{ServeOpts, ServeSummary, Server};

fn bench(name: &str) -> Benchmark {
    let params = BenchParams::default();
    all_suites(&params)
        .into_iter()
        .find(|b| b.name == name)
        .unwrap_or_else(|| panic!("no benchmark named {name}"))
}

fn small_job(bench_name: &str, route: bool) -> CellJob {
    CellJob {
        bench: bench(bench_name),
        variant: ArchVariant::Dd5,
        flow: FlowOpts {
            seeds: vec![1],
            place_effort: 0.05,
            route,
            ..Default::default()
        },
    }
}

/// Bind a daemon on an ephemeral port and run its accept loop on a
/// thread; the joined handle yields the end-of-life summary.
fn start_server() -> (SocketAddr, std::thread::JoinHandle<ServeSummary>) {
    let server = Server::bind(&ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        disk_cache: false,
        cache_cap_mb: None,
    })
    .expect("bind ephemeral port");
    let addr = server.addr();
    (addr, std::thread::spawn(move || server.run()))
}

/// Minimal blocking HTTP client: one request, read to EOF (the daemon
/// sends `Connection: close`), return (status, body-after-headers).
fn http_req(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: dd\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("send request");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    let text = String::from_utf8(buf).expect("UTF-8 response");
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {text:?}"));
    let body = match text.split_once("\r\n\r\n") {
        Some((_, b)) => b.to_string(),
        None => String::new(),
    };
    (status, body)
}

/// N threads racing the same submission must coalesce onto one job id
/// with exactly one fresh submission, one execution, and N-1 dedup hits.
#[test]
fn concurrent_identical_submits_execute_once() {
    let queue = PlanQueue::start(2, Arc::new(ArtifactCache::new()));
    let job = small_job("fsm-like", false);
    let results: Vec<(usize, bool)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let q = &queue;
                let j = job.clone();
                s.spawn(move || q.submit(j))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("submit thread")).collect()
    });
    let id = results[0].0;
    assert!(results.iter().all(|&(i, _)| i == id), "all submissions share one job id");
    assert_eq!(results.iter().filter(|&&(_, fresh)| fresh).count(), 1);
    let r = queue.wait_terminal(id).expect("job exists");
    assert_eq!(r.failed_seeds, 0);
    assert_eq!(queue.executed(), 1, "identical submissions must execute once");
    assert_eq!(queue.dedup_hits(), 7);
    assert_eq!(queue.len(), 1);
    queue.shutdown_and_join();
}

/// The event log is the deterministic lifecycle — `Scheduled`, `Running`,
/// seed events `0..n` in order, `Done` — and the serve auditor agrees.
#[test]
fn job_lifecycle_is_deterministic_and_audit_clean() {
    let queue = PlanQueue::start(1, Arc::new(ArtifactCache::new()));
    let mut job = small_job("fsm-like", false);
    job.flow.seeds = vec![1, 2];
    let (id, fresh) = queue.submit(job);
    assert!(fresh);
    let r = queue.wait_terminal(id).expect("job exists");
    assert_eq!(r.failed_seeds, 0);
    queue.shutdown_and_join();

    let snaps = queue.snapshots();
    assert_eq!(snaps.len(), 1);
    let s = &snaps[0];
    assert_eq!(s.state, JobState::Done);
    assert_eq!(s.n_seeds, 2);
    let states: Vec<JobState> = s
        .events
        .iter()
        .filter_map(|e| match e {
            JobEvent::State(st) => Some(*st),
            JobEvent::Seed { .. } => None,
        })
        .collect();
    assert_eq!(states, vec![JobState::Scheduled, JobState::Running, JobState::Done]);
    let seed_indices: Vec<usize> = s
        .events
        .iter()
        .filter_map(|e| match e {
            JobEvent::Seed { index, .. } => Some(*index),
            JobEvent::State(_) => None,
        })
        .collect();
    assert_eq!(seed_indices, vec![0, 1], "seed events stream in seed order");
    let violations = audit_serve(&snaps);
    assert!(violations.is_empty(), "audit found: {violations:?}");
}

/// The tentpole contract: `GET /jobs/<id>/result` is byte-for-byte what
/// the batch CLI renders for the same options — here on the routed,
/// closed-timing-loop path, against a *fresh* cache on the batch side
/// while the daemon's shared cache is warm.  Also pins the CI smoke's
/// dedup-on-resubmit wire format.
#[test]
fn daemon_result_is_byte_identical_to_batch_cli() {
    let (addr, handle) = start_server();
    let spec = "{\"bench\": \"fsm-like\", \"variant\": \"dd5\", \"seeds\": [1, 2], \
                \"place_effort\": 0.05, \"route\": true, \"timing_route\": true}";
    let (status, body) = http_req(addr, "POST", "/jobs", spec);
    assert_eq!(status, 201, "fresh submission: {body}");
    assert!(body.contains("\"id\": 0"), "{body}");
    assert!(body.contains("\"dedup\": false"), "{body}");

    // The result endpoint is 409 until the job is terminal.
    let daemon_body = loop {
        let (st, b) = http_req(addr, "GET", "/jobs/j0/result", "");
        if st == 200 {
            break b;
        }
        assert_eq!(st, 409, "non-terminal result fetch: {b}");
        std::thread::sleep(Duration::from_millis(25));
    };

    let flow = FlowOpts {
        seeds: vec![1, 2],
        place_effort: 0.05,
        route: true,
        route_timing_weights: true,
        ..Default::default()
    };
    let batch = run_benchmark_cached(&ArtifactCache::new(), &bench("fsm-like"), ArchVariant::Dd5, &flow);
    assert_eq!(daemon_body, flow_result_json(&batch), "daemon/batch byte-identity");

    // Identical resubmission: answered by the existing (finished) job.
    let (st, b) = http_req(addr, "POST", "/jobs", spec);
    assert_eq!(st, 200, "{b}");
    assert!(b.contains("\"id\": 0"), "{b}");
    assert!(b.contains("\"dedup\": true"), "{b}");
    assert!(b.contains("\"state\": \"done\""), "{b}");

    // The event stream of a finished job replays the whole log and ends.
    let (st, events) = http_req(addr, "GET", "/jobs/j0/events", "");
    assert_eq!(st, 200);
    assert!(events.contains("\"event\": \"seed\""), "{events}");
    assert!(events.contains("\"astar_pops\""), "{events}");
    assert!(events.contains("\"event\": \"end\", \"state\": \"done\""), "{events}");

    let (st, b) = http_req(addr, "POST", "/shutdown", "");
    assert_eq!(st, 200);
    assert!(b.contains("\"draining\": true"), "{b}");
    let summary = handle.join().expect("server thread");
    assert_eq!(summary.jobs, 1);
    assert_eq!(summary.executed, 1, "resubmission must not re-execute");
    assert_eq!(summary.dedup_hits, 1);
    assert_eq!(summary.failed_jobs, 0);
    assert!(summary.violations.is_empty(), "shutdown audit: {:?}", summary.violations);
}

/// Every malformed request is a structured 4xx — never a queued job,
/// never a connection drop — and an empty daemon shuts down clean.
#[test]
fn malformed_requests_get_structured_4xx() {
    let (addr, handle) = start_server();
    let cases: &[(&str, &str, &str, u16)] = &[
        ("POST", "/jobs", "{not json", 400),
        ("POST", "/jobs", "[1, 2]", 400),
        ("POST", "/jobs", "{\"seeds\": [1]}", 400),
        ("POST", "/jobs", "{\"bench\": \"fsm-like\", \"bogus\": 1}", 400),
        ("POST", "/jobs", "{\"bench\": \"fsm-like\", \"seeds\": []}", 400),
        ("POST", "/jobs", "{\"bench\": \"fsm-like\", \"route\": \"yes\"}", 400),
        ("POST", "/jobs", "{\"bench\": \"fsm-like\", \"variant\": \"dd9\"}", 400),
        ("POST", "/jobs", "{\"bench\": \"fsm-like\", \"channel_width\": 0}", 400),
        ("POST", "/jobs", "{\"bench\": \"no-such-circuit\"}", 404),
        ("GET", "/no-such-endpoint", "", 404),
        ("GET", "/jobs/99", "", 404),
        ("GET", "/jobs/99/result", "", 404),
        ("GET", "/jobs/not-a-number/events", "", 404),
        ("DELETE", "/jobs", "", 405),
        ("GET", "/shutdown", "", 405),
    ];
    for &(method, path, body, want) in cases {
        let (st, resp) = http_req(addr, method, path, body);
        assert_eq!(st, want, "{method} {path} {body:?} -> {resp}");
        assert!(resp.contains("\"error\""), "{method} {path}: {resp}");
    }
    let (st, stats) = http_req(addr, "GET", "/stats", "");
    assert_eq!(st, 200);
    assert!(stats.contains("\"jobs\": 0"), "{stats}");
    assert!(stats.contains("\"executed\": 0"), "{stats}");

    let (st, _) = http_req(addr, "POST", "/shutdown", "");
    assert_eq!(st, 200);
    let summary = handle.join().expect("server thread");
    assert_eq!(summary.jobs, 0);
    assert!(summary.violations.is_empty());
}
