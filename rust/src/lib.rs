//! # double-duty
//!
//! Reproduction of *"Double Duty: FPGA Architecture to Enable Concurrent
//! LUT and Adder Chain Usage"* (CS.AR 2025): a Stratix-10-like FPGA
//! architecture model with the DD5/DD6 Double-Duty logic-element variants,
//! a COFFE-2-like circuit-level modeling engine, and a complete VTR-like
//! CAD flow — arithmetic-aware synthesis, LUT technology mapping, ALM/LB
//! packing, timing-driven placement, PathFinder routing, and static timing
//! analysis — plus generators for the Kratos/Koios/VTR-style benchmark
//! suites and a harness that regenerates every table and figure of the
//! paper's evaluation.
//!
//! The placer's batched cost model (weighted HPWL + RUDY congestion) is
//! defined as a JAX/Pallas kernel AOT-compiled to HLO
//! (`python/compile/`); the [`runtime`] module evaluates it from the Rust
//! hot path — natively in this offline build (bit-matching the kernel's
//! reference semantics), through PJRT where an XLA toolchain exists.
//! Python never runs at flow time.
//!
//! ## Experiment engine
//!
//! The paper's evaluation is a grid — benchmark suite x architecture
//! variants x placement seeds.  [`flow::engine`] runs that grid as a
//! parallel, cached pipeline:
//!
//! * [`flow::engine::ExperimentPlan`] describes the grid;
//!   [`flow::engine::Engine::run`] executes it on a scoped-thread work
//!   queue ([`coordinator::parallel_indexed`]), one job per
//!   (circuit, variant, seed) cell.
//! * A content-addressed [`flow::engine::ArtifactCache`] computes each
//!   mapped netlist once per circuit and each packing once per
//!   (circuit, variant); seed jobs share the artifacts read-only.
//! * Determinism contract: results are bit-identical to the serial
//!   [`flow::run_benchmark`] path regardless of worker count or
//!   scheduling, because every job derives its RNG from the seed it
//!   carries and reduction happens in fixed grid order.
//!
//! The `dduty` CLI exposes the worker count as `--jobs N` (default: all
//! cores, or `DDUTY_WORKERS`); `benches/hotpath.rs` measures the sweep
//! speedup and cache hit rates.
//!
//! ## Intra-cell parallelism
//!
//! Inside one grid cell the two hot loops are themselves sharded and
//! incremental:
//!
//! * [`rrg`] is the shared routing-resource graph (node arena, CSR
//!   adjacency, PathFinder cost state); [`route`] runs deterministic
//!   parallel negotiated congestion over it — per-net A* in fixed waves
//!   against frozen cost snapshots on `--route-jobs N` workers, with
//!   fixed-order rip-up and commits, so `Routing` is bit-identical for
//!   any job count (`rust/tests/route_parallel.rs`).  `--timing-route`
//!   closes the timing loop ([`route::route_timing`]): per-*sink*
//!   criticalities from the STA's [`timing::SinkCrit`] arena weigh each
//!   A* target, and an STA re-run against the partial routing every
//!   `--sta-every K` iterations refreshes them with exponential
//!   smoothing (`--crit-alpha`), still bit-identical for any worker
//!   count (`rust/tests/timing_route.rs`).
//! * The annealing placer evaluates batched move proposals — uniform
//!   swaps plus temperature-scheduled macro-column shifts and median
//!   moves ([`place::MoveKind`]) — against an incremental two-lane cost
//!   cache ([`place::cost::IncrementalCost`]): criticality-weighted HPWL
//!   plus a per-sink timing lane fed from the same [`timing::SinkCrit`]
//!   arena the router consumes, refreshed with exponential smoothing
//!   (`--place-crit-alpha`) and re-normalized across seeds against the
//!   previous seed's achieved routed CPD (the engine's cross-seed
//!   place↔route feedback).  The PJRT kernel consumes the cached boxes
//!   directly and validates the wirelength lane.
//! * The synth→map→pack→STA front-end runs on dense CSR index arenas
//!   ([`netlist::index`]) and levelized wave schedules
//!   ([`coordinator::parallel_waves_with`]): the mapper's cut
//!   enumeration, the packer's attraction scoring, and STA's
//!   forward/backward passes shard within each level/scan while
//!   selection and commits stay serial in fixed order — `Netlist`,
//!   `Packing` and `TimingReport` are bit-identical for any job count
//!   (`rust/tests/frontend_parallel.rs`).
//!
//! A persistent artifact cache ([`flow::diskcache`]) serializes mapped
//! netlists and packings under `target/dd-cache` keyed by the same
//! content hashes, so repeated CLI invocations skip the map/pack stages
//! (`--no-disk-cache` opts out; `--cache-cap-mb N` bounds the store with
//! LRU-by-mtime eviction).
//!
//! ## Stage auditors
//!
//! [`check`] is the independent static-analysis layer over every stage
//! artifact: netlist lint (incl. the combinational-loop witness), pack /
//! place legality, route validity over the RRG, and timing sanity — each
//! re-derived from the dense arenas without the producer code paths, so
//! producer bugs cannot self-certify.  `dduty check` runs the auditors
//! over whole benchmark suites; `--check [strict]` gates the flow on them
//! after each stage.  The layer is a *contract*: any future stage must
//! ship its auditor here before its artifacts feed the flow.
//!
//! ## Flow as a service
//!
//! [`serve`] is the resident daemon (`dduty serve`): a std-only HTTP/JSON
//! server over the engine's appendable work queue
//! ([`flow::engine::PlanQueue`]) and shared [`flow::engine::ArtifactCache`].
//! Identical submissions dedup onto one execution
//! ([`flow::engine::CellJob::submission_key`]), per-job progress streams
//! as chunked events, and results are byte-identical to the batch CLI
//! for the same options ([`report::flow_result_json`] is the single
//! rendering both sides of that contract use).

pub mod arch;
pub mod coffe;
pub mod netlist;
pub mod util;

pub mod synth;
pub mod techmap;

pub mod pack;

pub mod timing;

pub mod place;
pub mod runtime;

pub mod rrg;

pub mod route;

pub mod bench_suites;

pub mod check;

pub mod coordinator;
pub mod flow;
pub mod report;
pub mod serve;
