//! Experiment harness: one function per table/figure of the paper's
//! evaluation (the DESIGN.md experiment index).  Each returns rendered
//! tables plus the raw series, so `cargo bench` targets, the `dduty exp`
//! CLI, and EXPERIMENTS.md all draw from the same code.
//!
//! Every suite sweep runs through the parallel experiment engine
//! ([`crate::flow::engine`]) against the process-wide artifact cache, so
//! a figure that evaluates N variants maps each circuit once and packs
//! once per (circuit, variant) — only the per-seed place/route jobs scale
//! with the grid.

use std::collections::HashMap;

use crate::arch::device::Device;
use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::{all_suites, koios_suite, kratos_suite, vtr_suite, BenchParams,
                          Benchmark, Suite};
use crate::check::{CheckMode, EquivSummary};
use crate::coordinator::default_workers;
use crate::flow::engine::{ArtifactCache, Engine, ExperimentPlan};
use crate::flow::{run_flow, FlowError, FlowOpts, FlowResult};
use crate::netlist::NetlistStats;
use crate::pack::{pack, PackOpts, Unrelated};
use crate::synth::multiplier::AdderAlgo;
use crate::synth::Circuit;
use crate::techmap::{map_circuit, MapOpts};
use crate::util::fault::FaultPlan;
use crate::util::stats::geomean;
use crate::util::Table;

/// Shared experiment effort knobs (scaled-down defaults for 1-core runs).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub quick: bool,
    pub seeds: Vec<u64>,
    /// Worker threads for the experiment engine (the CLI's `--jobs N`).
    pub jobs: usize,
    /// Worker threads inside each PathFinder run (`--route-jobs N`;
    /// bit-identical results for any value).
    pub route_jobs: usize,
    /// Back the artifact cache with `target/dd-cache` so repeated CLI
    /// invocations skip map/pack (the CLI enables this unless
    /// `--no-disk-cache`; programmatic/test callers default to off).
    pub disk_cache: bool,
    /// Byte-size cap on the persistent store in MiB (`--cache-cap-mb N`):
    /// stores evict least-recently-modified artifacts beyond the cap.
    /// `None` leaves the store unbounded.
    pub cache_cap_mb: Option<u64>,
    /// Run the stage auditors on every artifact the sweep produces
    /// (`--check [strict]`); see [`crate::check`].
    pub check: CheckMode,
    /// Route with the precomputed cost-to-target lookahead
    /// (`--lookahead on|off`, default on); `false` falls back to the
    /// legacy per-expansion Manhattan heuristic.
    pub lookahead: bool,
    /// Opt unroutable seeds into the deterministic escalation ladder
    /// (`--escalate`; see [`crate::flow::ESCALATION_LADDER`]).  Off by
    /// default — the paper sweeps measure non-convergence as data.
    pub escalate: bool,
    /// Deterministic fault injection (`--inject-faults <spec>`; see
    /// [`crate::util::fault`]).  Empty by default.
    pub faults: FaultPlan,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            quick: false,
            seeds: vec![1, 2, 3],
            jobs: default_workers(),
            route_jobs: 1,
            disk_cache: false,
            cache_cap_mb: None,
            check: CheckMode::Off,
            lookahead: true,
            escalate: false,
            faults: FaultPlan::default(),
        }
    }
}

impl ExpOpts {
    pub fn quick() -> Self {
        ExpOpts { quick: true, seeds: vec![1], ..Default::default() }
    }

    fn flow(&self) -> FlowOpts {
        FlowOpts {
            seeds: self.seeds.clone(),
            place_effort: if self.quick { 0.15 } else { 0.5 },
            route: true,
            route_jobs: self.route_jobs,
            check: self.check,
            lookahead: self.lookahead,
            escalate: self.escalate,
            faults: self.faults.clone(),
            ..Default::default()
        }
    }

    /// Engine bound to the artifact cache the CLI flags select
    /// ([`ArtifactCache::for_cli`]).
    fn engine(&self) -> Engine {
        Engine::with_cache(self.jobs, ArtifactCache::for_cli(self.disk_cache, self.cache_cap_mb))
    }
}

/// One row of the semantic-equivalence report: one (benchmark, variant,
/// view) triple, where `view` is `"map"` or `"pack"`.
pub struct EquivRow {
    pub bench: String,
    pub variant: ArchVariant,
    pub view: &'static str,
    pub summary: EquivSummary,
}

/// Render equivalence rows as a table in the caller's scan order
/// (`dduty check --equiv` iterates benchmarks × variants × views, so the
/// output is bit-identical for any `--jobs`).
pub fn equiv_table(rows: &[EquivRow]) -> Table {
    let mut t = Table::new(
        "Semantic equivalence: source AIG vs mapped/packed netlist",
        &["Benchmark", "Variant", "View", "Outputs", "Folded", "Sim cex",
          "SAT unsat", "SAT cex", "Undecided", "LUT merges", "Status"],
    );
    for r in rows {
        let s = &r.summary;
        let status = if s.all_proved() {
            "equivalent"
        } else if s.sim_refuted + s.sat_refuted > 0 {
            "MISMATCH"
        } else {
            "undecided"
        };
        t.row(&[
            r.bench.clone(),
            r.variant.name().to_string(),
            r.view.to_string(),
            s.outputs.to_string(),
            s.folded.to_string(),
            s.sim_refuted.to_string(),
            s.sat_proved.to_string(),
            s.sat_refuted.to_string(),
            s.undecided.to_string(),
            format!("{}/{}", s.merged_luts, s.merged_luts + s.unmerged_luts),
            status.to_string(),
        ]);
    }
    t
}

/// Table I (delegates to the COFFE engine).
pub fn table1() -> Table {
    crate::coffe::table1()
}

/// Table II.
pub fn table2() -> Table {
    crate::coffe::table2()
}

/// Table III: benchmark-suite statistics on the baseline architecture.
pub fn table3(opts: &ExpOpts) -> Table {
    let params = BenchParams::default();
    let engine = opts.engine();
    let mut t = Table::new(
        "Table III: benchmark suite statistics (baseline Stratix-10-like, scaled)",
        &["Benchmark", "Num. circuits", "ALMs avg", "ALMs max", "Adder% avg",
          "Adder% max", "Avg Fmax (MHz)"],
    );
    for (suite, benches) in [
        (Suite::Vtr, vtr_suite(&params)),
        (Suite::Koios, koios_suite(&params)),
        (Suite::Kratos, kratos_suite(&params)),
    ] {
        let plan = ExperimentPlan {
            benches: benches.clone(),
            variants: vec![ArchVariant::Baseline],
            flow: opts.flow(),
        };
        let results = engine.run(&plan).pop().expect("one variant row");
        let mut alms = Vec::new();
        let mut fracs = Vec::new();
        let mut fmaxs = Vec::new();
        for (b, r) in benches.iter().zip(&results) {
            // Mapped stats come from the same cached artifact the flow used.
            let mapped = engine.cache.mapped(b);
            let st = NetlistStats::of(&mapped.nl);
            alms.push(r.alms as f64);
            fracs.push(st.adder_fraction * 100.0);
            fmaxs.push(r.fmax_mhz);
        }
        let max_or = |v: &[f64]| v.iter().cloned().fold(0.0f64, f64::max);
        t.row(&[
            suite.name().to_string(),
            benches.len().to_string(),
            format!("{:.0}", crate::util::stats::mean(&alms)),
            format!("{:.0}", max_or(&alms)),
            format!("{:.1}%", crate::util::stats::mean(&fracs)),
            format!("{:.1}%", max_or(&fracs)),
            format!("{:.1}", crate::util::stats::mean(&fmaxs)),
        ]);
    }
    t
}

/// Fig. 5: CAD-improvement validation on Kratos — baseline VTR synthesis
/// vs improved Cascade / Wallace / Dadda (+ strength-DP binary tree).
/// Reports normalized geomeans of adders, ALMs, CPD, and ADP.
pub fn fig5(opts: &ExpOpts) -> (Table, HashMap<&'static str, [f64; 4]>) {
    let params = BenchParams::default();
    let algos: [AdderAlgo; 5] = [
        AdderAlgo::VtrBaseline,
        AdderAlgo::Cascade,
        AdderAlgo::BinaryTree,
        AdderAlgo::Wallace,
        AdderAlgo::Dadda,
    ];
    let engine = opts.engine();
    // Per algo, per circuit metrics.
    let mut per_algo: HashMap<&'static str, Vec<FlowResult>> = HashMap::new();
    for algo in algos {
        let benches: Vec<Benchmark> = kratos_suite(&params)
            .iter()
            .map(|b| b.with_algo(algo))
            .collect();
        let plan = ExperimentPlan {
            benches,
            variants: vec![ArchVariant::Baseline],
            flow: opts.flow(),
        };
        let results = engine.run(&plan).pop().expect("one variant row");
        per_algo.insert(algo.name(), results);
    }

    let base = &per_algo["vtr-baseline"];
    let mut t = Table::new(
        "Fig. 5: CAD validation on Kratos (normalized to baseline VTR synthesis, geomean)",
        &["Algorithm", "Adders", "ALMs", "CPD", "ADP"],
    );
    let mut series = HashMap::new();
    for algo in algos {
        let rs = &per_algo[algo.name()];
        let nad: Vec<f64> = rs
            .iter()
            .zip(base)
            .map(|(r, b)| r.adder_bits as f64 / b.adder_bits.max(1) as f64)
            .collect();
        let nalm: Vec<f64> = rs
            .iter()
            .zip(base)
            .map(|(r, b)| r.alms as f64 / b.alms.max(1) as f64)
            .collect();
        let ncpd: Vec<f64> = rs.iter().zip(base).map(|(r, b)| r.cpd_ns / b.cpd_ns).collect();
        let nadp: Vec<f64> = rs.iter().zip(base).map(|(r, b)| r.adp / b.adp).collect();
        let row = [geomean(&nad), geomean(&nalm), geomean(&ncpd), geomean(&nadp)];
        series.insert(algo.name(), row);
        t.row(&[
            algo.name().to_string(),
            format!("{:.3}", row[0]),
            format!("{:.3}", row[1]),
            format!("{:.3}", row[2]),
            format!("{:.3}", row[3]),
        ]);
    }
    (t, series)
}

/// Fig. 6: DD5 vs baseline across the three suites (normalized per circuit;
/// geomean rows per suite).
pub fn fig6(opts: &ExpOpts) -> (Table, Vec<(String, Suite, f64, f64, f64)>) {
    let params = BenchParams::default();
    let benches = all_suites(&params);
    // One plan, two variants: the mapped netlists are shared between the
    // baseline and DD5 passes through the artifact cache.
    let plan = ExperimentPlan {
        benches: benches.clone(),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: opts.flow(),
    };
    let mut grid = opts.engine().run(&plan);
    let dd5 = grid.pop().expect("dd5 row");
    let base = grid.pop().expect("baseline row");

    let mut rows = Vec::new();
    let mut t = Table::new(
        "Fig. 6: Double-Duty DD5 vs baseline (normalized; <1 is better)",
        &["Circuit", "Suite", "ALM area", "CPD", "ADP"],
    );
    for ((b, rb), rd) in benches.iter().zip(&base).zip(&dd5) {
        let area = rd.alm_area_mwta / rb.alm_area_mwta;
        let cpd = rd.cpd_ns / rb.cpd_ns;
        let adp = rd.adp / rb.adp;
        rows.push((b.name.clone(), b.suite, area, cpd, adp));
        t.row(&[
            b.name.clone(),
            b.suite.name().to_string(),
            format!("{:.3}", area),
            format!("{:.3}", cpd),
            format!("{:.3}", adp),
        ]);
    }
    for suite in [Suite::Koios, Suite::Vtr, Suite::Kratos] {
        let a: Vec<f64> = rows.iter().filter(|r| r.1 == suite).map(|r| r.2).collect();
        let c: Vec<f64> = rows.iter().filter(|r| r.1 == suite).map(|r| r.3).collect();
        let p: Vec<f64> = rows.iter().filter(|r| r.1 == suite).map(|r| r.4).collect();
        t.row(&[
            format!("GEOMEAN {}", suite.name()),
            suite.name().to_string(),
            format!("{:.3}", geomean(&a)),
            format!("{:.3}", geomean(&c)),
            format!("{:.3}", geomean(&p)),
        ]);
    }
    let all_a: Vec<f64> = rows.iter().map(|r| r.2).collect();
    let all_p: Vec<f64> = rows.iter().map(|r| r.4).collect();
    t.row(&[
        "GEOMEAN all".to_string(),
        "-".to_string(),
        format!("{:.3} (paper 0.891)", geomean(&all_a)),
        "-".to_string(),
        format!("{:.3} (paper 0.903)", geomean(&all_p)),
    ]);
    (t, rows)
}

/// Fig. 7: DD5 vs DD6 geomeans per suite at width 6 / 50% sparsity.
pub fn fig7(opts: &ExpOpts) -> Table {
    let params = BenchParams { width: 6, sparsity: 0.5, ..Default::default() };
    let benches = all_suites(&params);
    let plan = ExperimentPlan {
        benches: benches.clone(),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6],
        flow: opts.flow(),
    };
    let grid = opts.engine().run(&plan);
    let (base, dd5, dd6) = (&grid[0], &grid[1], &grid[2]);

    let mut t = Table::new(
        "Fig. 7: DD5 vs DD6 (normalized to baseline, geomean per suite)",
        &["Suite", "Arch", "ALM area", "CPD", "ADP"],
    );
    for suite in [Suite::Vtr, Suite::Koios, Suite::Kratos] {
        for (name, rs) in [("DD5", dd5), ("DD6", dd6)] {
            let sel = |f: &dyn Fn(&FlowResult, &FlowResult) -> f64| -> f64 {
                let v: Vec<f64> = benches
                    .iter()
                    .zip(rs.iter().zip(base))
                    .filter(|(b, _)| b.suite == suite)
                    .map(|(_, (r, b))| f(r, b))
                    .collect();
                geomean(&v)
            };
            t.row(&[
                suite.name().to_string(),
                name.to_string(),
                format!("{:.3}", sel(&|r, b| r.alm_area_mwta / b.alm_area_mwta)),
                format!("{:.3}", sel(&|r, b| r.cpd_ns / b.cpd_ns)),
                format!("{:.3}", sel(&|r, b| r.adp / b.adp)),
            ]);
        }
    }
    t
}

/// Fig. 8: routing channel utilization histogram on Kratos (baseline vs
/// DD5). Returns the table and (baseline, dd5) 10-bin histograms.
pub fn fig8(opts: &ExpOpts) -> (Table, Vec<f64>, Vec<f64>) {
    let params = BenchParams::default();
    let benches = kratos_suite(&params);
    let plan = ExperimentPlan {
        benches,
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: opts.flow(),
    };
    let mut grid = opts.engine().run(&plan);
    let dd5_results = grid.pop().expect("dd5 row");
    let base_results = grid.pop().expect("baseline row");

    let hist_of = |results: &[FlowResult]| -> Vec<f64> {
        let mut h = vec![0.0; 10];
        let mut n = 0usize;
        for r in results {
            if r.channel_util.is_empty() {
                continue;
            }
            let rh = {
                let mut hh = vec![0.0; 10];
                for &u in &r.channel_util {
                    hh[((u * 10.0) as usize).min(9)] += 1.0;
                }
                let tot: f64 = hh.iter().sum();
                hh.iter_mut().for_each(|v| *v /= tot);
                hh
            };
            for i in 0..10 {
                h[i] += rh[i];
            }
            n += 1;
        }
        h.iter_mut().for_each(|v| *v /= n.max(1) as f64);
        h
    };
    let hb = hist_of(&base_results);
    let hd = hist_of(&dd5_results);
    let mut t = Table::new(
        "Fig. 8: routing channel utilization histogram, Kratos average",
        &["Utilization bin", "Baseline", "DD5"],
    );
    for i in 0..10 {
        t.row(&[
            format!("{:.1}-{:.1}", i as f64 / 10.0, (i + 1) as f64 / 10.0),
            format!("{:.3}", hb[i]),
            format!("{:.3}", hd[i]),
        ]);
    }
    let mean_bin = |h: &[f64]| -> f64 {
        h.iter().enumerate().map(|(i, &v)| v * (i as f64 + 0.5) / 10.0).sum()
    };
    t.row(&[
        "mean utilization".to_string(),
        format!("{:.3}", mean_bin(&hb)),
        format!("{:.3} (paper: shifts higher)", mean_bin(&hd)),
    ]);
    (t, hb, hd)
}

/// Fig. 9 synthetic stress circuit: `n_adders` adder bits in 20-bit chains
/// plus `n_luts` 5-LUTs drawing inputs from a shared pool (so pairs can
/// co-habit an ALM's 8 general inputs, as the paper's stress circuit does).
pub fn stress_circuit(n_adders: usize, n_luts: usize) -> Circuit {
    let mut c = Circuit::new("stress");
    c.disable_dedup();
    // Shared input pool.
    let pool: Vec<crate::techmap::aig::Lit> =
        (0..192).map(|i| c.pi(&format!("p{i}"))).collect();
    // Adder chains of 20 bits.
    let mut made = 0usize;
    let mut ch = 0usize;
    while made < n_adders {
        let len = 20.min(n_adders - made);
        let ops: Vec<_> = (0..len)
            .map(|i| (pool[(ch * 7 + i) % 192], pool[(ch * 13 + i * 3 + 1) % 192]))
            .collect();
        let (sums, cout) = c.add_chain(ops, crate::techmap::aig::Lit::FALSE);
        c.po_bus(&format!("s{ch}"), &sums);
        c.po(&format!("co{ch}"), cout);
        made += len;
        ch += 1;
    }
    // Independent 5-LUTs: 5-input cones over pool windows.  Windows repeat
    // (so ALM pairs can share inputs, as the paper's stress circuit allows)
    // but each LUT gets a distinct function — a different conjunctive term
    // per window reuse — so structural hashing cannot collapse them.
    const PAIRS: [(usize, usize); 10] =
        [(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4)];
    for l in 0..n_luts {
        let base = (l * 5) % 181;
        let variant = PAIRS[(l / 181) % 10];
        let ins: Vec<_> = (0..5).map(|k| pool[(base + k) % 192]).collect();
        let mut acc = ins[0];
        for &x in &ins[1..] {
            acc = c.aig.xor(acc, x);
        }
        let g = c.aig.and(ins[variant.0], ins[variant.1]);
        let f = c.aig.or(acc, g);
        c.po(&format!("l{l}"), f);
    }
    c
}

/// Fig. 9: packing stress test — 500 adders, increasing LUT count,
/// unrelated clustering ON. Returns rows (n_luts, base area, dd5 area,
/// concurrent packed LUTs).
pub fn fig9() -> (Table, Vec<(usize, f64, f64, usize)>) {
    let n_adders = 500;
    let mut t = Table::new(
        "Fig. 9: packing stress test (500 adders + K 5-LUTs, unrelated clustering)",
        &["K LUTs", "Base ALMs", "DD5 ALMs", "Base area (MWTA)", "DD5 area (MWTA)",
          "Concurrent 5-LUTs"],
    );
    let mut rows = Vec::new();
    for k in (0..=500).step_by(50) {
        let circ = stress_circuit(n_adders, k);
        let nl = map_circuit(&circ, &MapOpts::default());
        let base_arch = Arch::coffe(ArchVariant::Baseline);
        let dd5_arch = Arch::coffe(ArchVariant::Dd5);
        let pb = pack(&nl, &base_arch, &PackOpts { unrelated: Unrelated::On });
        let pd = pack(&nl, &dd5_arch, &PackOpts { unrelated: Unrelated::On });
        let area_b = pb.stats.alms as f64 * base_arch.area.per_alm_total();
        let area_d = pd.stats.alms as f64 * dd5_arch.area.per_alm_total();
        rows.push((k, area_b, area_d, pd.stats.concurrent_luts));
        t.row(&[
            k.to_string(),
            pb.stats.alms.to_string(),
            pd.stats.alms.to_string(),
            format!("{:.0}", area_b),
            format!("{:.0}", area_d),
            pd.stats.concurrent_luts.to_string(),
        ]);
    }
    (t, rows)
}

/// Table IV: end-to-end stress test — fixed device sized for a Kratos
/// circuit, then add SHA instances until place/route fails.
pub fn table4(opts: &ExpOpts) -> Table {
    let params = BenchParams::default();
    let kratos_names = ["conv1d-FU-mini", "conv2d-FU-mini", "gemmt-FU-mini"];
    let mut t = Table::new(
        "Table IV: end-to-end stress test (max SHA instances in a fixed device)",
        &["Circuit", "Arch", "Max SHA", "Adders", "5-LUTs", "Concurrent",
          "CPD (ns)", "ALMs", "LBs"],
    );
    for name in kratos_names {
        let bench = kratos_suite(&params)
            .into_iter()
            .find(|b| b.name == name)
            .unwrap();
        let base_circ = bench.generate();

        // Device sized for baseline + small headroom (the paper fixes the
        // FPGA size needed for a successful baseline implementation).
        let nl0 = map_circuit(&base_circ, &MapOpts::default());
        let arch0 = Arch::coffe(ArchVariant::Baseline);
        let p0 = pack(&nl0, &arch0, &PackOpts::default());
        let device = Device::auto_size(p0.lbs.len() + 10, p0.stats.ios + 200, 1.30);

        for variant in [ArchVariant::Baseline, ArchVariant::Dd5] {
            let arch = Arch::coffe(variant);
            let mut best: Option<(usize, FlowResult)> = None;
            let mut n_sha = 0usize;
            loop {
                n_sha += 1;
                let mut circ = bench.generate();
                for s in 0..n_sha {
                    let sha = crate::bench_suites::vtr::sha_stress(&params);
                    circ.absorb(&sha, &format!("sha{s}_"));
                }
                let nl = map_circuit(&circ, &MapOpts::default());
                let packing = pack(&nl, &arch, &PackOpts { unrelated: Unrelated::Auto });
                // The fixed device is a hard contract: the placer errors on
                // any misfit (LB slots, I/O sites, or chain-macro windows)
                // instead of silently resizing, so every fit dimension is
                // the stress loop's stop condition.  `macro_windows` runs
                // the placer's own window-assignment rule, which subsumes
                // the macro-height check.
                if packing.lbs.len() > device.lb_capacity()
                    || packing.stats.ios > device.io_capacity()
                    || crate::place::macro_windows(&packing, &device).is_none()
                {
                    break;
                }
                let fo = FlowOpts {
                    seeds: vec![opts.seeds[0]],
                    place_effort: if opts.quick { 0.1 } else { 0.3 },
                    route_jobs: opts.route_jobs,
                    device: Some(device.clone()),
                    // The paper's W=400 leaves routing headroom so *logic*
                    // capacity binds; at our scale that corresponds to a
                    // wide channel, otherwise DD5's denser packing hits
                    // routing first and inverts the comparison.
                    channel_width: Some(112),
                    ..Default::default()
                };
                let r = run_flow(&circ, &arch, &fo);
                if !r.routed_ok {
                    break;
                }
                best = Some((n_sha, r));
                if n_sha > 40 {
                    break; // safety bound
                }
            }
            match best {
                Some((n, r)) => t.row(&[
                    name.to_string(),
                    variant.name().to_string(),
                    n.to_string(),
                    r.adder_bits.to_string(),
                    r.luts.to_string(),
                    r.concurrent_luts.to_string(),
                    format!("{:.2}", r.cpd_ns),
                    r.alms.to_string(),
                    r.lbs.to_string(),
                ]),
                None => t.row(&[
                    name.to_string(),
                    variant.name().to_string(),
                    "0".into(), "-".into(), "-".into(), "-".into(), "-".into(),
                    "-".into(), "-".into(),
                ]),
            };
        }
    }
    t
}

// ---------------------------------------------------------------------------
// Canonical JSON rendering (the daemon's wire format)
// ---------------------------------------------------------------------------

/// JSON string escaping: quote, backslash, and control characters.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Canonical JSON number: shortest round-trip text for finite values,
/// `null` for NaN/infinities (JSON has no spelling for them).
pub fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}

/// A JSON array of canonical numbers.
pub fn json_f64_arr(xs: &[f64]) -> String {
    let items: Vec<String> = xs.iter().map(|&x| json_f64(x)).collect();
    format!("[{}]", items.join(", "))
}

/// One structured [`FlowError`] as JSON — the PR-8 failure taxonomy on
/// the wire (stage, seed, cause, recovery action).
pub fn flow_error_json(e: &FlowError) -> String {
    let seed = match e.seed {
        Some(s) => s.to_string(),
        None => "null".to_string(),
    };
    format!(
        "{{\"stage\": \"{}\", \"seed\": {}, \"cause\": \"{}\", \"action\": \"{}\"}}",
        json_escape(e.stage),
        seed,
        json_escape(&e.cause),
        json_escape(e.action.name())
    )
}

/// The canonical single-line JSON rendering of a [`FlowResult`] — the
/// byte-identity surface of the `dd serve` determinism contract.  The
/// daemon's `/jobs/<id>/result` body is exactly this string, and
/// `rust/tests/serve.rs` asserts it matches the batch path's rendering
/// byte-for-byte for the same submission.  `failure_lines` threads the
/// end-of-run failure summary through the result as data
/// ([`FlowResult::failure_lines`]), so a daemon client sees exactly the
/// lines the batch CLI would print to stderr.
pub fn flow_result_json(r: &FlowResult) -> String {
    let errors: Vec<String> = r.errors.iter().map(flow_error_json).collect();
    let lines: Vec<String> =
        r.failure_lines().iter().map(|l| format!("\"{}\"", json_escape(l))).collect();
    format!(
        "{{\"name\": \"{}\", \"variant\": \"{}\", \"luts\": {}, \"adder_bits\": {}, \
         \"alms\": {}, \"lbs\": {}, \"concurrent_luts\": {}, \"alm_area_mwta\": {}, \
         \"cpd_ns\": {}, \"adp\": {}, \"fmax_mhz\": {}, \"routed_ok\": {}, \
         \"route_iters\": {}, \"channel_util\": {}, \"cpd_trace_ns\": {}, \
         \"dedup_hits\": {}, \"failed_seeds\": {}, \"escalations\": {}, \
         \"errors\": [{}], \"failure_lines\": [{}]}}",
        json_escape(&r.name),
        r.variant.name(),
        r.luts,
        r.adder_bits,
        r.alms,
        r.lbs,
        r.concurrent_luts,
        json_f64(r.alm_area_mwta),
        json_f64(r.cpd_ns),
        json_f64(r.adp),
        json_f64(r.fmax_mhz),
        r.routed_ok,
        json_f64(r.route_iters),
        json_f64_arr(&r.channel_util),
        json_f64_arr(&r.cpd_trace_ns),
        r.dedup_hits,
        r.failed_seeds,
        r.escalations,
        errors.join(", "),
        lines.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_circuit_shape() {
        let c = stress_circuit(100, 40);
        assert_eq!(c.num_adder_bits(), 100);
        let nl = map_circuit(&c, &MapOpts::default());
        assert!(nl.num_luts() >= 40);
        assert!(nl.check().is_empty());
    }

    #[test]
    fn fig9_dd5_absorbs_luts() {
        let (_, rows) = fig9();
        // At K=0, baseline is no larger than DD5 (DD5 ALM is bigger).
        let first = rows.first().unwrap();
        assert!(first.1 <= first.2 * 1.001);
        // At K=500, DD5 total area is clearly smaller (absorbed LUTs).
        let last = rows.last().unwrap();
        assert!(last.2 < last.1, "dd5 {} vs base {}", last.2, last.1);
        // Concurrency is substantial.
        assert!(last.3 > 50, "concurrent {}", last.3);
    }

    #[test]
    fn tables12_contain_paper_anchors() {
        let t1 = table1().render();
        assert!(t1.contains("289.6"));
        let t2 = table2().render();
        assert!(t2.contains("202.2"));
    }
}
