//! `dd serve` — the resident flow-as-a-service daemon.
//!
//! A long-running, std-only HTTP server (hand-rolled HTTP/JSON over
//! [`std::net::TcpListener`], no new deps) that accepts flow jobs, runs
//! them on the engine's resident [`PlanQueue`] over the shared
//! content-addressed [`ArtifactCache`], and dedups identical submissions
//! — concurrent identical jobs execute exactly once
//! ([`CellJob::submission_key`]).
//!
//! ## Endpoints
//!
//! | method | path               | purpose                                  |
//! |--------|--------------------|------------------------------------------|
//! | GET    | `/health`          | liveness probe                           |
//! | POST   | `/jobs`            | submit a job spec; returns id + dedup    |
//! | GET    | `/jobs`            | list every job (summary per job)         |
//! | GET    | `/jobs/<id>`       | one job: state, event log, result        |
//! | GET    | `/jobs/<id>/result`| the canonical result JSON (terminal only)|
//! | GET    | `/jobs/<id>/events`| chunked stream of events until terminal  |
//! | GET    | `/stats`           | submission/execution/dedup + cache stats |
//! | POST   | `/shutdown`        | drain the queue, stop, audit, exit       |
//!
//! ## Determinism contract
//!
//! A job's `/jobs/<id>/result` body is exactly
//! [`crate::report::flow_result_json`] of the [`FlowResult`] the batch
//! CLI computes for the same options: the queue runs every job through
//! [`crate::flow::engine::run_benchmark_cached_with`], the same single
//! definition of a cell as `dduty flow` — byte-identity is by
//! construction, and `rust/tests/serve.rs` pins it.
//!
//! ## Failure semantics
//!
//! A failing job is *data*: its state becomes `failed` and its result
//! carries the structured PR-8 [`crate::flow::FlowError`] records plus
//! the [`FlowResult::failure_lines`] the batch CLI would print to stderr
//! — the daemon owns neither the process's stderr nor its exit code.
//! On shutdown the daemon audits its own bookkeeping
//! ([`crate::check::audit_serve`], per the check-layer contract) and
//! reports violations in the final [`ServeSummary`].

pub mod http;
pub mod json;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::arch::ArchVariant;
use crate::bench_suites::{all_suites, BenchParams};
use crate::check::{self, Violation};
use crate::flow::engine::{
    ArtifactCache, CellJob, JobEvent, JobSnapshot, JobState, PlanQueue,
};
use crate::flow::{FlowOpts, FlowResult, SeedMetrics};
use crate::report::{flow_error_json, flow_result_json, json_escape, json_f64, json_f64_arr};
use crate::util::error::{Error, Result};
use json::Json;

/// Daemon configuration (the `dduty serve` CLI flags).
pub struct ServeOpts {
    /// Bind address, e.g. `127.0.0.1:7878` (port `0` = ephemeral, for
    /// tests).
    pub addr: String,
    /// Resident queue worker threads.
    pub workers: usize,
    /// Back the artifact cache with the persistent store.
    pub disk_cache: bool,
    /// Byte-size cap on the persistent store in MiB.
    pub cache_cap_mb: Option<u64>,
}

/// End-of-life report of one daemon run, printed by the CLI after a
/// clean shutdown.
pub struct ServeSummary {
    /// Distinct jobs ever submitted (dedup'd submissions excluded).
    pub jobs: usize,
    /// Jobs a worker actually executed.
    pub executed: usize,
    /// Submissions answered by an existing job.
    pub dedup_hits: usize,
    /// Jobs that ended `failed`.
    pub failed_jobs: usize,
    /// `check::audit_serve` findings over the full job history (empty on
    /// a healthy run).
    pub violations: Vec<Violation>,
}

/// A bound, not-yet-running daemon.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    queue: Arc<PlanQueue>,
}

impl Server {
    /// Bind the listener and start the resident worker pool.
    pub fn bind(opts: &ServeOpts) -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .map_err(|e| Error::msg(format!("bind {}: {e}", opts.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::msg(format!("local_addr: {e}")))?;
        let cache = ArtifactCache::for_cli(opts.disk_cache, opts.cache_cap_mb);
        let queue = Arc::new(PlanQueue::start(opts.workers, cache));
        Ok(Server { listener, addr, queue })
    }

    /// The bound address (resolves port 0 for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The resident queue (tests submit through it directly).
    pub fn queue(&self) -> &Arc<PlanQueue> {
        &self.queue
    }

    /// Accept-loop until a `POST /shutdown` arrives, then drain the
    /// queue, join every worker, audit the job history, and return the
    /// summary.  One thread per connection; handler threads are joined
    /// before shutdown completes, so no response is ever cut off.
    pub fn run(self) -> ServeSummary {
        let stop = Arc::new(AtomicBool::new(false));
        let submitted = Arc::new(AtomicUsize::new(0));
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            let queue = Arc::clone(&self.queue);
            let stop = Arc::clone(&stop);
            let submitted = Arc::clone(&submitted);
            let addr = self.addr;
            handlers.push(std::thread::spawn(move || {
                handle_connection(stream, &queue, &stop, &submitted, addr);
            }));
            // Reap finished handlers so a long-lived daemon does not
            // accumulate join handles.
            handlers = handlers
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
        for h in handlers {
            let _ = h.join();
        }
        // Drain every accepted job, then audit the daemon's own
        // bookkeeping — the check-layer contract applies to the serve
        // stage like any other.
        self.queue.shutdown_and_join();
        let snaps = self.queue.snapshots();
        let failed_jobs = snaps.iter().filter(|s| s.state == JobState::Failed).count();
        let violations = check::audit_serve(&snaps);
        ServeSummary {
            jobs: snaps.len(),
            executed: self.queue.executed(),
            dedup_hits: self.queue.dedup_hits(),
            failed_jobs,
            violations,
        }
    }
}

fn handle_connection(
    mut stream: TcpStream,
    queue: &PlanQueue,
    stop: &AtomicBool,
    submitted: &AtomicUsize,
    addr: SocketAddr,
) {
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            http::respond(&mut stream, 400, "Bad Request", &error_body(&e));
            return;
        }
    };
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["health"]) => http::respond(&mut stream, 200, "OK", "{\"ok\": true}"),
        ("POST", ["jobs"]) => match parse_job_spec(&req.body) {
            Ok(job) => {
                submitted.fetch_add(1, Ordering::Relaxed);
                let (id, fresh) = queue.submit(job);
                let state = match queue.snapshot(id) {
                    Some(s) => s.state.name(),
                    None => JobState::Scheduled.name(),
                };
                let body = format!(
                    "{{\"job\": \"j{id}\", \"id\": {id}, \"state\": \"{state}\", \
                     \"dedup\": {}}}",
                    !fresh
                );
                let (status, reason) = if fresh { (201, "Created") } else { (200, "OK") };
                http::respond(&mut stream, status, reason, &body);
            }
            Err((status, msg)) => {
                let reason = if status == 404 { "Not Found" } else { "Bad Request" };
                http::respond(&mut stream, status, reason, &error_body(&msg));
            }
        },
        ("GET", ["jobs"]) => {
            let rows: Vec<String> =
                queue.snapshots().iter().map(job_summary_json).collect();
            let body = format!("{{\"jobs\": [{}]}}", rows.join(", "));
            http::respond(&mut stream, 200, "OK", &body);
        }
        ("GET", ["jobs", id]) => match parse_job_id(id).and_then(|i| queue.snapshot(i)) {
            Some(s) => http::respond(&mut stream, 200, "OK", &job_detail_json(&s)),
            None => http::respond(&mut stream, 404, "Not Found", &unknown_job(id)),
        },
        ("GET", ["jobs", id, "result"]) => {
            match parse_job_id(id).and_then(|i| queue.snapshot(i)) {
                Some(s) if s.state.is_terminal() => match &s.result {
                    Some(r) => http::respond(&mut stream, 200, "OK", &flow_result_json(r)),
                    None => http::respond(
                        &mut stream,
                        500,
                        "Internal Server Error",
                        &error_body("terminal job carries no result"),
                    ),
                },
                Some(s) => http::respond(
                    &mut stream,
                    409,
                    "Conflict",
                    &format!(
                        "{{\"error\": \"job not terminal\", \"state\": \"{}\"}}",
                        s.state.name()
                    ),
                ),
                None => http::respond(&mut stream, 404, "Not Found", &unknown_job(id)),
            }
        }
        ("GET", ["jobs", id, "events"]) => match parse_job_id(id) {
            Some(i) if queue.snapshot(i).is_some() => stream_events(&mut stream, queue, i),
            _ => http::respond(&mut stream, 404, "Not Found", &unknown_job(id)),
        },
        ("GET", ["stats"]) => {
            http::respond(&mut stream, 200, "OK", &stats_json(queue, submitted))
        }
        ("POST", ["shutdown"]) => {
            http::respond(&mut stream, 200, "OK", "{\"ok\": true, \"draining\": true}");
            stop.store(true, Ordering::SeqCst);
            // Poke the accept loop so it observes the stop flag.
            let _ = TcpStream::connect(addr);
        }
        (_, ["health" | "jobs" | "stats" | "shutdown", ..]) => http::respond(
            &mut stream,
            405,
            "Method Not Allowed",
            &error_body(&format!("{} not allowed on {}", req.method, req.path)),
        ),
        _ => http::respond(
            &mut stream,
            404,
            "Not Found",
            &error_body(&format!("no such endpoint {}", req.path)),
        ),
    }
}

/// Stream a job's event log as chunked JSON lines until the job is
/// terminal (blocking on queue progress, not polling): every
/// [`JobEvent`] — state transitions and per-seed metrics with
/// `cpd_trace`, PathFinder iterations, and `astar_pops` — becomes one
/// chunk the moment it lands.
fn stream_events(stream: &mut TcpStream, queue: &PlanQueue, id: usize) {
    if !http::start_chunked(stream) {
        return;
    }
    let mut seen = 0usize;
    loop {
        let Some((state, events)) = queue.wait_progress(id, seen) else {
            break;
        };
        seen += events.len();
        for e in &events {
            if !http::write_chunk(stream, &format!("{}\n", event_json(e))) {
                return; // peer hung up; stop waiting on the job
            }
        }
        if state.is_terminal() {
            let _ = http::write_chunk(
                stream,
                &format!("{{\"event\": \"end\", \"state\": \"{}\"}}\n", state.name()),
            );
            break;
        }
    }
    let _ = http::end_chunked(stream);
}

fn error_body(msg: &str) -> String {
    format!("{{\"error\": \"{}\"}}", json_escape(msg))
}

fn unknown_job(id: &str) -> String {
    error_body(&format!("unknown job {id:?}"))
}

/// `j3` or bare `3` → 3.
fn parse_job_id(s: &str) -> Option<usize> {
    s.strip_prefix('j').unwrap_or(s).parse::<usize>().ok()
}

fn job_summary_json(s: &JobSnapshot) -> String {
    format!(
        "{{\"job\": \"j{}\", \"bench\": \"{}\", \"variant\": \"{}\", \
         \"state\": \"{}\", \"seeds\": {}, \"events\": {}}}",
        s.id,
        json_escape(&s.bench),
        s.variant.name(),
        s.state.name(),
        s.n_seeds,
        s.events.len()
    )
}

fn job_detail_json(s: &JobSnapshot) -> String {
    let events: Vec<String> = s.events.iter().map(event_json).collect();
    let result = match &s.result {
        Some(r) => flow_result_json(r),
        None => "null".to_string(),
    };
    format!(
        "{{\"job\": \"j{}\", \"bench\": \"{}\", \"variant\": \"{}\", \
         \"state\": \"{}\", \"seeds\": {}, \"submission_key\": \"{:016x}\", \
         \"events\": [{}], \"result\": {result}}}",
        s.id,
        json_escape(&s.bench),
        s.variant.name(),
        s.state.name(),
        s.n_seeds,
        s.key,
        events.join(", ")
    )
}

fn event_json(e: &JobEvent) -> String {
    match e {
        JobEvent::State(s) => {
            format!("{{\"event\": \"state\", \"state\": \"{}\"}}", s.name())
        }
        JobEvent::Seed { index, metrics } => seed_event_json(*index, metrics),
    }
}

/// One finished seed as a progress event: the per-seed metrics the
/// daemon streams incrementally (CPD, closed-loop `cpd_trace`,
/// PathFinder iterations, the deterministic `astar_pops` odometer, and
/// the structured error if the seed failed).
fn seed_event_json(index: usize, m: &SeedMetrics) -> String {
    let route_iters = match m.route_iters {
        Some(x) => json_f64(x),
        None => "null".to_string(),
    };
    let astar_pops = match m.astar_pops {
        Some(n) => n.to_string(),
        None => "null".to_string(),
    };
    let error = match &m.error {
        Some(e) => flow_error_json(e),
        None => "null".to_string(),
    };
    format!(
        "{{\"event\": \"seed\", \"index\": {index}, \"seed\": {}, \"cpd_ns\": {}, \
         \"routed_ok\": {}, \"route_iters\": {route_iters}, \"astar_pops\": {astar_pops}, \
         \"escalation\": {}, \"cpd_trace_ns\": {}, \"error\": {error}}}",
        m.seed,
        json_f64(m.cpd_ns),
        m.routed_ok,
        m.escalation,
        json_f64_arr(&m.cpd_trace_ns)
    )
}

fn stats_json(queue: &PlanQueue, submitted: &AtomicUsize) -> String {
    let st = &queue.cache().stats;
    format!(
        "{{\"submitted\": {}, \"jobs\": {}, \"executed\": {}, \"dedup_hits\": {}, \
         \"cache\": {{\"map_hits\": {}, \"map_misses\": {}, \"pack_hits\": {}, \
         \"pack_misses\": {}, \"lookahead_hits\": {}, \"lookahead_misses\": {}}}}}",
        submitted.load(Ordering::Relaxed),
        queue.len(),
        queue.executed(),
        queue.dedup_hits(),
        st.map_hits.load(Ordering::Relaxed),
        st.map_misses.load(Ordering::Relaxed),
        st.pack_hits.load(Ordering::Relaxed),
        st.pack_misses.load(Ordering::Relaxed),
        st.lookahead_hits.load(Ordering::Relaxed),
        st.lookahead_misses.load(Ordering::Relaxed),
    )
}

/// Parse a job-spec body into a [`CellJob`].  Strict: unknown fields,
/// wrong types, and malformed JSON are a 400; an unknown benchmark is a
/// 404.  Field names mirror the `dduty flow` CLI flags, and the defaults
/// are [`FlowOpts::default`] with the CLI's default variant (baseline) —
/// so a spec and the equivalent CLI invocation name the same cell.
pub fn parse_job_spec(body: &[u8]) -> std::result::Result<CellJob, (u16, String)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400u16, "body is not UTF-8".to_string()))?;
    let spec = json::parse(text).map_err(|e| (400u16, format!("bad JSON: {e}")))?;
    let obj = spec
        .as_obj()
        .ok_or((400u16, "job spec must be a JSON object".to_string()))?;

    let mut bench_name: Option<String> = None;
    let mut variant = ArchVariant::Baseline;
    let mut flow = FlowOpts::default();
    for (key, v) in obj {
        match key.as_str() {
            "bench" => bench_name = Some(str_field(v, key)?.to_string()),
            "variant" => {
                variant = match str_field(v, key)? {
                    "baseline" => ArchVariant::Baseline,
                    "dd5" => ArchVariant::Dd5,
                    "dd6" => ArchVariant::Dd6,
                    other => {
                        return Err((
                            400,
                            format!("unknown variant {other:?} (baseline|dd5|dd6)"),
                        ))
                    }
                }
            }
            "seeds" => {
                let arr = v
                    .as_arr()
                    .ok_or((400u16, "\"seeds\" must be an array of integers".to_string()))?;
                let mut seeds = Vec::with_capacity(arr.len());
                for s in arr {
                    seeds.push(count_field(s, "seeds")? as u64);
                }
                if seeds.is_empty() {
                    return Err((400, "\"seeds\" must be non-empty".to_string()));
                }
                flow.seeds = seeds;
            }
            "place_effort" => flow.place_effort = num_field(v, key)?,
            "route" => flow.route = bool_field(v, key)?,
            "timing_route" => flow.route_timing_weights = bool_field(v, key)?,
            "sta_every" => flow.sta_every = count_field(v, key)?,
            "crit_alpha" => flow.crit_alpha = num_field(v, key)?,
            "place_crit_alpha" => flow.place_crit_alpha = num_field(v, key)?,
            "move_mix" => flow.move_mix = num_field(v, key)?,
            "route_jobs" => flow.route_jobs = count_field(v, key)?.max(1),
            "lookahead" => flow.lookahead = bool_field(v, key)?,
            "escalate" => flow.escalate = bool_field(v, key)?,
            "route_pops_budget" => flow.route_pops_budget = count_field(v, key)?,
            "channel_width" => {
                let w = count_field(v, key)?;
                if w == 0 || w > u16::MAX as usize {
                    return Err((400, format!("\"channel_width\" out of range: {w}")));
                }
                flow.channel_width = Some(w as u16);
            }
            other => return Err((400, format!("unknown job-spec field {other:?}"))),
        }
    }
    let name = bench_name.ok_or((400u16, "job spec requires \"bench\"".to_string()))?;
    let params = BenchParams::default();
    let bench = all_suites(&params)
        .into_iter()
        .find(|b| b.name == name)
        .ok_or((404u16, format!("unknown benchmark {name:?}; see `dduty list`")))?;
    Ok(CellJob { bench, variant, flow })
}

fn str_field<'a>(v: &'a Json, key: &str) -> std::result::Result<&'a str, (u16, String)> {
    v.as_str().ok_or((400, format!("{key:?} must be a string")))
}

fn bool_field(v: &Json, key: &str) -> std::result::Result<bool, (u16, String)> {
    v.as_bool().ok_or((400, format!("{key:?} must be a boolean")))
}

fn num_field(v: &Json, key: &str) -> std::result::Result<f64, (u16, String)> {
    match v.as_f64() {
        Some(x) if x.is_finite() => Ok(x),
        _ => Err((400, format!("{key:?} must be a finite number"))),
    }
}

/// A non-negative integer field (counts, seeds, budgets).
fn count_field(v: &Json, key: &str) -> std::result::Result<usize, (u16, String)> {
    match v.as_f64() {
        Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 => {
            Ok(x as usize)
        }
        _ => Err((400, format!("{key:?} must be a non-negative integer"))),
    }
}

/// Re-exported for the byte-identity test: the daemon result body for
/// `r` (exactly [`flow_result_json`]).
pub fn result_body(r: &FlowResult) -> String {
    flow_result_json(r)
}
