//! Placement-cost kernel runtime.
//!
//! The placer's batched cost model (weighted HPWL + RUDY congestion, see
//! `python/compile/kernels/hpwl.py`) was designed to execute as an
//! AOT-compiled JAX/Pallas HLO artifact through PJRT.  The offline build
//! environment ships neither the `xla` crate nor a PJRT plugin, so this
//! runtime executes a *native* evaluator implementing exactly the same
//! math as the Pallas kernel's reference oracle
//! (`python/compile/kernels/ref.py`):
//!
//! * `whpwl = sum_n w_n * ((xmax - xmin) + (ymax - ymin))`
//! * RUDY demand `w * (dx + dy) / (dx * dy)` with `dx = xmax - xmin + 1`,
//!   spread uniformly over the covered bins of a fixed 64x64 grid
//!   (overlap of `[min, max+1)` with bin `[j, j+1)`, clipped to `[0, 1]`),
//! * `overflow = sum_bin max(demand - capacity, 0)`.
//!
//! All arithmetic is f32, mirroring the XLA kernel's precision, so the
//! placer's kernel-vs-incremental consistency check behaves identically.
//!
//! Artifact compatibility: when `cost_n{N}.hlo.txt` bucket files exist
//! (produced by `python/compile/aot.py` / `make artifacts`), their sizes
//! define the bucket ladder; otherwise a default ladder is used.  Inputs
//! beyond the largest bucket are rejected, exactly as the compiled
//! executables would be.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

/// Fixed congestion-grid side, matching python/compile/kernels/hpwl.py.
pub const GRID: usize = 64;

/// Bucket ladder used when no AOT artifacts are present (matches
/// python/compile/model.py's BUCKETS).
const DEFAULT_BUCKETS: [usize; 5] = [256, 512, 1024, 2048, 4096];

/// The placement-cost kernel with its net-count bucket ladder.
pub struct CostKernel {
    buckets: Vec<usize>,
}

/// Result of one kernel evaluation.
#[derive(Clone, Debug)]
pub struct CostEval {
    /// Weighted HPWL (in the caller's coordinate units — already unscaled).
    pub whpwl: f64,
    /// RUDY congestion map, row-major GRID x GRID.
    pub congestion: Vec<f32>,
    /// Total demand above capacity.
    pub overflow: f64,
}

/// Locate the artifacts directory: $DDUTY_ARTIFACTS, ./artifacts, or the
/// repo-root artifacts next to Cargo.toml.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("DDUTY_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl CostKernel {
    /// Build the kernel, taking the bucket ladder from any
    /// `cost_n*.hlo.txt` artifacts in `dir` (default ladder otherwise).
    pub fn load(dir: &Path) -> Result<CostKernel> {
        let mut buckets = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                let Some(rest) = name.strip_prefix("cost_n") else { continue };
                let Some(nstr) = rest.strip_suffix(".hlo.txt") else { continue };
                let nets: usize = nstr
                    .parse()
                    .with_context(|| format!("bucket size in {name}"))?;
                buckets.push(nets);
            }
        }
        if buckets.is_empty() {
            buckets = DEFAULT_BUCKETS.to_vec();
        }
        buckets.sort_unstable();
        buckets.dedup();
        Ok(CostKernel { buckets })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<CostKernel> {
        Self::load(&artifacts_dir())
    }

    /// Largest supported net count.
    pub fn max_nets(&self) -> usize {
        self.buckets.last().copied().unwrap_or(0)
    }

    /// Evaluate the cost model over per-net boxes
    /// `[xmin, xmax, ymin, ymax, weight]` in kernel grid coordinates
    /// (0..GRID), with a per-bin `capacity` for the overflow term.
    ///
    /// Boxes use *inclusive* bin coordinates: a net confined to one bin
    /// has `xmin == xmax`.
    pub fn evaluate(&self, boxes: &[[f32; 5]], capacity: f32) -> Result<CostEval> {
        let n_live = boxes.len();
        // Bucket selection kept for fidelity with the compiled path: the
        // native evaluator pads implicitly (absent nets contribute
        // nothing), but net counts beyond the ladder are rejected exactly
        // like the compiled executables would reject them.
        let _bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= n_live)
            .with_context(|| {
                format!("{} nets exceeds largest bucket {}", n_live, self.max_nets())
            })?;

        let mut whpwl = 0.0f32;
        let mut congestion = vec![0.0f32; GRID * GRID];
        for b in boxes {
            let [xmin, xmax, ymin, ymax, w] = *b;
            whpwl += w * ((xmax - xmin) + (ymax - ymin));

            let dx = xmax - xmin + 1.0;
            let dy = ymax - ymin + 1.0;
            let dens = w * (dx + dy) / (dx * dy);
            if dens == 0.0 {
                continue;
            }
            // Bins overlapping [min, max+1) along each axis.  The +1 edge
            // bin catches fractional maxima; its overlap is 0 for integral
            // coordinates, matching the reference's dense clip formula.
            let x0 = xmin.max(0.0).floor() as usize;
            let x1 = ((xmax.max(0.0).floor() as usize) + 1).min(GRID - 1);
            let y0 = ymin.max(0.0).floor() as usize;
            let y1 = ((ymax.max(0.0).floor() as usize) + 1).min(GRID - 1);
            for gy in y0..=y1 {
                let oy = (ymax + 1.0).min(gy as f32 + 1.0) - ymin.max(gy as f32);
                let oy = oy.clamp(0.0, 1.0);
                if oy == 0.0 {
                    continue;
                }
                let row = &mut congestion[gy * GRID..(gy + 1) * GRID];
                for (gx, cell) in row.iter_mut().enumerate().take(x1 + 1).skip(x0) {
                    let ox = (xmax + 1.0).min(gx as f32 + 1.0) - xmin.max(gx as f32);
                    *cell += dens * oy * ox.clamp(0.0, 1.0);
                }
            }
        }
        let overflow: f64 = congestion
            .iter()
            .map(|&c| (c - capacity).max(0.0) as f64)
            .sum();
        Ok(CostEval { whpwl: whpwl as f64, congestion, overflow })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel() -> CostKernel {
        CostKernel::load_default().expect("native kernel always loads")
    }

    #[test]
    fn loads_buckets_and_evaluates() {
        let k = kernel();
        assert!(k.max_nets() >= 1024);
        // One net: bbox (0,3)x(0,1), weight 2 -> whpwl = 2*(3+1) = 8.
        let eval = k.evaluate(&[[0.0, 3.0, 0.0, 1.0, 2.0]], 1e9).unwrap();
        assert!((eval.whpwl - 8.0).abs() < 1e-4, "whpwl {}", eval.whpwl);
        assert_eq!(eval.congestion.len(), GRID * GRID);
        assert_eq!(eval.overflow, 0.0);
        // RUDY integrates to w * (dx + dy) = 2 * (4 + 2) = 12.
        let total: f32 = eval.congestion.iter().sum();
        assert!((total - 12.0).abs() < 1e-3, "total {total}");
    }

    #[test]
    fn fractional_boxes_integrate_exactly() {
        let k = kernel();
        // Fractional bbox: demand must still integrate to w * (dx + dy).
        let (xmin, xmax, ymin, ymax, w) = (1.25f32, 3.75, 0.5, 0.5, 1.5);
        let eval = k.evaluate(&[[xmin, xmax, ymin, ymax, w]], f32::MAX).unwrap();
        let want = w * ((xmax - xmin + 1.0) + (ymax - ymin + 1.0));
        let total: f32 = eval.congestion.iter().sum();
        assert!((total - want).abs() < 1e-3, "total {total} want {want}");
    }

    #[test]
    fn bucket_selection_pads() {
        let k = kernel();
        // 1500 nets exceeds the 1024 bucket; a larger bucket must absorb it.
        let boxes: Vec<[f32; 5]> = (0..1500)
            .map(|i| {
                let x = (i % 60) as f32;
                let y = (i / 60 % 60) as f32;
                [x, (x + 2.0).min(63.0), y, (y + 1.0).min(63.0), 1.0]
            })
            .collect();
        let eval = k.evaluate(&boxes, 0.0).unwrap();
        assert!(eval.whpwl > 0.0);
        // capacity 0 -> overflow equals total demand.
        let total: f32 = eval.congestion.iter().sum();
        assert!((eval.overflow - total as f64).abs() < 1e-2 * total as f64 + 1e-3);
    }

    #[test]
    fn oversize_rejected() {
        let k = kernel();
        let boxes = vec![[0.0f32, 1.0, 0.0, 1.0, 1.0]; k.max_nets() + 1];
        assert!(k.evaluate(&boxes, 1.0).is_err());
    }
}
