//! `check::equiv` — SAT-based combinational equivalence checking for the
//! whole synth→map→pack flow.
//!
//! # The logic-neutrality contract
//!
//! Two flow stages claim to preserve logic and this module is the
//! enforcement mechanism for both:
//!
//! * **`techmap::map_circuit` (map)** — every LUT truth table, inverter,
//!   and `AdderBit` cell of the mapped netlist must compute exactly the
//!   function of the source AIG at the sequential cut (PIs + FF q in,
//!   POs + FF d out).
//! * **`pack` (pack)** — packing may *rearrange* (cluster, absorb
//!   operand feeders, break chains, route operands through the Z
//!   bypass) but must never change the computed function.  Every
//!   [`crate::pack::OperandPath`] variant — `Const`, `AbsorbedLut`,
//!   `RouteThrough`, `ZBypass` — resolves to the same boolean value the
//!   mapped netlist delivered on that operand pin, for `chain_break`
//!   and Z-bypass packings alike.
//!
//! # Pipeline
//!
//! For each comparison point (PO, then FF d — stable scan order):
//!
//! 1. **Fold** — spec and impl are rebuilt into one structurally-hashed
//!    miter AIG ([`miter`]); equivalent cones usually collapse so the
//!    XOR output is literally `FALSE`, which is a proof by construction.
//! 2. **Simulate** — surviving cones get 64-way word-parallel random
//!    simulation ([`sim`]) under a fixed seed; a non-zero miter word is
//!    an immediate counterexample.
//! 3. **SAT** — still-surviving cones are Tseitin-encoded ([`cnf`]) and
//!    discharged by the in-crate CDCL solver ([`sat`]): UNSAT proves
//!    equivalence, SAT yields an input-assignment witness, and a blown
//!    conflict budget degrades to a `Warning`-severity
//!    `equiv.undecided` — never a false verdict.
//!
//! Every witness is replayed through two *independent* evaluators — the
//! source circuit's [`crate::synth::circuit::Circuit::try_simulate_cut`]
//! and the plain-bool netlist interpreter
//! [`miter::replay_netlist`] — before it is reported, so an
//! `equiv.mismatch` violation always carries a concrete, re-checkable
//! input assignment.
//!
//! # Determinism
//!
//! Reports are bit-identical for any `--jobs`: SAT cones fan out over
//! [`crate::coordinator::parallel_indexed`] (index-ordered collection),
//! the simulation seed is fixed, CNF variable numbering follows node
//! ids, and violations are emitted in output scan order.  No wall-clock
//! reads, no hash-map iteration.

pub mod cnf;
pub mod miter;
pub mod sat;
pub mod sim;

use super::{Severity, Stage, Violation};
use crate::coordinator;
use crate::netlist::{CellKind, Netlist, NetlistIndex};
use crate::pack::Packing;
use crate::synth::circuit::Circuit;
use crate::techmap::aig::{LeafKind, Lit};
use miter::{EquivView, Miter, MiterOutput};
use sat::SatResult;

/// Tuning knobs for one equivalence run.
#[derive(Clone, Copy, Debug)]
pub struct EquivOpts {
    /// Random-simulation rounds (64 vectors each) before SAT.
    pub sim_rounds: usize,
    /// CDCL conflict budget per cone; exhaustion → `equiv.undecided`.
    pub max_conflicts: u64,
    /// Worker threads for the SAT wave; 0 = [`coordinator::default_workers`].
    pub jobs: usize,
}

impl Default for EquivOpts {
    fn default() -> Self {
        EquivOpts { sim_rounds: 8, max_conflicts: 100_000, jobs: 0 }
    }
}

/// Aggregate counters for one checked view.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EquivSummary {
    /// Comparison points scanned (POs + FF d pins).
    pub outputs: usize,
    /// Proven equivalent by structural folding (miter literal = FALSE).
    pub folded: usize,
    /// Refuted by random simulation.
    pub sim_refuted: usize,
    /// Proven equivalent by SAT (UNSAT miter cone).
    pub sat_proved: usize,
    /// Refuted by SAT (model witness).
    pub sat_refuted: usize,
    /// Conflict budget exhausted or unencodable cone.
    pub undecided: usize,
    /// LUT cells merged onto spec cones via local cut-point proofs.
    pub merged_luts: usize,
    /// LUT cells lifted via `from_truth` instead.
    pub unmerged_luts: usize,
}

impl EquivSummary {
    /// Every output proven equivalent (folded or SAT-UNSAT), none
    /// refuted, none undecided.
    pub fn all_proved(&self) -> bool {
        self.folded + self.sat_proved == self.outputs
    }
}

/// One counterexample: an input assignment under which spec and impl
/// disagree at `output`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mismatch {
    /// Scan label (`po <name>` or `ff<i>.d`).
    pub output: String,
    pub pi_vals: Vec<bool>,
    pub ff_vals: Vec<bool>,
    pub spec_val: bool,
    pub impl_val: bool,
}

/// Full result of checking one view.
#[derive(Debug, Default)]
pub struct EquivOutcome {
    pub summary: EquivSummary,
    pub violations: Vec<Violation>,
    pub mismatches: Vec<Mismatch>,
}

impl EquivOutcome {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

fn bits(vals: &[bool]) -> String {
    vals.iter().map(|&v| if v { '1' } else { '0' }).collect()
}

/// Per-output verdict, collected before rendering violations in scan
/// order so the report is independent of how the work was scheduled.
enum Verdict {
    Folded,
    SimRefuted(Vec<bool>),
    SatProved,
    SatRefuted(Vec<bool>),
    Undecided(&'static str),
}

/// Replay `assignment` (miter-input order: PIs then FF q) through both
/// independent evaluators and render the mismatch.  Falls back to the
/// miter AIG itself if an evaluator rejects the shape (which would
/// itself indicate a builder bug, not a spec/impl agreement).
fn render_mismatch(
    circ: &Circuit,
    nl: &Netlist,
    idx: &NetlistIndex,
    view: &EquivView<'_>,
    m: &Miter,
    oi: usize,
    out: &MiterOutput,
    assignment: &[bool],
) -> Mismatch {
    let n_pis = m.n_pis;
    let pi_vals: Vec<bool> = assignment.iter().copied().take(n_pis).collect();
    let ff_vals: Vec<bool> = assignment.iter().copied().skip(n_pis).collect();

    let miter_eval = |l: Lit| -> bool {
        m.aig.eval(l, |k| match k {
            LeafKind::Pi(i) => assignment.get(i as usize).copied().unwrap_or(false),
            _ => false,
        })
    };

    // Independent spec-side replay.
    let spec_val = match circ.try_simulate_cut(&pi_vals, &ff_vals) {
        Some((pos, ffd)) => {
            if oi < pos.len() {
                pos[oi]
            } else {
                ffd.get(oi - pos.len()).copied().unwrap_or_else(|| miter_eval(out.spec))
            }
        }
        None => miter_eval(out.spec),
    };

    // Independent impl-side replay: find the net feeding this output.
    let impl_net = if oi < nl.outputs.len() {
        nl.outputs
            .get(oi)
            .and_then(|&c| nl.cells.get(c as usize))
            .and_then(|c| c.ins.first())
            .copied()
    } else {
        let fi = oi - nl.outputs.len();
        nl.cells
            .iter()
            .filter(|c| matches!(c.kind, CellKind::Ff))
            .nth(fi)
            .and_then(|c| c.ins.first())
            .copied()
    };
    let impl_val = match (miter::replay_netlist(nl, idx, view, &pi_vals, &ff_vals), impl_net) {
        (Some(vals), Some(net)) => {
            vals.get(net as usize).copied().unwrap_or_else(|| miter_eval(out.impl_lit))
        }
        _ => miter_eval(out.impl_lit),
    };

    Mismatch { output: out.name.clone(), pi_vals, ff_vals, spec_val, impl_val }
}

/// Check one view of `nl` against `circ`.  Never panics; malformed
/// shapes surface as `equiv.shape` violations.
fn check_view(
    circ: &Circuit,
    nl: &Netlist,
    idx: &NetlistIndex,
    view: &EquivView<'_>,
    opts: &EquivOpts,
) -> EquivOutcome {
    let m = match miter::build(circ, nl, idx, view) {
        Ok(m) => m,
        Err(v) => {
            return EquivOutcome {
                summary: EquivSummary::default(),
                violations: vec![v],
                mismatches: Vec::new(),
            }
        }
    };

    let mut verdicts: Vec<Option<Verdict>> = Vec::with_capacity(m.outputs.len());
    for out in &m.outputs {
        verdicts.push(if out.miter == Lit::FALSE { Some(Verdict::Folded) } else { None });
    }

    // Simulation prefilter over the unresolved cones.
    let open: Vec<usize> =
        (0..m.outputs.len()).filter(|&i| verdicts[i].is_none()).collect();
    if !open.is_empty() {
        let lits: Vec<Lit> = open.iter().map(|&i| m.outputs[i].miter).collect();
        let hits = sim::prefilter(&m.aig, m.inputs.len(), &lits, opts.sim_rounds);
        for (k, hit) in hits.into_iter().enumerate() {
            if let Some(assignment) = hit {
                verdicts[open[k]] = Some(Verdict::SimRefuted(assignment));
            }
        }
    }

    // SAT wave over whatever survived, fixed order, index-ordered collection.
    let survivors: Vec<usize> =
        (0..m.outputs.len()).filter(|&i| verdicts[i].is_none()).collect();
    if !survivors.is_empty() {
        let jobs = if opts.jobs == 0 { coordinator::default_workers() } else { opts.jobs };
        let max_conflicts = opts.max_conflicts;
        let aig = &m.aig;
        let outs = &m.outputs;
        let n_inputs = m.inputs.len();
        let sat_verdicts: Vec<Verdict> =
            coordinator::parallel_indexed(survivors.len(), jobs, |k| {
                let oi = survivors[k];
                let Some(cone) = cnf::encode_cone(aig, outs[oi].miter) else {
                    return Verdict::Undecided("cone contains a non-PI leaf");
                };
                let mut solver = cone.solver;
                match solver.solve(max_conflicts) {
                    SatResult::Unsat => Verdict::SatProved,
                    SatResult::Sat(model) => {
                        let mut assignment = vec![false; n_inputs];
                        for &(i, v) in &cone.inputs {
                            if let (Some(slot), Some(&val)) =
                                (assignment.get_mut(i as usize), model.get(v as usize))
                            {
                                *slot = val;
                            }
                        }
                        Verdict::SatRefuted(assignment)
                    }
                    SatResult::Unknown => Verdict::Undecided("conflict budget exhausted"),
                }
            });
        for (k, v) in sat_verdicts.into_iter().enumerate() {
            verdicts[survivors[k]] = Some(v);
        }
    }

    // Render in output scan order.
    let mut summary = EquivSummary {
        outputs: m.outputs.len(),
        merged_luts: m.merged_luts,
        unmerged_luts: m.unmerged_luts,
        ..EquivSummary::default()
    };
    let mut violations = Vec::new();
    let mut mismatches = Vec::new();
    for (oi, verdict) in verdicts.into_iter().enumerate() {
        let out = &m.outputs[oi];
        match verdict {
            Some(Verdict::Folded) => summary.folded += 1,
            Some(Verdict::SatProved) => summary.sat_proved += 1,
            Some(v @ (Verdict::SimRefuted(_) | Verdict::SatRefuted(_))) => {
                let a = match v {
                    Verdict::SimRefuted(a) => {
                        summary.sim_refuted += 1;
                        a
                    }
                    Verdict::SatRefuted(a) => {
                        summary.sat_refuted += 1;
                        a
                    }
                    _ => Vec::new(),
                };
                let mm = render_mismatch(circ, nl, idx, view, &m, oi, out, &a);
                violations.push(Violation::new(
                    Stage::Equiv,
                    Severity::Error,
                    "equiv.mismatch",
                    out.name.clone(),
                    format!(
                        "spec={} impl={} under pis={} ffq={}",
                        mm.spec_val as u8,
                        mm.impl_val as u8,
                        bits(&mm.pi_vals),
                        bits(&mm.ff_vals),
                    ),
                ));
                mismatches.push(mm);
            }
            Some(Verdict::Undecided(why)) => {
                summary.undecided += 1;
                violations.push(Violation::new(
                    Stage::Equiv,
                    Severity::Warning,
                    "equiv.undecided",
                    out.name.clone(),
                    format!("equivalence not decided: {why}"),
                ));
            }
            None => {
                summary.undecided += 1;
                violations.push(Violation::new(
                    Stage::Equiv,
                    Severity::Warning,
                    "equiv.undecided",
                    out.name.clone(),
                    "no verdict recorded",
                ));
            }
        }
    }
    EquivOutcome { summary, violations, mismatches }
}

/// Check the mapped netlist against the source circuit.
pub fn equiv_mapped(circ: &Circuit, nl: &Netlist, opts: &EquivOpts) -> EquivOutcome {
    let idx = NetlistIndex::build(nl);
    check_view(circ, nl, &idx, &EquivView::Mapped, opts)
}

/// Check the packed view (operand paths applied) against the source
/// circuit.  Packing must be logic-neutral; any deviation is a mismatch.
pub fn equiv_packed(
    circ: &Circuit,
    nl: &Netlist,
    packing: &Packing,
    opts: &EquivOpts,
) -> EquivOutcome {
    let idx = NetlistIndex::build(nl);
    check_view(circ, nl, &idx, &EquivView::Packed(packing), opts)
}
