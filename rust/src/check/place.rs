//! Place legality: site exclusivity, carry-macro column alignment, and a
//! device-fit re-check.
//!
//! The one deliberate exception to the "no producer code" rule for this
//! subsystem: the device-fit re-check calls [`crate::place::macro_windows`]
//! — the same greedy column packer the placer uses — because "every chain
//! macro has a vertical window" is *defined* by that packer.  Everything
//! else (site occupancy, alignment, capacities) is recomputed from the
//! artifact alone.

use std::collections::HashMap;

use crate::arch::device::Loc;
use crate::pack::Packing;
use crate::place::{macro_windows, Placement};

use super::{Severity, Stage, Violation};

fn err(code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Place, Severity::Error, code, location, message)
}

/// Audit a placement of `packing` on `placement.device`.  Scan order: LBs
/// ascending, I/Os in `packing.ios` order, macros ascending, device fit.
pub fn audit_placement(packing: &Packing, placement: &Placement) -> Vec<Violation> {
    let mut out = Vec::new();
    let dev = &placement.device;

    // --- LB sites: arity, bounds, exclusivity (LBs ascending). -----------
    if placement.lb_loc.len() != packing.lbs.len() {
        out.push(err(
            "place.arity",
            "lb_loc".to_string(),
            format!(
                "{} LB locations for {} packed LBs",
                placement.lb_loc.len(),
                packing.lbs.len()
            ),
        ));
    }
    let mut site_owner: HashMap<Loc, usize> = HashMap::new();
    for (li, &loc) in placement.lb_loc.iter().enumerate() {
        if !dev.is_lb(loc) {
            out.push(err(
                "place.site-overlap",
                format!("lb {li}"),
                format!(
                    "placed at ({},{}) outside the {}x{} logic grid",
                    loc.x, loc.y, dev.lb_cols, dev.lb_rows
                ),
            ));
        }
        if let Some(&prev) = site_owner.get(&loc) {
            out.push(err(
                "place.site-overlap",
                format!("lb {li}"),
                format!("shares site ({},{}) with LB {prev}", loc.x, loc.y),
            ));
        } else {
            site_owner.insert(loc, li);
        }
    }

    // --- I/O pads (packing.ios order). -----------------------------------
    let mut pad_fill: HashMap<Loc, u16> = HashMap::new();
    for &cell in &packing.ios {
        match placement.io_loc.get(&cell) {
            None => out.push(err(
                "place.io-site",
                format!("io cell {cell}"),
                "I/O cell has no placed pad".to_string(),
            )),
            Some(&loc) => {
                if !dev.is_io(loc) {
                    out.push(err(
                        "place.io-site",
                        format!("io cell {cell}"),
                        format!("pad ({},{}) is not on the I/O perimeter", loc.x, loc.y),
                    ));
                }
                let fill = pad_fill.entry(loc).or_insert(0);
                *fill += 1;
                if *fill == dev.io_per_tile + 1 {
                    // Report once per overfilled tile, at the pad that tips it.
                    out.push(err(
                        "place.io-overlap",
                        format!("io cell {cell}"),
                        format!(
                            "pad tile ({},{}) holds more than {} I/Os",
                            loc.x, loc.y, dev.io_per_tile
                        ),
                    ));
                }
            }
        }
    }

    // --- Carry-macro alignment (macros ascending). ------------------------
    // A multi-LB chain macro must occupy one column, consecutive rows, in
    // macro order — the placer's column/window rule.
    for (ch, m) in packing.chain_macros.iter().enumerate() {
        if m.len() < 2 {
            continue;
        }
        let locs: Vec<Loc> = m
            .iter()
            .filter_map(|&lb| placement.lb_loc.get(lb).copied())
            .collect();
        if locs.len() != m.len() {
            out.push(err(
                "place.macro-alignment",
                format!("chain {ch}"),
                format!("macro references LB index out of range: {m:?}"),
            ));
            continue;
        }
        for (k, w) in locs.windows(2).enumerate() {
            if w[1].x != w[0].x || w[1].y != w[0].y + 1 {
                out.push(err(
                    "place.macro-alignment",
                    format!("chain {ch} lb {}..{}", m[k], m[k + 1]),
                    format!(
                        "macro breaks column alignment: ({},{}) then ({},{})",
                        w[0].x, w[0].y, w[1].x, w[1].y
                    ),
                ));
            }
        }
    }

    // --- Device-fit re-check. ---------------------------------------------
    if packing.lbs.len() > dev.lb_capacity() {
        out.push(err(
            "place.device-fit",
            "device".to_string(),
            format!(
                "{} LBs exceed the {} LB slots of a {}x{} device",
                packing.lbs.len(),
                dev.lb_capacity(),
                dev.lb_cols,
                dev.lb_rows
            ),
        ));
    }
    if packing.ios.len() > dev.io_capacity() {
        out.push(err(
            "place.device-fit",
            "device".to_string(),
            format!(
                "{} I/Os exceed the {} I/O sites",
                packing.ios.len(),
                dev.io_capacity()
            ),
        ));
    }
    let max_macro = packing.chain_macros.iter().map(|m| m.len()).max().unwrap_or(0);
    if max_macro > dev.lb_rows as usize {
        out.push(err(
            "place.device-fit",
            "device".to_string(),
            format!(
                "a {max_macro}-LB chain macro cannot stand in {} rows",
                dev.lb_rows
            ),
        ));
    }
    if macro_windows(packing, dev).is_none() {
        out.push(err(
            "place.device-fit",
            "device".to_string(),
            format!(
                "no vertical window assignment for every chain macro on {}x{}",
                dev.lb_cols, dev.lb_rows
            ),
        ));
    }

    out
}
