//! Minimal aligned-column table printer for paper-style tables.

/// A simple text table with a header row and aligned columns.
#[derive(Default, Clone)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a fraction as a signed percentage delta, e.g. `+3.72%`.
pub fn pct_delta(new: f64, base: f64) -> String {
    if base.abs() < 1e-12 {
        return "n/a".into();
    }
    let d = (new / base - 1.0) * 100.0;
    format!("{:+.2}%", d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row_strs(&["xx", "y"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a   bbbb"));
        assert!(s.contains("xx  y"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row_strs(&["x", "y"]);
    }

    #[test]
    fn pct_delta_formats() {
        assert_eq!(pct_delta(2366.6, 2167.3 * (2366.6 / 2167.3)), "+0.00%");
        assert_eq!(pct_delta(1.0372, 1.0), "+3.72%");
        assert_eq!(pct_delta(1.0, 0.0), "n/a");
    }
}
