//! VTR-standard-like general-logic benchmark generators: hashing, ALUs,
//! FSMs, crossbars — the low-adder-share (~19%) general-purpose profile,
//! plus the small SHA circuit Table IV's end-to-end stress test packs in.

use crate::synth::Circuit;
use crate::techmap::aig::Lit;
use crate::util::Rng;

use super::BenchParams;

/// Rotate-left of a bit vector.
fn rotl(v: &[Lit], n: usize) -> Vec<Lit> {
    let w = v.len();
    (0..w).map(|i| v[(i + w - n % w) % w]).collect()
}

/// SHA-like hash rounds: ch/maj/sigma networks + hard-chain adds.
/// (`sha_rounds` with scale 1 is the "small SHA circuit" of Table IV.)
pub fn sha_rounds(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("sha", p);
    let w = 16; // scaled word width
    let rounds = 2 + p.scale;
    let mut a = c.pi_bus("a", w);
    let mut b = c.pi_bus("b", w);
    let mut e = c.pi_bus("e", w);
    let msg: Vec<Vec<Lit>> = (0..rounds).map(|i| c.pi_bus(&format!("m{i}"), w)).collect();
    for r in 0..rounds {
        // ch(e, a, b) and maj(a, b, e) — classic LUT-heavy SHA logic.
        let ch: Vec<Lit> = (0..w)
            .map(|i| {
                let t = c.aig.and(e[i], a[i]);
                let u = c.aig.and(e[i].compl(), b[i]);
                c.aig.or(t, u)
            })
            .collect();
        let maj: Vec<Lit> = (0..w).map(|i| c.aig.maj3(a[i], b[i], e[i])).collect();
        let s0 = {
            let r2 = rotl(&a, 2);
            let r13 = rotl(&a, 13);
            let r22 = rotl(&a, 7);
            (0..w).map(|i| c.aig.xor3(r2[i], r13[i], r22[i])).collect::<Vec<_>>()
        };
        // Round adds on hard chains.
        let t1 = c.ripple_add(&ch, &msg[r]);
        let t2 = c.ripple_add(&s0, &maj);
        let sum = c.ripple_add(&t1[..w].to_vec(), &t2[..w].to_vec());
        // Rotate state.
        e = b;
        b = a;
        a = sum[..w].to_vec();
    }
    c.po_bus("ha", &a);
    c.po_bus("hb", &b);
    c.po_bus("he", &e);
    c
}

/// I/O-light SHA variant for the Table IV stress test: a single seed bus
/// is expanded internally into the round state and message words, and the
/// final state is folded onto one output word — same core ch/maj/sigma +
/// carry-chain structure as [`sha_rounds`], but each instance costs ~32
/// pads instead of ~144, matching how stress-test instances are fed in
/// practice (registered/duplicated I/O).
pub fn sha_stress(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("sha_stress", p);
    let w = 16;
    let rounds = 2 + p.scale;
    let seed = c.pi_bus("seed", w);
    let expand = |c: &mut Circuit, base: &[Lit], rot: usize, salt: usize| -> Vec<Lit> {
        let r = rotl(base, rot);
        (0..base.len())
            .map(|i| {
                if (salt >> (i % 4)) & 1 == 1 {
                    c.aig.xor(base[i], r[(i + 1) % base.len()])
                } else {
                    r[i]
                }
            })
            .collect()
    };
    let mut a = expand(&mut c, &seed, 3, 0b1010);
    let mut b = expand(&mut c, &seed, 7, 0b0110);
    let mut e = expand(&mut c, &seed, 11, 0b1100);
    let msg: Vec<Vec<Lit>> = (0..rounds)
        .map(|r| expand(&mut c, &seed, r * 5 + 1, 0b1001 ^ r))
        .collect();
    for r in 0..rounds {
        let ch: Vec<Lit> = (0..w)
            .map(|i| {
                let t = c.aig.and(e[i], a[i]);
                let u = c.aig.and(e[i].compl(), b[i]);
                c.aig.or(t, u)
            })
            .collect();
        let maj: Vec<Lit> = (0..w).map(|i| c.aig.maj3(a[i], b[i], e[i])).collect();
        let s0 = {
            let r2 = rotl(&a, 2);
            let r13 = rotl(&a, 13);
            let r22 = rotl(&a, 7);
            (0..w).map(|i| c.aig.xor3(r2[i], r13[i], r22[i])).collect::<Vec<_>>()
        };
        let t1 = c.ripple_add(&ch, &msg[r]);
        let t2 = c.ripple_add(&s0, &maj);
        let sum = c.ripple_add(&t1[..w].to_vec(), &t2[..w].to_vec());
        e = b;
        b = a;
        a = sum[..w].to_vec();
    }
    // Fold the state into one output word.
    let folded: Vec<Lit> = (0..w)
        .map(|i| {
            let t = c.aig.xor(a[i], b[i]);
            c.aig.xor(t, e[i])
        })
        .collect();
    c.po_bus("h", &folded);
    c
}

/// Multi-function ALU: add/sub on chains; and/or/xor/shift in LUTs.
pub fn alu(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("alu", p);
    let n = 1 + p.scale;
    let w = 8;
    for u in 0..n {
        let a = c.pi_bus(&format!("a{u}"), w);
        let b = c.pi_bus(&format!("b{u}"), w);
        let op = c.pi_bus(&format!("op{u}"), 2);
        let add = c.ripple_add(&a, &b);
        let nb: Vec<Lit> = b.iter().map(|&x| x.compl()).collect();
        let sub = c.ripple_add(&a, &nb);
        let logic: Vec<Lit> = (0..w)
            .map(|i| {
                let andv = c.aig.and(a[i], b[i]);
                let xorv = c.aig.xor(a[i], b[i]);
                c.aig.mux(op[0], andv, xorv)
            })
            .collect();
        let out: Vec<Lit> = (0..w)
            .map(|i| {
                let arith = c.aig.mux(op[0], add[i], sub[i]);
                c.aig.mux(op[1], arith, logic[i])
            })
            .collect();
        c.po_bus(&format!("r{u}"), &out);
    }
    c
}

/// Moore FSM bank: registered next-state logic (control-dominated).
pub fn fsm(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("fsm", p);
    let machines = 3 * p.scale;
    for m in 0..machines {
        let inp = c.pi_bus(&format!("in{m}"), 4);
        let state: Vec<Lit> = (0..4).map(|_| c.ff()).collect();
        // Random-ish but deterministic next-state network.
        let mut rng = Rng::new(p.seed ^ (m as u64) << 8);
        for (si, &q) in state.iter().enumerate() {
            let i1 = inp[rng.below(4)];
            let i2 = inp[rng.below(4)];
            let s1 = state[rng.below(4)];
            let s2 = state[(si + 1) % 4];
            let t = c.aig.xor(i1, s1);
            let u = c.aig.and(i2, s2);
            let v = c.aig.or(t, u);
            let d = c.aig.xor(v, q);
            c.set_ff_d(q, d);
        }
        let out = c.aig.maj3(state[0], state[1], state[2]);
        c.po(&format!("o{m}"), out);
    }
    c
}

/// Parameterized crossbar: N x N one-hot-select mux matrix (pure LUTs).
pub fn crossbar(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("xbar", p);
    let n = 3 + p.scale;
    let w = 4;
    let ins: Vec<Vec<Lit>> = (0..n).map(|i| c.pi_bus(&format!("i{i}"), w)).collect();
    for o in 0..n {
        let sel = c.pi_bus(&format!("sel{o}"), 2);
        let out: Vec<Lit> = (0..w)
            .map(|bi| {
                let m0 = c.aig.mux(sel[0], ins[0][bi], ins[1 % n][bi]);
                let m1 = c.aig.mux(sel[0], ins[2 % n][bi], ins[3 % n][bi]);
                c.aig.mux(sel[1], m0, m1)
            })
            .collect();
        c.po_bus(&format!("o{o}"), &out);
    }
    c
}

/// Counter array: registered increments (chains + FFs).
pub fn counters(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("counters", p);
    let n = 2 * p.scale;
    let w = 8;
    for u in 0..n {
        let en = c.pi(&format!("en{u}"));
        let q: Vec<Lit> = (0..w).map(|_| c.ff()).collect();
        let one: Vec<Lit> = (0..w).map(|i| if i == 0 { en } else { Lit::FALSE }).collect();
        let next = c.ripple_add(&q, &one);
        for (i, &qq) in q.iter().enumerate() {
            c.set_ff_d(qq, next[i]);
        }
        c.po_bus(&format!("cnt{u}"), &q);
        // Terminal-count and range decoders (LUT logic).
        let mut tc = Lit::TRUE;
        for &qq in &q {
            tc = c.aig.and(tc, qq);
        }
        c.po(&format!("tc{u}"), tc);
        for d in 0..4usize {
            let mut m = Lit::TRUE;
            for (i, &qq) in q.iter().enumerate() {
                let want = (0xA5u32 >> ((i + d) % 8)) & 1 == 1;
                let bit = if want { qq } else { qq.compl() };
                m = c.aig.and(m, bit);
            }
            c.po(&format!("dec{u}_{d}"), m);
        }
    }
    c
}

/// CORDIC-ish rotate stages: shifts (wires) + add/sub chains.
pub fn cordic(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("cordic", p);
    let w = 10;
    let stages = 2 + p.scale;
    let mut x = c.pi_bus("x", w);
    let mut y = c.pi_bus("y", w);
    for s in 0..stages {
        let dir = c.pi(&format!("d{s}"));
        let ys: Vec<Lit> = (0..w).map(|i| y.get(i + s + 1).copied().unwrap_or(Lit::FALSE)).collect();
        let xs: Vec<Lit> = (0..w).map(|i| x.get(i + s + 1).copied().unwrap_or(Lit::FALSE)).collect();
        // x' = x -/+ (y >> s), y' = y +/- (x >> s): mux the operand
        // complement by direction, then hard-add.
        let ys_m: Vec<Lit> = ys.iter().map(|&b| c.aig.xor(b, dir)).collect();
        let xs_m: Vec<Lit> = xs.iter().map(|&b| c.aig.xor(b, dir.compl())).collect();
        let nx = c.ripple_add(&x, &ys_m);
        let ny = c.ripple_add(&y, &xs_m);
        x = nx[..w].to_vec();
        y = ny[..w].to_vec();
    }
    // Quadrant correction network (pure LUT logic).
    let q0 = c.pi("q0");
    let q1 = c.pi("q1");
    let xc: Vec<Lit> = x
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            let sw = c.aig.mux(q0, b, y[i]);
            c.aig.xor(sw, q1)
        })
        .collect();
    let yc: Vec<Lit> = y
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            let sw = c.aig.mux(q0, b, x[i]);
            let t = c.aig.and(q1, sw.compl());
            let u = c.aig.and(q1.compl(), sw);
            c.aig.or(t, u)
        })
        .collect();
    c.po_bus("xo", &xc);
    c.po_bus("yo", &yc);
    c
}

/// FIR filter with constant taps (mixed adders/LUTs).
pub fn fir(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("fir", p);
    let mut rng = Rng::new(p.seed ^ 0xf14);
    let taps = 4;
    let n = 2 * p.scale;
    let xs: Vec<Vec<Lit>> = (0..n + taps)
        .map(|i| c.pi_bus(&format!("x{i}"), p.width))
        .collect();
    for o in 0..n {
        let coef: Vec<u64> = (0..taps)
            .map(|_| 1 + rng.below((1 << p.width) - 1) as u64)
            .collect();
        let rows: Vec<Vec<Lit>> = (0..taps)
            .map(|k| {
                crate::synth::multiplier::unrolled_mul(&mut c, &xs[o + k], coef[k],
                                                       p.width, p.algo)
            })
            .collect();
        let y = crate::synth::reduce_rows(&mut c, rows, p.algo);
        c.po_bus(&format!("y{o}"), &y);
    }
    c
}

/// Wide parity/ECC trees: XOR-dominated pure LUT logic.
pub fn parity(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("parity", p);
    let groups = 4 * p.scale;
    for g in 0..groups {
        let xs = c.pi_bus(&format!("d{g}"), 18);
        let mut acc = xs[0];
        for &x in &xs[1..] {
            acc = c.aig.xor(acc, x);
        }
        c.po(&format!("p{g}"), acc);
        // Syndrome bits over strided subsets.
        for s in 0..3 {
            let mut syn = Lit::FALSE;
            for (i, &x) in xs.iter().enumerate() {
                if i % 3 == s {
                    syn = c.aig.xor(syn, x);
                }
            }
            c.po(&format!("s{g}_{s}"), syn);
        }
    }
    c
}
