"""Kernel-vs-reference correctness: the CORE numeric signal for L1.

The Pallas kernel (interpret=True) must match the pure-jnp oracle bit-for
tolerance across shapes, weights, degenerate boxes, and padding masks.
Hypothesis drives randomized sweeps; fixed cases pin the edge behaviour.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.hpwl import GRID, NET_BLOCK, placement_cost_pallas
from compile.kernels.ref import placement_cost_ref
from compile.model import BUCKETS, placement_cost


def _rand_boxes(rng, n):
    """Random valid inclusive boxes inside the GRID."""
    xmin = rng.integers(0, GRID, n).astype(np.float32)
    ymin = rng.integers(0, GRID, n).astype(np.float32)
    xspan = rng.integers(0, GRID, n).astype(np.float32)
    yspan = rng.integers(0, GRID, n).astype(np.float32)
    xmax = np.minimum(xmin + xspan, GRID - 1).astype(np.float32)
    ymax = np.minimum(ymin + yspan, GRID - 1).astype(np.float32)
    w = rng.random(n).astype(np.float32) * 2.0
    valid = (rng.random(n) < 0.8).astype(np.float32)
    return xmin, xmax, ymin, ymax, w, valid


def _assert_match(args):
    got_h, got_c = placement_cost_pallas(*args)
    ref_h, ref_c = placement_cost_ref(*map(jnp.asarray, args))
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(ref_h),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(ref_c),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("blocks", [1, 2, 4, 8])
def test_kernel_matches_ref_random(blocks):
    rng = np.random.default_rng(blocks)
    _assert_match(_rand_boxes(rng, blocks * NET_BLOCK))


def test_all_padding_is_zero():
    n = NET_BLOCK
    z = np.zeros(n, np.float32)
    h, c = placement_cost_pallas(z, z, z, z, np.ones(n, np.float32), z)
    assert float(h[0]) == 0.0
    assert float(np.asarray(c).sum()) == 0.0


def test_single_net_single_bin():
    n = NET_BLOCK
    xmin = np.zeros(n, np.float32); xmax = np.zeros(n, np.float32)
    ymin = np.zeros(n, np.float32); ymax = np.zeros(n, np.float32)
    xmin[0] = xmax[0] = 5.0
    ymin[0] = ymax[0] = 7.0
    w = np.zeros(n, np.float32); w[0] = 1.0
    valid = np.zeros(n, np.float32); valid[0] = 1.0
    h, c = placement_cost_pallas(xmin, xmax, ymin, ymax, w, valid)
    # Zero-span net: HPWL 0, but RUDY demand (1+1)/(1*1) = 2 in its bin.
    assert float(h[0]) == 0.0
    c = np.asarray(c)
    assert c[7, 5] == pytest.approx(2.0)
    assert float(c.sum()) == pytest.approx(2.0)


def test_full_grid_net():
    n = NET_BLOCK
    xmin = np.zeros(n, np.float32)
    xmax = np.full(n, GRID - 1, np.float32)
    ymin = np.zeros(n, np.float32)
    ymax = np.full(n, GRID - 1, np.float32)
    w = np.zeros(n, np.float32); w[0] = 1.0
    valid = np.zeros(n, np.float32); valid[0] = 1.0
    h, c = placement_cost_pallas(xmin, xmax, ymin, ymax, w, valid)
    assert float(h[0]) == pytest.approx(2.0 * (GRID - 1))
    # Demand integrates to w * (dx + dy) = 2 * GRID.
    assert float(np.asarray(c).sum()) == pytest.approx(2.0 * GRID, rel=1e-5)
    # Uniform spread.
    assert np.allclose(np.asarray(c), 2.0 * GRID / (GRID * GRID), atol=1e-6)


def test_weights_scale_linearly():
    rng = np.random.default_rng(0)
    args = _rand_boxes(rng, NET_BLOCK)
    h1, c1 = placement_cost_pallas(*args)
    args3 = list(args); args3[4] = args[4] * 3.0
    h3, c3 = placement_cost_pallas(*args3)
    np.testing.assert_allclose(np.asarray(h3), 3 * np.asarray(h1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c3), 3 * np.asarray(c1),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 4))
def test_hypothesis_sweep(seed, blocks):
    rng = np.random.default_rng(seed)
    _assert_match(_rand_boxes(rng, blocks * NET_BLOCK))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_degenerate_boxes(seed):
    """Many zero-span boxes and zero weights mixed in."""
    rng = np.random.default_rng(seed)
    n = NET_BLOCK
    xmin = rng.integers(0, GRID, n).astype(np.float32)
    ymin = rng.integers(0, GRID, n).astype(np.float32)
    args = (xmin, xmin.copy(), ymin, ymin.copy(),
            (rng.random(n) < 0.5).astype(np.float32),
            (rng.random(n) < 0.5).astype(np.float32))
    _assert_match(args)


class TestModel:
    """L2 model: overflow penalty semantics + bucket shapes lower cleanly."""

    def test_overflow_zero_when_capacity_high(self):
        rng = np.random.default_rng(1)
        args = _rand_boxes(rng, NET_BLOCK)
        _, cong = placement_cost_pallas(*args)
        cap = np.asarray([float(np.asarray(cong).max()) + 1.0], np.float32)
        _, _, ov = placement_cost(*args, cap)
        assert float(ov[0]) == 0.0

    def test_overflow_counts_excess(self):
        rng = np.random.default_rng(2)
        args = _rand_boxes(rng, NET_BLOCK)
        _, cong = placement_cost_pallas(*args)
        cap = np.asarray([0.0], np.float32)
        _, _, ov = placement_cost(*args, cap)
        assert float(ov[0]) == pytest.approx(float(np.asarray(cong).sum()),
                                             rel=1e-5)

    @pytest.mark.parametrize("n", BUCKETS)
    def test_buckets_lower(self, n):
        import jax
        from compile.aot import lower_bucket
        text = lower_bucket(n)
        assert "HloModule" in text
        assert len(text) > 1000
