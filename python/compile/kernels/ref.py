"""Pure-jnp oracle for the placement-cost Pallas kernel.

Computes the same weighted HPWL and RUDY congestion map as
``hpwl.placement_cost_pallas`` with no Pallas, no blocking — the
correctness reference for pytest / hypothesis sweeps.
"""

import jax.numpy as jnp

from .hpwl import GRID


def placement_cost_ref(xmin, xmax, ymin, ymax, w, valid):
    """Reference (whpwl f32[1], cong f32[GRID, GRID])."""
    w = w * valid
    span = (xmax - xmin) + (ymax - ymin)
    whpwl = jnp.sum(w * span)[None]

    dx = xmax - xmin + 1.0
    dy = ymax - ymin + 1.0
    dens = w * (dx + dy) / (dx * dy)

    cells = jnp.arange(GRID, dtype=jnp.float32)
    ox = jnp.clip(jnp.minimum(xmax[:, None] + 1.0, cells[None, :] + 1.0)
                  - jnp.maximum(xmin[:, None], cells[None, :]), 0.0, 1.0)
    oy = jnp.clip(jnp.minimum(ymax[:, None] + 1.0, cells[None, :] + 1.0)
                  - jnp.maximum(ymin[:, None], cells[None, :]), 0.0, 1.0)
    cong = jnp.einsum("b,by,bx->yx", dens, oy, ox)
    return whpwl, cong
