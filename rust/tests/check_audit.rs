//! Mutation tests for the [`double_duty::check`] stage auditors.
//!
//! Pattern (one test per auditor, per the check-subsystem contract): build
//! a small real artifact through the production flow, assert the
//! uncorrupted artifact audits clean, inject one specific corruption, and
//! assert the auditor reports exactly that violation code.  A lint that
//! never fires is indistinguishable from a lint that works; these tests
//! are the difference.
//!
//! Also drives two producer *failure paths* through the violation types
//! (the disk cache's integrity rejection and the placer's fixed-device
//! misfit errors), asserting the surfaced messages name the failing
//! dimension rather than a generic "failed".

use double_duty::arch::{Arch, ArchVariant, Device};
use double_duty::bench_suites::{all_suites, BenchParams};
use double_duty::check::{
    audit_lookahead, audit_netlist, audit_packing, audit_placement, audit_recovery,
    audit_routing, audit_serve, audit_timing, check_benchmark, Severity, Stage, Violation,
};
use double_duty::flow::diskcache::{DiskCache, CACHE_VERSION};
use double_duty::flow::engine::{
    ArtifactCache, JobEvent, JobSnapshot, JobState, MappedCircuit,
};
use double_duty::flow::{
    assemble_result, FlowError, FlowOpts, RecoveryAction, SeedMetrics, ESCALATION_LADDER,
};
use double_duty::netlist::{CellKind, Netlist, NetlistIndex, NO_NET};
use double_duty::pack::{pack, PackOpts, Packing};
use double_duty::place::cost::NetModel;
use double_duty::place::{place, PlaceOpts, Placement};
use double_duty::route::{route, RouteOpts, Routing};
use double_duty::rrg::lookahead::Lookahead;
use double_duty::rrg::RrGraph;
use double_duty::synth::circuit::Circuit;
use double_duty::synth::multiplier::{soft_mul, AdderAlgo};
use double_duty::techmap::aig::Lit;
use double_duty::techmap::{map_circuit, MapOpts};
use double_duty::timing::{sta, SinkCrit};
use double_duty::util::error::Error;

/// A real mapped-and-packed multiplier (same fixture the timing suite
/// uses): long carry chains, absorbed operand LUTs, FFs-free datapath.
fn mul_fixture(v: ArchVariant) -> (Netlist, Packing, Arch) {
    let mut c = Circuit::new("m");
    let x = c.pi_bus("x", 6);
    let y = c.pi_bus("y", 6);
    let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
    c.po_bus("p", &p);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(v);
    let packing = pack(&nl, &arch, &PackOpts::default());
    (nl, packing, arch)
}

fn placed(nl: &Netlist, packing: &Packing, arch: &Arch) -> Placement {
    place(nl, packing, arch, &PlaceOpts { effort: 0.1, ..Default::default() })
        .expect("auto-sized placement fits")
}

fn routed(nl: &Netlist, packing: &Packing, arch: &Arch, pl: &Placement) -> (NetModel, Routing) {
    let mut model = NetModel::build(nl, packing);
    model.set_weights(&[], false);
    let r = route(&model, pl, arch, &RouteOpts::default());
    (model, r)
}

fn has_code(vs: &[Violation], code: &str) -> bool {
    vs.iter().any(|v| v.code == code)
}

// --- netlist auditor -------------------------------------------------------

#[test]
fn netlist_audit_catches_chain_position_gap() {
    let (mut nl, _, _) = mul_fixture(ArchVariant::Dd5);
    let idx = NetlistIndex::build(&nl);
    assert!(audit_netlist(&nl, &idx).is_empty(), "uncorrupted netlist audits clean");

    // Shift one mid-chain bit's position: chain 0 now has a pos gap (and a
    // duplicate position) without touching any net, so the index stays valid.
    let victim = nl
        .cells
        .iter()
        .position(|c| matches!(c.kind, CellKind::AdderBit { pos: 1, .. }))
        .expect("fixture has a multi-bit chain");
    let CellKind::AdderBit { chain, pos } = nl.cells[victim].kind.clone() else {
        unreachable!()
    };
    nl.cells[victim].kind = CellKind::AdderBit { chain, pos: pos + 1 };

    let vs = audit_netlist(&nl, &idx);
    assert!(has_code(&vs, "netlist.chain-break"), "expected netlist.chain-break in {vs:?}");
}

#[test]
fn netlist_audit_catches_dangling_input() {
    let (mut nl, _, _) = mul_fixture(ArchVariant::Baseline);
    let idx = NetlistIndex::build(&nl);
    assert!(audit_netlist(&nl, &idx).is_empty());

    let victim = nl
        .cells
        .iter()
        .position(|c| matches!(c.kind, CellKind::Lut { .. }) && !c.ins.is_empty())
        .expect("fixture has a LUT");
    nl.cells[victim].ins[0] = NO_NET;

    let vs = audit_netlist(&nl, &idx);
    assert!(has_code(&vs, "netlist.dangling-input"), "expected netlist.dangling-input in {vs:?}");
}

// --- pack auditor ----------------------------------------------------------

/// Clean means: no Error-severity violations.  (Carry-macro LBs may carry
/// the documented pin-budget *warning* — that is the audited severity
/// split, not noise.)
fn assert_pack_clean(nl: &Netlist, packing: &Packing, arch: &Arch) {
    let vs = audit_packing(nl, packing, arch);
    let errors: Vec<_> = vs.iter().filter(|v| v.severity == Severity::Error).collect();
    assert!(errors.is_empty(), "uncorrupted packing has error violations: {errors:?}");
}

#[test]
fn pack_audit_catches_half_miscount() {
    let (nl, mut packing, arch) = mul_fixture(ArchVariant::Dd5);
    assert_pack_clean(&nl, &packing, &arch);

    let ai = packing
        .alms
        .iter()
        .position(|a| !a.logic_luts.is_empty())
        .expect("fixture packs logic LUTs");
    packing.alms[ai].logic_halves += 1;

    let vs = audit_packing(&nl, &packing, &arch);
    assert!(has_code(&vs, "pack.lut-halves"), "expected pack.lut-halves in {vs:?}");
}

#[test]
fn pack_audit_catches_double_packed_alm() {
    let (nl, mut packing, arch) = mul_fixture(ArchVariant::Dd5);
    assert_pack_clean(&nl, &packing, &arch);

    let dup = packing.lbs[0].alms[0];
    packing.lbs[0].alms.push(dup);

    let vs = audit_packing(&nl, &packing, &arch);
    assert!(has_code(&vs, "pack.cell-double-packed"), "expected pack.cell-double-packed in {vs:?}");
}

#[test]
fn pack_audit_catches_chain_macro_mismatch() {
    let (nl, mut packing, arch) = mul_fixture(ArchVariant::Dd5);
    assert_pack_clean(&nl, &packing, &arch);
    assert!(!packing.chain_macros.is_empty(), "fixture has carry chains");

    // Append a bogus LB to a stored macro: the recomputed LB walk of the
    // chain's ALMs can no longer match it.
    packing.chain_macros[0].push(0);

    let vs = audit_packing(&nl, &packing, &arch);
    assert!(
        has_code(&vs, "pack.chain-macro-mismatch"),
        "expected pack.chain-macro-mismatch in {vs:?}"
    );
}

// --- place auditor ---------------------------------------------------------

#[test]
fn place_audit_catches_site_overlap() {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let mut pl = placed(&nl, &packing, &arch);
    assert!(audit_placement(&packing, &pl).is_empty(), "uncorrupted placement audits clean");

    assert!(pl.lb_loc.len() >= 2, "fixture spans multiple LBs");
    pl.lb_loc[1] = pl.lb_loc[0];

    let vs = audit_placement(&packing, &pl);
    assert!(has_code(&vs, "place.site-overlap"), "expected place.site-overlap in {vs:?}");
}

#[test]
fn place_audit_catches_broken_macro_column() {
    // A 64-bit ripple chain guarantees a multi-LB macro (20 adder bits per
    // LB), which the mul fixture's short chains do not.
    let mut c = Circuit::new("chain");
    let x = c.pi_bus("x", 64);
    let y = c.pi_bus("y", 64);
    let ops: Vec<(Lit, Lit)> = x.iter().copied().zip(y.iter().copied()).collect();
    let (sums, cout) = c.add_chain(ops, Lit::FALSE);
    c.po_bus("s", &sums);
    c.po("co", cout);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Baseline);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let mac = packing
        .chain_macros
        .iter()
        .find(|m| m.len() >= 2)
        .cloned()
        .expect("fixture has a multi-LB chain macro");

    let mut pl = placed(&nl, &packing, &arch);
    assert!(audit_placement(&packing, &pl).is_empty());

    // Nudge the macro's second LB off its column.
    let lb = mac[1];
    let old = pl.lb_loc[lb];
    pl.lb_loc[lb] = double_duty::arch::device::Loc::new(old.x + 1, old.y);

    let vs = audit_placement(&packing, &pl);
    assert!(has_code(&vs, "place.macro-alignment"), "expected place.macro-alignment in {vs:?}");
}

// --- route auditor ---------------------------------------------------------

#[test]
fn route_audit_catches_stolen_wire() {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let pl = placed(&nl, &packing, &arch);
    let (model, mut r) = routed(&nl, &packing, &arch, &pl);
    assert!(r.success, "fixture must route (iterations {})", r.iterations);
    assert!(
        audit_routing(&model, &pl, &arch, &r).is_empty(),
        "uncorrupted routing audits clean"
    );

    // Commit one of net A's wires to net B as well: the recount sees an
    // overused node the router never reported (and net B now owns a wire
    // its own tree never reaches).
    let donor = r.net_nodes.iter().position(|n| !n.is_empty()).expect("routed net");
    let node = r.net_nodes[donor][0];
    let victim = (0..r.net_nodes.len())
        .find(|&i| i != donor && !r.net_nodes[i].is_empty() && !r.net_nodes[i].contains(&node))
        .expect("second net avoiding the donor's wire");
    r.net_nodes[victim].push(node);
    r.net_nodes[victim].sort_unstable();

    let vs = audit_routing(&model, &pl, &arch, &r);
    assert!(has_code(&vs, "route.overuse-count"), "expected route.overuse-count in {vs:?}");
    assert!(has_code(&vs, "route.overuse"), "expected route.overuse in {vs:?}");
}

// --- lookahead auditor -----------------------------------------------------

#[test]
fn lookahead_audit_catches_inflated_class_distance() {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let pl = placed(&nl, &packing, &arch);
    let graph = RrGraph::build(&pl.device, &arch);
    let la = Lookahead::build(&graph);
    assert!(audit_lookahead(&graph, &la).is_empty(), "built map audits clean");

    // Inflate one class distance: (dir 0, |dx| 0, |dy| 0) is truly 0
    // hops, so any estimate above it is inadmissible at every target
    // whose corner set covers a dir-0 node's own location.
    let mut dist = la.dist().to_vec();
    dist[0] = 60_000;
    let bad = Lookahead::from_raw(la.width(), la.height(), la.tracks(), dist)
        .expect("shape is unchanged");
    let vs = audit_lookahead(&graph, &bad);
    assert!(
        has_code(&vs, "lookahead.admissibility"),
        "expected lookahead.admissibility in {vs:?}"
    );
}

// --- timing auditor --------------------------------------------------------

#[test]
fn timing_audit_catches_out_of_range_criticality() {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let idx = NetlistIndex::build(&nl);
    let mut rpt = sta(&nl, &packing, &arch, |_, _, _| 200.0);
    assert!(audit_timing(&nl, &idx, &rpt).is_empty(), "uncorrupted report audits clean");

    let mut vals = rpt.sink_crit.values().to_vec();
    assert!(!vals.is_empty());
    vals[0] = 1.5; // criticality > 1 is meaningless
    rpt.sink_crit = SinkCrit::from_raw(idx.sink_offsets().to_vec(), vals);

    let vs = audit_timing(&nl, &idx, &rpt);
    assert!(has_code(&vs, "timing.crit-range"), "expected timing.crit-range in {vs:?}");
}

#[test]
fn timing_audit_catches_endpoint_beyond_cpd() {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Baseline);
    let idx = NetlistIndex::build(&nl);
    let mut rpt = sta(&nl, &packing, &arch, |_, _, _| 200.0);
    assert!(audit_timing(&nl, &idx, &rpt).is_empty());

    let po = *nl.outputs.first().expect("fixture has outputs");
    rpt.arrival[po as usize] = rpt.cpd_ps + 1000.0;

    let vs = audit_timing(&nl, &idx, &rpt);
    assert!(
        has_code(&vs, "timing.arrival-exceeds-cpd"),
        "expected timing.arrival-exceeds-cpd in {vs:?}"
    );
}

// --- producer failure paths through the violation types --------------------

/// PR-5 placer misfit errors, wrapped the way `check_benchmark` wraps
/// them: the violation message must name the failing dimension (chain
/// macro height, LB slots, I/O sites) — not a generic failure.
#[test]
fn place_misfit_errors_surface_as_named_violations() {
    let mut c = Circuit::new("chain");
    let x = c.pi_bus("x", 64);
    let y = c.pi_bus("y", 64);
    let ops: Vec<(Lit, Lit)> = x.iter().copied().zip(y.iter().copied()).collect();
    let (sums, cout) = c.add_chain(ops, Lit::FALSE);
    c.po_bus("s", &sums);
    c.po("co", cout);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Baseline);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let max_macro = packing.chain_macros.iter().map(|m| m.len()).max().unwrap_or(1);
    assert!(max_macro >= 2, "want a multi-LB chain macro");

    // Wide enough for every LB, too short for the macro.
    let short = Device::new(packing.lbs.len() as u16 + 2, max_macro as u16 - 1);
    let err = place(&nl, &packing, &arch, &PlaceOpts {
        effort: 0.05,
        device: Some(short),
        ..Default::default()
    })
    .expect_err("macro-misfit device must error");
    let v = Violation::from_producer_error(Stage::Place, "place.device-misfit", "device", &err);
    let s = v.to_string();
    assert!(s.contains("place.device-misfit"), "{s}");
    assert!(s.contains("chain macro"), "misfit violation must name the dimension: {s}");

    // Tall enough for the macro, starved of capacity.
    let tiny = Device::new(1, max_macro as u16);
    let err = place(&nl, &packing, &arch, &PlaceOpts {
        effort: 0.05,
        device: Some(tiny),
        ..Default::default()
    })
    .expect_err("capacity-misfit device must error");
    let v = Violation::from_producer_error(Stage::Place, "place.device-misfit", "device", &err);
    let s = v.to_string();
    assert!(
        s.contains("LB slots") || s.contains("I/O sites"),
        "capacity violation must name the starved dimension: {s}"
    );
}

/// The disk cache's integrity rejection (corrupted artifact loads as a
/// miss) expressed as a violation naming the integrity dimension.
#[test]
fn diskcache_integrity_failure_surfaces_as_violation() {
    let root = std::path::PathBuf::from("target").join("dd-check-audit-cache");
    let _ = std::fs::remove_dir_all(&root);
    let cache = DiskCache::new(&root);

    let (nl, _, _) = mul_fixture(ArchVariant::Baseline);
    let fingerprint = ArtifactCache::netlist_fingerprint(&nl);
    let m = MappedCircuit { nl, dedup_hits: 0, fingerprint };
    cache.store_mapped(11, &m);
    assert!(cache.load_mapped(11).is_some(), "intact artifact loads");

    let file = format!("map-v{CACHE_VERSION}-{:016x}.dd", 11u64);
    std::fs::write(root.join(&file), "ddmap1\ngarbage\n").expect("corrupt the artifact");
    assert!(
        cache.load_mapped(11).is_none(),
        "integrity check must reject the corrupted artifact"
    );

    let err = Error::msg(format!(
        "mapped artifact {file} failed the disk-cache integrity check \
         (bad header or fingerprint mismatch)"
    ));
    let v = Violation::from_producer_error(Stage::Netlist, "flow.cache-integrity", file, &err);
    let s = v.to_string();
    assert!(s.contains("flow.cache-integrity"), "{s}");
    assert!(s.contains("integrity"), "violation must name the failing dimension: {s}");
    let _ = std::fs::remove_dir_all(&root);
}

// --- recovery auditor ------------------------------------------------------

/// One healthy routed seed for the synthetic recovery chains.
fn seed_ok(seed: u64, cpd_ns: f64, used_prior_ps: Option<f64>) -> SeedMetrics {
    SeedMetrics {
        seed,
        cpd_ns,
        routed_ok: true,
        route_iters: Some(3.0),
        astar_pops: Some(100),
        channel_util: Vec::new(),
        cpd_trace_ns: Vec::new(),
        escalation: 0,
        used_prior_ps,
        error: None,
    }
}

/// A realistic chained cell: two healthy seeds feeding the chain, one
/// ladder-rescued (degraded) seed, and one healthy seed that must have
/// inherited its prior *past* the degraded one.
fn recovery_fixture() -> (double_duty::flow::FlowResult, Vec<SeedMetrics>) {
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let _ = nl;
    let mut s3 = seed_ok(3, 6.0, Some(4000.0));
    s3.escalation = 1; // rescued at the first rung: degraded, no error
    let seeds = vec![
        seed_ok(1, 5.0, None),
        seed_ok(2, 4.0, Some(5000.0)),
        s3,
        // Seed 3 is degraded, so seed 4 still consumes seed 2's CPD.
        seed_ok(4, 4.5, Some(4000.0)),
    ];
    (assemble_result("m", &arch, &packing, &seeds, 0), seeds)
}

#[test]
fn recovery_audit_clean_on_consistent_chain() {
    let (r, seeds) = recovery_fixture();
    let vs = audit_recovery(&r, &seeds, true);
    assert!(vs.is_empty(), "consistent chain must audit clean: {vs:?}");
}

#[test]
fn recovery_audit_catches_prior_chain_break() {
    let (r, mut seeds) = recovery_fixture();
    // As if the degraded seed 3 had (illegally) fed the chain.
    seeds[3].used_prior_ps = Some(6000.0);
    let vs = audit_recovery(&r, &seeds, true);
    assert!(has_code(&vs, "recovery.prior-chaining"), "expected recovery.prior-chaining in {vs:?}");
    assert!(!has_code(&vs, "recovery.failure-counts"), "counters are untouched: {vs:?}");
}

#[test]
fn recovery_audit_catches_prior_in_unchained_run() {
    let (r, seeds) = recovery_fixture();
    // The same seeds claim priors, but the run never chained.
    let vs = audit_recovery(&r, &seeds, false);
    assert!(has_code(&vs, "recovery.prior-chaining"), "expected recovery.prior-chaining in {vs:?}");
}

#[test]
fn recovery_audit_catches_out_of_ladder_rung() {
    let (r, mut seeds) = recovery_fixture();
    seeds[2].escalation = ESCALATION_LADDER.len() as u8 + 1;
    let vs = audit_recovery(&r, &seeds, true);
    assert!(
        has_code(&vs, "recovery.escalation-provenance"),
        "expected recovery.escalation-provenance in {vs:?}"
    );
}

#[test]
fn recovery_audit_catches_unrouted_escalation_without_error() {
    let (_, mut seeds) = recovery_fixture();
    // An unrouted seed that claims it stopped mid-ladder with no error
    // record: impossible — the ladder only stops early on success.
    seeds[2].routed_ok = false;
    seeds[2].used_prior_ps = Some(4000.0);
    seeds[3].used_prior_ps = Some(4000.0);
    let (nl, packing, arch) = mul_fixture(ArchVariant::Dd5);
    let _ = nl;
    let r = assemble_result("m", &arch, &packing, &seeds, 0);
    let vs = audit_recovery(&r, &seeds, true);
    assert!(
        has_code(&vs, "recovery.escalation-provenance"),
        "expected recovery.escalation-provenance in {vs:?}"
    );
    assert!(!has_code(&vs, "recovery.prior-chaining"), "chain itself is legal: {vs:?}");
}

#[test]
fn recovery_audit_catches_counter_drift() {
    let (r, seeds) = recovery_fixture();

    let mut bad = r.clone();
    bad.failed_seeds += 1;
    let vs = audit_recovery(&bad, &seeds, true);
    assert!(has_code(&vs, "recovery.failure-counts"), "expected recovery.failure-counts in {vs:?}");
    assert!(!has_code(&vs, "recovery.prior-chaining"), "{vs:?}");

    let mut bad = r.clone();
    bad.escalations = 0;
    let vs = audit_recovery(&bad, &seeds, true);
    assert!(has_code(&vs, "recovery.failure-counts"), "expected recovery.failure-counts in {vs:?}");

    let mut bad = r.clone();
    bad.routed_ok = false;
    let vs = audit_recovery(&bad, &seeds, true);
    assert!(has_code(&vs, "recovery.failure-counts"), "expected recovery.failure-counts in {vs:?}");

    // A dropped error record trips the same counter check.
    let mut bad = r.clone();
    let mut seeds2 = seeds.clone();
    seeds2[1].routed_ok = false;
    seeds2[1].error = Some(FlowError::stage_failure(
        "route",
        Some(2),
        "synthetic".to_string(),
        RecoveryAction::SkipSeed,
    ));
    bad.routed_ok = false; // keep the conjunction consistent
    let vs = audit_recovery(&bad, &seeds2, true);
    assert!(has_code(&vs, "recovery.failure-counts"), "expected recovery.failure-counts in {vs:?}");
}

// --- whole-chain smoke (the `dduty check` path) ----------------------------

/// `check_benchmark` over a real shipped benchmark must come back with no
/// Error-severity violations — the same gate `dduty check --strict` applies
/// to the full suites.
#[test]
fn check_benchmark_is_strict_clean_on_a_shipped_bench() {
    let params = BenchParams::default();
    let bench = all_suites(&params)
        .into_iter()
        .find(|b| b.name == "gemmt-FU-mini")
        .expect("shipped benchmark");
    let cache = ArtifactCache::for_cli(false, None);
    let opts = FlowOpts {
        seeds: vec![1],
        route: false, // placement + pre-route STA keep this test fast
        place_effort: 0.1,
        ..Default::default()
    };
    for variant in [ArchVariant::Baseline, ArchVariant::Dd5] {
        let report = check_benchmark(&cache, &bench, variant, &opts);
        assert!(
            !report.has_errors(),
            "{:?}: {} — {:?}",
            variant,
            report.summary(),
            report.violations
        );
    }
}

// --- serve auditor ---------------------------------------------------------

/// A healthy one-job daemon history: full lifecycle event log, seed
/// events in order while running, a clean terminal result.
fn serve_fixture() -> Vec<JobSnapshot> {
    let (r, seeds) = recovery_fixture();
    let mut events = vec![
        JobEvent::State(JobState::Scheduled),
        JobEvent::State(JobState::Running),
    ];
    for (i, m) in seeds.iter().enumerate() {
        events.push(JobEvent::Seed { index: i, metrics: m.clone() });
    }
    events.push(JobEvent::State(JobState::Done));
    vec![JobSnapshot {
        id: 0,
        key: 0x1111,
        bench: "m".to_string(),
        variant: ArchVariant::Dd5,
        n_seeds: seeds.len(),
        state: JobState::Done,
        events,
        result: Some(r),
    }]
}

#[test]
fn serve_audit_clean_on_healthy_history() {
    let jobs = serve_fixture();
    let vs = audit_serve(&jobs);
    assert!(vs.is_empty(), "healthy history must audit clean: {vs:?}");
}

/// Each bookkeeping corruption trips its code — the auditor re-derives
/// the lifecycle from the event log, so a scheduler bug cannot
/// self-certify.
#[test]
fn serve_audit_catches_lifecycle_corruption() {
    // Skipping Running: Scheduled -> Done is not a lifecycle edge.
    let mut jobs = serve_fixture();
    jobs[0].events.remove(1);
    assert!(has_code(&audit_serve(&jobs), "serve.state-transition"));

    // A seed event before the job ever ran.
    let mut jobs = serve_fixture();
    let seed = jobs[0].events.remove(2);
    jobs[0].events.insert(0, seed);
    assert!(has_code(&audit_serve(&jobs), "serve.state-transition"));

    // Seed events out of order (indices 1, 0, ...).
    let mut jobs = serve_fixture();
    jobs[0].events.swap(2, 3);
    assert!(has_code(&audit_serve(&jobs), "serve.state-transition"));

    // Snapshot state disagrees with where the event log ends.
    let mut jobs = serve_fixture();
    jobs[0].state = JobState::Failed;
    assert!(has_code(&audit_serve(&jobs), "serve.state-transition"));
}

#[test]
fn serve_audit_catches_result_inconsistency() {
    // A done job with no result to serve.
    let mut jobs = serve_fixture();
    jobs[0].result = None;
    assert!(has_code(&audit_serve(&jobs), "serve.result-consistency"));

    // A done job whose result records seed failures.
    let mut jobs = serve_fixture();
    if let Some(r) = jobs[0].result.as_mut() {
        r.failed_seeds = 1;
    }
    assert!(has_code(&audit_serve(&jobs), "serve.result-consistency"));

    // A still-running job already carrying a result.
    let mut jobs = serve_fixture();
    jobs[0].state = JobState::Running;
    jobs[0].events.truncate(2); // Scheduled, Running
    assert!(has_code(&audit_serve(&jobs), "serve.result-consistency"));
    // ... and dropping the result makes the same shape clean.
    jobs[0].result = None;
    assert!(audit_serve(&jobs).is_empty(), "{:?}", audit_serve(&jobs));
}

/// Two jobs sharing a submission key means dedup failed to coalesce
/// identical submissions onto one execution.
#[test]
fn serve_audit_catches_duplicate_submission_keys() {
    let mut jobs = serve_fixture();
    let mut twin = jobs[0].clone();
    twin.id = 1;
    jobs.push(twin);
    let vs = audit_serve(&jobs);
    assert!(has_code(&vs, "serve.dedup-key"), "expected serve.dedup-key in {vs:?}");

    // Distinct keys are fine.
    jobs[1].key = 0x2222;
    assert!(audit_serve(&jobs).is_empty());
}
