//! Bench harness regenerating the paper's Fig. 6 (DD5 vs baseline).
//! Run: cargo bench --bench fig6_dd5   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    report::fig6(&opts).0.print();
    println!();
    println!("[fig6_dd5] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
