//! End-to-end CAD flow orchestration: synth -> map -> pack -> place ->
//! route -> STA, with multi-seed averaging (the paper runs 3 seeds per
//! experiment) and the metric set every table/figure consumes.
//!
//! The flow is factored into grid-job primitives so the serial path here
//! and the parallel experiment engine ([`engine`]) share one code path and
//! therefore produce bit-identical results:
//!
//! * [`arch_for_run`] — per-run architecture overrides,
//! * [`place_route_seed`] — one (circuit, variant, seed) cell, reading
//!   the shared dense index arenas (and, in the closed timing loop, the
//!   previous seed's achieved-CPD prior) through a [`SeedCtx`],
//! * [`assemble_result`] — fixed-order seed reduction into a
//!   [`FlowResult`].
//!
//! ## Cross-seed place↔route feedback
//!
//! With `--timing-route`, seeds of one (circuit, variant) cell form a
//! chain: each seed's achieved post-route CPD feeds the *next* seed as a
//! criticality prior ([`SeedCtx::cpd_prior_ps`] →
//! [`crate::timing::rescale_crit`]), so both the placer's per-sink lane
//! and the router's seed weights optimize toward the CPD routing actually
//! delivers rather than the pre-route estimate.  The chain runs in fixed
//! seed order in both the serial path and the engine, so results stay
//! bit-identical between them.
//!
//! ## Failure semantics
//!
//! Stage failures are *data*, not process death.  Every seed job runs
//! under `catch_unwind` ([`place_route_seed`]), so a panic — organic or
//! injected via `--inject-faults` ([`crate::util::fault::FaultPlan`]) —
//! becomes a [`SeedMetrics`] carrying a structured [`FlowError`]
//! (stage, seed, cause, recovery action) while the rest of the plan
//! completes; a misfit device is a failed-seed entry for the same
//! reason.  Unroutable seeds can opt into a **deterministic escalation
//! ladder** ([`FlowOpts::escalate`], [`ESCALATION_LADDER`]): fixed
//! retry rungs (+25% then +50% channel width, then lookahead-off) with
//! no wall-clock anywhere — degradation triggers only on deterministic
//! odometers (`astar_pops` budgets, iteration caps) — so a faulted or
//! escalated run is exactly as bit-reproducible across `--jobs` /
//! `--route-jobs` as a clean one.  Failed seeds and escalated
//! (degraded) seeds are excluded from the CPD-prior chain; the
//! `check::audit_recovery` auditor re-verifies all of this per cell.

pub mod diskcache;
pub mod engine;

use crate::arch::device::Device;
use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::Benchmark;
use crate::check::{self, CheckMode};
use crate::netlist::{Netlist, NetlistIndex, PackIndex};
use crate::pack::{pack, PackOpts, Packing, Unrelated};
use crate::place::{place_with, PlaceOpts};
use crate::route::{
    route, route_timing, routed_net_delay, term_sink_crit, LookaheadMode, RouteOpts, Routing,
    TimingCtx,
};
use crate::rrg::{lookahead::Lookahead, RrGraph};
use crate::synth::Circuit;
use crate::techmap::{map_circuit, MapOpts};
use crate::timing::{sta_routed, TimingReport};
use crate::util::fault::FaultPlan;
use crate::util::stats::mean;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Flow options.
#[derive(Clone, Debug)]
pub struct FlowOpts {
    pub seeds: Vec<u64>,
    pub place_effort: f64,
    pub unrelated: Unrelated,
    pub route: bool,
    /// Worker threads inside each PathFinder run (`--route-jobs`; results
    /// are bit-identical for any value — see `rust/tests/route_parallel.rs`).
    pub route_jobs: usize,
    /// Timing-driven routing (`--timing-route`): seed the router with
    /// per-sink criticalities from a pre-route STA and, with
    /// [`FlowOpts::sta_every`] > 0, close the loop by re-running STA
    /// against the evolving routing between PathFinder iterations.  Off
    /// by default: figures are unchanged unless requested.
    pub route_timing_weights: bool,
    /// With `route_timing_weights`: refresh criticalities from an STA
    /// over the partial routing every this many PathFinder iterations
    /// (`--sta-every K`; `0` keeps the static pre-route weights).
    pub sta_every: usize,
    /// Criticality smoothing factor for the closed loop
    /// (`--crit-alpha A`; `crit' = A*new + (1-A)*old`).
    pub crit_alpha: f64,
    /// Smoothing factor for the *placer's* per-sink criticality refresh
    /// (`--place-crit-alpha`), matching the router's recurrence.
    pub place_crit_alpha: f64,
    /// Annealer move-type mix scale in [0, 1] (`--move-mix`): scales the
    /// temperature-scheduled macro-shift / median-move probabilities;
    /// `0.0` proposes uniform swaps only.
    pub move_mix: f64,
    pub use_kernel: bool,
    /// Fixed device (Table IV stress); `None` auto-sizes per design.
    pub device: Option<Device>,
    pub channel_width: Option<u16>,
    /// Run the stage auditors ([`crate::check`]) on each artifact as the
    /// flow produces it (`--check [strict]`).  [`CheckMode::Warn`] prints
    /// violations and continues; [`CheckMode::Strict`] fails the run.
    /// Deliberately *not* part of the engine's cache keys: auditing never
    /// changes an artifact, so checked and unchecked runs may share them.
    pub check: CheckMode,
    /// Router A* lookahead (`--lookahead on|off`, default on): guide each
    /// sink's search with the per-device class-distance map and route
    /// sinks in criticality order (see [`crate::rrg::lookahead`]).  `false`
    /// reproduces the pre-lookahead router bit-for-bit.  Part of the
    /// engine's CPD-prior cache key — the two modes route differently.
    pub lookahead: bool,
    /// Deterministic retry/escalation ladder for unroutable seeds
    /// (`--escalate`): on `success: false`, re-route through the fixed
    /// [`ESCALATION_LADDER`] rungs (+25% / +50% channel width, then
    /// lookahead-off).  Off by default — the Table IV stress sweep
    /// *measures* non-convergence and must not be rescued.  Part of the
    /// engine's CPD-prior cache key.
    pub escalate: bool,
    /// Deterministic router give-up odometer (`--route-pops-budget N`):
    /// a PathFinder run stops (unconverged) once its fixed-order A*
    /// heap-pop count reaches `N`.  `0` (default) = unlimited.  A
    /// *logical* budget, never a wall clock, so it is bit-identical for
    /// any worker count.  Part of the engine's CPD-prior cache key.
    pub route_pops_budget: usize,
    /// Deterministic fault-injection plan (`--inject-faults <spec>`;
    /// empty = no faults).  See [`crate::util::fault`].  Part of the
    /// engine's CPD-prior cache key so faulted results never alias
    /// clean ones.
    pub faults: FaultPlan,
}

impl Default for FlowOpts {
    fn default() -> Self {
        FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.5,
            unrelated: Unrelated::Auto,
            route: true,
            route_jobs: 1,
            route_timing_weights: false,
            sta_every: 4,
            crit_alpha: 0.5,
            place_crit_alpha: 0.5,
            move_mix: 1.0,
            use_kernel: false,
            device: None,
            channel_width: None,
            check: CheckMode::Off,
            lookahead: true,
            escalate: false,
            route_pops_budget: 0,
            faults: FaultPlan::default(),
        }
    }
}

/// What the flow did (or will do) about a failure — the recovery-action
/// field of [`FlowError`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryAction {
    /// The seed was skipped; the cell's surviving seeds still average.
    SkipSeed,
    /// A panic was caught and isolated to this job; the plan continued.
    IsolateJob,
    /// The escalation ladder ran out of rungs; the seed stays unrouted.
    LadderExhausted,
    /// An upstream (per-benchmark) artifact failed, so every seed of the
    /// cell was skipped.
    SkipCell,
}

impl RecoveryAction {
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryAction::SkipSeed => "seed skipped",
            RecoveryAction::IsolateJob => "job isolated",
            RecoveryAction::LadderExhausted => "escalation exhausted",
            RecoveryAction::SkipCell => "cell skipped",
        }
    }
}

/// Structured flow failure: which stage failed, for which seed (when
/// seed-scoped), why, and what the flow did about it.  Replaces the
/// old placement `panic!` — failures thread through
/// [`SeedMetrics::error`] / [`FlowResult::errors`] as data and surface
/// in the engine's fixed-order failure summary.
#[derive(Clone, Debug, PartialEq)]
pub struct FlowError {
    /// Failing stage (`"map"`, `"pack"`, `"place"`, `"route"`, `"job"`
    /// for an isolated panic).
    pub stage: &'static str,
    /// Seed of the failing job; `None` for per-benchmark stages.
    pub seed: Option<u64>,
    pub cause: String,
    pub action: RecoveryAction,
}

impl FlowError {
    pub fn stage_failure(
        stage: &'static str,
        seed: Option<u64>,
        cause: String,
        action: RecoveryAction,
    ) -> FlowError {
        FlowError { stage, seed, cause, action }
    }

    /// A panic caught by the engine's job isolation.
    pub fn job_panic(seed: Option<u64>, cause: String) -> FlowError {
        FlowError { stage: "job", seed, cause, action: RecoveryAction::IsolateJob }
    }
}

impl std::fmt::Display for FlowError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.seed {
            Some(s) => write!(f, "{} failed (seed {s}): {} [{}]", self.stage, self.cause,
                              self.action.name()),
            None => write!(f, "{} failed: {} [{}]", self.stage, self.cause, self.action.name()),
        }
    }
}

/// Best-effort human-readable panic payload (every panic in this crate
/// carries a `&str` or `String`).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// The fixed escalation ladder for unroutable seeds: per rung, the
/// channel-width percentage of the base width and whether the A*
/// lookahead stays on.  +25% width, +50% width, then +50% with the
/// lookahead off (the most conservative router).  A fixed sequence —
/// never adapted from timing or load — so escalated runs keep the
/// bit-identity contract.
pub const ESCALATION_LADDER: &[(u32, bool)] = &[(125, true), (150, true), (150, false)];

/// Channel width of an escalation rung: `base` scaled to `pct` percent
/// (rounded up), and always at least one track wider than the base so
/// every rung makes progress even at tiny widths.
pub fn escalated_width(base: u16, pct: u32) -> u16 {
    let scaled = (base as u64 * pct as u64 + 99) / 100;
    scaled.max(base as u64 + 1).min(u16::MAX as u64) as u16
}

/// Metrics of one flow run (averaged over seeds).
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub name: String,
    pub variant: ArchVariant,
    pub luts: usize,
    pub adder_bits: usize,
    pub alms: usize,
    pub lbs: usize,
    pub concurrent_luts: usize,
    /// ALM area in MWTA (alms x per-variant ALM area — the paper's "Total
    /// ALM Area" of Table IV).
    pub alm_area_mwta: f64,
    /// Critical path delay, ns (post-route when routed).
    pub cpd_ns: f64,
    /// Area-delay product (MWTA x ns).
    pub adp: f64,
    pub fmax_mhz: f64,
    pub routed_ok: bool,
    pub route_iters: f64,
    /// Channel-utilization samples for Fig. 8: per routing channel, the
    /// utilization averaged element-wise across seeds (every seed routes
    /// the same deterministic device, so the sample vectors align).
    pub channel_util: Vec<f64>,
    /// Closed-loop timing trajectory (ns): achieved critical-path delay
    /// at each inter-iteration STA refresh, with the final post-route CPD
    /// appended — averaged element-wise across seeds when the per-seed
    /// traces align, else the first seed's trace.  Empty unless
    /// [`FlowOpts::route_timing_weights`] is on.
    pub cpd_trace_ns: Vec<f64>,
    pub dedup_hits: usize,
    /// Seeds that produced no usable result (carry a [`FlowError`]).
    pub failed_seeds: usize,
    /// Seeds rescued by the escalation ladder (degraded: routed at an
    /// escalated channel width and excluded from CPD-prior chaining).
    pub escalations: usize,
    /// Structured failures, in seed order (one entry per failed seed).
    pub errors: Vec<FlowError>,
}

impl FlowResult {
    /// Result of a cell whose upstream (per-benchmark) stage failed:
    /// every seed is a failure with the same cause, all metrics zero.
    pub fn failed(
        name: &str,
        variant: ArchVariant,
        error: FlowError,
        n_seeds: usize,
    ) -> FlowResult {
        FlowResult {
            name: name.to_string(),
            variant,
            luts: 0,
            adder_bits: 0,
            alms: 0,
            lbs: 0,
            concurrent_luts: 0,
            alm_area_mwta: 0.0,
            cpd_ns: 0.0,
            adp: 0.0,
            fmax_mhz: 0.0,
            routed_ok: false,
            route_iters: 0.0,
            channel_util: Vec::new(),
            cpd_trace_ns: Vec::new(),
            dedup_hits: 0,
            failed_seeds: n_seeds,
            escalations: 0,
            errors: vec![error; n_seeds],
        }
    }

    /// This cell's failure-summary lines: one per structured error (in
    /// seed order) plus the escalation-rescue note.  The single source
    /// for both the engine's fixed-order end-of-run
    /// [`engine::FailureSummary`] and the daemon's per-job failure JSON
    /// — `dd serve` owns neither the process's stderr nor its exit
    /// code, so the summary travels through the result as data.
    pub fn failure_lines(&self) -> Vec<String> {
        let mut lines = Vec::with_capacity(self.errors.len());
        for e in &self.errors {
            lines.push(format!("[{:?}/{}] {e}", self.variant, self.name));
        }
        if self.escalations > 0 {
            lines.push(format!(
                "[{:?}/{}] {} seed(s) rescued by the escalation ladder (degraded)",
                self.variant, self.name, self.escalations
            ));
        }
        lines
    }
}

/// Outcome of the place/route stage for one seed — the unit of work the
/// experiment engine schedules.
#[derive(Clone, Debug)]
pub struct SeedMetrics {
    pub seed: u64,
    /// Critical-path delay in ns (post-route when routed, else the
    /// placer's estimate).
    pub cpd_ns: f64,
    pub routed_ok: bool,
    /// Router convergence iterations (`None` when routing was skipped).
    pub route_iters: Option<f64>,
    /// Per-channel utilization samples (empty when routing was skipped).
    pub channel_util: Vec<f64>,
    /// Closed-loop CPD trajectory in ns (refresh points + final; empty
    /// for timing-oblivious runs).
    pub cpd_trace_ns: Vec<f64>,
    /// Escalation-ladder rung that produced this result: `0` = the base
    /// attempt, `k > 0` = [`ESCALATION_LADDER`]`[k - 1]`.  A non-zero
    /// value marks the seed *degraded* — it routed, but on an escalated
    /// channel width — which excludes it from CPD-prior chaining.
    pub escalation: u8,
    /// The CPD prior (ps) this seed actually consumed — recorded on
    /// every path (including failures) so `check::audit_recovery` can
    /// re-verify the chain bit-exactly.
    pub used_prior_ps: Option<f64>,
    /// Structured failure, when the seed produced no usable result.
    /// `None` with `routed_ok: false` is *measured* non-convergence
    /// (no ladder ran) — a result, not an error.
    pub error: Option<FlowError>,
    /// Deterministic A* heap-pop odometer of the attempt that produced
    /// this result (`None` when routing was skipped or the seed failed
    /// before routing).  Streamed per-seed by `dd serve` as a
    /// wall-clock-free progress measure.
    pub astar_pops: Option<usize>,
}

impl SeedMetrics {
    /// A seed that produced no usable result: zeroed metrics plus the
    /// structured failure.
    pub fn failed(seed: u64, used_prior_ps: Option<f64>, error: FlowError) -> SeedMetrics {
        SeedMetrics {
            seed,
            cpd_ns: 0.0,
            routed_ok: false,
            route_iters: None,
            channel_util: Vec::new(),
            cpd_trace_ns: Vec::new(),
            escalation: 0,
            used_prior_ps,
            error: Some(error),
            astar_pops: None,
        }
    }
}

/// Apply per-run architecture overrides (channel width).  Shared by the
/// serial flow and the experiment engine so both pack and route against
/// identical architectures.
pub fn arch_for_run(arch: &Arch, opts: &FlowOpts) -> Arch {
    let mut arch = arch.clone();
    if let Some(w) = opts.channel_width {
        arch.routing.channel_width = w;
    }
    arch
}

/// Per-seed shared context: the dense index arenas (built once per
/// (netlist, packing) and shared read-only across seeds — by the engine,
/// through its artifact cache) plus the cross-seed feedback prior.
pub struct SeedCtx<'a> {
    pub idx: &'a NetlistIndex,
    pub pidx: &'a PackIndex,
    /// Achieved post-route CPD (ps) of the previous seed in the cell's
    /// chain; `None` for the first seed or timing-oblivious runs.  Fed to
    /// the placer ([`PlaceOpts::cpd_prior_ps`]) and into the router's
    /// seed criticalities via [`crate::timing::rescale_crit`].
    pub cpd_prior_ps: Option<f64>,
    /// Artifact cache to fetch the router's per-device lookahead map
    /// through (memo + disk; see [`engine::ArtifactCache::lookahead`]).
    /// `None` falls back to the process-global memo — results are
    /// identical either way, the cache only adds the on-disk layer.
    pub la_cache: Option<&'a engine::ArtifactCache>,
    /// Benchmark name of the cell this seed belongs to — the label
    /// fault-injection sites match against (`""` matches only wildcard
    /// faults).
    pub label: &'a str,
}

impl<'a> SeedCtx<'a> {
    /// Context with no feedback prior, no artifact cache, and no label.
    pub fn new(idx: &'a NetlistIndex, pidx: &'a PackIndex) -> SeedCtx<'a> {
        SeedCtx { idx, pidx, cpd_prior_ps: None, la_cache: None, label: "" }
    }
}

/// Place (and optionally route + STA) one seed of an already-packed
/// design.  Deterministic in (inputs, seed, prior): the only RNG is
/// constructed here from `seed`, so scheduling order cannot perturb
/// results.  Never panics the caller: a stage failure (e.g. a
/// caller-fixed device that cannot fit the design) comes back as a
/// [`SeedMetrics`] carrying a [`FlowError`], and any panic that escapes
/// a stage — including ones injected by [`FlowOpts::faults`] — is
/// caught here and isolated to this seed as a `job` error
/// ([`RecoveryAction::IsolateJob`]), so the rest of the plan completes.
pub fn place_route_seed(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &FlowOpts,
    seed: u64,
    ctx: &SeedCtx,
) -> SeedMetrics {
    match catch_unwind(AssertUnwindSafe(|| {
        place_route_seed_inner(nl, packing, arch, opts, seed, ctx)
    })) {
        Ok(m) => m,
        Err(payload) => SeedMetrics::failed(
            seed,
            ctx.cpd_prior_ps,
            FlowError::job_panic(Some(seed), panic_message(payload.as_ref())),
        ),
    }
}

fn place_route_seed_inner(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &FlowOpts,
    seed: u64,
    ctx: &SeedCtx,
) -> SeedMetrics {
    // `--check`: audit the upstream artifacts once per seed cell (cheap
    // linear scans), then each artifact this cell produces right after
    // its stage.  Strict mode panics inside `enforce` — which the
    // isolation wrapper above turns into a failed-seed entry, so one
    // strict violation no longer kills a whole sweep.
    if opts.check != CheckMode::Off {
        check::enforce(opts.check, "netlist", &check::audit_netlist(nl, ctx.idx));
        check::enforce(opts.check, "pack", &check::audit_packing(nl, packing, arch));
    }
    opts.faults.fire_panic("place", ctx.label, Some(seed));
    let pl = match place_with(
        nl,
        packing,
        arch,
        &PlaceOpts {
            seed,
            effort: opts.place_effort,
            timing_driven: true,
            crit_alpha: opts.place_crit_alpha,
            move_mix: opts.move_mix,
            cpd_prior_ps: ctx.cpd_prior_ps,
            sta_jobs: opts.route_jobs.max(1),
            use_kernel: opts.use_kernel,
            device: opts.device.clone(),
            ..Default::default()
        },
        ctx.idx,
        ctx.pidx,
    ) {
        Ok(pl) => pl,
        // The placer's hardened sizing contract (a fixed device that
        // cannot fit the design) and any other placement failure become
        // a failed-seed entry; the run continues.
        Err(e) => {
            return SeedMetrics::failed(
                seed,
                ctx.cpd_prior_ps,
                FlowError::stage_failure(
                    "place",
                    Some(seed),
                    e.to_string(),
                    RecoveryAction::SkipSeed,
                ),
            )
        }
    };
    if opts.check != CheckMode::Off {
        check::enforce(opts.check, "place", &check::audit_placement(packing, &pl));
    }
    if opts.route {
        let mut model = crate::place::cost::NetModel::build(nl, packing);
        model.set_weights(&[], false);
        let route_jobs = opts.route_jobs.max(1);
        // One route attempt against `rarch` — the run arch for the base
        // attempt, an escalated-width clone for ladder rungs.  The
        // lookahead resolves per attempt (its map is keyed by (device,
        // channel width), so every rung needs its own) through the
        // engine's artifact cache when one is plumbed (adds the disk
        // layer), else the process-global memo.
        let attempt = |rarch: &Arch, use_la: bool| -> (Routing, TimingReport) {
            let la: Option<std::sync::Arc<Lookahead>> = if use_la {
                Some(match ctx.la_cache {
                    Some(cache) => cache.lookahead(&pl.device, rarch),
                    None => crate::rrg::lookahead::shared(&RrGraph::build(&pl.device, rarch)),
                })
            } else {
                None
            };
            if opts.check != CheckMode::Off {
                if let Some(m) = &la {
                    let graph = RrGraph::build(&pl.device, rarch);
                    check::enforce(
                        opts.check,
                        "lookahead",
                        &check::audit_lookahead(&graph, m),
                    );
                }
            }
            let la_mode = match &la {
                Some(m) => LookaheadMode::Shared(m.clone()),
                None => LookaheadMode::Off,
            };
            if opts.route_timing_weights {
                // Timing-driven: a pre-route STA over the placed distance
                // estimates seeds per-sink criticality weights —
                // re-normalized against the previous seed's achieved CPD
                // when the chain carries one — and (with sta_every > 0)
                // the router closes the loop by refreshing them from STA
                // runs against the evolving routing.  The index arenas
                // come prebuilt through `ctx` and are shared with every
                // refresh.
                let idx = ctx.idx;
                let pidx = ctx.pidx;
                let rpt = crate::timing::sta_with(
                    nl,
                    idx,
                    pidx,
                    packing,
                    rarch,
                    |net, sink, _| {
                        crate::place::net_endpoint_delay(
                            &model, &pl.lb_loc, &pl.io_loc, rarch, net, sink,
                        )
                    },
                    route_jobs,
                );
                let mut sink_crit = term_sink_crit(&model, idx, &rpt.sink_crit);
                crate::timing::rescale_crit(&mut sink_crit, rpt.cpd_ps, ctx.cpd_prior_ps);
                let ropts = RouteOpts {
                    jobs: route_jobs,
                    sink_crit,
                    lookahead: la_mode.clone(),
                    pops_budget: opts.route_pops_budget,
                    ..RouteOpts::default()
                };
                let tctx = TimingCtx {
                    nl,
                    idx,
                    pidx,
                    packing,
                    sta_every: opts.sta_every,
                    crit_alpha: opts.crit_alpha,
                    sta_jobs: route_jobs,
                };
                let r = route_timing(&model, &pl, rarch, &ropts, &tctx);
                // Final post-route report over the SAME prebuilt arenas
                // (and sharded like the refreshes) — `sta_routed` would
                // rebuild both indexes from scratch per seed.  Identical
                // result: the index build is deterministic and STA is
                // jobs-invariant.
                let rpt = crate::timing::sta_with(
                    nl,
                    idx,
                    pidx,
                    packing,
                    rarch,
                    routed_net_delay(&r, &model, rarch),
                    route_jobs,
                );
                (r, rpt)
            } else {
                let ropts = RouteOpts {
                    jobs: route_jobs,
                    lookahead: la_mode.clone(),
                    pops_budget: opts.route_pops_budget,
                    ..RouteOpts::default()
                };
                let r = route(&model, &pl, rarch, &ropts);
                let rpt = sta_routed(nl, packing, rarch, &r, &model);
                (r, rpt)
            }
        };

        opts.faults.fire_panic("route", ctx.label, Some(seed));
        let (mut r, mut rpt) = attempt(arch, opts.lookahead);
        if opts.faults.forces_noconverge(ctx.label, seed, 0) {
            r.success = false;
        }
        // Deterministic escalation ladder: on non-convergence, retry the
        // route through the fixed rungs.  Each rung is a fresh, pure
        // attempt against a clone of the run arch, so the sequence of
        // results — and which rung wins — is bit-identical for any
        // `--jobs`/`--route-jobs`.  `cur_arch` tracks the arch of the
        // attempt that produced the final (r, rpt), for the auditors.
        let mut cur_arch = arch.clone();
        let mut escalation: u8 = 0;
        let mut error: Option<FlowError> = None;
        if !r.success && opts.escalate {
            let base_w = arch.routing.channel_width;
            for (rung, &(pct, la_on)) in ESCALATION_LADDER.iter().enumerate() {
                escalation = rung as u8 + 1;
                let mut rarch = arch.clone();
                rarch.routing.channel_width = escalated_width(base_w, pct);
                let (r2, rpt2) = attempt(&rarch, la_on && opts.lookahead);
                r = r2;
                rpt = rpt2;
                cur_arch = rarch;
                if opts.faults.forces_noconverge(ctx.label, seed, escalation) {
                    r.success = false;
                }
                if r.success {
                    break;
                }
            }
            if !r.success {
                error = Some(FlowError::stage_failure(
                    "route",
                    Some(seed),
                    format!(
                        "unroutable after {} escalation rungs ({} nodes overused)",
                        ESCALATION_LADDER.len(),
                        r.overused
                    ),
                    RecoveryAction::LadderExhausted,
                ));
            }
        }
        if opts.check != CheckMode::Off {
            check::enforce(
                opts.check,
                "route",
                &check::audit_routing(&model, &pl, &cur_arch, &r),
            );
            check::enforce(opts.check, "timing", &check::audit_timing(nl, ctx.idx, &rpt));
        }
        let cpd_trace_ns = if opts.route_timing_weights {
            let mut t: Vec<f64> = r.cpd_trace.iter().map(|c| c / 1000.0).collect();
            t.push(rpt.cpd_ps / 1000.0);
            t
        } else {
            Vec::new()
        };
        SeedMetrics {
            seed,
            cpd_ns: rpt.cpd_ps / 1000.0,
            routed_ok: r.success,
            route_iters: Some(r.iterations as f64),
            astar_pops: Some(r.astar_pops),
            channel_util: r.channel_util,
            cpd_trace_ns,
            escalation,
            used_prior_ps: ctx.cpd_prior_ps,
            error,
        }
    } else {
        SeedMetrics {
            seed,
            cpd_ns: pl.est_cpd_ps / 1000.0,
            routed_ok: true,
            route_iters: None,
            astar_pops: None,
            channel_util: Vec::new(),
            cpd_trace_ns: Vec::new(),
            escalation: 0,
            used_prior_ps: ctx.cpd_prior_ps,
            error: None,
        }
    }
}

/// Run every seed of one (netlist, packing, arch) cell in fixed seed
/// order over shared index arenas, chaining each seed's achieved
/// post-route CPD into the next seed's criticality prior when the closed
/// timing loop is on (`route && route_timing_weights`; timing-oblivious
/// runs carry no prior).  This is the single definition of the cross-seed
/// feedback chain — the serial flow, the cached benchmark runner, and the
/// engine's cell jobs all call it, so the bit-identity contract between
/// them cannot drift.  `label` is the benchmark name fault-injection
/// sites match against.  `record(si, cpd_ps)` observes each
/// *successfully routed* chained seed's achieved CPD (the engine writes
/// these into its artifact cache as the provenance trail; pass a no-op
/// elsewhere); failed, errored, and ladder-escalated (degraded) seeds
/// neither feed the chain nor get recorded.  `on_seed(si, &m)` observes
/// *every* seed's metrics, in seed order, the moment the seed finishes —
/// the progress tap `dd serve` streams incremental per-job events from
/// (pass a no-op elsewhere; observation cannot alter the chain).
#[allow(clippy::too_many_arguments)]
pub fn chain_seeds(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &FlowOpts,
    label: &str,
    idx: &NetlistIndex,
    pidx: &PackIndex,
    la_cache: Option<&engine::ArtifactCache>,
    mut record: impl FnMut(usize, f64),
    mut on_seed: impl FnMut(usize, &SeedMetrics),
) -> Vec<SeedMetrics> {
    let chained = opts.route && opts.route_timing_weights;
    let mut prior: Option<f64> = None;
    let mut out = Vec::with_capacity(opts.seeds.len());
    for (si, &seed) in opts.seeds.iter().enumerate() {
        let ctx = SeedCtx { idx, pidx, cpd_prior_ps: prior, la_cache, label };
        let m = place_route_seed(nl, packing, arch, opts, seed, &ctx);
        // Only a *legally routed, undegraded* seed feeds the chain: a CPD
        // measured over a failed (still-overused) routing is not an
        // achieved result, and one measured on an escalated channel width
        // is not comparable to the base architecture — neither may poison
        // the next seed's criticalities or the provenance record.
        if chained && m.routed_ok && m.error.is_none() && m.escalation == 0 {
            let achieved = m.cpd_ns * 1000.0;
            record(si, achieved);
            prior = Some(achieved);
        }
        on_seed(si, &m);
        out.push(m);
    }
    out
}

/// Reduce per-seed metrics (in seed order) into the averaged result.
/// Failed seeds (those carrying a [`FlowError`]) contribute nothing to
/// the averaged metrics — a zeroed CPD is not a measurement — but are
/// counted in [`FlowResult::failed_seeds`] and listed in
/// [`FlowResult::errors`]; measured non-convergence without an error
/// (no ladder ran) still averages, exactly as before the taxonomy.
pub fn assemble_result(
    name: &str,
    arch: &Arch,
    packing: &Packing,
    seeds: &[SeedMetrics],
    dedup_hits: usize,
) -> FlowResult {
    let healthy: Vec<&SeedMetrics> = seeds.iter().filter(|s| s.error.is_none()).collect();
    let cpds: Vec<f64> = healthy.iter().map(|s| s.cpd_ns).collect();
    let iters: Vec<f64> = healthy.iter().filter_map(|s| s.route_iters).collect();
    let routed_ok = seeds.iter().all(|s| s.routed_ok);
    let failed_seeds = seeds.len() - healthy.len();
    let escalations = seeds.iter().filter(|s| s.escalation > 0).count();
    let errors: Vec<FlowError> = seeds.iter().filter_map(|s| s.error.clone()).collect();

    // Channel utilization: element-wise mean across seeds.  All seeds
    // route the same (deterministically sized) device, so sample vectors
    // align; if they ever did not, fall back to pooling the raw samples
    // rather than silently dropping data.  (Failed seeds carry no
    // samples, so the emptiness filter already excludes them.)
    let with_samples: Vec<&Vec<f64>> = seeds
        .iter()
        .map(|s| &s.channel_util)
        .filter(|v| !v.is_empty())
        .collect();
    let channel_util = match with_samples.first() {
        None => Vec::new(),
        Some(first) if with_samples.iter().all(|v| v.len() == first.len()) => {
            let mut acc = vec![0.0f64; first.len()];
            for v in &with_samples {
                for (a, &x) in acc.iter_mut().zip(v.iter()) {
                    *a += x;
                }
            }
            let n = with_samples.len() as f64;
            acc.iter_mut().for_each(|x| *x /= n);
            acc
        }
        Some(_) => with_samples.iter().flat_map(|v| v.iter().copied()).collect(),
    };

    // Closed-loop CPD trajectory: element-wise mean across seeds when the
    // per-seed traces align (same refresh count), else the first seed's.
    let with_traces: Vec<&Vec<f64>> = seeds
        .iter()
        .map(|s| &s.cpd_trace_ns)
        .filter(|v| !v.is_empty())
        .collect();
    let cpd_trace_ns = match with_traces.first() {
        None => Vec::new(),
        Some(first) if with_traces.iter().all(|v| v.len() == first.len()) => {
            let mut acc = vec![0.0f64; first.len()];
            for v in &with_traces {
                for (a, &x) in acc.iter_mut().zip(v.iter()) {
                    *a += x;
                }
            }
            let n = with_traces.len() as f64;
            acc.iter_mut().for_each(|x| *x /= n);
            acc
        }
        Some(first) => (*first).clone(),
    };

    // With every seed failed there is no measurement: cpd 0, fmax 0 (an
    // infinite fmax would read as the best row of a sweep table).
    let cpd_ns = if cpds.is_empty() { 0.0 } else { mean(&cpds) };
    let alm_area_mwta = packing.stats.alms as f64 * arch.area.alm_mwta;
    FlowResult {
        name: name.to_string(),
        variant: arch.variant,
        luts: packing.stats.luts,
        adder_bits: packing.stats.adder_bits,
        alms: packing.stats.alms,
        lbs: packing.stats.lbs,
        concurrent_luts: packing.stats.concurrent_luts,
        alm_area_mwta,
        cpd_ns,
        adp: alm_area_mwta * cpd_ns,
        fmax_mhz: if cpd_ns > 0.0 { 1000.0 / cpd_ns } else { 0.0 },
        routed_ok,
        route_iters: mean(&iters),
        channel_util,
        cpd_trace_ns,
        dedup_hits,
        failed_seeds,
        escalations,
        errors,
    }
}

/// Run the mapped portion once (deterministic), then place/route per seed.
///
/// With `opts.check != Off`, semantic equivalence
/// ([`crate::check::equiv`]) gates both logic-neutral stages: the mapped
/// netlist is checked against the source AIG, and the packed view is
/// checked again on top of it (`equiv-map` / `equiv-pack`; strict mode
/// fails the run on any mismatch).
pub fn run_flow(circ: &Circuit, arch: &Arch, opts: &FlowOpts) -> FlowResult {
    let nl = map_circuit(circ, &MapOpts::default());
    if opts.check != CheckMode::Off {
        let eopts = crate::check::EquivOpts::default();
        let em = crate::check::equiv_mapped(circ, &nl, &eopts);
        crate::check::enforce(opts.check, "equiv-map", &em.violations);
        let arch_run = arch_for_run(arch, opts);
        let packing = pack(&nl, &arch_run, &PackOpts { unrelated: opts.unrelated });
        let ep = crate::check::equiv_packed(circ, &nl, &packing, &eopts);
        crate::check::enforce(opts.check, "equiv-pack", &ep.violations);
    }
    run_flow_mapped(&circ.name, &nl, arch, opts, circ.dedup_hits)
}

/// Flow from an already-mapped netlist.  Builds the dense index arenas
/// once and shares them across every seed; with the closed timing loop
/// on, seeds chain their achieved CPDs (see the module docs).
pub fn run_flow_mapped(
    name: &str,
    nl: &Netlist,
    arch: &Arch,
    opts: &FlowOpts,
    dedup_hits: usize,
) -> FlowResult {
    let arch = arch_for_run(arch, opts);
    let packing = pack(nl, &arch, &PackOpts { unrelated: opts.unrelated });
    let idx = NetlistIndex::build(nl);
    let pidx = PackIndex::build(nl, &packing);
    let seeds =
        chain_seeds(nl, &packing, &arch, opts, name, &idx, &pidx, None, |_, _| {}, |_, _| {});
    let result = assemble_result(name, &arch, &packing, &seeds, dedup_hits);
    if opts.check != CheckMode::Off {
        let chained = opts.route && opts.route_timing_weights;
        check::enforce(
            opts.check,
            "recovery",
            &check::audit_recovery(&result, &seeds, chained),
        );
    }
    result
}

/// Run a benchmark on one architecture variant.
pub fn run_benchmark(b: &Benchmark, variant: ArchVariant, opts: &FlowOpts) -> FlowResult {
    let circ = b.generate();
    let arch = Arch::coffe(variant);
    let mut r = run_flow(&circ, &arch, opts);
    r.name = b.name.clone();
    r
}

/// Pack-only fast path (Fig. 9 and quick stats).
pub fn pack_only(circ: &Circuit, variant: ArchVariant, unrelated: Unrelated) -> Packing {
    let nl = map_circuit(circ, &MapOpts::default());
    let arch = Arch::coffe(variant);
    pack(&nl, &arch, &PackOpts { unrelated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{kratos_suite, BenchParams};
    use crate::synth::multiplier::{soft_mul, AdderAlgo};

    #[test]
    fn escalated_width_is_progressive() {
        assert_eq!(escalated_width(100, 125), 125);
        assert_eq!(escalated_width(100, 150), 150);
        // ceil, and always at least one track wider than the base.
        assert_eq!(escalated_width(3, 125), 4);
        assert_eq!(escalated_width(1, 125), 2);
        for &(pct, _) in ESCALATION_LADDER {
            assert!(escalated_width(112, pct) > 112);
        }
    }

    #[test]
    fn flow_error_display_carries_taxonomy_fields() {
        let e = FlowError::stage_failure(
            "place",
            Some(7),
            "device 2x2 cannot fit 9 LBs".to_string(),
            RecoveryAction::SkipSeed,
        );
        let s = e.to_string();
        assert!(s.contains("place") && s.contains("seed 7") && s.contains("seed skipped"), "{s}");
        let p = FlowError::job_panic(None, "boom".to_string());
        assert!(p.to_string().contains("job isolated"));
        assert_eq!(panic_message(&Box::new("boom") as &(dyn std::any::Any + Send)), "boom");
    }

    #[test]
    fn full_flow_on_kratos_circuit() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[2]; // gemmt
        let opts = FlowOpts { seeds: vec![1], place_effort: 0.2, ..Default::default() };
        let base = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(base.alms > 0 && base.cpd_ns > 0.0 && base.adp > 0.0);
        assert!(base.routed_ok, "routing failed");
        let dd5 = run_benchmark(b, ArchVariant::Dd5, &opts);
        // The paper's core claim: DD5 uses no more ALMs on adder circuits.
        assert!(dd5.alms <= base.alms, "dd5 {} vs base {}", dd5.alms, base.alms);
    }

    #[test]
    fn multi_seed_averaging_runs() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[0];
        let opts = FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.1,
            route: false,
            ..Default::default()
        };
        let r = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(r.cpd_ns > 0.0);
    }

    /// Multi-seed channel utilization is the element-wise mean of the
    /// single-seed runs (not silently the last seed's samples).
    #[test]
    fn channel_util_is_seed_mean() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let arch = Arch::paper(ArchVariant::Baseline);
        let mk = |seeds: Vec<u64>| {
            run_flow(&c, &arch, &FlowOpts { seeds, place_effort: 0.1, ..Default::default() })
        };
        let s1 = mk(vec![1]);
        let s2 = mk(vec![2]);
        let both = mk(vec![1, 2]);
        assert!(!both.channel_util.is_empty());
        assert_eq!(both.channel_util.len(), s1.channel_util.len());
        for i in 0..both.channel_util.len() {
            let want = (s1.channel_util[i] + s2.channel_util[i]) / 2.0;
            assert!(
                (both.channel_util[i] - want).abs() < 1e-12,
                "sample {i}: {} vs {}",
                both.channel_util[i],
                want
            );
        }
    }
}
