//! Persistent on-disk artifact cache backing [`super::engine::ArtifactCache`].
//!
//! Mapped netlists and packings are serialized as line-based text under
//! `target/dd-cache/` (override the root via [`DiskCache::new`]), keyed by
//! the *same* content hashes the in-memory cache uses — `map-<bench
//! key>.dd` and `pack-<pack key>.dd` — so repeated CLI invocations skip
//! the map and pack stages entirely.  The CLI opts out with
//! `--no-disk-cache`.
//!
//! The format reconstructs artifacts *exactly* (cell/net order, Vec
//! contents, chain ids): every consumer downstream of a disk hit sees
//! byte-identical structures, preserving the experiment engine's
//! determinism contract.  Loads are integrity-checked — a mapped artifact
//! must re-fingerprint to its stored hash and pass `Netlist::check`;
//! anything malformed is treated as a miss and recomputed.  Stores are
//! best-effort (I/O errors are ignored) and write-temp-then-rename so
//! concurrent processes never observe torn files.
//!
//! Malformed artifacts are additionally **quarantined**: the corrupt
//! file is renamed to `*.quarantine` (so the evidence survives the
//! recompute-and-restore that would otherwise overwrite it) and a
//! `flow.cache-integrity` [`Violation`] is recorded for the engine's
//! end-of-run failure summary
//! ([`ArtifactCache::take_cache_violations`]).  At most
//! [`QUARANTINE_CAP`] quarantine files are retained per store; beyond
//! that corrupt files are deleted outright.  A *missing* file is still a
//! silent miss — only content that exists and fails its checks is
//! evidence of corruption.  [`DiskCache::with_faults`] wires the
//! fault-injection harness in: a `corrupt:cache` fault truncates
//! matching artifacts at store time so tests drive this exact path.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::arch::ArchVariant;
use crate::check::{Stage, Violation};
use crate::netlist::{Cell, CellId, CellKind, Net, Netlist};
use crate::pack::{OperandPath, PackStats, PackedAlm, PackedLb, Packing};
use crate::rrg::lookahead::Lookahead;
use crate::util::error::Error;
use crate::util::fault::FaultPlan;

use super::engine::{ArtifactCache, MappedCircuit};

/// Cache generation.  The content-hash keys encode only *input* identity
/// (benchmark parameters, netlist fingerprint, arch facets, pack options)
/// — not the mapping/packing algorithms themselves — so stale artifacts
/// would silently survive algorithm changes.  Bump this whenever
/// `techmap`/`pack` semantics change; it is part of every file name, so
/// old generations become unreachable (and harmless) on disk.
pub const CACHE_VERSION: u32 = 1;

/// Most `*.quarantine` files retained per store; further corrupt
/// artifacts are deleted instead of renamed, so a persistently corrupting
/// environment (bad disk, hostile writer) cannot grow the store
/// unboundedly through the quarantine path.
pub const QUARANTINE_CAP: usize = 8;

/// Handle on one cache directory.
#[derive(Clone, Debug)]
pub struct DiskCache {
    root: PathBuf,
    /// Byte-size cap on the store; `None` = unbounded.  When set, every
    /// store is followed by LRU-by-mtime eviction (see [`Self::with_cap_mb`]).
    cap_bytes: Option<u64>,
    /// Injected store-time corruption ([`Self::with_faults`]); the empty
    /// plan by default.
    faults: FaultPlan,
    /// Cache-integrity violations recorded by quarantines, drained by
    /// [`ArtifactCache::take_cache_violations`] for the engine's failure
    /// summary.  `Arc`-shared so clones of one handle report into the
    /// same sink.
    violations: Arc<Mutex<Vec<Violation>>>,
}

impl DiskCache {
    pub fn new(root: impl Into<PathBuf>) -> DiskCache {
        DiskCache {
            root: root.into(),
            cap_bytes: None,
            faults: FaultPlan::default(),
            violations: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Store with a byte-size cap (the CLI's `--cache-cap-mb N`): after
    /// every write, `.dd` artifacts are evicted least-recently-*modified*
    /// first until the store fits.  Loads do not refresh mtimes, so this
    /// approximates LRU by write recency — cheap, filesystem-portable,
    /// and deterministic (ties break on file name).
    pub fn with_cap_mb(root: impl Into<PathBuf>, cap_mb: u64) -> DiskCache {
        let mut c = DiskCache::new(root);
        c.cap_bytes = Some(cap_mb.saturating_mul(1024 * 1024));
        c
    }

    /// A handle whose stores inject the `corrupt:cache` faults of `plan`
    /// (see [`crate::util::fault`]) — the fault-injection harness's way
    /// to exercise the integrity-check → quarantine path with real files.
    pub fn with_faults(root: impl Into<PathBuf>, faults: FaultPlan) -> DiskCache {
        let mut c = DiskCache::new(root);
        c.faults = faults;
        c
    }

    /// The CLI default: `target/dd-cache` under the working directory.
    pub fn default_root() -> PathBuf {
        PathBuf::from("target").join("dd-cache")
    }

    fn mapped_path(&self, key: u64) -> PathBuf {
        self.root.join(format!("map-v{CACHE_VERSION}-{key:016x}.dd"))
    }

    fn packing_path(&self, key: u64) -> PathBuf {
        self.root.join(format!("pack-v{CACHE_VERSION}-{key:016x}.dd"))
    }

    fn lookahead_path(&self, key: u64) -> PathBuf {
        self.root.join(format!("look-v{CACHE_VERSION}-{key:016x}.dd"))
    }

    /// Load a mapped-circuit artifact; `None` on miss or integrity
    /// failure.  A file that exists but fails its checks is quarantined.
    pub fn load_mapped(&self, key: u64) -> Option<MappedCircuit> {
        let path = self.mapped_path(key);
        let text = fs::read_to_string(&path).ok()?; // absent = silent miss
        match mapped_from_text(&text) {
            Some(m) => Some(m),
            None => {
                self.quarantine(&path, "mapped artifact");
                None
            }
        }
    }

    /// Store a mapped-circuit artifact (best-effort).
    pub fn store_mapped(&self, key: u64, m: &MappedCircuit) {
        let Some(body) = netlist_text(&m.nl) else { return };
        let text = format!(
            "ddmap1\ndedup {}\nfp {}\n{}",
            m.dedup_hits, m.fingerprint, body
        );
        write_atomic(&self.mapped_path(key), &self.maybe_corrupt("map", "ddmap1", text));
        self.evict_to_cap();
    }

    /// Load a packing artifact; `None` on miss or malformed content
    /// (the latter quarantined).
    pub fn load_packing(&self, key: u64) -> Option<Packing> {
        let path = self.packing_path(key);
        let text = fs::read_to_string(&path).ok()?;
        match packing_from_text(&text) {
            Some(p) => Some(p),
            None => {
                self.quarantine(&path, "packing artifact");
                None
            }
        }
    }

    /// Store a packing artifact (best-effort).
    pub fn store_packing(&self, key: u64, p: &Packing) {
        write_atomic(
            &self.packing_path(key),
            &self.maybe_corrupt("pack", "ddpack1", packing_text(p)),
        );
        self.evict_to_cap();
    }

    /// Load a router-lookahead artifact ([`crate::rrg::lookahead`]);
    /// `None` on miss, malformed content, or a dimension mismatch with
    /// the expected grid (the key already hashes the dimensions and
    /// `LOOKAHEAD_VERSION`, so the stored dims are an integrity check,
    /// not extra identity).
    pub fn load_lookahead(
        &self,
        key: u64,
        width: usize,
        height: usize,
        tracks: usize,
    ) -> Option<Lookahead> {
        let path = self.lookahead_path(key);
        let text = fs::read_to_string(&path).ok()?;
        let Some((dims, dist)) = lookahead_from_text(&text) else {
            self.quarantine(&path, "lookahead artifact");
            return None;
        };
        if dims != [width, height, tracks] {
            // A well-formed artifact for a different grid is a caller
            // expectation mismatch, not corruption: miss, keep the file.
            return None;
        }
        match Lookahead::from_raw(width, height, tracks, dist) {
            Some(la) => Some(la),
            None => {
                self.quarantine(&path, "lookahead artifact");
                None
            }
        }
    }

    /// Store a router-lookahead artifact (best-effort).
    pub fn store_lookahead(&self, key: u64, la: &Lookahead) {
        let dist: String = la
            .dist()
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        let text = format!(
            "ddlook1\ndims {} {} {}\ndist {dist}\nend\n",
            la.width(),
            la.height(),
            la.tracks()
        );
        write_atomic(&self.lookahead_path(key), &self.maybe_corrupt("look", "ddlook1", text));
        self.evict_to_cap();
    }

    /// Drain the integrity violations recorded by quarantines since the
    /// last call (or construction).
    pub fn take_violations(&self) -> Vec<Violation> {
        std::mem::take(&mut *self.violations.lock().unwrap())
    }

    /// Apply an injected `corrupt:cache` fault to an outgoing artifact:
    /// keep the magic line (so the load reaches the *parse* stage rather
    /// than looking like a foreign file) and replace the body.  Identity
    /// when no fault matches.
    fn maybe_corrupt(&self, kind: &str, magic: &str, text: String) -> String {
        if self.faults.corrupts(kind) {
            format!("{magic}\ncorrupted-by-fault-injection\n")
        } else {
            text
        }
    }

    /// A file exists but failed its integrity checks: move it aside as
    /// `*.quarantine` (deleting instead once [`QUARANTINE_CAP`] is
    /// reached) and record a `flow.cache-integrity` violation.  The
    /// caller then reports a miss, so the artifact is recomputed and
    /// re-stored — results are unaffected; only the evidence and the
    /// report change.
    fn quarantine(&self, path: &Path, what: &str) {
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("<artifact>")
            .to_string();
        let kept = fs::read_dir(&self.root)
            .map(|rd| {
                rd.flatten()
                    .filter(|e| {
                        e.path().extension().and_then(|x| x.to_str()) == Some("quarantine")
                    })
                    .count()
            })
            .unwrap_or(0);
        let disposition = if kept < QUARANTINE_CAP
            && fs::rename(path, path.with_extension("quarantine")).is_ok()
        {
            "quarantined for inspection"
        } else {
            let _ = fs::remove_file(path);
            "removed (quarantine cap reached)"
        };
        self.violations.lock().unwrap().push(Violation::from_producer_error(
            Stage::Recovery,
            "flow.cache-integrity",
            &name,
            &Error::msg(format!(
                "{what} failed its integrity check; {disposition}; recomputing"
            )),
        ));
    }

    /// Enforce the byte cap: list this store's `.dd` artifacts and remove
    /// them least-recently-modified first (file-name tie-break keeps the
    /// order deterministic under coarse mtime granularity) until the total
    /// fits.  Best-effort like the stores themselves — I/O errors are
    /// skipped, never surfaced; the cache is an accelerator, not a
    /// correctness dependency.
    fn evict_to_cap(&self) {
        let Some(cap) = self.cap_bytes else { return };
        let Ok(rd) = fs::read_dir(&self.root) else { return };
        let mut files: Vec<(std::time::SystemTime, PathBuf, u64)> = Vec::new();
        let mut total = 0u64;
        for e in rd.flatten() {
            let path = e.path();
            if path.extension().and_then(|x| x.to_str()) != Some("dd") {
                continue;
            }
            let Ok(md) = e.metadata() else { continue };
            if !md.is_file() {
                continue;
            }
            let mtime = md.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            total += md.len();
            files.push((mtime, path, md.len()));
        }
        if total <= cap {
            return;
        }
        files.sort();
        for (_, path, len) in files {
            if total <= cap {
                break;
            }
            if fs::remove_file(&path).is_ok() {
                total -= len;
            }
        }
    }
}

/// Write via a per-process temp file + rename so readers never see a
/// partially written artifact.  All failures are silent: the disk cache is
/// an accelerator, never a correctness dependency.
fn write_atomic(path: &Path, text: &str) {
    let Some(dir) = path.parent() else { return };
    if fs::create_dir_all(dir).is_err() {
        return;
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    if fs::write(&tmp, text).is_ok() && fs::rename(&tmp, path).is_err() {
        let _ = fs::remove_file(&tmp);
    }
}

/// `"prefix value"` -> `"value"`.
fn field<'a>(line: &'a str, prefix: &str) -> Option<&'a str> {
    line.strip_prefix(prefix)?.strip_prefix(' ').map(str::trim)
}

/// Parse a whitespace-separated number list.
fn nums<T: std::str::FromStr>(s: &str) -> Option<Vec<T>> {
    s.split_whitespace().map(|t| t.parse().ok()).collect()
}

/// Parse a mapped-circuit artifact; `None` on any malformation or
/// integrity failure (bad magic, truncation, fingerprint mismatch,
/// `Netlist::check` errors).
fn mapped_from_text(text: &str) -> Option<MappedCircuit> {
    let mut lines = text.lines();
    if lines.next()? != "ddmap1" {
        return None;
    }
    let dedup_hits: usize = field(lines.next()?, "dedup")?.parse().ok()?;
    let fingerprint: u64 = field(lines.next()?, "fp")?.parse().ok()?;
    let nl = netlist_from_lines(&mut lines)?;
    if !nl.check().is_empty() || ArtifactCache::netlist_fingerprint(&nl) != fingerprint {
        return None;
    }
    Some(MappedCircuit { nl, dedup_hits, fingerprint })
}

/// Parse a lookahead artifact into its stored (dims, dist); `None` on
/// malformation.  The caller checks dims against its expected grid —
/// that mismatch is a miss, not corruption.
fn lookahead_from_text(text: &str) -> Option<([usize; 3], Vec<u16>)> {
    let mut lines = text.lines();
    if lines.next()? != "ddlook1" {
        return None;
    }
    let dims: Vec<usize> = nums(field(lines.next()?, "dims")?)?;
    if dims.len() != 3 {
        return None;
    }
    let dist: Vec<u16> = nums(field(lines.next()?, "dist")?)?;
    if lines.next()? != "end" {
        return None;
    }
    Some(([dims[0], dims[1], dims[2]], dist))
}

// ---------------------------------------------------------------------------
// Netlist <-> text
// ---------------------------------------------------------------------------

/// Exact netlist serialization.  Returns `None` when any name would break
/// the line format (the generators never produce such names; this guards
/// future inputs rather than failing silently on load).
fn netlist_text(nl: &Netlist) -> Option<String> {
    let ok = |s: &str| !s.contains('|') && !s.chars().any(|c| c.is_whitespace());
    if !ok(&nl.name)
        || nl.cells.iter().any(|c| !ok(&c.name))
        || nl.nets.iter().any(|n| !ok(&n.name))
    {
        return None;
    }
    let join = |ids: &[u32]| -> String {
        ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
    };
    let mut s = String::new();
    s.push_str(&format!("name {}\n", nl.name));
    s.push_str(&format!("chains {}\n", nl.num_chains));
    s.push_str(&format!("cells {}\n", nl.cells.len()));
    for c in &nl.cells {
        let kind = match c.kind {
            CellKind::Input => "in".to_string(),
            CellKind::Output => "out".to_string(),
            CellKind::Lut { k, truth } => format!("lut:{k}:{truth}"),
            CellKind::AdderBit { chain, pos } => format!("add:{chain}:{pos}"),
            CellKind::Ff => "ff".to_string(),
            CellKind::Const(v) => format!("cst:{}", v as u8),
        };
        s.push_str(&format!("C {kind}|{}|{}|{}\n", c.name, join(&c.ins), join(&c.outs)));
    }
    s.push_str(&format!("nets {}\n", nl.nets.len()));
    for n in &nl.nets {
        let drv = match n.driver {
            Some((c, p)) => format!("{c}:{p}"),
            None => "-".to_string(),
        };
        let sinks: String = n
            .sinks
            .iter()
            .map(|&(c, p)| format!("{c}:{p}"))
            .collect::<Vec<_>>()
            .join(" ");
        s.push_str(&format!("N {}|{drv}|{sinks}\n", n.name));
    }
    s.push_str(&format!("inputs {}\n", join(&nl.inputs)));
    s.push_str(&format!("outputs {}\n", join(&nl.outputs)));
    s.push_str("end\n");
    Some(s)
}

fn parse_pin(t: &str) -> Option<(CellId, u8)> {
    let (c, p) = t.split_once(':')?;
    Some((c.parse().ok()?, p.parse().ok()?))
}

fn netlist_from_lines<'a, I: Iterator<Item = &'a str>>(lines: &mut I) -> Option<Netlist> {
    let name = field(lines.next()?, "name")?.to_string();
    let num_chains: u32 = field(lines.next()?, "chains")?.parse().ok()?;
    let n_cells: usize = field(lines.next()?, "cells")?.parse().ok()?;
    let mut cells: Vec<Cell> = Vec::with_capacity(n_cells);
    for _ in 0..n_cells {
        let rest = lines.next()?.strip_prefix("C ")?;
        let parts: Vec<&str> = rest.split('|').collect();
        if parts.len() != 4 {
            return None;
        }
        let ks: Vec<&str> = parts[0].split(':').collect();
        let kind = match ks[0] {
            "in" => CellKind::Input,
            "out" => CellKind::Output,
            "lut" if ks.len() == 3 => CellKind::Lut {
                k: ks[1].parse().ok()?,
                truth: ks[2].parse().ok()?,
            },
            "add" if ks.len() == 3 => CellKind::AdderBit {
                chain: ks[1].parse().ok()?,
                pos: ks[2].parse().ok()?,
            },
            "ff" => CellKind::Ff,
            "cst" if ks.len() == 2 => CellKind::Const(ks[1] == "1"),
            _ => return None,
        };
        cells.push(Cell {
            kind,
            name: parts[1].to_string(),
            ins: nums(parts[2])?,
            outs: nums(parts[3])?,
        });
    }
    let n_nets: usize = field(lines.next()?, "nets")?.parse().ok()?;
    let mut nets: Vec<Net> = Vec::with_capacity(n_nets);
    for _ in 0..n_nets {
        let rest = lines.next()?.strip_prefix("N ")?;
        let parts: Vec<&str> = rest.split('|').collect();
        if parts.len() != 3 {
            return None;
        }
        let driver = if parts[1] == "-" { None } else { Some(parse_pin(parts[1])?) };
        let sinks: Option<Vec<(CellId, u8)>> =
            parts[2].split_whitespace().map(parse_pin).collect();
        nets.push(Net { name: parts[0].to_string(), driver, sinks: sinks? });
    }
    // The writer always emits the trailing space ("inputs \n" for an empty
    // list), so a missing prefix here is corruption, not emptiness.
    let inputs: Vec<CellId> = nums(field(lines.next()?, "inputs")?)?;
    let outputs: Vec<CellId> = nums(field(lines.next()?, "outputs")?)?;
    if lines.next()? != "end" {
        return None;
    }
    Some(Netlist { name, cells, nets, inputs, outputs, num_chains })
}

// ---------------------------------------------------------------------------
// Packing <-> text
// ---------------------------------------------------------------------------

fn sorted<T: Ord + Copy>(set: &HashSet<T>) -> Vec<T> {
    let mut v: Vec<T> = set.iter().copied().collect();
    v.sort_unstable();
    v
}

fn join_u32(ids: &[u32]) -> String {
    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
}

fn join_usize(ids: &[usize]) -> String {
    ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
}

fn packing_text(p: &Packing) -> String {
    let mut s = String::new();
    s.push_str("ddpack1\n");
    s.push_str(&format!("variant {}\n", p.variant.name()));
    s.push_str(&format!("alms {}\n", p.alms.len()));
    for a in &p.alms {
        let chain = a.chain.map(|c| c.to_string()).unwrap_or_else(|| "-".to_string());
        let paths: String = a
            .operand_paths
            .iter()
            .flatten()
            .map(|op| match op {
                OperandPath::Const => "c".to_string(),
                OperandPath::RouteThrough => "r".to_string(),
                OperandPath::ZBypass => "z".to_string(),
                OperandPath::AbsorbedLut(l) => format!("a{l}"),
            })
            .collect::<Vec<_>>()
            .join(" ");
        s.push_str(&format!(
            "A {chain}|{}|{}|{paths}|{}|{}|{}|{}|{}\n",
            a.logic_halves,
            join_u32(&a.adder_bits),
            join_u32(&a.logic_luts),
            join_u32(&a.ffs),
            join_u32(&sorted(&a.gen_inputs)),
            join_u32(&sorted(&a.z_inputs)),
            join_u32(&sorted(&a.outputs)),
        ));
    }
    s.push_str(&format!("lbs {}\n", p.lbs.len()));
    for lb in &p.lbs {
        s.push_str(&format!(
            "B {}|{}|{}|{}\n",
            join_usize(&lb.alms),
            join_u32(&sorted(&lb.inputs)),
            join_u32(&sorted(&lb.outputs)),
            join_u32(&lb.chains),
        ));
    }
    s.push_str(&format!("macros {}\n", p.chain_macros.len()));
    for m in &p.chain_macros {
        s.push_str(&format!("M {}\n", join_usize(m)));
    }
    s.push_str(&format!("ios {}\n", join_u32(&p.ios)));
    let st = &p.stats;
    s.push_str(&format!(
        "stats {} {} {} {} {} {} {} {}\n",
        st.alms, st.lbs, st.adder_bits, st.luts, st.absorbed_luts,
        st.concurrent_luts, st.ffs, st.ios
    ));
    s.push_str("end\n");
    s
}

fn parse_path_tok(t: &str) -> Option<OperandPath> {
    match t {
        "c" => Some(OperandPath::Const),
        "r" => Some(OperandPath::RouteThrough),
        "z" => Some(OperandPath::ZBypass),
        _ => t.strip_prefix('a')?.parse().ok().map(OperandPath::AbsorbedLut),
    }
}

fn packing_from_text(text: &str) -> Option<Packing> {
    let mut lines = text.lines();
    if lines.next()? != "ddpack1" {
        return None;
    }
    let variant = match field(lines.next()?, "variant")? {
        "baseline" => ArchVariant::Baseline,
        "dd5" => ArchVariant::Dd5,
        "dd6" => ArchVariant::Dd6,
        _ => return None,
    };
    let n_alms: usize = field(lines.next()?, "alms")?.parse().ok()?;
    let mut alms: Vec<PackedAlm> = Vec::with_capacity(n_alms);
    for _ in 0..n_alms {
        let rest = lines.next()?.strip_prefix("A ")?;
        let parts: Vec<&str> = rest.split('|').collect();
        if parts.len() != 9 {
            return None;
        }
        let chain = if parts[0] == "-" { None } else { Some(parts[0].parse().ok()?) };
        let logic_halves: usize = parts[1].parse().ok()?;
        let adder_bits: Vec<u32> = nums(parts[2])?;
        let flat: Option<Vec<OperandPath>> =
            parts[3].split_whitespace().map(parse_path_tok).collect();
        let flat = flat?;
        if flat.len() != 2 * adder_bits.len() {
            return None;
        }
        let operand_paths: Vec<[OperandPath; 2]> =
            flat.chunks(2).map(|c| [c[0], c[1]]).collect();
        alms.push(PackedAlm {
            adder_bits,
            operand_paths,
            logic_luts: nums(parts[4])?,
            logic_halves,
            ffs: nums(parts[5])?,
            gen_inputs: nums::<u32>(parts[6])?.into_iter().collect(),
            z_inputs: nums::<u32>(parts[7])?.into_iter().collect(),
            outputs: nums::<u32>(parts[8])?.into_iter().collect(),
            chain,
        });
    }
    let n_lbs: usize = field(lines.next()?, "lbs")?.parse().ok()?;
    let mut lbs: Vec<PackedLb> = Vec::with_capacity(n_lbs);
    for _ in 0..n_lbs {
        let rest = lines.next()?.strip_prefix("B ")?;
        let parts: Vec<&str> = rest.split('|').collect();
        if parts.len() != 4 {
            return None;
        }
        lbs.push(PackedLb {
            alms: nums(parts[0])?,
            inputs: nums::<u32>(parts[1])?.into_iter().collect(),
            outputs: nums::<u32>(parts[2])?.into_iter().collect(),
            chains: nums(parts[3])?,
        });
    }
    let n_macros: usize = field(lines.next()?, "macros")?.parse().ok()?;
    let mut chain_macros: Vec<Vec<usize>> = Vec::with_capacity(n_macros);
    for _ in 0..n_macros {
        let rest = lines.next()?.strip_prefix('M')?;
        chain_macros.push(nums(rest)?);
    }
    let ios: Vec<u32> = nums(field(lines.next()?, "ios")?)?;
    let st: Vec<usize> = nums(field(lines.next()?, "stats")?)?;
    if st.len() != 8 {
        return None;
    }
    let stats = PackStats {
        alms: st[0],
        lbs: st[1],
        adder_bits: st[2],
        luts: st[3],
        absorbed_luts: st[4],
        concurrent_luts: st[5],
        ffs: st[6],
        ios: st[7],
    };
    if lines.next()? != "end" || stats.alms != alms.len() || stats.lbs != lbs.len() {
        return None;
    }
    Some(Packing { variant, alms, lbs, chain_macros, ios, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::pack::{pack, PackOpts};
    use crate::place::cost::NetModel;
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dd-cache-test-{tag}-{}", std::process::id()))
    }

    fn mapped_mul() -> Netlist {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        map_circuit(&c, &MapOpts::default())
    }

    #[test]
    fn netlist_text_round_trip_is_exact() {
        let nl = mapped_mul();
        let text = netlist_text(&nl).expect("serializable names");
        let back = netlist_from_lines(&mut text.lines()).expect("parses");
        assert_eq!(back.name, nl.name);
        assert_eq!(back.num_chains, nl.num_chains);
        assert_eq!(back.cells.len(), nl.cells.len());
        assert_eq!(back.nets.len(), nl.nets.len());
        assert_eq!(back.inputs, nl.inputs);
        assert_eq!(back.outputs, nl.outputs);
        for (a, b) in nl.cells.iter().zip(back.cells.iter()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.name, b.name);
            assert_eq!(a.ins, b.ins);
            assert_eq!(a.outs, b.outs);
        }
        for (a, b) in nl.nets.iter().zip(back.nets.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.driver, b.driver);
            assert_eq!(a.sinks, b.sinks);
        }
        // The exactness that matters downstream: same fingerprint.
        assert_eq!(
            ArtifactCache::netlist_fingerprint(&back),
            ArtifactCache::netlist_fingerprint(&nl)
        );
    }

    #[test]
    fn packing_round_trip_preserves_placement_inputs() {
        let nl = mapped_mul();
        let arch = Arch::paper(ArchVariant::Dd5);
        let p = pack(&nl, &arch, &PackOpts::default());
        let back = packing_from_text(&packing_text(&p)).expect("parses");
        assert_eq!(back.variant, p.variant);
        assert_eq!(back.chain_macros, p.chain_macros);
        assert_eq!(back.ios, p.ios);
        assert_eq!(back.stats.alms, p.stats.alms);
        assert_eq!(back.stats.concurrent_luts, p.stats.concurrent_luts);
        for (a, b) in p.alms.iter().zip(back.alms.iter()) {
            assert_eq!(a.adder_bits, b.adder_bits);
            assert_eq!(a.operand_paths, b.operand_paths);
            assert_eq!(a.logic_luts, b.logic_luts);
            assert_eq!(a.logic_halves, b.logic_halves);
            assert_eq!(a.ffs, b.ffs);
            assert_eq!(a.gen_inputs, b.gen_inputs);
            assert_eq!(a.z_inputs, b.z_inputs);
            assert_eq!(a.outputs, b.outputs);
            assert_eq!(a.chain, b.chain);
        }
        for (a, b) in p.lbs.iter().zip(back.lbs.iter()) {
            assert_eq!(a.alms, b.alms);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.outputs, b.outputs);
            assert_eq!(a.chains, b.chains);
        }
        // Determinism proxy: the placer's net model is identical.
        let m0 = NetModel::build(&nl, &p);
        let m1 = NetModel::build(&nl, &back);
        assert_eq!(m0.nets.len(), m1.nets.len());
        for (a, b) in m0.nets.iter().zip(m1.nets.iter()) {
            assert_eq!(a.net, b.net);
            assert_eq!(a.terms, b.terms);
        }
    }

    #[test]
    fn disk_cache_store_load_cycle() {
        let root = tmp_root("cycle");
        let cache = DiskCache::new(&root);
        let nl = mapped_mul();
        let fingerprint = ArtifactCache::netlist_fingerprint(&nl);
        let m = MappedCircuit { nl, dedup_hits: 3, fingerprint };
        assert!(cache.load_mapped(7).is_none(), "cold cache must miss");
        cache.store_mapped(7, &m);
        let got = cache.load_mapped(7).expect("stored artifact loads");
        assert_eq!(got.dedup_hits, 3);
        assert_eq!(got.fingerprint, fingerprint);
        assert_eq!(got.nl.cells.len(), m.nl.cells.len());

        let arch = Arch::paper(ArchVariant::Baseline);
        let p = pack(&m.nl, &arch, &PackOpts::default());
        cache.store_packing(9, &p);
        let back = cache.load_packing(9).expect("stored packing loads");
        assert_eq!(back.stats.alms, p.stats.alms);

        // Corrupt file -> integrity check treats it as a miss.
        std::fs::write(
            root.join(format!("map-v{CACHE_VERSION}-{:016x}.dd", 7u64)),
            "ddmap1\ngarbage\n",
        )
        .unwrap();
        assert!(cache.load_mapped(7).is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn lookahead_store_load_cycle() {
        use crate::arch::device::Device;
        use crate::rrg::{lookahead, RrGraph};
        let root = tmp_root("look");
        let _ = std::fs::remove_dir_all(&root);
        let cache = DiskCache::new(&root);
        let mut arch = Arch::paper(ArchVariant::Baseline);
        arch.routing.channel_width = 4;
        let g = RrGraph::build(&Device::new(4, 4), &arch);
        let la = Lookahead::build(&g);
        let key = lookahead::cache_key(g.width, g.height, g.tracks);
        assert!(cache.load_lookahead(key, g.width, g.height, g.tracks).is_none());
        cache.store_lookahead(key, &la);
        let back = cache
            .load_lookahead(key, g.width, g.height, g.tracks)
            .expect("stored lookahead loads");
        assert_eq!(back.dist(), la.dist());
        // Wrong expected dims -> integrity miss, not a wrong artifact.
        assert!(cache.load_lookahead(key, g.width + 1, g.height, g.tracks).is_none());
        // Corrupt file -> miss.
        std::fs::write(
            root.join(format!("look-v{CACHE_VERSION}-{key:016x}.dd")),
            "ddlook1\ngarbage\n",
        )
        .unwrap();
        assert!(cache.load_lookahead(key, g.width, g.height, g.tracks).is_none());
        let _ = std::fs::remove_dir_all(&root);
    }

    fn store_bytes(root: &Path) -> u64 {
        std::fs::read_dir(root)
            .map(|rd| {
                rd.flatten()
                    .filter(|e| e.path().extension().and_then(|x| x.to_str()) == Some("dd"))
                    .filter_map(|e| e.metadata().ok())
                    .map(|m| m.len())
                    .sum()
            })
            .unwrap_or(0)
    }

    #[test]
    fn byte_cap_evicts_lru_by_mtime() {
        let root = tmp_root("evict");
        let _ = std::fs::remove_dir_all(&root);
        let nl = mapped_mul();
        let fingerprint = ArtifactCache::netlist_fingerprint(&nl);
        let m = MappedCircuit { nl, dedup_hits: 0, fingerprint };

        // Learn one artifact's size with an unbounded store.
        let unbounded = DiskCache::new(&root);
        unbounded.store_mapped(1, &m);
        let one = store_bytes(&root);
        assert!(one > 0);
        let _ = std::fs::remove_dir_all(&root);

        // Cap at ~2.5 artifacts: storing 4 must evict down to the cap.
        let cap_bytes = one * 5 / 2;
        let mut capped = DiskCache::new(&root);
        capped.cap_bytes = Some(cap_bytes);
        for key in 1..=4u64 {
            capped.store_mapped(key, &m);
        }
        let total = store_bytes(&root);
        assert!(total <= cap_bytes, "store {total} bytes exceeds cap {cap_bytes}");
        assert!(total >= one, "eviction deleted everything");
        // Evicted keys read as clean misses; at least one key survives.
        let alive = (1..=4u64).filter(|&k| capped.load_mapped(k).is_some()).count();
        assert!((1..4).contains(&alive), "{alive} artifacts alive");
        // The unbounded handle never evicts.
        let _ = std::fs::remove_dir_all(&root);
        let unbounded = DiskCache::new(&root);
        for key in 1..=4u64 {
            unbounded.store_mapped(key, &m);
        }
        assert_eq!(store_bytes(&root), 4 * one);
        let _ = std::fs::remove_dir_all(&root);

        // `with_cap_mb` wires megabytes through.
        let c = DiskCache::with_cap_mb(&root, 3);
        assert_eq!(c.cap_bytes, Some(3 * 1024 * 1024));
    }

    #[test]
    fn corrupt_artifact_is_quarantined_and_reported() {
        let root = tmp_root("quar");
        let _ = std::fs::remove_dir_all(&root);
        let cache = DiskCache::new(&root);
        let nl = mapped_mul();
        let fingerprint = ArtifactCache::netlist_fingerprint(&nl);
        let m = MappedCircuit { nl, dedup_hits: 0, fingerprint };
        cache.store_mapped(3, &m);
        let path = root.join(format!("map-v{CACHE_VERSION}-{:016x}.dd", 3u64));
        std::fs::write(&path, "ddmap1\ngarbage\n").unwrap();
        assert!(cache.load_mapped(3).is_none());
        assert!(!path.exists(), "corrupt file left in place");
        assert!(path.with_extension("quarantine").exists(), "evidence not retained");
        let vs = cache.take_violations();
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].code, "flow.cache-integrity");
        assert!(cache.take_violations().is_empty(), "drain is one-shot");
        // After the quarantine the slot is a clean miss; a fresh store
        // restores normal service.
        assert!(cache.load_mapped(3).is_none());
        cache.store_mapped(3, &m);
        assert!(cache.load_mapped(3).is_some());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn injected_store_corruption_drives_the_quarantine_path() {
        use crate::util::fault::FaultPlan;
        let root = tmp_root("inject");
        let _ = std::fs::remove_dir_all(&root);
        let faulty =
            DiskCache::with_faults(&root, FaultPlan::parse("corrupt:cache:map").unwrap());
        let nl = mapped_mul();
        let fingerprint = ArtifactCache::netlist_fingerprint(&nl);
        let m = MappedCircuit { nl, dedup_hits: 0, fingerprint };
        faulty.store_mapped(5, &m);
        // The fault corrupted the stored body (magic intact): the load
        // must take the real integrity-check -> quarantine path.
        assert!(faulty.load_mapped(5).is_none());
        let vs = faulty.take_violations();
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].code, "flow.cache-integrity");
        // A map-kind fault leaves packing stores untouched.
        let arch = Arch::paper(ArchVariant::Baseline);
        let p = pack(&m.nl, &arch, &PackOpts::default());
        faulty.store_packing(6, &p);
        assert!(faulty.load_packing(6).is_some());
        let _ = std::fs::remove_dir_all(&root);
    }
}
