//! BLIF-subset reader/writer for mapped netlists.
//!
//! Supports `.model/.inputs/.outputs/.names/.latch/.subckt adder/.param
//! chain_break/.end`.  `.names` blocks become LUT cells (truth table parsed
//! from the SOP cover); `.subckt adder a=.. b=.. cin=.. sum=.. cout=..`
//! becomes an adder bit — the same convention VTR's architecture files use
//! for carry-chain primitives.  This is interchange + golden-file tooling,
//! not a general BLIF implementation (no multi-model hierarchies, no
//! don't-cares).
//!
//! ## Chain-boundary annotation
//!
//! Chain membership is reconstructed from carry connectivity: an adder bit
//! whose `cin` is driven by an existing bit's `cout` joins that chain.
//! That rule is ambiguous for *cascaded* chains — a chain whose bit 0
//! takes its carry-in from another chain's final `cout` would silently
//! merge into it on re-read.  The writer therefore emits a
//! `.param chain_break` marker before each such boundary bit, and the
//! reader starts a fresh chain when it sees one, so cascades round-trip
//! without merging.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use super::{CellKind, Netlist, NetId};

/// Serialize a netlist to BLIF text.
pub fn write_blif(nl: &Netlist) -> String {
    let mut s = String::new();
    let _ = writeln!(s, ".model {}", nl.name);
    let ins: Vec<&str> = nl
        .inputs
        .iter()
        .map(|&c| nl.nets[nl.cells[c as usize].outs[0] as usize].name.as_str())
        .collect();
    let outs: Vec<&str> = nl
        .outputs
        .iter()
        .map(|&c| nl.nets[nl.cells[c as usize].ins[0] as usize].name.as_str())
        .collect();
    let _ = writeln!(s, ".inputs {}", ins.join(" "));
    let _ = writeln!(s, ".outputs {}", outs.join(" "));
    for cell in &nl.cells {
        match cell.kind {
            CellKind::Lut { k, truth } => {
                let names: Vec<&str> = cell
                    .ins
                    .iter()
                    .map(|&n| nl.nets[n as usize].name.as_str())
                    .collect();
                let out = &nl.nets[cell.outs[0] as usize].name;
                let _ = writeln!(s, ".names {} {}", names.join(" "), out);
                for row in 0..(1u64 << k) {
                    if truth >> row & 1 == 1 {
                        let bits: String = (0..k)
                            .map(|b| if row >> b & 1 == 1 { '1' } else { '0' })
                            .collect();
                        let _ = writeln!(s, "{} 1", bits);
                    }
                }
            }
            CellKind::AdderBit { pos, .. } => {
                let n = |id: NetId| nl.nets[id as usize].name.as_str();
                // Chain-boundary annotation: a chain head whose carry-in is
                // itself another chain's cout is ambiguous to the
                // connectivity-based reader — mark it so cascaded chains
                // round-trip without merging.
                let cascaded_head = pos == 0
                    && matches!(
                        nl.nets[cell.ins[2] as usize].driver,
                        Some((drv, 1)) if matches!(nl.cells[drv as usize].kind,
                                                   CellKind::AdderBit { .. })
                    );
                if cascaded_head {
                    let _ = writeln!(s, ".param chain_break");
                }
                let _ = writeln!(
                    s,
                    ".subckt adder a={} b={} cin={} sumout={} cout={}",
                    n(cell.ins[0]), n(cell.ins[1]), n(cell.ins[2]),
                    n(cell.outs[0]), n(cell.outs[1])
                );
            }
            CellKind::Ff => {
                let _ = writeln!(
                    s,
                    ".latch {} {} re clk 2",
                    nl.nets[cell.ins[0] as usize].name,
                    nl.nets[cell.outs[0] as usize].name
                );
            }
            CellKind::Const(v) => {
                let out = &nl.nets[cell.outs[0] as usize].name;
                let _ = writeln!(s, ".names {}", out);
                if v {
                    let _ = writeln!(s, "1");
                }
            }
            CellKind::Input | CellKind::Output => {}
        }
    }
    s.push_str(".end\n");
    s
}

/// Parse the BLIF subset produced by [`write_blif`].
pub fn read_blif(text: &str) -> Result<Netlist> {
    let mut nl = Netlist::new("top");
    let mut nets: HashMap<String, NetId> = HashMap::new();
    let mut chains_next = 0u32;

    // Join continuation lines.
    let mut lines: Vec<String> = Vec::new();
    let mut cur = String::new();
    for raw in text.lines() {
        let raw = raw.split('#').next().unwrap_or("").trim_end();
        if let Some(stripped) = raw.strip_suffix('\\') {
            cur.push_str(stripped);
            cur.push(' ');
        } else {
            cur.push_str(raw);
            if !cur.trim().is_empty() {
                lines.push(cur.trim().to_string());
            }
            cur.clear();
        }
    }

    let mut get_net = |nl: &mut Netlist, nets: &mut HashMap<String, NetId>, name: &str| -> NetId {
        *nets.entry(name.to_string()).or_insert_with(|| nl.add_net(name.to_string()))
    };

    let mut i = 0usize;
    let mut pending_outputs: Vec<String> = Vec::new();
    // Set by `.param chain_break`: the next adder bit starts a new chain
    // even if its cin is driven by an existing chain's cout.
    let mut force_chain_break = false;
    while i < lines.len() {
        let line = lines[i].clone();
        let mut tok = line.split_whitespace();
        match tok.next() {
            Some(".model") => {
                nl.name = tok.next().unwrap_or("top").to_string();
                i += 1;
            }
            Some(".inputs") => {
                for name in tok {
                    let n = get_net(&mut nl, &mut nets, name);
                    nl.add_cell(CellKind::Input, name, vec![], vec![n]);
                }
                i += 1;
            }
            Some(".outputs") => {
                pending_outputs.extend(tok.map(|s| s.to_string()));
                i += 1;
            }
            Some(".names") => {
                let sig: Vec<&str> = tok.collect();
                if sig.is_empty() {
                    bail!("empty .names");
                }
                let (in_names, out_name) = sig.split_at(sig.len() - 1);
                let k = in_names.len();
                if k > 6 {
                    bail!(".names with {k} inputs exceeds 6-LUT");
                }
                // Collect cover rows.
                let mut truth = 0u64;
                let mut is_const1 = false;
                i += 1;
                while i < lines.len() && !lines[i].starts_with('.') {
                    let row = &lines[i];
                    let parts: Vec<&str> = row.split_whitespace().collect();
                    if k == 0 {
                        if parts == ["1"] {
                            is_const1 = true;
                        }
                    } else {
                        if parts.len() != 2 || parts[1] != "1" {
                            bail!("unsupported cover row: {row}");
                        }
                        let pat = parts[0].as_bytes();
                        if pat.len() != k {
                            bail!("cover width mismatch: {row}");
                        }
                        // Expand '-' don't-cares.
                        let mut rows = vec![0u64];
                        for (b, &ch) in pat.iter().enumerate() {
                            match ch {
                                b'0' => {}
                                b'1' => rows.iter_mut().for_each(|r| *r |= 1 << b),
                                b'-' => {
                                    let mut extra: Vec<u64> =
                                        rows.iter().map(|r| r | 1 << b).collect();
                                    rows.append(&mut extra);
                                }
                                _ => bail!("bad cover char in {row}"),
                            }
                        }
                        for r in rows {
                            truth |= 1u64 << r;
                        }
                    }
                    i += 1;
                }
                let out = get_net(&mut nl, &mut nets, out_name[0]);
                if k == 0 {
                    nl.add_cell(CellKind::Const(is_const1),
                                format!("const_{}", out_name[0]), vec![], vec![out]);
                } else {
                    let ins: Vec<NetId> = in_names
                        .iter()
                        .map(|n| get_net(&mut nl, &mut nets, n))
                        .collect();
                    nl.add_cell(CellKind::Lut { k: k as u8, truth },
                                format!("lut_{}", out_name[0]), ins, vec![out]);
                }
            }
            Some(".latch") => {
                let parts: Vec<&str> = tok.collect();
                if parts.len() < 2 {
                    bail!("bad .latch");
                }
                let d = get_net(&mut nl, &mut nets, parts[0]);
                let q = get_net(&mut nl, &mut nets, parts[1]);
                nl.add_cell(CellKind::Ff, format!("ff_{}", parts[1]), vec![d], vec![q]);
                i += 1;
            }
            Some(".subckt") => {
                let cname = tok.next().ok_or_else(|| anyhow!("bad .subckt"))?;
                if cname != "adder" {
                    bail!("unsupported subckt {cname}");
                }
                let mut conn: HashMap<&str, &str> = HashMap::new();
                for kv in tok {
                    let (k, v) = kv.split_once('=')
                        .ok_or_else(|| anyhow!("bad subckt pin {kv}"))?;
                    conn.insert(k, v);
                }
                let pin = |p: &str| -> Result<&str> {
                    conn.get(p).copied().context(format!("missing pin {p}"))
                };
                let a = get_net(&mut nl, &mut nets, pin("a")?);
                let b = get_net(&mut nl, &mut nets, pin("b")?);
                let cin = get_net(&mut nl, &mut nets, pin("cin")?);
                let sum = get_net(&mut nl, &mut nets, pin("sumout")?);
                let cout = get_net(&mut nl, &mut nets, pin("cout")?);
                // Chain reconstruction: a bit whose cin is driven by an
                // existing bit's cout joins that chain; otherwise new chain.
                // A preceding `.param chain_break` overrides the join — the
                // cin is a cascade from another chain's final cout.
                let (chain, pos) = match nl.nets[cin as usize].driver {
                    Some((c, 1)) if !force_chain_break
                        && matches!(nl.cells[c as usize].kind,
                                    CellKind::AdderBit { .. }) => {
                        match nl.cells[c as usize].kind {
                            CellKind::AdderBit { chain, pos } => (chain, pos + 1),
                            _ => unreachable!(),
                        }
                    }
                    _ => {
                        let ch = chains_next;
                        chains_next += 1;
                        (ch, 0)
                    }
                };
                force_chain_break = false;
                nl.add_cell(CellKind::AdderBit { chain, pos },
                            format!("fa_{chain}_{pos}"),
                            vec![a, b, cin], vec![sum, cout]);
                i += 1;
            }
            Some(".param") => {
                match tok.next() {
                    Some("chain_break") => force_chain_break = true,
                    other => bail!("unsupported .param {}", other.unwrap_or("<none>")),
                }
                i += 1;
            }
            Some(".end") => break,
            Some(other) => bail!("unsupported directive {other}"),
            None => {
                i += 1;
            }
        }
    }
    for name in pending_outputs {
        let n = get_net(&mut nl, &mut nets, &name);
        nl.add_cell(CellKind::Output, format!("out_{name}"), vec![n], vec![]);
    }
    nl.num_chains = chains_next;
    Ok(nl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::CellKind;

    fn sample() -> Netlist {
        let mut nl = Netlist::new("samp");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let c = nl.add_input("c");
        let y = nl.add_net("y");
        nl.add_cell(CellKind::Lut { k: 3, truth: 0b1110_1000 }, "maj",
                    vec![a, b, c], vec![y]);
        let gnd = nl.add_net("gnd");
        nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![gnd]);
        let s0 = nl.add_net("s0");
        let c0 = nl.add_net("c0");
        let s1 = nl.add_net("s1");
        let c1 = nl.add_net("c1");
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 0 }, "fa0",
                    vec![a, b, gnd], vec![s0, c0]);
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 1 }, "fa1",
                    vec![c, y, c0], vec![s1, c1]);
        nl.num_chains = 1;
        nl.add_output("o0", s0);
        nl.add_output("o1", s1);
        nl
    }

    #[test]
    fn round_trip_preserves_structure() {
        let nl = sample();
        let text = write_blif(&nl);
        let back = read_blif(&text).unwrap();
        assert!(back.check().is_empty(), "{:?}", back.check());
        assert_eq!(back.num_luts(), nl.num_luts());
        assert_eq!(back.num_adders(), nl.num_adders());
        assert_eq!(back.inputs.len(), nl.inputs.len());
        assert_eq!(back.outputs.len(), nl.outputs.len());
        assert_eq!(back.num_chains, 1);
        // Chain order reconstructed.
        let chain = back.chain_cells(0);
        assert_eq!(chain.len(), 2);
    }

    #[test]
    fn truth_table_round_trip() {
        let nl = sample();
        let back = read_blif(&write_blif(&nl)).unwrap();
        let lut = back.cells.iter().find(|c| matches!(c.kind, CellKind::Lut { .. })).unwrap();
        match lut.kind {
            CellKind::Lut { k, truth } => {
                assert_eq!(k, 3);
                assert_eq!(truth, 0b1110_1000);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn dont_care_expansion() {
        let text = "\
.model t
.inputs a b
.outputs y
.names a b y
-1 1
.end
";
        let nl = read_blif(text).unwrap();
        let lut = nl.cells.iter().find(|c| matches!(c.kind, CellKind::Lut { .. })).unwrap();
        match lut.kind {
            // b & (a | !a) = b -> rows 10 (2) and 11 (3) set.
            CellKind::Lut { truth, .. } => assert_eq!(truth, 0b1100),
            _ => unreachable!(),
        }
    }

    #[test]
    fn rejects_unknown_directive() {
        assert!(read_blif(".model x\n.gate foo\n.end\n").is_err());
        assert!(read_blif(".model x\n.param frobnicate\n.end\n").is_err());
    }

    /// Two chains where the second's carry-in cascades from the first's
    /// final cout.  Without the `.param chain_break` marker the reader
    /// would merge them into one chain (the latent ambiguity from the
    /// ROADMAP); with it the chain structure round-trips.
    #[test]
    fn cascaded_chains_round_trip_without_merging() {
        let mut nl = Netlist::new("casc");
        let a0 = nl.add_input("a0");
        let b0 = nl.add_input("b0");
        let a1 = nl.add_input("a1");
        let b1 = nl.add_input("b1");
        let a2 = nl.add_input("a2");
        let b2 = nl.add_input("b2");
        let gnd = nl.add_net("gnd");
        nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![gnd]);
        let s0 = nl.add_net("s0");
        let c0 = nl.add_net("c0");
        let s1 = nl.add_net("s1");
        let c1 = nl.add_net("c1");
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 0 }, "fa0",
                    vec![a0, b0, gnd], vec![s0, c0]);
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 1 }, "fa1",
                    vec![a1, b1, c0], vec![s1, c1]);
        // Chain 1's bit 0 takes chain 0's final cout as carry-in.
        let s2 = nl.add_net("s2");
        let c2 = nl.add_net("c2");
        nl.add_cell(CellKind::AdderBit { chain: 1, pos: 0 }, "fa2",
                    vec![a2, b2, c1], vec![s2, c2]);
        nl.num_chains = 2;
        nl.add_output("o0", s0);
        nl.add_output("o1", s1);
        nl.add_output("o2", s2);
        assert!(nl.check().is_empty(), "{:?}", nl.check());

        let text = write_blif(&nl);
        assert!(text.contains(".param chain_break"), "marker missing:\n{text}");
        let back = read_blif(&text).unwrap();
        assert!(back.check().is_empty(), "{:?}", back.check());
        assert_eq!(back.num_chains, 2, "cascaded chains merged on re-read");
        let lens: Vec<usize> = (0..back.num_chains)
            .map(|ch| back.chain_cells(ch).len())
            .collect();
        let mut sorted_lens = lens.clone();
        sorted_lens.sort_unstable();
        assert_eq!(sorted_lens, vec![1, 2]);
        // The marker only fires on cascades: a plain netlist stays clean.
        let plain = write_blif(&sample());
        assert!(!plain.contains("chain_break"));
    }

    #[test]
    fn const_cells() {
        let text = ".model t\n.inputs\n.outputs y\n.names y\n1\n.end\n";
        let nl = read_blif(text).unwrap();
        assert!(nl.cells.iter().any(|c| matches!(c.kind, CellKind::Const(true))));
    }
}
