//! `dduty` — CLI for the Double-Duty reproduction.
//!
//! Subcommands:
//!   exp <table1|table2|table3|table4|fig5|fig6|fig7|fig8|fig9|all> [--quick] [--jobs N]
//!       [--route-jobs N] [--lookahead on|off] [--no-disk-cache] [--cache-cap-mb N]
//!       Regenerate a paper table/figure (experiment-engine sweeps run on
//!       N worker threads; default: all cores / DDUTY_WORKERS).
//!   flow --bench <name> [--variant baseline|dd5|dd6] [--seed N | --seeds a,b,c]
//!        [--no-route] [--jobs N] [--route-jobs N] [--lookahead on|off]
//!        [--no-disk-cache] [--cache-cap-mb N] [--timing-route] [--sta-every K]
//!        [--crit-alpha A] [--place-crit-alpha A] [--move-mix F]
//!       Run the full CAD flow on one benchmark and print its metrics
//!       (multi-seed runs place/route the seeds in parallel; --jobs also
//!       shards the mapper/packer front-end and --route-jobs each
//!       PathFinder run, all with bit-identical results; --timing-route
//!       runs closed-loop timing-driven routing: per-sink criticalities
//!       seed the router and are refreshed by an STA against the partial
//!       routing every K PathFinder iterations with smoothing factor A —
//!       --sta-every 0 keeps the static pre-route weights; across seeds,
//!       each seed's achieved CPD re-normalizes the next seed's placement
//!       and routing criticalities.  --place-crit-alpha A smooths the
//!       placer's per-sink criticality refresh; --move-mix F in [0, 1]
//!       scales the annealer's macro-shift/median move probabilities,
//!       0 = uniform swaps only; --lookahead off falls back to the legacy
//!       per-expansion Manhattan heuristic, bit-identical to pre-lookahead
//!       builds).
//!   check [<bench|suite> ...] [--variant baseline|dd5|dd6|all] [--strict]
//!         [--quick] [--no-route] [--route-jobs N] [--lookahead on|off]
//!         [--no-disk-cache] [--cache-cap-mb N] [--equiv] [--jobs N]
//!       Run the stage auditors ([`double_duty::check`]) over the named
//!       benchmarks/suites (default: every shipped suite) on each listed
//!       architecture variant, re-deriving netlist, packing, placement,
//!       routing and timing invariants from the artifacts alone.  Exits
//!       nonzero under `--strict` if any Error-severity violation is
//!       found.  Artifacts come from the same persistent cache the other
//!       subcommands fill, so `dduty check` after `dduty exp` audits what
//!       actually ran.  `--equiv` switches to *semantic* verification
//!       ([`double_duty::check::equiv`]): SAT-based combinational
//!       equivalence of the mapped and packed netlists against the
//!       source AIG, reporting any `equiv.mismatch` with a replayable
//!       counterexample input assignment (`--jobs N` parallelizes the
//!       SAT cones; output is bit-identical for any N).
//!   serve [--addr HOST:PORT] [--jobs N] [--no-disk-cache] [--cache-cap-mb N]
//!       Run the resident flow-as-a-service daemon
//!       ([`double_duty::serve`]): accepts flow jobs over hand-rolled
//!       HTTP/JSON (`POST /jobs`), runs them on the engine's appendable
//!       work queue against the shared artifact cache (identical
//!       submissions dedup onto one execution), streams per-job progress
//!       (`GET /jobs/<id>/events`, chunked), and serves results
//!       byte-identical to `dduty flow` for the same options
//!       (`GET /jobs/<id>/result`).  `POST /shutdown` drains the queue,
//!       audits the job history (`check::audit_serve`), and exits 0 on a
//!       clean run.
//!   list
//!       List available benchmarks.
//!   coffe
//!       Print the COFFE component report (Tables I & II).
//!
//! `exp` and `flow` also accept `--check [strict]`: the flow then runs
//! the same auditors on every artifact as it is produced — warn mode
//! prints violations and continues, strict mode fails the run.  Checked
//! flows additionally gate the two logic-neutral stages semantically:
//! the mapped netlist and the packed view are each proven equivalent to
//! the source AIG (`equiv-map` / `equiv-pack`) before place and route.
//!
//! Failure semantics: `exp` and `flow` never die on a failing job.  A
//! panicking seed, a device misfit, or an unroutable seed becomes a
//! structured failure record; the run completes, prints the engine's
//! failure summary on stderr, and the process exits with code 3 when
//! any seed failed.  `--escalate` opts unroutable seeds into the
//! deterministic retry ladder (+25% / +50% channel width, then
//! lookahead-off), `--route-pops-budget N` bounds each route attempt by
//! the deterministic A*-pop odometer, and `--inject-faults <spec>`
//! injects deterministic faults (stage panics, forced non-convergence,
//! cache corruption — see [`double_duty::util::fault`]) to exercise
//! these paths on demand.
//!
//! Mapped netlists and packings persist under `target/dd-cache` so
//! repeated invocations skip the map/pack stages; `--no-disk-cache`
//! keeps a run memory-only, and `--cache-cap-mb N` bounds the store
//! (least-recently-modified artifacts are evicted beyond N MiB).
//! Artifacts that fail their load-time integrity checks are quarantined
//! as `*.quarantine` and reported in the failure summary.

use double_duty::arch::ArchVariant;
use double_duty::bench_suites::{all_suites, BenchParams};
use double_duty::check::{self, CheckMode, Severity};
use double_duty::coordinator::default_workers;
use double_duty::flow::engine::{process_failures, ArtifactCache, Engine, ExperimentPlan};
use double_duty::flow::FlowOpts;
use double_duty::report::{self, ExpOpts};
use double_duty::serve::{ServeOpts, Server};
use double_duty::util::fault::FaultPlan;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args[1..]),
        "flow" => cmd_flow(&args[1..]),
        "check" => cmd_check(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "list" => cmd_list(),
        "coffe" => {
            report::table1().print();
            println!();
            report::table2().print();
        }
        _ => {
            eprintln!("usage: dduty <exp|flow|check|serve|list|coffe> ...");
            eprintln!("  dduty exp <table1|table2|table3|table4|fig5..fig9|all> [--quick] \
                       [--jobs N] [--route-jobs N] [--lookahead on|off] [--no-disk-cache] \
                       [--cache-cap-mb N] [--check [strict]] [--escalate] \
                       [--inject-faults <spec>]");
            eprintln!("  dduty flow --bench <name> [--variant baseline|dd5|dd6] \
                       [--seed N | --seeds a,b,c] [--no-route] [--jobs N] \
                       [--route-jobs N] [--lookahead on|off] [--no-disk-cache] \
                       [--cache-cap-mb N] [--timing-route] [--sta-every K] \
                       [--crit-alpha A] [--place-crit-alpha A] [--move-mix F] \
                       [--check [strict]] [--escalate] [--route-pops-budget N] \
                       [--inject-faults <spec>]");
            eprintln!("  dduty check [--equiv] [<bench|suite> ...] [--variant baseline|dd5|dd6|all] \
                       [--strict] [--quick] [--no-route] [--route-jobs N] \
                       [--lookahead on|off] [--no-disk-cache] [--cache-cap-mb N]");
            eprintln!("  dduty serve [--addr HOST:PORT] [--jobs N] [--no-disk-cache] \
                       [--cache-cap-mb N]");
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
    // Isolated job failures surface as data, not crashes: the run above
    // completed, but any failed seed makes the invocation exit 3 so
    // scripts and CI can gate on it.
    if process_failures() > 0 {
        std::process::exit(3);
    }
}

/// Numeric worker-count flag (`--jobs` / `--route-jobs`).  A malformed
/// value is a hard error, not a silent fallback.
fn parse_count_flag(args: &[String], flag: &str, default: usize) -> usize {
    let Some(i) = args.iter().position(|a| a == flag) else {
        return default;
    };
    match args.get(i + 1).map(|s| s.parse::<usize>()) {
        Some(Ok(n)) => n.max(1),
        _ => {
            eprintln!("{flag} requires a numeric worker count");
            std::process::exit(2);
        }
    }
}

fn parse_jobs(args: &[String]) -> usize {
    parse_count_flag(args, "--jobs", default_workers())
}

fn parse_route_jobs(args: &[String]) -> usize {
    parse_count_flag(args, "--route-jobs", 1)
}

/// `--sta-every K`: closed-loop STA refresh interval for `--timing-route`
/// (0 = static pre-route weights).  Malformed values are hard errors.
fn parse_sta_every(args: &[String], default: usize) -> usize {
    let Some(i) = args.iter().position(|a| a == "--sta-every") else {
        return default;
    };
    match args.get(i + 1).map(|s| s.parse::<usize>()) {
        Some(Ok(n)) => n,
        _ => {
            eprintln!("--sta-every requires a numeric iteration count (0 = static weights)");
            std::process::exit(2);
        }
    }
}

/// Unit-interval float flag (`--crit-alpha`, `--place-crit-alpha`,
/// `--move-mix`): value must lie in [0, 1].  Malformed or out-of-range
/// values are hard errors.
fn parse_unit_flag(args: &[String], flag: &str, what: &str, default: f64) -> f64 {
    let Some(i) = args.iter().position(|a| a == flag) else {
        return default;
    };
    match args.get(i + 1).map(|s| s.parse::<f64>()) {
        Some(Ok(a)) if (0.0..=1.0).contains(&a) => a,
        _ => {
            eprintln!("{flag} requires {what} in [0, 1]");
            std::process::exit(2);
        }
    }
}

/// `--cache-cap-mb N`: optional byte cap (in MiB) on the persistent
/// artifact store.  Malformed values are hard errors.
fn parse_cache_cap_mb(args: &[String]) -> Option<u64> {
    let i = args.iter().position(|a| a == "--cache-cap-mb")?;
    match args.get(i + 1).map(|s| s.parse::<u64>()) {
        Some(Ok(n)) => Some(n.max(1)),
        _ => {
            eprintln!("--cache-cap-mb requires a numeric size in MiB");
            std::process::exit(2);
        }
    }
}

/// `--lookahead on|off`: toggle the router's precomputed cost-to-target
/// lookahead (default on).  `off` reproduces the legacy Manhattan
/// heuristic and in-terms-order sink routing bit for bit.
fn parse_lookahead(args: &[String]) -> bool {
    let Some(i) = args.iter().position(|a| a == "--lookahead") else {
        return true;
    };
    match args.get(i + 1).map(|s| s.as_str()) {
        Some("on") => true,
        Some("off") => false,
        _ => {
            eprintln!("--lookahead requires on|off");
            std::process::exit(2);
        }
    }
}

/// `--check [strict]`: run the stage auditors on each artifact the flow
/// produces.  Bare `--check` warns (prints violations, continues);
/// `--check strict` fails the run on any Error-severity violation.
fn parse_check_mode(args: &[String]) -> CheckMode {
    let Some(i) = args.iter().position(|a| a == "--check") else {
        return CheckMode::Off;
    };
    match args.get(i + 1).map(|s| s.as_str()) {
        Some("strict") => CheckMode::Strict,
        _ => CheckMode::Warn,
    }
}

/// `--inject-faults <spec>`: deterministic fault injection (see
/// [`double_duty::util::fault`] for the grammar).  A malformed spec is a
/// hard error — it must never silently inject nothing.
fn parse_fault_plan(args: &[String]) -> FaultPlan {
    let Some(i) = args.iter().position(|a| a == "--inject-faults") else {
        return FaultPlan::default();
    };
    let Some(spec) = args.get(i + 1) else {
        eprintln!("--inject-faults requires a spec (e.g. panic:place:*:2)");
        std::process::exit(2);
    };
    match FaultPlan::parse(spec) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("--inject-faults: {e}");
            std::process::exit(2);
        }
    }
}

/// `--route-pops-budget N`: deterministic per-attempt give-up budget on
/// the router's A*-pop odometer (0 = unlimited).  Malformed values are
/// hard errors.
fn parse_pops_budget(args: &[String]) -> usize {
    let Some(i) = args.iter().position(|a| a == "--route-pops-budget") else {
        return 0;
    };
    match args.get(i + 1).map(|s| s.parse::<usize>()) {
        Some(Ok(n)) => n,
        _ => {
            eprintln!("--route-pops-budget requires a numeric pop count (0 = unlimited)");
            std::process::exit(2);
        }
    }
}

fn exp_opts(args: &[String]) -> ExpOpts {
    let mut opts = if args.iter().any(|a| a == "--quick") {
        ExpOpts::quick()
    } else {
        ExpOpts::default()
    };
    opts.jobs = parse_jobs(args);
    opts.route_jobs = parse_route_jobs(args);
    opts.disk_cache = !args.iter().any(|a| a == "--no-disk-cache");
    opts.cache_cap_mb = parse_cache_cap_mb(args);
    opts.check = parse_check_mode(args);
    opts.lookahead = parse_lookahead(args);
    opts.escalate = args.iter().any(|a| a == "--escalate");
    opts.faults = parse_fault_plan(args);
    opts
}

fn cmd_exp(args: &[String]) {
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let opts = exp_opts(args);
    let run_one = |w: &str| match w {
        "table1" => report::table1().print(),
        "table2" => report::table2().print(),
        "table3" => report::table3(&opts).print(),
        "table4" => report::table4(&opts).print(),
        "fig5" => report::fig5(&opts).0.print(),
        "fig6" => report::fig6(&opts).0.print(),
        "fig7" => report::fig7(&opts).print(),
        "fig8" => report::fig8(&opts).0.print(),
        "fig9" => report::fig9().0.print(),
        other => {
            eprintln!("unknown experiment {other}");
            std::process::exit(2);
        }
    };
    if which == "all" {
        for w in ["table1", "table2", "table3", "fig5", "fig6", "fig7", "fig8",
                  "fig9", "table4"] {
            run_one(w);
            println!();
        }
    } else {
        run_one(which);
    }
}

fn cmd_flow(args: &[String]) {
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let bench_name = get("--bench").unwrap_or_else(|| "gemmt-FU-mini".to_string());
    let variant = match get("--variant").as_deref() {
        Some("dd5") => ArchVariant::Dd5,
        Some("dd6") => ArchVariant::Dd6,
        _ => ArchVariant::Baseline,
    };
    let seed: u64 = match get("--seed") {
        None => 1,
        Some(s) => s.parse().unwrap_or_else(|_| {
            eprintln!("--seed expects an integer, got {s:?}");
            std::process::exit(2);
        }),
    };
    let seeds: Vec<u64> = match get("--seeds") {
        None => vec![seed],
        Some(list) => {
            // Reject malformed entries instead of silently dropping them —
            // running on the wrong seed set would look like success.
            let parsed: Result<Vec<u64>, _> =
                list.split(',').map(|t| t.trim().parse::<u64>()).collect();
            match parsed {
                Ok(v) if !v.is_empty() => v,
                _ => {
                    eprintln!("--seeds expects a comma-separated list of integers, got {list:?}");
                    std::process::exit(2);
                }
            }
        }
    };
    let route = !args.iter().any(|a| a == "--no-route");
    let use_kernel = args.iter().any(|a| a == "--kernel");
    let route_timing_weights = args.iter().any(|a| a == "--timing-route");
    let flow_defaults = FlowOpts::default();
    let sta_every = parse_sta_every(args, flow_defaults.sta_every);
    let crit_alpha =
        parse_unit_flag(args, "--crit-alpha", "a smoothing factor", flow_defaults.crit_alpha);
    let place_crit_alpha = parse_unit_flag(
        args,
        "--place-crit-alpha",
        "a smoothing factor",
        flow_defaults.place_crit_alpha,
    );
    let move_mix =
        parse_unit_flag(args, "--move-mix", "a move-mix scale", flow_defaults.move_mix);
    let jobs = parse_jobs(args);
    let route_jobs = parse_route_jobs(args);
    let cache_cap_mb = parse_cache_cap_mb(args);

    let params = BenchParams::default();
    let Some(bench) = all_suites(&params).into_iter().find(|b| b.name == bench_name) else {
        eprintln!("unknown benchmark {bench_name}; see `dduty list`");
        std::process::exit(2);
    };
    let n_seeds = seeds.len();
    let plan = ExperimentPlan {
        benches: vec![bench],
        variants: vec![variant],
        flow: FlowOpts {
            seeds,
            route,
            route_jobs,
            route_timing_weights,
            sta_every,
            crit_alpha,
            place_crit_alpha,
            move_mix,
            use_kernel,
            lookahead: parse_lookahead(args),
            check: parse_check_mode(args),
            escalate: args.iter().any(|a| a == "--escalate"),
            route_pops_budget: parse_pops_budget(args),
            faults: parse_fault_plan(args),
            ..Default::default()
        },
    };
    let disk_cache = !args.iter().any(|a| a == "--no-disk-cache");
    let cache = ArtifactCache::for_cli(disk_cache, cache_cap_mb);
    let r = Engine::with_cache(jobs, cache)
        .run(&plan)
        .pop()
        .and_then(|mut row| row.pop())
        .expect("one grid cell");
    println!("circuit        : {}", r.name);
    println!("architecture   : {}", r.variant.name());
    println!("seeds          : {n_seeds}");
    println!("LUTs / adders  : {} / {}", r.luts, r.adder_bits);
    println!("ALMs / LBs     : {} / {}", r.alms, r.lbs);
    println!("concurrent LUTs: {}", r.concurrent_luts);
    println!("ALM area (MWTA): {:.0}", r.alm_area_mwta);
    println!("CPD            : {:.2} ns  (Fmax {:.1} MHz)", r.cpd_ns, r.fmax_mhz);
    println!("ADP            : {:.0}", r.adp);
    println!("routed         : {} (iters {:.0})", r.routed_ok, r.route_iters);
    if r.failed_seeds > 0 || r.escalations > 0 {
        println!(
            "failed seeds   : {} ({} escalation(s))",
            r.failed_seeds, r.escalations
        );
        for e in &r.errors {
            println!("  {e}");
        }
    }
    if !r.cpd_trace_ns.is_empty() {
        // Closed-loop trajectory: CPD at each STA refresh, then final.
        let trace: Vec<String> = r.cpd_trace_ns.iter().map(|c| format!("{c:.2}")).collect();
        println!("CPD trajectory : {} ns", trace.join(" -> "));
    }
    println!("chain dedup    : {} hits", r.dedup_hits);
}

/// `dduty check`: audit cached (or freshly built) stage artifacts for the
/// selected benchmarks x variants and report every invariant violation.
fn cmd_check(args: &[String]) {
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let strict = args.iter().any(|a| a == "--strict");
    let quick = args.iter().any(|a| a == "--quick");
    let route = !args.iter().any(|a| a == "--no-route");
    let route_jobs = parse_route_jobs(args);
    let cache_cap_mb = parse_cache_cap_mb(args);
    let disk_cache = !args.iter().any(|a| a == "--no-disk-cache");
    let variants: Vec<ArchVariant> = match get("--variant").as_deref() {
        None | Some("all") => vec![ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6],
        Some("baseline") => vec![ArchVariant::Baseline],
        Some("dd5") => vec![ArchVariant::Dd5],
        Some("dd6") => vec![ArchVariant::Dd6],
        Some(other) => {
            eprintln!("unknown variant {other} (expected baseline|dd5|dd6|all)");
            std::process::exit(2);
        }
    };

    // Positional selectors name benchmarks or whole suites; none selects
    // every shipped suite.  Flag values must not read as selectors.
    const VALUE_FLAGS: &[&str] =
        &["--variant", "--jobs", "--route-jobs", "--cache-cap-mb", "--lookahead"];
    let mut selectors: Vec<&str> = Vec::new();
    let mut skip_value = false;
    for a in args {
        if skip_value {
            skip_value = false;
            continue;
        }
        if VALUE_FLAGS.contains(&a.as_str()) {
            skip_value = true;
        } else if !a.starts_with("--") {
            selectors.push(a.as_str());
        }
    }

    let params = BenchParams::default();
    let benches: Vec<_> = all_suites(&params)
        .into_iter()
        .filter(|b| {
            selectors.is_empty()
                || selectors.iter().any(|s| *s == b.name || *s == b.suite.name())
        })
        .collect();
    if benches.is_empty() {
        eprintln!("no benchmark or suite matches; see `dduty list`");
        std::process::exit(2);
    }

    let opts = FlowOpts {
        seeds: vec![1],
        route,
        route_jobs,
        place_effort: if quick { 0.15 } else { 0.5 },
        lookahead: parse_lookahead(args),
        ..Default::default()
    };
    let cache = ArtifactCache::for_cli(disk_cache, cache_cap_mb);

    // `--equiv`: semantic equivalence (map + pack logic neutrality)
    // instead of the structural stage audits.
    if args.iter().any(|a| a == "--equiv") {
        let eopts = check::EquivOpts { jobs: parse_jobs(args), ..Default::default() };
        let mut rows: Vec<report::EquivRow> = Vec::new();
        let (mut errors, mut warnings) = (0usize, 0usize);
        for b in &benches {
            for &variant in &variants {
                let rep = check::check_equiv_benchmark(&cache, b, variant, &opts, &eopts);
                for (view, oc) in [("map", &rep.mapped), ("pack", &rep.packed)] {
                    for v in &oc.violations {
                        println!("equiv {:20} [{:8}] {view}: {v}", b.name, variant.name());
                        match v.severity {
                            Severity::Error => errors += 1,
                            Severity::Warning => warnings += 1,
                        }
                    }
                    rows.push(report::EquivRow {
                        bench: b.name.clone(),
                        variant,
                        view,
                        summary: oc.summary,
                    });
                }
            }
        }
        report::equiv_table(&rows).print();
        println!(
            "equiv: {} benchmark(s) x {} variant(s): {errors} error(s), {warnings} warning(s)",
            benches.len(),
            variants.len()
        );
        if strict && errors > 0 {
            std::process::exit(1);
        }
        return;
    }

    let (mut errors, mut warnings) = (0usize, 0usize);
    for b in &benches {
        for &variant in &variants {
            let report = check::check_benchmark(&cache, b, variant, &opts);
            let status = if report.is_clean() {
                "clean".to_string()
            } else {
                report.summary()
            };
            println!("check {:20} [{:8}] {status}", b.name, variant.name());
            for v in &report.violations {
                println!("  {v}");
                match v.severity {
                    Severity::Error => errors += 1,
                    Severity::Warning => warnings += 1,
                }
            }
        }
    }
    println!(
        "check: {} benchmark(s) x {} variant(s): {errors} error(s), {warnings} warning(s)",
        benches.len(),
        variants.len()
    );
    if strict && errors > 0 {
        std::process::exit(1);
    }
}

/// `dduty serve`: run the resident flow-as-a-service daemon until a
/// `POST /shutdown` drains the queue.  Exit 0 on a clean run, 1 if the
/// shutdown audit ([`check::audit_serve`]) finds a violation, 2 on a
/// bind failure.  Per-job flow failures stay job data (served as JSON);
/// they never touch the process failure count or the exit code.
fn cmd_serve(args: &[String]) {
    let get = |flag: &str| -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let opts = ServeOpts {
        addr: get("--addr").unwrap_or_else(|| "127.0.0.1:7878".to_string()),
        workers: parse_jobs(args),
        disk_cache: !args.iter().any(|a| a == "--no-disk-cache"),
        cache_cap_mb: parse_cache_cap_mb(args),
    };
    let server = match Server::bind(&opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("dd serve: {e}");
            std::process::exit(2);
        }
    };
    println!("dd serve listening on {}", server.addr());
    let summary = server.run();
    println!(
        "dd serve: {} job(s), {} executed, {} dedup hit(s), {} failed",
        summary.jobs, summary.executed, summary.dedup_hits, summary.failed_jobs
    );
    if !summary.violations.is_empty() {
        for v in &summary.violations {
            eprintln!("  {v}");
        }
        eprintln!("dd serve: shutdown audit found {} violation(s)", summary.violations.len());
        std::process::exit(1);
    }
}

fn cmd_list() {
    let params = BenchParams::default();
    for b in all_suites(&params) {
        println!("{:20} [{}]", b.name, b.suite.name());
    }
}
