//! PathFinder negotiated-congestion routing on a grid routing-resource
//! graph.
//!
//! The RR abstraction: every grid corner (x, y) carries `W` horizontal and
//! `W` vertical track nodes.  Horizontal tracks chain along x, vertical
//! along y; turns connect track `t` to `t` and `(t+1) % W` (a Wilton-like
//! twist, so planes are not isolated).  Block output pins reach an
//! `fc_out` fraction of the adjacent tracks, input pins an `fc_in`
//! fraction (selection hashed per block so pins spread over the channel).
//!
//! Classic PathFinder: route every net by A*, then re-route while any node
//! is overused, inflating present-congestion cost and accumulating history
//! cost each iteration.  Produces per-sink routed path lengths (for the
//! post-route STA) and the channel-utilization histogram of Fig. 8.

use std::collections::{BinaryHeap, HashMap};

use crate::arch::device::{Device, Loc};
use crate::arch::Arch;
use crate::netlist::{CellId, NetId};
use crate::place::cost::{NetModel, Term};
use crate::place::Placement;

/// Router options.
#[derive(Clone, Copy, Debug)]
pub struct RouteOpts {
    pub max_iters: usize,
    /// Initial present-congestion factor and its per-iteration growth.
    pub pres_fac0: f64,
    pub pres_mult: f64,
    /// History cost increment per overused node per iteration.
    pub hist_fac: f64,
}

impl Default for RouteOpts {
    fn default() -> Self {
        RouteOpts { max_iters: 45, pres_fac0: 0.5, pres_mult: 1.6, hist_fac: 0.5 }
    }
}

/// Routing result.
#[derive(Clone, Debug)]
pub struct Routing {
    pub success: bool,
    pub iterations: usize,
    /// Per external net: per sink terminal, wire-hop count of its path.
    pub sink_hops: Vec<Vec<(Term, usize)>>,
    /// Occupancy / capacity per channel node (for the Fig. 8 histogram).
    pub channel_util: Vec<f64>,
    /// Total wirelength in hops.
    pub wirelength: usize,
    /// Nodes still overused at exit (0 on success).
    pub overused: usize,
    /// Debug: overused node descriptors (dir, x, y, track, occupancy).
    pub overused_nodes: Vec<(usize, usize, usize, usize, u16)>,
    /// Debug: per-net routed node ids.
    pub net_nodes: Vec<Vec<usize>>,
}

impl Routing {
    /// Fig. 8 histogram: fraction of channel segments per utilization bin.
    pub fn util_histogram(&self, bins: usize) -> Vec<f64> {
        let mut h = vec![0.0; bins];
        if self.channel_util.is_empty() {
            return h;
        }
        for &u in &self.channel_util {
            let b = ((u * bins as f64) as usize).min(bins - 1);
            h[b] += 1.0;
        }
        let total: f64 = h.iter().sum();
        h.iter_mut().for_each(|v| *v /= total);
        h
    }

    /// Routed interconnect delay for a sink with `hops` wire segments.
    pub fn hop_delay(arch: &Arch, hops: usize) -> f64 {
        arch.delays.conn_block
            + (hops as f64 / arch.routing.segment_len as f64).ceil().max(1.0)
                * arch.delays.wire_segment
    }
}

/// Node indexing: dir (0 = H, 1 = V) x width x height x W tracks.
#[derive(Clone, Copy)]
struct Geometry {
    w: usize,
    h: usize,
    tracks: usize,
}

impl Geometry {
    #[inline]
    fn id(&self, dir: usize, x: usize, y: usize, t: usize) -> usize {
        ((dir * self.h + y) * self.w + x) * self.tracks + t
    }

    #[inline]
    fn decode(&self, id: usize) -> (usize, usize, usize, usize) {
        let t = id % self.tracks;
        let rest = id / self.tracks;
        let x = rest % self.w;
        let rest = rest / self.w;
        let y = rest % self.h;
        let dir = rest / self.h;
        (dir, x, y, t)
    }

    fn num_nodes(&self) -> usize {
        2 * self.w * self.h * self.tracks
    }

    /// Manhattan distance heuristic from node to target location.
    #[inline]
    fn heur(&self, id: usize, tx: usize, ty: usize) -> f64 {
        let (_, x, y, _) = self.decode(id);
        ((x as i64 - tx as i64).abs() + (y as i64 - ty as i64).abs()) as f64
    }
}

#[derive(PartialEq)]
struct QItem {
    prio: f64,
    cost: f64,
    node: usize,
}
impl Eq for QItem {}
impl Ord for QItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.prio.partial_cmp(&self.prio).unwrap_or(std::cmp::Ordering::Equal)
    }
}
impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Channel nodes a block pin can reach: a hashed `frac` subset of the
/// tracks, spread over the four channel corners adjacent to the block
/// (blocks have pins on all sides, so their taps must not pile onto a
/// single grid point).
fn pin_nodes(geo: &Geometry, loc: Loc, frac: f64, salt: u64) -> Vec<usize> {
    let tracks = geo.tracks;
    let n = ((tracks as f64 * frac).ceil() as usize).clamp(2, tracks) * 2;
    let mut v = Vec::with_capacity(n);
    let mut x = (loc.x as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((loc.y as u64).wrapping_mul(0xBF58476D1CE4E5B9))
        .wrapping_add(salt);
    let corners = [
        (loc.x as usize, loc.y as usize),
        (loc.x.saturating_sub(1) as usize, loc.y as usize),
        (loc.x as usize, loc.y.saturating_sub(1) as usize),
        (loc.x.saturating_sub(1) as usize, loc.y.saturating_sub(1) as usize),
    ];
    for _ in 0..n {
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        let t = (x % tracks as u64) as usize;
        let (cx, cy) = corners[((x >> 17) % 4) as usize];
        let dir = ((x >> 33) & 1) as usize;
        if cx < geo.w && cy < geo.h {
            v.push(geo.id(dir, cx, cy, t));
        }
    }
    v.sort_unstable();
    v.dedup();
    v
}

/// Route a placed design.
pub fn route(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    opts: &RouteOpts,
) -> Routing {
    let device = &placement.device;
    let geo = Geometry {
        w: device.width() as usize,
        h: device.height() as usize,
        tracks: arch.routing.channel_width as usize,
    };
    let n_nodes = geo.num_nodes();

    let term_loc = |t: Term| -> Loc {
        match t {
            Term::Lb(i) => placement.lb_loc[i],
            Term::Io(c) => placement.io_loc[&c],
        }
    };

    // Per-net terminals (source first).
    let nets: Vec<(NetId, Vec<Term>)> = model
        .nets
        .iter()
        .map(|en| (en.net, en.terms.clone()))
        .collect();

    let mut occ = vec![0u16; n_nodes];
    let mut hist = vec![0.0f32; n_nodes];
    // Per net: routed node set (tree) and per-sink paths.
    let mut net_nodes: Vec<Vec<usize>> = vec![Vec::new(); nets.len()];
    let mut sink_hops: Vec<Vec<(Term, usize)>> = vec![Vec::new(); nets.len()];

    let mut pres_fac = opts.pres_fac0;
    let mut iterations = 0;
    let mut success = false;

    // A* state arrays, reused across searches.
    let mut cost_arr = vec![f64::INFINITY; n_nodes];
    let mut prev = vec![usize::MAX; n_nodes];
    let mut touched: Vec<usize> = Vec::new();

    for iter in 0..opts.max_iters {
        iterations = iter + 1;
        // First iteration routes everything; later iterations rip up and
        // re-route only nets touching overused nodes (VPR's incremental
        // rip-up — the bulk of nets keep their legal routes).
        let congested: Vec<bool> = if iter == 0 {
            vec![true; nets.len()]
        } else {
            net_nodes
                .iter()
                .map(|ns| ns.iter().any(|&n| occ[n] as f64 > arch_cap()))
                .collect()
        };
        for (ni, (_, terms)) in nets.iter().enumerate() {
            if !congested[ni] {
                continue;
            }
            // Rip up.
            for &n in &net_nodes[ni] {
                occ[n] = occ[n].saturating_sub(1);
            }
            net_nodes[ni].clear();
            sink_hops[ni].clear();

            let src_loc = term_loc(terms[0]);
            let src_nodes = pin_nodes(&geo, src_loc, arch.routing.fc_out,
                                      17 + 131 * ni as u64);

            // Route tree as a set of nodes with hop-distance from source.
            // Seeds (source track taps) are search entry points but only
            // nodes actually used by a sink path get committed.
            let mut tree: HashMap<usize, usize> = HashMap::new(); // node -> hops
            let mut used: Vec<usize> = Vec::new();
            for &id in &src_nodes {
                tree.insert(id, 0);
            }

            for &sink in &terms[1..] {
                let dst_loc = term_loc(sink);
                let dst_nodes = pin_nodes(&geo, dst_loc, arch.routing.fc_in,
                                          71 + 131 * ni as u64);
                // Target node set.
                let mut is_target = HashMap::new();
                for &id in &dst_nodes {
                    is_target.insert(id, ());
                }

                // A* from the current tree.
                let mut heap: BinaryHeap<QItem> = BinaryHeap::new();
                for &n in touched.iter() {
                    cost_arr[n] = f64::INFINITY;
                    prev[n] = usize::MAX;
                }
                touched.clear();
                let mut seeds: Vec<(usize, usize)> =
                    tree.iter().map(|(&n, &h)| (n, h)).collect();
                seeds.sort_unstable(); // deterministic A* tie-breaking
                for (n, hops) in seeds {
                    // Fresh source taps pay their own congestion cost
                    // (otherwise a net would happily start on an occupied
                    // tap it never perceives); nodes already on this net's
                    // committed tree re-enter free.
                    let entry = if hops == 0 && !net_nodes[ni].contains(&n) {
                        let over = (occ[n] as f64 + 1.0 - arch_cap()).max(0.0);
                        (1.0 + hist[n] as f64) * (1.0 + over * pres_fac)
                    } else {
                        0.0
                    };
                    cost_arr[n] = entry;
                    prev[n] = usize::MAX;
                    touched.push(n);
                    heap.push(QItem {
                        prio: entry + geo.heur(n, dst_loc.x as usize, dst_loc.y as usize),
                        cost: entry,
                        node: n,
                    });
                }
                let mut found = usize::MAX;
                while let Some(QItem { cost, node, .. }) = heap.pop() {
                    if cost > cost_arr[node] {
                        continue;
                    }
                    if is_target.contains_key(&node) {
                        found = node;
                        break;
                    }
                    let (dir, x, y, t) = geo.decode(node);
                    let mut push = |nid: usize, heap: &mut BinaryHeap<QItem>,
                                    cost_arr: &mut Vec<f64>, prev: &mut Vec<usize>,
                                    touched: &mut Vec<usize>| {
                        // PathFinder node cost.
                        let over = (occ[nid] as f64 + 1.0
                            - arch_cap())
                            .max(0.0);
                        let c_node = (1.0 + hist[nid] as f64) * (1.0 + over * pres_fac);
                        let nc = cost + c_node;
                        if nc < cost_arr[nid] {
                            if cost_arr[nid].is_infinite() && prev[nid] == usize::MAX {
                                touched.push(nid);
                            }
                            cost_arr[nid] = nc;
                            prev[nid] = node;
                            heap.push(QItem {
                                // VPR's astar_fac: inflate the admissible
                                // heuristic for a large search-space cut at
                                // bounded routing-cost suboptimality.
                                prio: nc + 1.3 * geo.heur(nid, dst_loc.x as usize,
                                                          dst_loc.y as usize),
                                cost: nc,
                                node: nid,
                            });
                        }
                    };
                    if dir == 0 {
                        // Horizontal: extend along x; turn onto V at (x, y).
                        if x + 1 < geo.w {
                            push(geo.id(0, x + 1, y, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        }
                        if x > 0 {
                            push(geo.id(0, x - 1, y, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        }
                        push(geo.id(1, x, y, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        push(geo.id(1, x, y, (t + 1) % geo.tracks), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                    } else {
                        if y + 1 < geo.h {
                            push(geo.id(1, x, y + 1, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        }
                        if y > 0 {
                            push(geo.id(1, x, y - 1, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        }
                        push(geo.id(0, x, y, t), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                        push(geo.id(0, x, y, (t + 1) % geo.tracks), &mut heap, &mut cost_arr, &mut prev, &mut touched);
                    }
                }

                if found == usize::MAX {
                    // Unroutable sink this iteration; count as overuse and
                    // keep going (pressure will reshape other nets).
                    sink_hops[ni].push((sink, (src_loc.dist(dst_loc) as usize).max(1)));
                    continue;
                }
                // Walk back, add path to tree.
                let mut path = Vec::new();
                let mut cur = found;
                while cur != usize::MAX && !tree.contains_key(&cur) {
                    path.push(cur);
                    cur = prev[cur];
                }
                let base_hops = if cur == usize::MAX { 0 } else { tree[&cur] };
                // The attachment node is used (it may be a fresh seed tap).
                if cur != usize::MAX {
                    used.push(cur);
                }
                let hops = base_hops + path.len();
                sink_hops[ni].push((sink, hops));
                for (off, &n) in path.iter().rev().enumerate() {
                    tree.insert(n, base_hops + off + 1);
                    used.push(n);
                }
            }

            // Commit occupancy for path nodes only (dedup).
            used.sort_unstable();
            used.dedup();
            for &n in &used {
                occ[n] += 1;
                net_nodes[ni].push(n);
            }
        }

        // Overuse accounting.
        let mut overused = 0usize;
        for n in 0..n_nodes {
            if occ[n] as f64 > arch_cap() {
                overused += 1;
                hist[n] += opts.hist_fac as f32;
            }
        }
        if overused == 0 {
            success = true;
            break;
        }
        pres_fac *= opts.pres_mult;
    }

    let overused = occ.iter().filter(|&&o| o as f64 > arch_cap()).count();
    let overused_nodes: Vec<(usize, usize, usize, usize, u16)> = occ
        .iter()
        .enumerate()
        .filter(|&(_, &o)| o as f64 > arch_cap())
        .map(|(id, &o)| {
            let (d, x, y, t) = geo.decode(id);
            (d, x, y, t, o)
        })
        .collect();

    // Channel utilization: average occupancy per channel segment (all W
    // tracks of one direction at one grid point form a "channel").
    let mut channel_util = Vec::with_capacity(2 * geo.w * geo.h);
    for dir in 0..2 {
        for y in 0..geo.h {
            for x in 0..geo.w {
                let used: usize = (0..geo.tracks)
                    .filter(|&t| occ[geo.id(dir, x, y, t)] > 0)
                    .count();
                channel_util.push(used as f64 / geo.tracks as f64);
            }
        }
    }

    let wirelength = occ.iter().map(|&o| o as usize).sum();

    Routing { success, iterations, sink_hops, channel_util, wirelength, overused, overused_nodes, net_nodes }
}

/// Per-track capacity (1 wire per track node).
#[inline]
fn arch_cap() -> f64 {
    1.0
}

/// Per-net, per-sink routed delays for post-route STA.
pub fn routed_net_delay<'a>(
    routing: &'a Routing,
    model: &'a NetModel,
    arch: &'a Arch,
) -> impl Fn(NetId, CellId, u8) -> f64 + 'a {
    // net -> (ExtNet index) for lookup.
    let mut by_net: HashMap<NetId, usize> = HashMap::new();
    for (i, en) in model.nets.iter().enumerate() {
        by_net.insert(en.net, i);
    }
    move |net: NetId, sink: CellId, _pin: u8| -> f64 {
        let Some(&i) = by_net.get(&net) else { return 0.0 };
        // Per-sink routed hops: the sink cell's terminal identifies which
        // branch of the route tree it rides. Cells without a terminal
        // (intra-LB) and IO sinks fall back to the worst branch.
        let hops = match model.term_of_cell(sink) {
            Some(t) => routing.sink_hops[i]
                .iter()
                .find(|&&(st, _)| st == t)
                .map(|&(_, h)| h)
                .unwrap_or_else(|| {
                    routing.sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0)
                }),
            None => routing.sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0),
        };
        if hops == 0 {
            return 0.0;
        }
        Routing::hop_delay(arch, hops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::place::{place, PlaceOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn routed(w: usize) -> (Routing, NetModel, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        let r = route(&model, &pl, &arch, &RouteOpts::default());
        (r, model, arch)
    }

    #[test]
    fn routes_small_multiplier() {
        let (r, model, _) = routed(5);
        assert!(r.success, "unrouted after {} iters ({} overused)", r.iterations, r.overused);
        assert_eq!(r.sink_hops.len(), model.num_nets());
        // Every sink of every net has a path.
        for (i, en) in model.nets.iter().enumerate() {
            assert_eq!(r.sink_hops[i].len(), en.terms.len() - 1);
        }
        assert!(r.wirelength > 0);
    }

    #[test]
    fn histogram_normalized() {
        let (r, _, _) = routed(5);
        let h = r.util_histogram(10);
        assert_eq!(h.len(), 10);
        let sum: f64 = h.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hop_delay_monotone() {
        let arch = Arch::paper(ArchVariant::Baseline);
        assert!(Routing::hop_delay(&arch, 8) > Routing::hop_delay(&arch, 2));
    }

    #[test]
    fn tight_channel_increases_congestion() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let mut arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        arch.routing.channel_width = 48;
        let wide = route(&model, &pl, &arch, &RouteOpts::default());
        arch.routing.channel_width = 12;
        let narrow = route(&model, &pl, &arch, &RouteOpts::default());
        let mean_u = |r: &Routing| {
            r.channel_util.iter().sum::<f64>() / r.channel_util.len() as f64
        };
        assert!(mean_u(&narrow) > mean_u(&wide));
    }
}
