//! Grid device model: a W x H array of logic-block tiles ringed by I/O.
//!
//! Mirrors VPR's auto-sized square device: given a packed design, the
//! smallest grid that fits its LB and I/O demand (plus a utilization
//! margin) is chosen.  Carry chains that span LBs must occupy vertically
//! adjacent tiles, so chain macros constrain legal placements.

/// A physical location: `(x, y)` tile coordinates. I/O lives on the
/// perimeter ring (x or y == 0 or max); logic tiles fill the interior.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Loc {
    pub x: u16,
    pub y: u16,
}

impl Loc {
    pub fn new(x: u16, y: u16) -> Self {
        Loc { x, y }
    }

    /// Manhattan distance between two locations.
    pub fn dist(self, other: Loc) -> u32 {
        (self.x.abs_diff(other.x) as u32) + (self.y.abs_diff(other.y) as u32)
    }
}

/// The FPGA device grid.
#[derive(Clone, Debug)]
pub struct Device {
    /// Interior logic columns (x in 1..=lb_cols).
    pub lb_cols: u16,
    /// Interior logic rows (y in 1..=lb_rows).
    pub lb_rows: u16,
    /// I/O pad capacity per perimeter tile.
    pub io_per_tile: u16,
}

impl Device {
    pub fn new(lb_cols: u16, lb_rows: u16) -> Self {
        Device { lb_cols, lb_rows, io_per_tile: 8 }
    }

    /// Smallest square device fitting `lbs` logic blocks and `ios` pads,
    /// with a packing margin (VPR defaults to ~around 1.0 for fixed-size
    /// runs; we leave a small slack so placement has freedom).
    pub fn auto_size(lbs: usize, ios: usize, margin: f64) -> Self {
        let mut n = 2u16;
        loop {
            let d = Device::new(n, n);
            if d.lb_capacity() as f64 >= lbs as f64 * margin
                && d.io_capacity() >= ios
            {
                return d;
            }
            n += 1;
            assert!(n < 2000, "device would exceed 2000x2000");
        }
    }

    pub fn lb_capacity(&self) -> usize {
        self.lb_cols as usize * self.lb_rows as usize
    }

    pub fn io_capacity(&self) -> usize {
        // Perimeter ring around the (cols+2) x (rows+2) grid, corners excluded.
        2 * (self.lb_cols as usize + self.lb_rows as usize) * self.io_per_tile as usize
    }

    /// Full grid width including I/O ring.
    pub fn width(&self) -> u16 {
        self.lb_cols + 2
    }

    pub fn height(&self) -> u16 {
        self.lb_rows + 2
    }

    /// Is `loc` an interior logic tile?
    pub fn is_lb(&self, loc: Loc) -> bool {
        (1..=self.lb_cols).contains(&loc.x) && (1..=self.lb_rows).contains(&loc.y)
    }

    /// Is `loc` on the I/O perimeter?
    pub fn is_io(&self, loc: Loc) -> bool {
        let on_x_edge = loc.x == 0 || loc.x == self.lb_cols + 1;
        let on_y_edge = loc.y == 0 || loc.y == self.lb_rows + 1;
        (on_x_edge || on_y_edge) && loc.x <= self.lb_cols + 1 && loc.y <= self.lb_rows + 1
    }

    /// All interior logic tile locations, row-major.
    pub fn lb_locs(&self) -> Vec<Loc> {
        let mut v = Vec::with_capacity(self.lb_capacity());
        for y in 1..=self.lb_rows {
            for x in 1..=self.lb_cols {
                v.push(Loc::new(x, y));
            }
        }
        v
    }

    /// All perimeter I/O tile locations (corners excluded).
    pub fn io_locs(&self) -> Vec<Loc> {
        let mut v = Vec::new();
        for x in 1..=self.lb_cols {
            v.push(Loc::new(x, 0));
            v.push(Loc::new(x, self.lb_rows + 1));
        }
        for y in 1..=self.lb_rows {
            v.push(Loc::new(0, y));
            v.push(Loc::new(self.lb_cols + 1, y));
        }
        v
    }

    /// Can a vertical chain macro of `len` LBs start at `loc`?
    pub fn chain_fits(&self, loc: Loc, len: u16) -> bool {
        self.is_lb(loc) && loc.y + len - 1 <= self.lb_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_size_fits() {
        let d = Device::auto_size(100, 40, 1.1);
        assert!(d.lb_capacity() >= 110);
        assert!(d.io_capacity() >= 40);
    }

    #[test]
    fn loc_classification() {
        let d = Device::new(4, 4);
        assert!(d.is_lb(Loc::new(1, 1)));
        assert!(d.is_lb(Loc::new(4, 4)));
        assert!(!d.is_lb(Loc::new(0, 1)));
        assert!(!d.is_lb(Loc::new(5, 1)));
        assert!(d.is_io(Loc::new(0, 2)));
        assert!(d.is_io(Loc::new(2, 5)));
        assert!(!d.is_io(Loc::new(2, 2)));
    }

    #[test]
    fn loc_lists_consistent() {
        let d = Device::new(3, 5);
        assert_eq!(d.lb_locs().len(), 15);
        assert!(d.lb_locs().iter().all(|&l| d.is_lb(l)));
        assert!(d.io_locs().iter().all(|&l| d.is_io(l)));
        assert_eq!(d.io_locs().len(), 2 * (3 + 5));
    }

    #[test]
    fn chain_fit() {
        let d = Device::new(4, 4);
        assert!(d.chain_fits(Loc::new(2, 1), 4));
        assert!(!d.chain_fits(Loc::new(2, 2), 4));
    }

    #[test]
    fn manhattan() {
        assert_eq!(Loc::new(1, 1).dist(Loc::new(4, 3)), 5);
    }
}
