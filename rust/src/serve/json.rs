//! Minimal strict JSON parser for the daemon's request bodies.
//!
//! std-only and deliberately tiny: numbers are `f64`, objects are
//! *ordered* `Vec<(String, Json)>` pairs — never a `HashMap`, so nothing
//! here can leak hash-iteration order into responses (the determinism
//! lint bans it) — and any syntax error, duplicate-free guarantee
//! violation, or trailing garbage is a structured `Err(String)` the
//! daemon turns into a 400, never a panic (the panic-hygiene lint covers
//! this module).

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// First value under `key` in an object (objects preserve source
    /// order; lookup is a linear scan).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// Parse one JSON document.  Trailing non-whitespace is an error — a
/// request body is exactly one value.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

/// Nesting cap: a request body has no business being deeper, and the
/// recursive-descent parser must not let a hostile body overflow the
/// daemon's stack.
const MAX_DEPTH: usize = 32;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected {:?} at byte {}", b as char, self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("invalid number at byte {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("invalid number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes up to the next quote/escape.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| format!("invalid UTF-8 in string at byte {start}"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| "invalid \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("invalid \\u escape {hex:?}"))?;
                            let c = char::from_u32(code)
                                .ok_or_else(|| format!("\\u{hex} is not a scalar value"))?;
                            out.push(c);
                            self.pos += 4;
                        }
                        _ => return Err(format!("invalid escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                _ => return Err("unterminated string".to_string()),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut items: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(items));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if items.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate key {key:?}"));
            }
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            items.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(items));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".to_string()));
        let v = parse(r#"{"bench": "x", "seeds": [1, 2], "route": false}"#).unwrap();
        assert_eq!(v.get("bench").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("seeds").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert_eq!(v.get("route").and_then(Json::as_bool), Some(false));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn preserves_object_order() {
        let v = parse(r#"{"b": 1, "a": 2}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["b", "a"]);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "{\"a\": 1,}", "nul", "1 2",
            "{\"a\": 1, \"a\": 2}", "\"unterminated", "\"bad \\q escape\"",
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
        // Hostile nesting must error, not overflow the stack.
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err());
    }
}
