//! Bench harness regenerating the paper's Fig. 8 (channel utilization histogram).
//! Run: cargo bench --bench fig8_congestion   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    report::fig8(&opts).0.print();
    println!();
    println!("[fig8_congestion] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
