//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! placer move evaluation, router A*, packer, mapper, and the PJRT kernel
//! evaluation latency. No criterion offline — simple timed loops with
//! enough iterations for stable medians.
use std::time::Instant;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::coordinator::default_workers;
use double_duty::flow::engine::{Engine, ExperimentPlan};
use double_duty::flow::FlowOpts;
use double_duty::pack::{pack, PackOpts};
use double_duty::place::cost::NetModel;
use double_duty::place::{place, PlaceOpts};
use double_duty::route::{route, RouteOpts};
use double_duty::techmap::{map_circuit, MapOpts};

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    if per > 0.1 {
        println!("{name:<28} {:>10.1} ms/iter", per * 1e3);
    } else {
        println!("{name:<28} {:>10.1} us/iter", per * 1e6);
    }
}

fn main() {
    let params = BenchParams::default();
    let bench = &kratos_suite(&params)[2];
    let circ = bench.generate();
    let arch = Arch::coffe(ArchVariant::Dd5);

    timed("synth+map gemmt", 5, || {
        let c = bench.generate();
        let _ = map_circuit(&c, &MapOpts::default());
    });

    let nl = map_circuit(&circ, &MapOpts::default());
    timed("pack gemmt", 10, || {
        let _ = pack(&nl, &arch, &PackOpts::default());
    });

    let packing = pack(&nl, &arch, &PackOpts::default());
    timed("place gemmt (effort 0.3)", 3, || {
        let _ = place(&nl, &packing, &arch,
                      &PlaceOpts { effort: 0.3, ..Default::default() });
    });

    let pl = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() });
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);
    timed("route gemmt", 3, || {
        let _ = route(&model, &pl, &arch, &RouteOpts::default());
    });

    timed("full_cost (rust)", 200, || {
        let _ = model.full_cost(&pl.lb_loc, &pl.io_loc);
    });
    let moved = [(0usize, double_duty::arch::device::Loc::new(2, 2))];
    timed("move_delta (rust)", 20_000, || {
        let _ = model.move_delta(&pl.lb_loc, &pl.io_loc, &moved);
    });

    match double_duty::place::kernel_accel::KernelCost::try_new(model.num_nets()) {
        Ok(mut k) => {
            timed("full_cost+congestion (PJRT)", 50, || {
                let _ = k.evaluate(&model, &pl.lb_loc, &pl.io_loc, &pl.device).unwrap();
            });
        }
        Err(e) => println!("PJRT kernel unavailable: {e}"),
    }

    timed("sta gemmt", 50, || {
        let _ = double_duty::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
    });

    // Experiment-engine sweep: the paper-style grid (Kratos suite x
    // {baseline, DD5} x 3 seeds), serial vs parallel.  Both runs start
    // with a cold cache; results must match bit-for-bit (the engine's
    // determinism contract), so the wall-clock delta is pure scheduling.
    let sweep = ExperimentPlan {
        benches: kratos_suite(&params),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.15,
            route: false,
            ..Default::default()
        },
    };
    let grid_cells = sweep.benches.len() * sweep.variants.len() * sweep.flow.seeds.len();
    // Warm the process-wide COFFE sizing cache for every swept variant so
    // neither timed run pays the one-time Arch::coffe cost.
    for &v in &sweep.variants {
        let _ = Arch::coffe(v);
    }
    let t0 = Instant::now();
    let serial = Engine::new(1).run(&sweep);
    let t_serial = t0.elapsed().as_secs_f64();

    let workers = default_workers();
    let engine = Engine::new(workers);
    let t1 = Instant::now();
    let parallel = engine.run(&sweep);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.iter().flatten().zip(parallel.iter().flatten()) {
        assert!(
            a.alms == b.alms && a.cpd_ns == b.cpd_ns && a.adp == b.adp,
            "parallel engine diverged from serial on {}",
            a.name
        );
    }
    let st = &engine.cache.stats;
    use std::sync::atomic::Ordering::Relaxed;
    println!("engine sweep ({grid_cells} cells)  serial {t_serial:>8.2} s");
    println!(
        "engine sweep ({grid_cells} cells)  x{workers:<2} jobs {t_parallel:>6.2} s  ({:.2}x speedup)",
        t_serial / t_parallel.max(1e-9)
    );
    println!(
        "artifact cache: map {} misses / {} hits, pack {} misses / {} hits",
        st.map_misses.load(Relaxed),
        st.map_hits.load(Relaxed),
        st.pack_misses.load(Relaxed),
        st.pack_hits.load(Relaxed)
    );
}
