//! Transistor sizing by coordinate descent — the COFFE loop.
//!
//! COFFE alternates HSPICE evaluation with per-transistor width updates
//! until the objective converges.  We do the same over the Elmore model:
//! sweep each width over a discrete grid, keep the best, repeat until a
//! full pass makes no change.  Two objectives mirror COFFE's behaviour the
//! paper leans on (§III-B): the local crossbar is on the critical LUT path
//! and gets sized for *delay*; the AddMux crossbar has slack (the Z path is
//! short) and gets sized for *area·delay²* — which is exactly why the paper
//! observes the smaller AddMux crossbar ends up *slower* than the local
//! crossbar.

/// Sizing objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Minimize delay (aggressive, critical-path components).
    Delay,
    /// Minimize area * delay^2 (lazy, slack-tolerant components).
    AreaDelaySq,
}

/// Discrete width grid COFFE-style sizing explores.
pub const WIDTH_GRID: [f64; 10] = [0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0];

/// Coordinate-descent sizing over `n` widths.
///
/// `eval(widths) -> (delay_ps, area_mwta)`; returns the optimized widths.
pub fn size_circuit<F>(n: usize, objective: Objective, eval: F) -> Vec<f64>
where
    F: Fn(&[f64]) -> (f64, f64),
{
    let score = |d: f64, a: f64| match objective {
        // "Delay" still carries a weak area term (COFFE optimizes tile
        // area x delay; pure delay would blow widths to the grid edge).
        Objective::Delay => a * d * d * d,
        Objective::AreaDelaySq => a * d,
    };
    let mut w = vec![1.0; n];
    let (d0, a0) = eval(&w);
    let mut best = score(d0, a0);
    // Converges in a handful of passes on these 3-5 variable circuits; the
    // pass cap guards against grid-edge oscillation.
    for _pass in 0..12 {
        let mut changed = false;
        for i in 0..n {
            let keep = w[i];
            let mut best_w = keep;
            for &cand in WIDTH_GRID.iter() {
                if (cand - keep).abs() < 1e-12 {
                    continue;
                }
                w[i] = cand;
                let (d, a) = eval(&w);
                let s = score(d, a);
                if s < best - 1e-12 {
                    best = s;
                    best_w = cand;
                }
            }
            if (best_w - keep).abs() > 1e-12 {
                changed = true;
            }
            w[i] = best_w;
        }
        if !changed {
            break;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coffe::mux::Mux;
    use crate::coffe::rc::Tech;

    fn eval_mux(n_inputs: usize, t: &Tech, w: &[f64]) -> (f64, f64) {
        let mut m = Mux::new(n_inputs);
        m.w = [w[0], w[1], w[2], w[3]];
        (m.delay_ps(t, 500.0, 5.0), m.area_mwta(t))
    }

    #[test]
    fn delay_objective_beats_unit_sizing() {
        let t = Tech::n20();
        let w = size_circuit(4, Objective::Delay, |w| eval_mux(16, &t, w));
        let (d_opt, _) = eval_mux(16, &t, &w);
        let (d_unit, _) = eval_mux(16, &t, &[1.0, 1.0, 1.0, 2.0]);
        assert!(d_opt <= d_unit);
    }

    #[test]
    fn lazy_objective_yields_smaller_slower_circuit() {
        let t = Tech::n20();
        let w_fast = size_circuit(4, Objective::Delay, |w| eval_mux(16, &t, w));
        let w_lazy = size_circuit(4, Objective::AreaDelaySq, |w| eval_mux(16, &t, w));
        let (d_fast, a_fast) = eval_mux(16, &t, &w_fast);
        let (d_lazy, a_lazy) = eval_mux(16, &t, &w_lazy);
        assert!(a_lazy <= a_fast);
        assert!(d_lazy >= d_fast);
    }

    #[test]
    fn deterministic() {
        let t = Tech::n20();
        let w1 = size_circuit(4, Objective::Delay, |w| eval_mux(10, &t, w));
        let w2 = size_circuit(4, Objective::Delay, |w| eval_mux(10, &t, w));
        assert_eq!(w1, w2);
    }
}
