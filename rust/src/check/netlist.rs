//! Netlist lint: structural legality of the mapped IR, plus the
//! combinational-loop witness.
//!
//! The levelization in [`NetlistIndex`] is built by a Kahn pass whose
//! cycle detection is only a `debug_assert` — release builds would
//! silently mis-level a cyclic netlist.  The auditor therefore treats the
//! levelization as a *witness* and re-verifies it edge by edge: every
//! combinational edge (non-FF driver → non-FF sink) must strictly
//! increase the level, and the topological order must cover every cell
//! exactly once.  A cycle cannot satisfy both, so a clean audit proves
//! acyclicity without re-running the producer's traversal.

use std::collections::HashMap;

use crate::netlist::{CellKind, Netlist, NetlistIndex, NO_NET};

use super::{Severity, Stage, Violation};

fn err(code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Netlist, Severity::Error, code, location, message)
}

/// Audit a mapped netlist.  Scan order: cells ascending (pin shapes,
/// dangling inputs), nets ascending (driver/sink consistency), chains
/// ascending (carry continuity), then the levelization witness.
pub fn audit_netlist(nl: &Netlist, idx: &NetlistIndex) -> Vec<Violation> {
    let mut out = Vec::new();

    // --- Pin shapes + dangling inputs (cells ascending). -----------------
    for (ci, cell) in nl.cells.iter().enumerate() {
        let (want_ins, want_outs) = match cell.kind {
            CellKind::Input => (0usize, 1usize),
            CellKind::Output => (1, 0),
            CellKind::Lut { k, .. } => (k as usize, 1),
            CellKind::AdderBit { .. } => (3, 2),
            CellKind::Ff => (1, 1),
            CellKind::Const(_) => (0, 1),
        };
        if cell.ins.len() != want_ins || cell.outs.len() != want_outs {
            out.push(err(
                "netlist.pin-shape",
                format!("cell {ci}"),
                format!(
                    "{:?} has {}/{} in/out pins, expected {want_ins}/{want_outs}",
                    cell.kind,
                    cell.ins.len(),
                    cell.outs.len()
                ),
            ));
        }
        if let CellKind::Lut { k, truth } = cell.kind {
            if k > 6 {
                out.push(err(
                    "netlist.pin-shape",
                    format!("cell {ci}"),
                    format!("LUT width k={k} exceeds the 6-input ALM LUT"),
                ));
            } else if (1..6).contains(&k) && truth >= (1u64 << (1u32 << k)) {
                out.push(err(
                    "netlist.pin-shape",
                    format!("cell {ci}"),
                    format!("truth table {truth:#x} wider than 2^{}", 1u32 << k),
                ));
            }
        }
        for (pin, &net) in cell.ins.iter().enumerate() {
            if net == NO_NET {
                out.push(err(
                    "netlist.dangling-input",
                    format!("cell {ci} pin {pin}"),
                    format!("{:?} input pin {pin} is unconnected", cell.kind),
                ));
            } else if net as usize >= nl.nets.len() {
                out.push(err(
                    "netlist.dangling-input",
                    format!("cell {ci} pin {pin}"),
                    format!("input pin {pin} references net {net} out of range"),
                ));
            }
        }
        for (pin, &net) in cell.outs.iter().enumerate() {
            if net != NO_NET && net as usize >= nl.nets.len() {
                out.push(err(
                    "netlist.dangling-input",
                    format!("cell {ci} out {pin}"),
                    format!("output pin {pin} references net {net} out of range"),
                ));
            }
        }
    }

    // --- Driver / sink consistency (nets ascending). ---------------------
    // Recompute each net's driver count from the cell side: the stored
    // `net.driver` must be the unique producing pin.
    let mut drive_count: Vec<u32> = vec![0; nl.nets.len()];
    for cell in &nl.cells {
        for &net in &cell.outs {
            if net != NO_NET && (net as usize) < nl.nets.len() {
                drive_count[net as usize] += 1;
            }
        }
    }
    for (ni, net) in nl.nets.iter().enumerate() {
        if drive_count[ni] > 1 {
            out.push(err(
                "netlist.multi-driven",
                format!("net {ni}"),
                format!("driven by {} output pins", drive_count[ni]),
            ));
        }
        match net.driver {
            Some((c, p)) => {
                let ok = (c as usize) < nl.cells.len()
                    && nl.cells[c as usize].outs.get(p as usize).copied() == Some(ni as u32);
                if !ok {
                    out.push(err(
                        "netlist.multi-driven",
                        format!("net {ni}"),
                        format!("stored driver (cell {c} pin {p}) does not drive this net"),
                    ));
                }
            }
            None => {
                if !net.sinks.is_empty() {
                    out.push(err(
                        "netlist.undriven",
                        format!("net {ni}"),
                        format!("{} sink(s) but no driver", net.sinks.len()),
                    ));
                }
            }
        }
        for &(c, p) in &net.sinks {
            let ok = (c as usize) < nl.cells.len()
                && nl.cells[c as usize].ins.get(p as usize).copied() == Some(ni as u32);
            if !ok {
                out.push(err(
                    "netlist.undriven",
                    format!("net {ni}"),
                    format!("sink backref (cell {c} pin {p}) does not read this net"),
                ));
            }
        }
    }

    // --- Carry-chain continuity (chains ascending). ----------------------
    // Chain bits must occupy positions 0..len contiguously (a gap in `pos`
    // is a chain break), and each bit's cout must drive the next bit's
    // cin through a dedicated two-terminal connection.
    for ch in 0..nl.num_chains {
        let bits = nl.chain_cells(ch);
        let mut pos_seen: HashMap<u32, u32> = HashMap::new();
        for &b in &bits {
            if let CellKind::AdderBit { pos, .. } = nl.cells[b as usize].kind {
                if let Some(prev) = pos_seen.insert(pos, b) {
                    out.push(err(
                        "netlist.chain-break",
                        format!("chain {ch} pos {pos}"),
                        format!("position held by both cell {prev} and cell {b}"),
                    ));
                }
            }
        }
        for (want, &b) in bits.iter().enumerate() {
            if let CellKind::AdderBit { pos, .. } = nl.cells[b as usize].kind {
                if pos as usize != want {
                    out.push(err(
                        "netlist.chain-break",
                        format!("chain {ch}"),
                        format!("position gap: expected pos {want}, found pos {pos} (cell {b})"),
                    ));
                    break; // one gap report per chain; later bits all shift
                }
            }
        }
        for w in bits.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (ka, kb) = (&nl.cells[a as usize], &nl.cells[b as usize]);
            let (Some(&cout), Some(&cin)) = (ka.outs.get(1), kb.ins.get(2)) else {
                continue; // pin-shape violation already reported above
            };
            if cout != cin {
                out.push(err(
                    "netlist.chain-break",
                    format!("chain {ch} cell {a}->{b}"),
                    format!("cout net {cout} does not feed the next bit's cin (net {cin})"),
                ));
            }
        }
    }

    // --- Levelization witness (combinational-loop check). ----------------
    // The topological order must cover every cell exactly once ...
    let mut seen = vec![false; nl.cells.len()];
    let mut dup = false;
    for &c in idx.topo_order() {
        if (c as usize) >= seen.len() || seen[c as usize] {
            dup = true;
            break;
        }
        seen[c as usize] = true;
    }
    if dup || idx.topo_order().len() != nl.cells.len() {
        out.push(err(
            "netlist.comb-loop",
            "topo order".to_string(),
            format!(
                "topological order covers {} of {} cells exactly once: combinational \
                 cycle or stale index",
                idx.topo_order().len(),
                nl.cells.len()
            ),
        ));
    }
    // ... and every combinational edge (non-FF driver -> non-FF sink) must
    // strictly increase the level.  A cycle cannot satisfy this for all
    // of its edges, so this is a complete witness.
    let is_ff = |c: u32| matches!(nl.cells[c as usize].kind, CellKind::Ff);
    for (ni, _) in nl.nets.iter().enumerate() {
        let Some((drv, _)) = idx.driver(ni as u32) else { continue };
        if (drv as usize) >= nl.cells.len() || is_ff(drv) {
            continue;
        }
        for (sink, _pin) in idx.sinks(ni as u32) {
            if (sink as usize) >= nl.cells.len() || is_ff(sink) {
                continue;
            }
            if idx.level(drv) >= idx.level(sink) {
                out.push(err(
                    "netlist.comb-loop",
                    format!("net {ni}"),
                    format!(
                        "combinational edge cell {drv} (level {}) -> cell {sink} (level {}) \
                         does not increase the level",
                        idx.level(drv),
                        idx.level(sink)
                    ),
                ));
            }
        }
    }

    out
}
