//! Lookahead admissibility: the routing lookahead's class distances must
//! never exceed the true congestion-free remaining hop count, re-derived
//! by an independent backward BFS on the forward RRG adjacency.
//!
//! The router prices every A* seed and expansion with
//! [`Lookahead::query`] (scaled by its heuristic factor); A* returns
//! cheapest paths only while the heuristic *underestimates* the true
//! remaining cost.  Because every RRG node costs at least 1.0 under
//! [`crate::rrg::CostState::node_cost`], hop count is the binding lower
//! bound: for a sample of target locations this auditor runs a backward
//! BFS from **every** node on the target's four saturated channel
//! corners — a superset of any sink's actual pin taps, so the BFS
//! distance lower-bounds the tap distance — and flags any node whose
//! lookahead estimate exceeds it.  The BFS walks a reverse adjacency
//! built here from [`RrGraph::neighbors`], sharing none of the map
//! construction code in [`crate::rrg::lookahead`], so a builder bug (or
//! a corrupted disk-cache artifact) cannot self-certify.
//!
//! Scan order: shape first, then sampled targets in fixed corner →
//! center order, nodes ascending within each target; the violation list
//! is capped at [`MAX_REPORTED`] entries with a final summary violation
//! naming the total count.

use crate::rrg::lookahead::Lookahead;
use crate::rrg::RrGraph;

use super::{Severity, Stage, Violation};

/// Cap on individually reported admissibility violations; a corrupted
/// map class typically breaks thousands of (node, target) pairs at once
/// and listing them all would drown the report.
pub const MAX_REPORTED: usize = 16;

fn err(code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Lookahead, Severity::Error, code, location, message)
}

/// Audit `la` against a freshly built `graph`: shape, then sampled
/// admissibility (`la.query(n, tx, ty)` must lower-bound the true hop
/// distance from `n` to the target's corner nodes for every node `n`).
pub fn audit_lookahead(graph: &RrGraph, la: &Lookahead) -> Vec<Violation> {
    let mut out = Vec::new();
    let n_nodes = graph.num_nodes();
    if n_nodes == 0 {
        return out;
    }

    // Recover the grid shape from the CSR itself (the last node id
    // decodes to the maximal coordinate in every dimension) instead of
    // trusting either party's accessors.
    let (_, wx, hy, tt) = graph.decode(n_nodes - 1);
    let (width, height, tracks) = (wx + 1, hy + 1, tt + 1);
    if la.width() != width
        || la.height() != height
        || la.tracks() != tracks
        || la.dist().len() != 2 * width * height
    {
        out.push(err(
            "lookahead.shape",
            "lookahead".to_string(),
            format!(
                "map describes a {}x{} grid with {} tracks ({} classes) but the RRG decodes \
                 to {width}x{height} with {tracks} tracks",
                la.width(),
                la.height(),
                la.tracks(),
                la.dist().len(),
            ),
        ));
        return out; // query() would misdecode node ids below
    }

    // Reverse adjacency, rebuilt here from the forward CSR.
    let mut rev_start: Vec<u32> = vec![0; n_nodes + 1];
    for n in 0..n_nodes {
        for &nb in graph.neighbors(n) {
            rev_start[nb as usize + 1] += 1;
        }
    }
    for i in 0..n_nodes {
        rev_start[i + 1] += rev_start[i];
    }
    let mut rev: Vec<u32> = vec![0; rev_start[n_nodes] as usize];
    let mut cursor = rev_start.clone();
    for n in 0..n_nodes {
        for &nb in graph.neighbors(n) {
            let c = &mut cursor[nb as usize];
            rev[*c as usize] = n as u32;
            *c += 1;
        }
    }

    // Deterministic target sample: the four grid corners plus the
    // center — the extremes exercise the saturated-corner clamping in
    // `query`, the center the generic both-axes case.
    let mut targets: Vec<(usize, usize)> = vec![
        (0, 0),
        (width - 1, 0),
        (0, height - 1),
        (width - 1, height - 1),
        (width / 2, height / 2),
    ];
    targets.dedup();

    let mut reported = 0usize;
    let mut total = 0usize;
    let mut dist: Vec<u32> = Vec::new();
    let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    for &(tx, ty) in &targets {
        // Seed: every node on one of the four saturated corner
        // locations — the superset `pin_nodes` draws sink taps from.
        dist.clear();
        dist.resize(n_nodes, u32::MAX);
        queue.clear();
        let cx = [tx, tx.saturating_sub(1)];
        let cy = [ty, ty.saturating_sub(1)];
        for n in 0..n_nodes {
            let (_, x, y, _) = graph.decode(n);
            if cx.contains(&x) && cy.contains(&y) {
                dist[n] = 0;
                queue.push_back(n);
            }
        }
        while let Some(n) = queue.pop_front() {
            let d = dist[n] + 1;
            for &p in &rev[rev_start[n] as usize..rev_start[n + 1] as usize] {
                if dist[p as usize] == u32::MAX {
                    dist[p as usize] = d;
                    queue.push_back(p as usize);
                }
            }
        }
        for (n, &d) in dist.iter().enumerate() {
            if d == u32::MAX {
                continue; // unreachable: any finite estimate is moot
            }
            let est = la.query(n, tx, ty);
            if est > d as f64 + 1e-9 {
                total += 1;
                if reported < MAX_REPORTED {
                    reported += 1;
                    let (dd, x, y, t) = graph.decode(n);
                    out.push(err(
                        "lookahead.admissibility",
                        format!("node {n} target ({tx},{ty})"),
                        format!(
                            "estimate {est} exceeds the true {d}-hop distance from wire \
                             (dir {dd}, x {x}, y {y}, track {t})"
                        ),
                    ));
                }
            }
        }
    }
    if total > reported {
        out.push(err(
            "lookahead.admissibility",
            "lookahead".to_string(),
            format!("{total} inadmissible (node, target) pairs in all ({reported} listed)"),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::device::Device;
    use crate::arch::{Arch, ArchVariant};

    fn graph(w: u16, h: u16, tracks: u32) -> RrGraph {
        let mut arch = Arch::paper(ArchVariant::Baseline);
        arch.routing.channel_width = tracks;
        RrGraph::build(&Device::new(w, h), &arch)
    }

    #[test]
    fn built_map_audits_clean() {
        let g = graph(4, 3, 4);
        let la = Lookahead::build(&g);
        let v = audit_lookahead(&g, &la);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn wrong_shape_is_flagged_without_scanning() {
        let g = graph(4, 3, 4);
        let other = Lookahead::build(&graph(5, 5, 4));
        let v = audit_lookahead(&g, &other);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].code, "lookahead.shape");
    }

    #[test]
    fn inflated_class_distance_is_inadmissible() {
        let g = graph(4, 4, 3);
        let la = Lookahead::build(&g);
        let mut dist = la.dist().to_vec();
        dist[0] = 60_000; // class (dir 0, |dx| 0, |dy| 0): true distance 0
        let bad = Lookahead::from_raw(la.width(), la.height(), la.tracks(), dist).unwrap();
        let v = audit_lookahead(&g, &bad);
        assert!(v.iter().any(|x| x.code == "lookahead.admissibility"), "{v:?}");
        // Capped: never more than the cap plus the one summary entry.
        assert!(v.len() <= MAX_REPORTED + 1, "{} violations", v.len());
    }
}
