//! Static timing analysis over a packed (and optionally placed/routed)
//! netlist.
//!
//! The graph is the mapped netlist itself; per-variant component delays
//! come from [`crate::arch::Delays`] (COFFE-calibrated).  Net delays are
//! supplied by the caller — the placer passes a distance-based estimate,
//! the router passes actual per-sink routed-wire delays — so one STA
//! serves both pre- and post-route analysis.
//!
//! ## Levelized wave-parallel passes
//!
//! Both passes run over the dense [`NetlistIndex`] arenas instead of
//! per-call `HashMap`s, as *waves* of independent per-cell jobs on the
//! shared worker pool ([`crate::coordinator::parallel_waves_with`]):
//!
//! * **forward** — cells within one combinational level have no arrival
//!   dependencies on each other, so each level is one wave (ascending);
//!   a cell reads only lower-level arrivals and writes its own slot,
//! * **backward** — required times are computed per *cell* as the min
//!   over that cell's consumers (not relaxed driver-by-driver), so levels
//!   descend as waves; FF required times form one extra wave at the end
//!   (an FF's consumers can share level 0 with it), and criticality
//!   extraction is a final wave of per-net jobs.
//!
//! ## Per-sink criticality
//!
//! Criticality is extracted at *sink* granularity: the final wave writes
//! one `1 - slack/cpd` value per (net, sink) slot into a [`SinkCrit`]
//! arena laid out exactly like the [`NetlistIndex`] CSR fanout
//! (`sink_offsets()[n] .. sink_offsets()[n + 1]`, stored sink order), and
//! `net_crit[n]` remains the max over net `n`'s slots.  The per-sink
//! arena is what closed-loop timing-driven routing consumes
//! ([`crate::route::term_sink_crit`] folds it onto routing terminals so
//! the router's A* can weigh each sink target by its own slack).
//!
//! **Determinism contract** (same as the router's): a cell's arrival /
//! required value is a pure function of its fan-in/fan-out values from
//! strictly earlier waves, and `max`/`min` reductions over a fixed
//! operand set are order-independent for the NaN-free delays used here —
//! so the [`TimingReport`] is bit-identical for any worker count
//! (enforced by `rust/tests/frontend_parallel.rs`).
//!
//! Adder operand sinks are the paths that differentiate the
//! architectures: on the baseline every operand takes
//! `crossbar + (LUT ->) adder` (133.4 ps class); on DD variants a
//! Z-bypassed operand takes `AddMux crossbar + AddMux` (77.05 + 68.77 ps)
//! — the ~48% cut of Table II that shows up as the Table IV CPD gains.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::arch::Arch;
use crate::coordinator::parallel_waves_with;
use crate::netlist::{CellId, CellKind, Netlist, NetId, NetlistIndex, PackIndex};
use crate::pack::{OperandPath, Packing};

/// Minimum cell count before STA spins up worker threads; below this the
/// waves run on the calling thread (identical results either way).
const PAR_MIN_CELLS: usize = 128;

/// STA result.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Critical path delay in picoseconds.
    pub cpd_ps: f64,
    /// Per-net criticality in [0, 1] (max over the net's sinks).
    pub net_crit: Vec<f64>,
    /// Per-sink criticality arena (see module docs and [`SinkCrit`]).
    pub sink_crit: SinkCrit,
    /// Cell arrival times (at outputs), for debugging / reports.
    pub arrival: Vec<f64>,
}

/// Per-sink criticality in the CSR layout of the [`NetlistIndex`] fanout:
/// `net(n)[si]` is the criticality in [0, 1] of sink `si` of net `n`, in
/// the index's stored sink order (aligned with `NetlistIndex::sinks(n)`).
#[derive(Clone, Debug, Default)]
pub struct SinkCrit {
    /// CSR offsets (length `nets + 1`), a copy of
    /// [`NetlistIndex::sink_offsets`].
    start: Vec<u32>,
    /// One criticality per sink slot.
    crit: Vec<f64>,
}

impl SinkCrit {
    /// Build from raw CSR parts: `start` is the offset array (length
    /// `nets + 1`, a copy of [`NetlistIndex::sink_offsets`]) and `crit`
    /// the flat per-slot arena.  Exists for the check subsystem's
    /// mutation tests, which need to hand-corrupt an arena; producers go
    /// through [`sta_with`].
    pub fn from_raw(start: Vec<u32>, crit: Vec<f64>) -> SinkCrit {
        SinkCrit { start, crit }
    }

    /// Number of nets the CSR covers (`start.len() - 1`).
    pub fn num_nets(&self) -> usize {
        self.start.len().saturating_sub(1)
    }

    /// The CSR offset array (length `num_nets() + 1`) — lets auditors
    /// validate the shape without risking the slicing in [`Self::net`].
    pub fn offsets(&self) -> &[u32] {
        &self.start
    }

    /// Criticalities of `net`'s sinks, in stored sink order.
    #[inline]
    pub fn net(&self, net: NetId) -> &[f64] {
        let a = self.start[net as usize] as usize;
        let b = self.start[net as usize + 1] as usize;
        &self.crit[a..b]
    }

    /// The flat slot arena (all nets, CSR order).
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.crit
    }

    /// Total sink slots.
    pub fn len(&self) -> usize {
        self.crit.len()
    }

    pub fn is_empty(&self) -> bool {
        self.crit.is_empty()
    }
}

impl TimingReport {
    pub fn fmax_mhz(&self) -> f64 {
        if self.cpd_ps <= 0.0 {
            return f64::INFINITY;
        }
        1e6 / self.cpd_ps
    }

    /// Bit-exact equality over every field — the single definition the
    /// determinism suites (hotpath bench, `rust/tests/timing_route.rs`)
    /// compare reports with, so a new field cannot be silently left out
    /// of some checks.
    pub fn bits_eq(&self, other: &TimingReport) -> bool {
        let v = |a: &[f64], b: &[f64]| {
            a.len() == b.len()
                && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
        };
        self.cpd_ps.to_bits() == other.cpd_ps.to_bits()
            && v(&self.net_crit, &other.net_crit)
            && v(self.sink_crit.values(), other.sink_crit.values())
            && v(&self.arrival, &other.arrival)
    }
}

/// Rescale per-terminal criticalities against an *achieved*-CPD prior
/// from a previously routed seed (the cross-seed place↔route feedback
/// loop): `crit' = crit^γ` with `γ = cpd_est / cpd_prior` (clamped to
/// [1/4, 4]) — the criticality-exponent form VPR uses for timing
/// pressure.  The fixed points 0 and 1 are preserved, so zero-slack
/// sinks never acquire phantom weight and the fully-critical path stays
/// pinned; when the router achieved a *worse* CPD than the estimate
/// (`γ < 1`, the usual case — pre-route estimates undershoot), the
/// mid-range sharpens upward so near-critical connections pull harder,
/// and when the router beat the estimate (`γ > 1`) pressure relaxes.
/// Under uniform delay scaling criticality is scale-invariant, so the
/// exponent only encodes how far the estimate *missed*, not the absolute
/// period.  `crit` is the per-terminal shape
/// [`crate::place::cost::NetModel::fold_sink_crit`] produces; `None` or
/// non-positive priors leave it untouched.
pub fn rescale_crit(crit: &mut [Vec<f64>], cpd_est_ps: f64, cpd_prior_ps: Option<f64>) {
    let Some(prior) = cpd_prior_ps else { return };
    if !(prior.is_finite() && prior > 0.0 && cpd_est_ps > 0.0) {
        return;
    }
    let gamma = (cpd_est_ps / prior).clamp(0.25, 4.0);
    for v in crit.iter_mut() {
        for c in v.iter_mut() {
            *c = c.powf(gamma).clamp(0.0, 1.0);
        }
    }
}

/// Sink-kind classification for input-path delays.
fn sink_input_delay(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    cell: CellId,
    pin: u8,
    pidx: &PackIndex,
) -> f64 {
    let d = &arch.delays;
    match nl.cells[cell as usize].kind {
        CellKind::Lut { k, .. } => {
            // Local crossbar + LUT read.
            let lut_d = if k <= 5 { d.lut5 } else { d.lut6 };
            d.lb_in_to_alm_in + lut_d + d.alm_out_to_lb_out + d.dd6_outmux_extra
        }
        CellKind::AdderBit { .. } => {
            if pin == 2 {
                // Carry-in: handled as a carry edge, no input network.
                0.0
            } else {
                // Operand entry: depends on the packed path.
                let path = pidx
                    .alm_of(cell)
                    .and_then(|ai| {
                        let alm = &packing.alms[ai];
                        alm.adder_bits
                            .iter()
                            .position(|&b| b == cell)
                            .map(|bi| alm.operand_paths[bi][pin as usize])
                    })
                    .unwrap_or(OperandPath::RouteThrough);
                match path {
                    OperandPath::ZBypass => d.lb_in_to_z + d.z_to_adder,
                    OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough => {
                        d.lb_in_to_alm_in + d.alm_in_to_adder
                    }
                    OperandPath::Const => 0.0,
                }
            }
        }
        CellKind::Ff => d.lb_in_to_alm_in + d.ff_setup,
        CellKind::Output => d.io,
        CellKind::Input | CellKind::Const(_) => 0.0,
    }
}

/// Output launch delay of a cell (applied once at its output).
fn cell_output_delay(nl: &Netlist, arch: &Arch, cell: CellId, pin: u8) -> f64 {
    let d = &arch.delays;
    match nl.cells[cell as usize].kind {
        CellKind::Input => d.io,
        CellKind::Ff => d.ff_clk_q,
        CellKind::AdderBit { .. } => {
            if pin == 0 {
                d.adder_sum + d.alm_out_to_lb_out + d.dd6_outmux_extra
            } else {
                d.carry_hop
            }
        }
        // LUT logic delay is charged at the sink (crossbar+LUT), output
        // driver at the sink computation; avoid double counting.
        CellKind::Lut { .. } | CellKind::Const(_) | CellKind::Output => 0.0,
    }
}

/// Post-route STA: net delays come from the routed trees over the
/// routing-resource graph — each sink is charged for the wire hops of its
/// branch ([`crate::rrg::hop_delay`]), so the critical path reflects the
/// actual negotiated routes rather than placement distance estimates.
pub fn sta_routed(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    routing: &crate::route::Routing,
    model: &crate::place::cost::NetModel,
) -> TimingReport {
    let delay = crate::route::routed_net_delay(routing, model, arch);
    sta(nl, packing, arch, delay)
}

/// Run STA.  `net_delay(net, sink_cell, sink_pin)` gives the interconnect
/// delay from the net's driver LB pin to the sink LB pin (0 for intra-LB
/// feedback).  Convenience wrapper that builds the dense indexes and runs
/// serially; hot callers (the placer's periodic STA, benches) build the
/// indexes once and call [`sta_with`].
pub fn sta<F>(nl: &Netlist, packing: &Packing, arch: &Arch, net_delay: F) -> TimingReport
where
    F: Fn(NetId, CellId, u8) -> f64 + Sync,
{
    let idx = NetlistIndex::build(nl);
    let pidx = PackIndex::build(nl, packing);
    sta_with(nl, &idx, &pidx, packing, arch, net_delay, 1)
}

#[inline]
fn fget(slot: &AtomicU64) -> f64 {
    f64::from_bits(slot.load(Ordering::Relaxed))
}

#[inline]
fn fput(slot: &AtomicU64, v: f64) {
    slot.store(v.to_bits(), Ordering::Relaxed);
}

/// [`sta`] over prebuilt indexes, with the levelized passes sharded over
/// `jobs` workers.  Bit-identical for any `jobs` (see module docs).
pub fn sta_with<F>(
    nl: &Netlist,
    idx: &NetlistIndex,
    pidx: &PackIndex,
    packing: &Packing,
    arch: &Arch,
    net_delay: F,
    jobs: usize,
) -> TimingReport
where
    F: Fn(NetId, CellId, u8) -> f64 + Sync,
{
    let n = nl.cells.len();
    let workers = if n >= PAR_MIN_CELLS { jobs.max(1) } else { 1 };

    // --- Forward pass: arrivals, one wave per level (ascending). ---------
    let arrival: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    parallel_waves_with(idx.wave_offsets(), workers, || (), |_, i| {
        let c = idx.topo_order()[i];
        let cu = c as usize;
        let cell = &nl.cells[cu];
        let a = if matches!(cell.kind, CellKind::Ff) {
            0.0 // launch from the clock edge
        } else {
            let mut a: f64 = 0.0;
            for (pin, &net) in cell.ins.iter().enumerate() {
                if let Some((drv, dpin)) = idx.driver(net) {
                    let src = if matches!(nl.cells[drv as usize].kind, CellKind::Ff) {
                        arch.delays.ff_clk_q
                    } else {
                        fget(&arrival[drv as usize]) + cell_output_delay(nl, arch, drv, dpin)
                    };
                    let is_carry = matches!(cell.kind, CellKind::AdderBit { .. }) && pin == 2;
                    let wire = if is_carry {
                        // Carry chain: dedicated path; LB hop cost if the
                        // previous bit sits in another LB.
                        if pidx.same_lb(c, drv) { 0.0 } else { arch.delays.carry_lb_hop }
                    } else {
                        net_delay(net, c, pin as u8)
                    };
                    let input = sink_input_delay(nl, packing, arch, c, pin as u8, pidx);
                    a = a.max(src + wire + input);
                }
            }
            a
        };
        fput(&arrival[cu], a);
    });

    // --- CPD: max arrival at POs and FF d inputs (serial reduction). -----
    let mut cpd = 0.0f64;
    for (ci, cell) in nl.cells.iter().enumerate() {
        match cell.kind {
            CellKind::Output => cpd = cpd.max(fget(&arrival[ci])),
            CellKind::Ff => {
                let net = cell.ins[0];
                if let Some((drv, dpin)) = idx.driver(net) {
                    let src = fget(&arrival[drv as usize]) + cell_output_delay(nl, arch, drv, dpin);
                    let wire = net_delay(net, ci as CellId, 0);
                    let input =
                        sink_input_delay(nl, packing, arch, ci as CellId, 0, pidx);
                    cpd = cpd.max(src + wire + input);
                }
            }
            _ => {}
        }
    }
    if cpd <= 0.0 {
        cpd = 1.0;
    }

    // --- Backward pass: required times per cell, levels descending. ------
    // required(c) = min over c's non-FF consumers of (required(consumer)
    // - wire - input), floored at `cpd` for timing endpoints (POs, FFs).
    // A consumer always sits at a strictly higher level than its
    // combinational driver, so descending level waves see final values;
    // FFs get a dedicated wave after all levels (their consumers can share
    // level 0), and per-net criticality extraction is the last wave.
    let required: Vec<AtomicU64> =
        (0..n).map(|_| AtomicU64::new(f64::INFINITY.to_bits())).collect();
    let net_crit: Vec<AtomicU64> =
        (0..nl.nets.len()).map(|_| AtomicU64::new(0)).collect();
    // Per-sink criticality slots, CSR-aligned with the index fanout.
    let sink_slots: Vec<AtomicU64> =
        (0..idx.num_sink_slots()).map(|_| AtomicU64::new(0)).collect();
    let mut sched: Vec<CellId> = Vec::with_capacity(n);
    let mut offs: Vec<usize> = Vec::with_capacity(idx.num_levels() + 3);
    offs.push(0);
    for l in (0..idx.num_levels()).rev() {
        sched.extend(
            idx.level_cells(l)
                .iter()
                .copied()
                .filter(|&c| !matches!(nl.cells[c as usize].kind, CellKind::Ff)),
        );
        offs.push(sched.len());
    }
    sched.extend((0..n as CellId).filter(|&c| matches!(nl.cells[c as usize].kind, CellKind::Ff)));
    offs.push(sched.len());
    let cell_jobs = sched.len();
    offs.push(cell_jobs + nl.nets.len());

    parallel_waves_with(&offs, workers, || (), |_, i| {
        if i < cell_jobs {
            let c = sched[i];
            let cell = &nl.cells[c as usize];
            let mut req = if matches!(cell.kind, CellKind::Output | CellKind::Ff) {
                cpd
            } else {
                f64::INFINITY
            };
            for &net in &cell.outs {
                for (s, pin) in idx.sinks(net) {
                    if matches!(nl.cells[s as usize].kind, CellKind::Ff) {
                        continue; // FF d inputs do not propagate required
                    }
                    let wire = net_delay(net, s, pin);
                    let input = sink_input_delay(nl, packing, arch, s, pin, pidx);
                    req = req.min(fget(&required[s as usize]) - wire - input);
                }
            }
            fput(&required[c as usize], req);
        } else {
            // Criticality: one `1 - slack/cpd` per sink slot; the net's
            // value is the max over its slots.
            let ni = (i - cell_jobs) as NetId;
            let Some((drv, dpin)) = idx.driver(ni) else { return };
            let drv_arr = fget(&arrival[drv as usize]) + cell_output_delay(nl, arch, drv, dpin);
            let base = idx.sink_offsets()[ni as usize] as usize;
            let mut crit = 0.0f64;
            for (si, (sink, pin)) in idx.sinks(ni).enumerate() {
                let wire = net_delay(ni, sink, pin);
                let input = sink_input_delay(nl, packing, arch, sink, pin, pidx);
                let slack = fget(&required[sink as usize]) - (drv_arr + wire + input);
                let c = (1.0 - slack / cpd).clamp(0.0, 1.0);
                fput(&sink_slots[base + si], c);
                crit = crit.max(c);
            }
            fput(&net_crit[ni as usize], crit);
        }
    });

    TimingReport {
        cpd_ps: cpd,
        net_crit: net_crit.iter().map(fget).collect(),
        sink_crit: SinkCrit {
            start: idx.sink_offsets().to_vec(),
            crit: sink_slots.iter().map(fget).collect(),
        },
        arrival: arrival.iter().map(fget).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchVariant;
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn mul_setup(v: ArchVariant) -> (Netlist, Packing, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(v);
        let packing = pack(&nl, &arch, &PackOpts::default());
        (nl, packing, arch)
    }

    #[test]
    fn cpd_positive_and_finite() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Baseline);
        let rpt = sta(&nl, &packing, &arch, |_, _, _| 200.0);
        assert!(rpt.cpd_ps > 0.0 && rpt.cpd_ps.is_finite());
        assert!(rpt.fmax_mhz() > 0.0);
    }

    #[test]
    fn criticalities_bounded() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Dd5);
        let rpt = sta(&nl, &packing, &arch, |_, _, _| 150.0);
        assert!(rpt.net_crit.iter().all(|&c| (0.0..=1.0).contains(&c)));
        // At least one net is fully critical.
        assert!(rpt.net_crit.iter().any(|&c| c > 0.99));
    }

    /// The per-sink arena is CSR-consistent with the netlist fanout, and
    /// every net's criticality is exactly the max over its sink slots.
    #[test]
    fn sink_crit_consistent_with_net_crit() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Dd5);
        let idx = NetlistIndex::build(&nl);
        let rpt = sta(&nl, &packing, &arch, |net, _, pin| {
            100.0 + (net % 9) as f64 + 3.0 * pin as f64
        });
        assert_eq!(rpt.sink_crit.len(), idx.num_sink_slots());
        for (ni, net) in nl.nets.iter().enumerate() {
            let slots = rpt.sink_crit.net(ni as NetId);
            assert_eq!(slots.len(), net.sinks.len(), "net {ni}");
            assert!(slots.iter().all(|&c| (0.0..=1.0).contains(&c)));
            let max = slots.iter().fold(0.0f64, |m, &c| m.max(c));
            assert_eq!(
                max.to_bits(),
                rpt.net_crit[ni].to_bits(),
                "net {ni}: max sink crit vs net_crit"
            );
        }
    }

    #[test]
    fn longer_wires_increase_cpd() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Baseline);
        let short = sta(&nl, &packing, &arch, |_, _, _| 50.0).cpd_ps;
        let long = sta(&nl, &packing, &arch, |_, _, _| 500.0).cpd_ps;
        assert!(long > short);
    }

    /// Adder-dominated path: DD5's Z bypass must not be slower than the
    /// baseline LUT feed (paper Table IV observes CPD *improvements*).
    #[test]
    fn dd5_adder_feed_not_slower() {
        let (nl_b, pk_b, arch_b) = mul_setup(ArchVariant::Baseline);
        let (nl_d, pk_d, arch_d) = mul_setup(ArchVariant::Dd5);
        let b = sta(&nl_b, &pk_b, &arch_b, |_, _, _| 200.0).cpd_ps;
        let d = sta(&nl_d, &pk_d, &arch_d, |_, _, _| 200.0).cpd_ps;
        // Same netlist structure; DD5 operand entries are never slower.
        assert!(d <= b * 1.02, "dd5 {d} vs baseline {b}");
    }

    #[test]
    fn dd6_output_mux_penalty_shows() {
        let (nl_d, pk_d, arch_d) = mul_setup(ArchVariant::Dd5);
        let (nl_6, pk_6, arch_6) = mul_setup(ArchVariant::Dd6);
        let d5 = sta(&nl_d, &pk_d, &arch_d, |_, _, _| 200.0).cpd_ps;
        let d6 = sta(&nl_6, &pk_6, &arch_6, |_, _, _| 200.0).cpd_ps;
        assert!(d6 >= d5, "dd6 {d6} vs dd5 {d5}");
    }

    /// Prior rescaling is a criticality-exponent correction: a prior
    /// above the estimate sharpens mid-range criticalities upward, one
    /// below relaxes them, and the fixed points 0 and 1 never move (no
    /// phantom weight on zero-slack sinks).
    #[test]
    fn rescale_crit_renormalizes_to_prior() {
        let mut c = vec![vec![0.5, 1.0], vec![0.0]];
        rescale_crit(&mut c, 100.0, None);
        assert_eq!(c, vec![vec![0.5, 1.0], vec![0.0]]);
        // Router achieved 2x the estimate: gamma = 0.5 sharpens upward.
        rescale_crit(&mut c, 100.0, Some(200.0));
        assert!((c[0][0] - 0.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(c[0][1], 1.0, "fully critical stays pinned");
        assert_eq!(c[1][0], 0.0, "zero-slack-pressure sinks stay at zero");
        // Router beat the estimate: gamma = 2 relaxes the mid-range.
        let mut d = vec![vec![0.2]];
        rescale_crit(&mut d, 100.0, Some(50.0));
        assert!((d[0][0] - 0.04).abs() < 1e-12);
        let mut e = vec![vec![0.4]];
        rescale_crit(&mut e, 100.0, Some(0.0));
        assert_eq!(e[0][0], 0.4, "non-positive prior is ignored");
    }

    /// Parallel STA must equal the serial path bit-for-bit.
    #[test]
    fn sta_with_is_jobs_invariant() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Dd5);
        let idx = NetlistIndex::build(&nl);
        let pidx = PackIndex::build(&nl, &packing);
        let delay = |net: NetId, _: CellId, pin: u8| 100.0 + (net % 7) as f64 + pin as f64;
        let base = sta_with(&nl, &idx, &pidx, &packing, &arch, delay, 1);
        for jobs in [2usize, 4, 8] {
            let r = sta_with(&nl, &idx, &pidx, &packing, &arch, delay, jobs);
            assert_eq!(r.cpd_ps.to_bits(), base.cpd_ps.to_bits(), "jobs={jobs}");
            assert_eq!(r.arrival.len(), base.arrival.len());
            for (a, b) in r.arrival.iter().zip(base.arrival.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "jobs={jobs}");
            }
            for (a, b) in r.net_crit.iter().zip(base.net_crit.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "jobs={jobs}");
            }
            assert_eq!(r.sink_crit.len(), base.sink_crit.len());
            for (a, b) in r.sink_crit.values().iter().zip(base.sink_crit.values().iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "sink crit jobs={jobs}");
            }
        }
    }
}
