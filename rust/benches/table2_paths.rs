//! Bench harness regenerating the paper's Table II (path delays).
//! Run: cargo bench --bench table2_paths   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    let _ = &opts; report::table2().print();
    println!();
    println!("[table2_paths] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
