//! Parameterized two-level pass-transistor multiplexer circuit.
//!
//! The workhorse of FPGA interconnect modeling: N inputs arranged as
//! `n_groups` first-level branches of `n_per_group` pass transistors, a
//! second pass level selecting the group, and a two-stage inverter buffer
//! driving the output load.  Evaluated with Elmore delay; area in MWTA
//! including SRAM configuration bits.

use super::rc::{elmore_ps, transistor_area_mwta, RcStage, Tech};

/// SRAM cell area in MWTA (6T cell, COFFE's convention).
pub const SRAM_MWTA: f64 = 4.0;

/// A sized two-level mux.
#[derive(Clone, Debug)]
pub struct Mux {
    pub n_inputs: usize,
    pub n_per_group: usize,
    pub n_groups: usize,
    /// Widths: [level-1 pass, level-2 pass, buffer inv 1, buffer inv 2].
    pub w: [f64; 4],
}

impl Mux {
    /// Create with a near-square level split and unit widths.
    pub fn new(n_inputs: usize) -> Self {
        let n_per_group = (n_inputs as f64).sqrt().ceil() as usize;
        let n_groups = n_inputs.div_ceil(n_per_group);
        Mux { n_inputs, n_per_group, n_groups, w: [1.0, 1.0, 1.0, 2.0] }
    }

    /// Worst-case Elmore delay (ps) from a driven input to the output,
    /// given the upstream driver resistance and the output load (fF).
    pub fn delay_ps(&self, tech: &Tech, r_drv: f64, c_load: f64) -> f64 {
        let [wp1, wp2, wb1, wb2] = self.w;
        // Node after driver: all first-level drains in the selected group
        // hang on the input wire? No — the input wire sees one pass gate.
        let stages = [
            // Driver charges the input node: pass-gate source junction.
            RcStage { r: r_drv, c: tech.c_drain_min * wp1 + tech.c_wire },
            // Through level-1 pass: intermediate node carries the drains of
            // this group's other level-1 transistors plus one level-2 source.
            RcStage {
                r: tech.r_nmos(wp1),
                c: self.n_per_group as f64 * tech.c_drain_min * wp1
                    + tech.c_drain_min * wp2
                    + tech.c_wire,
            },
            // Through level-2 pass: sense node carries all group drains and
            // the buffer input gate.
            RcStage {
                r: tech.r_nmos(wp2),
                c: self.n_groups as f64 * tech.c_drain_min * wp2
                    + tech.c_inv_in(wb1),
            },
            // Buffer stage 1.
            RcStage { r: tech.r_inv(wb1), c: tech.c_inv_out(wb1) + tech.c_inv_in(wb2) },
            // Buffer stage 2 into the load.
            RcStage { r: tech.r_inv(wb2), c: tech.c_inv_out(wb2) + c_load },
        ];
        elmore_ps(&stages)
    }

    /// Layout area (MWTA), including pass transistors, buffers, and SRAM.
    pub fn area_mwta(&self, tech: &Tech) -> f64 {
        let [wp1, wp2, wb1, wb2] = self.w;
        let pass = self.n_inputs as f64 * transistor_area_mwta(wp1)
            + self.n_groups as f64 * transistor_area_mwta(wp2);
        let buf = transistor_area_mwta(wb1) + transistor_area_mwta(tech.beta * wb1)
            + transistor_area_mwta(wb2) + transistor_area_mwta(tech.beta * wb2);
        // One-hot SRAM per level-1 column + per group.
        let sram = (self.n_per_group + self.n_groups) as f64 * SRAM_MWTA;
        pass + buf + sram
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_split_covers_inputs() {
        for n in [2, 10, 16, 30, 60] {
            let m = Mux::new(n);
            assert!(m.n_per_group * m.n_groups >= n, "split for {n}");
        }
    }

    #[test]
    fn bigger_mux_is_slower_and_larger() {
        let t = Tech::n20();
        let small = Mux::new(4);
        let large = Mux::new(32);
        assert!(large.delay_ps(&t, 500.0, 1.0) > small.delay_ps(&t, 500.0, 1.0));
        assert!(large.area_mwta(&t) > small.area_mwta(&t));
    }

    #[test]
    fn wider_buffers_speed_up_loaded_output() {
        let t = Tech::n20();
        let mut m = Mux::new(16);
        let slow = m.delay_ps(&t, 500.0, 20.0);
        m.w = [1.0, 1.0, 2.0, 6.0];
        let fast = m.delay_ps(&t, 500.0, 20.0);
        assert!(fast < slow);
    }

    #[test]
    fn area_monotone_in_width() {
        let t = Tech::n20();
        let mut m = Mux::new(16);
        let a1 = m.area_mwta(&t);
        m.w = [2.0, 2.0, 2.0, 4.0];
        assert!(m.area_mwta(&t) > a1);
    }
}
