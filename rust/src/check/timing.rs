//! Timing sanity: the STA report must be internally consistent with the
//! netlist it claims to time.
//!
//! Invariants re-derived from the artifact (no STA re-run): arrivals are
//! finite and non-negative; along every combinational edge (non-FF driver
//! → non-FF sink) the sink's arrival is no earlier than the driver's
//! (all component delays are non-negative, so arrival is monotone along
//! paths); every primary-output arrival is bounded by the reported CPD
//! (the CPD is their max); the per-sink criticality arena has exactly the
//! index's CSR shape with every value in [0, 1]; and each net's
//! criticality is **bitwise** the max-fold (from 0.0) of its sink slots —
//! the same reduction the producer and the determinism suites use.

use crate::netlist::{CellKind, Netlist, NetlistIndex};
use crate::timing::TimingReport;

use super::{Severity, Stage, Violation};

/// Slop for comparisons that cross independently rounded sums.
const EPS: f64 = 1e-9;

fn err(code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Timing, Severity::Error, code, location, message)
}

/// Audit a timing report against the netlist/index it was computed from.
/// Scan order: global arity, cells ascending (arrival range), nets
/// ascending (monotonicity, criticality), outputs ascending.
pub fn audit_timing(nl: &Netlist, idx: &NetlistIndex, rpt: &TimingReport) -> Vec<Violation> {
    let mut out = Vec::new();

    // --- CPD. -------------------------------------------------------------
    if !(rpt.cpd_ps.is_finite() && rpt.cpd_ps > 0.0) {
        out.push(err(
            "timing.cpd",
            "cpd".to_string(),
            format!("reported CPD {} ps is not finite and positive", rpt.cpd_ps),
        ));
    }

    // --- Arity. -----------------------------------------------------------
    let mut shape_ok = true;
    if rpt.arrival.len() != nl.cells.len() || rpt.net_crit.len() != nl.nets.len() {
        out.push(err(
            "timing.arity",
            "report".to_string(),
            format!(
                "{} arrivals / {} net criticalities for {} cells / {} nets",
                rpt.arrival.len(),
                rpt.net_crit.len(),
                nl.cells.len(),
                nl.nets.len()
            ),
        ));
        shape_ok = false;
    }
    // The sink-crit arena must be *the* index CSR: same offsets, same
    // slot count.  Validated before any `net()` slicing.
    if rpt.sink_crit.num_nets() != nl.nets.len()
        || rpt.sink_crit.len() != idx.num_sink_slots()
        || rpt.sink_crit.offsets() != idx.sink_offsets()
    {
        out.push(err(
            "timing.csr-shape",
            "sink_crit".to_string(),
            format!(
                "arena covers {} nets / {} slots, index has {} nets / {} slots \
                 (or offsets diverge)",
                rpt.sink_crit.num_nets(),
                rpt.sink_crit.len(),
                nl.nets.len(),
                idx.num_sink_slots()
            ),
        ));
        shape_ok = false;
    }

    // --- Criticality range (flat arena scan). -----------------------------
    for (slot, &c) in rpt.sink_crit.values().iter().enumerate() {
        if !(0.0..=1.0).contains(&c) || c.is_nan() {
            out.push(err(
                "timing.crit-range",
                format!("sink slot {slot}"),
                format!("sink criticality {c} outside [0, 1]"),
            ));
        }
    }

    if !shape_ok {
        return out; // per-cell / per-net scans below index by these shapes
    }

    // --- Arrival range (cells ascending). ---------------------------------
    for (ci, &a) in rpt.arrival.iter().enumerate() {
        if !(a.is_finite() && a >= 0.0) {
            out.push(err(
                "timing.arrival-range",
                format!("cell {ci}"),
                format!("arrival {a} ps is not finite and non-negative"),
            ));
        }
    }

    // --- Edge monotonicity + per-net criticality (nets ascending). --------
    let is_ff = |c: u32| matches!(nl.cells[c as usize].kind, CellKind::Ff);
    for ni in 0..nl.nets.len() {
        // net_crit must be bitwise the max-fold of the net's sink slots.
        let fold = rpt
            .sink_crit
            .net(ni as u32)
            .iter()
            .fold(0.0f64, |m, &c| m.max(c));
        if fold.to_bits() != rpt.net_crit[ni].to_bits() {
            out.push(err(
                "timing.net-crit-mismatch",
                format!("net {ni}"),
                format!(
                    "net criticality {} is not the max of its sink slots ({fold})",
                    rpt.net_crit[ni]
                ),
            ));
        }
        let Some((drv, _)) = idx.driver(ni as u32) else { continue };
        if is_ff(drv) {
            continue; // FF launches re-time from the clock edge
        }
        for (sink, _pin) in idx.sinks(ni as u32) {
            if is_ff(sink) {
                continue; // FF d-pins capture; their arrival is 0 by definition
            }
            let (ad, asv) = (rpt.arrival[drv as usize], rpt.arrival[sink as usize]);
            if asv + EPS < ad {
                out.push(err(
                    "timing.arrival-monotone",
                    format!("net {ni}"),
                    format!(
                        "combinational edge cell {drv} -> cell {sink} goes back in time: \
                         arrival {ad} ps then {asv} ps"
                    ),
                ));
            }
        }
    }

    // --- Endpoint arrivals bounded by the CPD (outputs ascending). --------
    for &po in &nl.outputs {
        let a = rpt.arrival[po as usize];
        if a > rpt.cpd_ps + EPS {
            out.push(err(
                "timing.arrival-exceeds-cpd",
                format!("cell {po}"),
                format!(
                    "primary-output arrival {a} ps exceeds the reported CPD {} ps",
                    rpt.cpd_ps
                ),
            ));
        }
    }

    out
}
