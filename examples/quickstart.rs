//! Quickstart: run the full CAD flow (synthesize -> map -> pack -> place ->
//! route -> STA) on one Kratos-like circuit for both the baseline and the
//! Double-Duty DD5 architecture, and print the comparison.
//!
//!     cargo run --release --example quickstart

use double_duty::arch::ArchVariant;
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::flow::{run_benchmark, FlowOpts};

fn main() {
    let params = BenchParams::default();
    let bench = &kratos_suite(&params)[2]; // gemmt-FU-mini
    let opts = FlowOpts { seeds: vec![1], ..Default::default() };

    println!("== Double-Duty quickstart: {} ==", bench.name);
    let base = run_benchmark(bench, ArchVariant::Baseline, &opts);
    let dd5 = run_benchmark(bench, ArchVariant::Dd5, &opts);

    println!("{:<18} {:>12} {:>12}", "metric", "baseline", "dd5");
    println!("{:<18} {:>12} {:>12}", "ALMs", base.alms, dd5.alms);
    println!("{:<18} {:>12} {:>12}", "LBs", base.lbs, dd5.lbs);
    println!("{:<18} {:>12} {:>12}", "concurrent LUTs", base.concurrent_luts, dd5.concurrent_luts);
    println!("{:<18} {:>12.0} {:>12.0}", "ALM area (MWTA)", base.alm_area_mwta, dd5.alm_area_mwta);
    println!("{:<18} {:>12.2} {:>12.2}", "CPD (ns)", base.cpd_ns, dd5.cpd_ns);
    println!("{:<18} {:>12.0} {:>12.0}", "ADP", base.adp, dd5.adp);
    println!();
    println!("area ratio dd5/baseline: {:.3}", dd5.alm_area_mwta / base.alm_area_mwta);
    println!("adp  ratio dd5/baseline: {:.3}", dd5.adp / base.adp);
    assert!(dd5.alms <= base.alms, "DD5 should never need more ALMs");
}
