//! Hand-rolled HTTP/1.1 subset for the daemon: request parsing with hard
//! size caps, fixed `Content-Length` responses, and chunked
//! transfer-encoding for the incremental job-event stream.
//!
//! Deliberately minimal (std-only, no new deps): one request per
//! connection (`Connection: close`), bodies only via `Content-Length`,
//! no keep-alive, no TLS.  Every malformed input is an `Err(String)` the
//! caller turns into a 4xx — never a panic — and every write is
//! best-effort (a client that hung up mid-response is its own problem,
//! not the daemon's).

use std::io::{Read, Write};
use std::net::TcpStream;

/// Request head cap: beyond this, the peer is not speaking our protocol.
const MAX_HEAD: usize = 16 * 1024;
/// Body cap: job specs are small; anything bigger is abuse.
const MAX_BODY: usize = 1024 * 1024;

/// A parsed request: method, path, body.  Headers beyond
/// `Content-Length` are read and discarded.
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Read and parse one request from `stream`.
pub fn read_request(stream: &mut TcpStream) -> Result<Request, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 1024];
    let head_len = loop {
        if let Some(p) = head_end(&buf) {
            break p;
        }
        if buf.len() > MAX_HEAD {
            return Err(format!("request head exceeds {MAX_HEAD} bytes"));
        }
        let n = stream.read(&mut tmp).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request".to_string());
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_len]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || !path.starts_with('/') {
        return Err(format!("malformed request line {request_line:?}"));
    }
    let mut content_length: usize = 0;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad Content-Length {:?}", value.trim()))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(format!("body exceeds {MAX_BODY} bytes"));
    }
    let mut body: Vec<u8> = buf[head_len + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp).map_err(|e| format!("read body: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".to_string());
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body })
}

/// Write a complete JSON response.  Best-effort: a peer that closed the
/// socket loses the response, nothing else happens.
pub fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &str) {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// Start a 200 chunked response (the job-event stream).  Returns `false`
/// when the peer is gone.
pub fn start_chunked(stream: &mut TcpStream) -> bool {
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    stream.write_all(head.as_bytes()).and_then(|_| stream.flush()).is_ok()
}

/// Write one chunk (one JSON event line).  Returns `false` when the peer
/// is gone, so the streamer can stop waiting on the job.
pub fn write_chunk(stream: &mut TcpStream, data: &str) -> bool {
    let framed = format!("{:x}\r\n{data}\r\n", data.len());
    stream.write_all(framed.as_bytes()).and_then(|_| stream.flush()).is_ok()
}

/// Terminate a chunked response.
pub fn end_chunked(stream: &mut TcpStream) -> bool {
    stream.write_all(b"0\r\n\r\n").and_then(|_| stream.flush()).is_ok()
}
