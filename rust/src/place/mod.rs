//! Timing-driven simulated-annealing placement (the VPR substitute).
//!
//! Blocks are packed LBs plus I/O pads; carry chains spanning multiple LBs
//! are vertical macros that move as units.  Cost is criticality-weighted
//! HPWL (the classic VPR formulation); criticalities refresh from STA
//! periodically.  Moves flow through a batched proposal pipeline —
//! randomness is drawn per batch, then each candidate is scored against
//! the incremental per-net bounding-box cost cache
//! ([`cost::IncrementalCost`]) and committed in order.  The batched
//! full-cost + congestion evaluation runs through the AOT-compiled
//! JAX/Pallas kernel via PJRT ([`kernel_accel`]), fed straight from the
//! cached boxes — python never executes at placement time.

pub mod cost;
pub mod kernel_accel;

use std::collections::HashMap;

use crate::arch::device::{Device, Loc};
use crate::arch::Arch;
use crate::netlist::{CellId, Netlist, NetId};
use crate::pack::Packing;
use crate::timing;
use crate::util::Rng;

pub use cost::{IncrementalCost, NetModel, PlacementCost};

/// Placement result: locations for every LB and I/O cell.
#[derive(Clone, Debug)]
pub struct Placement {
    pub device: Device,
    /// Location of each packed LB (index parallel to `Packing::lbs`).
    pub lb_loc: Vec<Loc>,
    /// Location of each I/O cell.
    pub io_loc: HashMap<CellId, Loc>,
    /// Final placement cost (weighted HPWL).
    pub cost: f64,
    /// Post-placement estimated critical path (ps).
    pub est_cpd_ps: f64,
}

/// Placer options.
#[derive(Clone, Debug)]
pub struct PlaceOpts {
    pub seed: u64,
    /// Moves per temperature = `effort * blocks^(4/3)` (VPR's inner_num).
    pub effort: f64,
    /// Timing-driven (criticality-weighted) vs pure wirelength.
    pub timing_driven: bool,
    /// Evaluate the full cost + congestion map through the PJRT kernel at
    /// each temperature (validated against the incremental Rust cost).
    pub use_kernel: bool,
    /// Fix the device size (Table IV stress tests); `None` auto-sizes.
    pub device: Option<Device>,
}

impl Default for PlaceOpts {
    fn default() -> Self {
        PlaceOpts {
            seed: 1,
            effort: 1.0,
            timing_driven: true,
            use_kernel: false,
            device: None,
        }
    }
}

/// Net -> placement delay estimate: connection block + wire segments.
pub fn est_net_delay(arch: &Arch, src: Loc, dst: Loc) -> f64 {
    if src == dst {
        return 0.0; // intra-LB feedback (local crossbar charged in STA)
    }
    let d = src.dist(dst);
    let segs = (d as f64 / arch.routing.segment_len as f64).ceil().max(1.0);
    arch.delays.conn_block + segs * arch.delays.wire_segment
}

/// Place a packed design.
pub fn place(nl: &Netlist, packing: &Packing, arch: &Arch, opts: &PlaceOpts) -> Placement {
    let mut rng = Rng::new(opts.seed);

    // --- Device sizing. ----------------------------------------------------
    // Tallest chain macro constrains the minimum grid height.
    let max_macro = packing
        .chain_macros
        .iter()
        .map(|m| m.len())
        .max()
        .unwrap_or(1)
        .max(1);
    let mut device = opts.device.clone().unwrap_or_else(|| {
        Device::auto_size(packing.lbs.len(), packing.ios.len(), 1.15)
    });
    while (device.lb_rows as usize) < max_macro {
        device = Device::new(device.lb_cols + 1, device.lb_rows + 1);
    }
    assert!(
        device.lb_capacity() >= packing.lbs.len(),
        "device too small: {} LBs for {} slots",
        packing.lbs.len(),
        device.lb_capacity()
    );
    assert!(device.io_capacity() >= packing.ios.len(), "not enough I/O sites");

    // --- Macro identification. ---------------------------------------------
    // lb -> macro id; macros are vertically-consecutive LB lists.
    let mut lb_macro: Vec<Option<usize>> = vec![None; packing.lbs.len()];
    let mut macros: Vec<Vec<usize>> = Vec::new();
    for m in &packing.chain_macros {
        if m.len() > 1 {
            let id = macros.len();
            for &lb in m {
                // An LB can belong to at most one macro (chains packed into
                // the same LBs merge their macros).
                if lb_macro[lb].is_none() {
                    lb_macro[lb] = Some(id);
                }
            }
            macros.push(m.clone());
        }
    }

    // --- Initial placement. --------------------------------------------------
    let mut grid: HashMap<Loc, usize> = HashMap::new(); // loc -> lb index
    let mut lb_loc: Vec<Loc> = vec![Loc::new(0, 0); packing.lbs.len()];
    let lb_locs = device.lb_locs();
    // Macros first: place each in a free vertical window, column-major scan.
    let mut col_fill: Vec<u16> = vec![1; device.lb_cols as usize + 1]; // next free y per col
    for m in &macros {
        let len = m.len() as u16;
        let mut placed = false;
        for x in 1..=device.lb_cols {
            let y0 = col_fill[x as usize];
            if y0 + len - 1 <= device.lb_rows {
                for (i, &lb) in m.iter().enumerate() {
                    let loc = Loc::new(x, y0 + i as u16);
                    grid.insert(loc, lb);
                    lb_loc[lb] = loc;
                }
                col_fill[x as usize] = y0 + len;
                placed = true;
                break;
            }
        }
        assert!(placed, "no vertical window for chain macro of {} LBs", m.len());
    }
    // Singles into remaining slots.
    let mut free: Vec<Loc> = lb_locs
        .iter()
        .copied()
        .filter(|l| !grid.contains_key(l))
        .collect();
    rng.shuffle(&mut free);
    let mut fi = 0;
    for lb in 0..packing.lbs.len() {
        if lb_macro[lb].is_some() && grid.values().any(|&v| v == lb) {
            continue;
        }
        if lb_macro[lb].is_some() {
            continue; // already placed with macro
        }
        let loc = free[fi];
        fi += 1;
        grid.insert(loc, lb);
        lb_loc[lb] = loc;
    }
    // I/Os round-robin over pad sites.
    let io_sites = device.io_locs();
    let mut io_loc: HashMap<CellId, Loc> = HashMap::new();
    let mut io_fill: HashMap<Loc, u16> = HashMap::new();
    let mut site_i = 0usize;
    for &io in &packing.ios {
        loop {
            let s = io_sites[site_i % io_sites.len()];
            let f = io_fill.entry(s).or_insert(0);
            if *f < device.io_per_tile {
                *f += 1;
                io_loc.insert(io, s);
                break;
            }
            site_i += 1;
        }
        site_i += 1;
    }

    // --- Net model. -----------------------------------------------------------
    // STA runs repeatedly during annealing (initial, every 4th temperature,
    // final); build the dense netlist/packing indexes once and share them
    // across every call instead of paying per-call HashMap rebuilds.
    let nl_index = crate::netlist::NetlistIndex::build(nl);
    let pack_index = crate::netlist::PackIndex::build(nl, packing);
    let mut model = cost::NetModel::build(nl, packing);
    let mut crit = vec![0.0f64; nl.nets.len()];
    if opts.timing_driven {
        let rpt = timing::sta_with(nl, &nl_index, &pack_index, packing, arch,
                                   |_, _, _| arch.delays.wire_segment * 2.0, 1);
        crit = rpt.net_crit;
    }
    model.set_weights(&crit, opts.timing_driven);
    // Incremental cost cache: per-net bbox + weighted cost, refreshed per
    // temperature (after weight updates) and updated per accepted move.
    let mut inc = cost::IncrementalCost::new(&model, &lb_loc, &io_loc);

    // Optional PJRT kernel evaluator.
    let mut kernel = if opts.use_kernel {
        kernel_accel::KernelCost::try_new(model.num_nets()).ok()
    } else {
        None
    };

    // --- Annealing schedule (VPR-style adaptive). -------------------------------
    let n_blocks = packing.lbs.len().max(2);
    let n_lb = lb_loc.len();
    let moves_per_t = ((opts.effort * (n_blocks as f64).powf(4.0 / 3.0)) as usize).max(64);
    // Initial temperature: 20x the std-dev of random move deltas.
    let mut t = {
        let mut deltas = Vec::with_capacity(64);
        if n_lb >= 2 {
            let rmax = device.lb_cols.max(device.lb_rows);
            for _ in 0..64 {
                let p = propose_move(&mut rng, n_lb, rmax);
                if let Some(dc) = apply_proposal(&p, &device, &mut grid, &mut lb_loc,
                                                 &lb_macro, &macros, &model, &mut inc,
                                                 &io_loc, f64::INFINITY)
                {
                    deltas.push(dc.abs());
                }
            }
        }
        let m = crate::util::stats::mean(&deltas);
        (20.0 * m).max(1.0)
    };
    let mut rlim = device.lb_cols.max(device.lb_rows);
    let mut temp_idx = 0usize;
    let t_min = 0.005 * inc.total().max(1.0) / model.num_nets().max(1) as f64;

    // Batched move-proposal pipeline: each batch draws all its randomness
    // up front, then evaluates the candidates against the incremental cost
    // cache and commits them in order.  Today the evaluation stage scores
    // candidates one at a time (bit-identical to an interleaved loop); the
    // split exists so a batch evaluator — e.g. scoring a whole batch
    // through the PJRT kernel — can replace the inner stage without
    // touching proposal generation or the RNG stream.
    const MOVE_BATCH: usize = 32;
    let mut batch: Vec<MoveProposal> = Vec::with_capacity(MOVE_BATCH);

    while t > t_min {
        let mut accepted = 0usize;
        let mut done = 0usize;
        while done < moves_per_t && n_lb >= 2 {
            let take = MOVE_BATCH.min(moves_per_t - done);
            batch.clear();
            for _ in 0..take {
                batch.push(propose_move(&mut rng, n_lb, rlim));
            }
            for p in &batch {
                if apply_proposal(p, &device, &mut grid, &mut lb_loc, &lb_macro,
                                  &macros, &model, &mut inc, &io_loc, t)
                    .is_some()
                {
                    accepted += 1;
                }
            }
            done += take;
        }
        let alpha = {
            let r = accepted as f64 / moves_per_t as f64;
            // VPR's adaptive alpha.
            if r > 0.96 { 0.5 } else if r > 0.8 { 0.9 } else if r > 0.15 { 0.95 } else { 0.8 }
        };
        t *= alpha;
        // Adapt range limit toward 44% acceptance.
        let r = accepted as f64 / moves_per_t as f64;
        let new_rlim = (rlim as f64 * (1.0 - 0.44 + r)).clamp(1.0, device.lb_cols.max(device.lb_rows) as f64);
        rlim = new_rlim.round() as u16;
        // Refresh criticalities + rebuild the cost cache (weights feed the
        // cached per-net costs, and the re-sum caps f64 drift).  STA is the
        // placer's most expensive periodic step; every 4th temperature
        // tracks criticality closely enough (perf pass, EXPERIMENTS.md §Perf).
        temp_idx += 1;
        if opts.timing_driven && temp_idx % 4 == 0 {
            let rpt = timing::sta_with(nl, &nl_index, &pack_index, packing, arch,
                                       |net, sink, _| {
                net_endpoint_delay(&model, &lb_loc, &io_loc, arch, net, sink)
            }, 1);
            model.set_weights(&rpt.net_crit, true);
        }
        let cur_cost = inc.refresh(&model, &lb_loc, &io_loc);
        // Kernel-evaluated full cost from the cached boxes: consistency
        // check + congestion signal.
        if let Some(k) = kernel.as_mut() {
            if let Ok(kc) = k.evaluate_cached(&model, &inc, &device) {
                // Within float tolerance of the Rust cost.
                debug_assert!((kc.whpwl - cur_cost).abs() <= 1e-3 * cur_cost.max(1.0) + 1.0,
                              "kernel {} vs rust {}", kc.whpwl, cur_cost);
            }
        }
    }

    // Final STA with placed delays.
    let rpt = timing::sta_with(nl, &nl_index, &pack_index, packing, arch, |net, sink, _| {
        net_endpoint_delay(&model, &lb_loc, &io_loc, arch, net, sink)
    }, 1);

    let cost = inc.refresh(&model, &lb_loc, &io_loc);
    Placement { device, lb_loc, io_loc, cost, est_cpd_ps: rpt.cpd_ps }
}

/// Estimated interconnect delay for one net sink given current locations.
pub fn net_endpoint_delay(
    model: &cost::NetModel,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    arch: &Arch,
    net: NetId,
    sink_cell: CellId,
) -> f64 {
    let Some((src, dst)) = model.endpoint_locs(net, sink_cell, lb_loc, io_loc) else {
        return 0.0;
    };
    est_net_delay(arch, src, dst)
}

/// One pre-drawn SA move candidate: a block pick, a displacement, and the
/// Metropolis uniform.  All randomness is drawn at proposal time so
/// evaluation/commit is a deterministic pipeline over the batch.
#[derive(Clone, Copy, Debug)]
struct MoveProposal {
    block: usize,
    dx: i32,
    dy: i32,
    accept_draw: f64,
}

/// Draw one move proposal within range limit `rlim`.
fn propose_move(rng: &mut Rng, n_blocks: usize, rlim: u16) -> MoveProposal {
    MoveProposal {
        block: rng.below(n_blocks),
        dx: rng.range(-(rlim as i64), rlim as i64) as i32,
        dy: rng.range(-(rlim as i64), rlim as i64) as i32,
        accept_draw: rng.f64(),
    }
}

/// Metropolis acceptance with a pre-drawn uniform.
#[inline]
fn accepts(p: &MoveProposal, delta: f64, t: f64) -> bool {
    delta <= 0.0 || (t > 0.0 && p.accept_draw < (-delta / t).exp())
}

/// Evaluate and (maybe) commit one proposal: resolve the target window for
/// the picked block (macro or single LB), score the affected nets against
/// the incremental cost cache, accept by Metropolis, and on acceptance
/// update grid/locations and the cache. Returns the accepted cost delta.
#[allow(clippy::too_many_arguments)]
fn apply_proposal(
    p: &MoveProposal,
    device: &Device,
    grid: &mut HashMap<Loc, usize>,
    lb_loc: &mut Vec<Loc>,
    lb_macro: &[Option<usize>],
    macros: &[Vec<usize>],
    model: &cost::NetModel,
    inc: &mut cost::IncrementalCost,
    io_loc: &HashMap<CellId, Loc>,
    t: f64,
) -> Option<f64> {
    let n = lb_loc.len();
    if n < 2 {
        return None;
    }
    let a = p.block;
    let a_loc = lb_loc[a];
    let (dx, dy) = (p.dx, p.dy);

    if let Some(mid) = lb_macro[a] {
        // Macro move: shift the whole vertical run to a new column window.
        let m = &macros[mid];
        let len = m.len() as u16;
        let base = lb_loc[m[0]];
        let nx = (base.x as i32 + dx).clamp(1, device.lb_cols as i32) as u16;
        let ny = (base.y as i32 + dy).clamp(1, (device.lb_rows - len + 1).max(1) as i32) as u16;
        if nx == base.x && ny == base.y {
            return None;
        }
        // Target window must be empty or contain only single (non-macro) LBs
        // we can swap out.
        let mut displaced: Vec<(usize, Loc)> = Vec::new();
        for i in 0..len {
            let tgt = Loc::new(nx, ny + i);
            if let Some(&occ) = grid.get(&tgt) {
                if lb_macro[occ].is_some() && !m.contains(&occ) {
                    return None; // macro collision: reject
                }
                if !m.contains(&occ) {
                    displaced.push((occ, Loc::new(0, 0)));
                }
            }
        }
        // Rehouse displaced singles in slots the macro actually vacates:
        // old slots outside the new window.  When the move overlaps its own
        // footprint (a small same-column shift), the overlapping old slots
        // stay macro-occupied — handing one to a displaced single would put
        // two blocks on one tile.
        let vacated: Vec<Loc> = (0..len)
            .map(|i| Loc::new(base.x, base.y + i))
            .filter(|l| l.x != nx || l.y < ny || l.y >= ny + len)
            .collect();
        if displaced.len() > vacated.len() {
            return None; // not enough freed slots to rehouse everyone
        }
        for (d, &slot) in displaced.iter_mut().zip(vacated.iter()) {
            d.1 = slot;
        }
        // Compute delta over affected nets.
        let mut moved: Vec<(usize, Loc)> = Vec::new();
        for (i, &lb) in m.iter().enumerate() {
            moved.push((lb, Loc::new(nx, ny + i as u16)));
        }
        for &(lb, loc) in &displaced {
            moved.push((lb, loc));
        }
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            for &(lb, _) in &moved {
                grid.remove(&lb_loc[lb]);
            }
            for &(lb, loc) in &moved {
                grid.insert(loc, lb);
                lb_loc[lb] = loc;
            }
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
        return None;
    }

    // Single LB: swap with another location (occupied by single or empty).
    let nx = (a_loc.x as i32 + dx).clamp(1, device.lb_cols as i32) as u16;
    let ny = (a_loc.y as i32 + dy).clamp(1, device.lb_rows as i32) as u16;
    let b_loc = Loc::new(nx, ny);
    if b_loc == a_loc {
        return None;
    }
    let occupant = grid.get(&b_loc).copied();
    if let Some(b) = occupant {
        if lb_macro[b].is_some() {
            return None;
        }
        let moved = [(a, b_loc), (b, a_loc)];
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            grid.insert(a_loc, b);
            grid.insert(b_loc, a);
            lb_loc[a] = b_loc;
            lb_loc[b] = a_loc;
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
    } else {
        let moved = [(a, b_loc)];
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            grid.remove(&a_loc);
            grid.insert(b_loc, a);
            lb_loc[a] = b_loc;
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchVariant;
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn setup() -> (Netlist, Packing, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        (nl, packing, arch)
    }

    #[test]
    fn placement_is_legal() {
        let (nl, packing, arch) = setup();
        let p = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() });
        // Every LB on a distinct logic tile.
        let mut seen = std::collections::HashSet::new();
        for &loc in &p.lb_loc {
            assert!(p.device.is_lb(loc), "LB off-grid at {loc:?}");
            assert!(seen.insert(loc), "two LBs at {loc:?}");
        }
        // IOs on the periphery.
        for loc in p.io_loc.values() {
            assert!(p.device.is_io(*loc));
        }
        assert!(p.est_cpd_ps > 0.0);
    }

    #[test]
    fn chain_macros_stay_vertical() {
        let (nl, packing, arch) = setup();
        let p = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() });
        for m in &packing.chain_macros {
            if m.len() < 2 {
                continue;
            }
            for w in m.windows(2) {
                let a = p.lb_loc[w[0]];
                let b = p.lb_loc[w[1]];
                assert_eq!(a.x, b.x, "macro not in one column");
                assert_eq!(b.y, a.y + 1, "macro not vertically consecutive");
            }
        }
    }

    #[test]
    fn annealing_improves_over_initial() {
        let (nl, packing, arch) = setup();
        // Effort 0 -> essentially initial placement.
        let rough = place(&nl, &packing, &arch,
                          &PlaceOpts { effort: 0.05, seed: 3, ..Default::default() });
        let tuned = place(&nl, &packing, &arch,
                          &PlaceOpts { effort: 1.5, seed: 3, ..Default::default() });
        assert!(tuned.cost <= rough.cost * 1.05,
                "tuned {} vs rough {}", tuned.cost, rough.cost);
    }

    #[test]
    fn deterministic_per_seed() {
        let (nl, packing, arch) = setup();
        let a = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, seed: 7, ..Default::default() });
        let b = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, seed: 7, ..Default::default() });
        assert_eq!(a.lb_loc, b.lb_loc);
        assert_eq!(a.cost, b.cost);
    }
}
