//! PJRT runtime: load the AOT-compiled placement-cost HLO artifacts and
//! execute them from the Rust hot path.
//!
//! The interchange format is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax >= 0.5 emits protos with 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Artifacts come in net-count buckets (`cost_n{N}.hlo.txt`); the runtime
//! compiles each once and picks the smallest bucket that fits the live net
//! count, padding the rest with `valid = 0`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Fixed congestion-grid side, matching python/compile/kernels/hpwl.py.
pub const GRID: usize = 64;

/// One compiled bucket.
struct Bucket {
    nets: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The placement-cost kernel, compiled for every available bucket.
pub struct CostKernel {
    _client: xla::PjRtClient,
    buckets: Vec<Bucket>,
}

/// Result of one kernel evaluation.
#[derive(Clone, Debug)]
pub struct CostEval {
    /// Weighted HPWL (in the caller's coordinate units — already unscaled).
    pub whpwl: f64,
    /// RUDY congestion map, row-major GRID x GRID.
    pub congestion: Vec<f32>,
    /// Total demand above capacity.
    pub overflow: f64,
}

/// Locate the artifacts directory: $DDUTY_ARTIFACTS, ./artifacts, or the
/// repo-root artifacts next to Cargo.toml.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("DDUTY_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl CostKernel {
    /// Load and compile every `cost_n*.hlo.txt` bucket in `dir`.
    pub fn load(dir: &Path) -> Result<CostKernel> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut buckets = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("artifacts dir {dir:?} (run `make artifacts`)"))?;
        for e in entries {
            let path = e?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            let Some(rest) = name.strip_prefix("cost_n") else { continue };
            let Some(nstr) = rest.strip_suffix(".hlo.txt") else { continue };
            let nets: usize = nstr.parse().with_context(|| format!("bucket size in {name}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
            buckets.push(Bucket { nets, exe });
        }
        if buckets.is_empty() {
            bail!("no cost_n*.hlo.txt artifacts in {dir:?} — run `make artifacts`");
        }
        buckets.sort_by_key(|b| b.nets);
        Ok(CostKernel { _client: client, buckets })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<CostKernel> {
        Self::load(&artifacts_dir())
    }

    /// Largest supported net count.
    pub fn max_nets(&self) -> usize {
        self.buckets.last().map(|b| b.nets).unwrap_or(0)
    }

    /// Evaluate the cost model over per-net boxes
    /// `[xmin, xmax, ymin, ymax, weight]` in kernel grid coordinates
    /// (0..GRID), with a per-bin `capacity` for the overflow term.
    pub fn evaluate(&self, boxes: &[[f32; 5]], capacity: f32) -> Result<CostEval> {
        let n_live = boxes.len();
        let bucket = self
            .buckets
            .iter()
            .find(|b| b.nets >= n_live)
            .with_context(|| {
                format!("{} nets exceeds largest bucket {}", n_live, self.max_nets())
            })?;
        let n = bucket.nets;

        let mut xmin = vec![0.0f32; n];
        let mut xmax = vec![0.0f32; n];
        let mut ymin = vec![0.0f32; n];
        let mut ymax = vec![0.0f32; n];
        let mut w = vec![0.0f32; n];
        let mut valid = vec![0.0f32; n];
        for (i, b) in boxes.iter().enumerate() {
            xmin[i] = b[0];
            xmax[i] = b[1];
            ymin[i] = b[2];
            ymax[i] = b[3];
            w[i] = b[4];
            valid[i] = 1.0;
        }

        let lits = [
            xla::Literal::vec1(&xmin),
            xla::Literal::vec1(&xmax),
            xla::Literal::vec1(&ymin),
            xla::Literal::vec1(&ymax),
            xla::Literal::vec1(&w),
            xla::Literal::vec1(&valid),
            xla::Literal::vec1(&[capacity]),
        ];
        let result = bucket
            .exe
            .execute::<xla::Literal>(&lits)
            .context("kernel execute")?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            bail!("expected 3-tuple from cost kernel, got {}", parts.len());
        }
        let whpwl = parts[0].to_vec::<f32>()?[0] as f64;
        let congestion = parts[1].to_vec::<f32>()?;
        let overflow = parts[2].to_vec::<f32>()?[0] as f64;
        Ok(CostEval { whpwl, congestion, overflow })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel() -> Option<CostKernel> {
        CostKernel::load_default().ok()
    }

    #[test]
    fn loads_buckets_and_evaluates() {
        let Some(k) = kernel() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(k.max_nets() >= 1024);
        // One net: bbox (0,3)x(0,1), weight 2 -> whpwl = 2*(3+1) = 8.
        let eval = k.evaluate(&[[0.0, 3.0, 0.0, 1.0, 2.0]], 1e9).unwrap();
        assert!((eval.whpwl - 8.0).abs() < 1e-4, "whpwl {}", eval.whpwl);
        assert_eq!(eval.congestion.len(), GRID * GRID);
        assert_eq!(eval.overflow, 0.0);
        // RUDY integrates to w * (dx + dy) = 2 * (4 + 2) = 12.
        let total: f32 = eval.congestion.iter().sum();
        assert!((total - 12.0).abs() < 1e-3, "total {total}");
    }

    #[test]
    fn bucket_selection_pads() {
        let Some(k) = kernel() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        // 1500 nets forces the 4096 bucket.
        let boxes: Vec<[f32; 5]> = (0..1500)
            .map(|i| {
                let x = (i % 60) as f32;
                let y = (i / 60 % 60) as f32;
                [x, (x + 2.0).min(63.0), y, (y + 1.0).min(63.0), 1.0]
            })
            .collect();
        let eval = k.evaluate(&boxes, 0.0).unwrap();
        assert!(eval.whpwl > 0.0);
        // capacity 0 -> overflow equals total demand.
        let total: f32 = eval.congestion.iter().sum();
        assert!((eval.overflow - total as f64).abs() < 1e-2 * total as f64 + 1e-3);
    }

    #[test]
    fn oversize_rejected() {
        let Some(k) = kernel() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let boxes = vec![[0.0f32, 1.0, 0.0, 1.0, 1.0]; k.max_nets() + 1];
        assert!(k.evaluate(&boxes, 1.0).is_err());
    }
}
