//! Fault-tolerance contracts of the flow engine, end to end:
//!
//! * a seed that panics mid-plan is isolated — the run completes, the
//!   failure surfaces as a structured [`FlowError`], and every surviving
//!   cell is bit-identical to a clean run at any worker count;
//! * a device misfit is a failed-seed record, not a process death;
//! * the escalation ladder rescues forced non-convergence
//!   deterministically across `--route-jobs`, marks the seed degraded,
//!   and reports ladder exhaustion as a structured failure;
//! * injected disk-cache corruption drives the real integrity-check →
//!   quarantine → recompute path through the artifact cache.
//!
//! Every fault here comes from [`double_duty::util::fault`], so the
//! faulted runs are exactly as reproducible as clean ones.

use double_duty::arch::device::Device;
use double_duty::arch::ArchVariant;
use double_duty::bench_suites::{vtr_suite, BenchParams, Benchmark};
use double_duty::flow::diskcache::DiskCache;
use double_duty::flow::engine::{ArtifactCache, Engine, ExperimentPlan};
use double_duty::flow::{run_benchmark, FlowOpts, FlowResult, RecoveryAction};
use double_duty::util::fault::FaultPlan;

fn benches(n: usize) -> Vec<Benchmark> {
    vtr_suite(&BenchParams::default())[..n].to_vec()
}

fn plan(benches: Vec<Benchmark>, flow: FlowOpts) -> ExperimentPlan {
    ExperimentPlan { benches, variants: vec![ArchVariant::Baseline], flow }
}

fn assert_cells_bit_identical(a: &FlowResult, b: &FlowResult, what: &str) {
    assert_eq!(a.name, b.name, "{what}");
    assert_eq!(a.cpd_ns.to_bits(), b.cpd_ns.to_bits(), "{what}: cpd {} vs {}", a.cpd_ns, b.cpd_ns);
    assert_eq!(a.adp.to_bits(), b.adp.to_bits(), "{what}: adp");
    assert_eq!(a.routed_ok, b.routed_ok, "{what}: routed_ok");
    assert_eq!(a.route_iters.to_bits(), b.route_iters.to_bits(), "{what}: iters");
    assert_eq!(a.channel_util, b.channel_util, "{what}: channel_util");
    assert_eq!(a.failed_seeds, b.failed_seeds, "{what}: failed_seeds");
    assert_eq!(a.escalations, b.escalations, "{what}: escalations");
    assert_eq!(a.errors, b.errors, "{what}: errors");
}

/// A panic injected into one seed of one benchmark is isolated to that
/// job: the plan completes, the failure is a structured record, and the
/// surviving artifacts are bit-identical to a clean run — at any worker
/// count.
#[test]
fn injected_panic_is_isolated_and_survivors_are_bit_identical() {
    let bs = benches(2);
    let victim = bs[0].name.clone();
    let flow = FlowOpts { seeds: vec![1, 2], place_effort: 0.05, route: false, ..Default::default() };
    let clean = Engine::new(1).run(&plan(bs.clone(), flow.clone()));

    let faulted_flow = FlowOpts {
        faults: FaultPlan::parse(&format!("panic:place:{victim}:2")).expect("spec"),
        ..flow.clone()
    };
    let hit = Engine::new(1).run(&plan(bs.clone(), faulted_flow.clone()));

    // The victim cell lost exactly seed 2 and says so, structurally.
    let cell = &hit[0][0];
    assert_eq!(cell.failed_seeds, 1, "exactly one seed fails");
    assert_eq!(cell.errors.len(), 1);
    assert_eq!(cell.errors[0].stage, "job", "caught panics report as isolated jobs");
    assert_eq!(cell.errors[0].seed, Some(2));
    assert_eq!(cell.errors[0].action, RecoveryAction::IsolateJob);
    assert!(cell.errors[0].cause.contains("injected fault"), "{}", cell.errors[0].cause);
    assert!(!cell.routed_ok, "a failed seed may not report a fully healthy cell");
    assert!(cell.cpd_ns > 0.0, "the surviving seed still averages");

    // The untouched cell is bit-identical to the clean run.
    assert_cells_bit_identical(&hit[0][1], &clean[0][1], "survivor vs clean");

    // And the whole faulted grid is invariant under the worker count.
    let hit_par = Engine::new(4).run(&plan(bs, faulted_flow));
    for (row_a, row_b) in hit.iter().zip(hit_par.iter()) {
        for (a, b) in row_a.iter().zip(row_b.iter()) {
            assert_cells_bit_identical(a, b, "jobs=1 vs jobs=4");
        }
    }
}

/// The old `panic!` on a placement misfit is gone: a device too small for
/// the circuit yields failed-seed records and a completed run.
#[test]
fn device_misfit_is_a_failed_seed_not_a_crash() {
    let b = &benches(1)[0];
    let opts = FlowOpts {
        seeds: vec![1, 2],
        place_effort: 0.05,
        route: false,
        device: Some(Device::new(1, 1)),
        ..Default::default()
    };
    let r = run_benchmark(b, ArchVariant::Baseline, &opts);
    assert_eq!(r.failed_seeds, 2, "every seed misfits");
    assert_eq!(r.errors.len(), 2);
    for e in &r.errors {
        assert_eq!(e.stage, "place");
        assert_eq!(e.action, RecoveryAction::SkipSeed);
    }
    assert!(!r.routed_ok);
    assert_eq!(r.cpd_ns, 0.0, "no measurement without a healthy seed");
    assert_eq!(r.fmax_mhz, 0.0, "zero, not infinite, fmax");
}

/// Forced base non-convergence is rescued by the first escalation rung,
/// the seed is marked degraded, and the rescue is bit-identical across
/// `--route-jobs` — the ladder inherits the router's jobs-invariance.
#[test]
fn escalation_ladder_rescues_deterministically_across_route_jobs() {
    let b = &benches(1)[0];
    let base = FlowOpts {
        seeds: vec![1],
        place_effort: 0.05,
        escalate: true,
        faults: FaultPlan::parse("noconverge:route:*:1").expect("spec"),
        ..Default::default()
    };
    let runs: Vec<FlowResult> = [1usize, 2, 8]
        .iter()
        .map(|&rj| run_benchmark(b, ArchVariant::Baseline, &FlowOpts { route_jobs: rj, ..base.clone() }))
        .collect();
    for r in &runs {
        assert!(r.routed_ok, "the ladder must rescue the forced failure");
        assert_eq!(r.escalations, 1, "rescued at the first rung");
        assert_eq!(r.failed_seeds, 0);
        assert!(r.errors.is_empty());
        assert!(r.cpd_ns > 0.0);
    }
    for r in &runs[1..] {
        assert_cells_bit_identical(r, &runs[0], "route-jobs sweep");
    }

    // Without the ladder the same fault is *measured* non-convergence:
    // no error record, no escalation, just an unrouted result.
    let off = run_benchmark(b, ArchVariant::Baseline, &FlowOpts { escalate: false, ..base.clone() });
    assert!(!off.routed_ok);
    assert_eq!(off.escalations, 0);
    assert_eq!(off.failed_seeds, 0, "measured non-convergence is a result, not an error");
    assert!(off.errors.is_empty());
}

/// When every rung is forced to fail too, the ladder exhausts and the
/// seed carries a structured `LadderExhausted` failure.
#[test]
fn exhausted_ladder_reports_structured_failure() {
    let b = &benches(1)[0];
    let opts = FlowOpts {
        seeds: vec![1],
        place_effort: 0.05,
        escalate: true,
        faults: FaultPlan::parse("noconverge-all:route:*:1").expect("spec"),
        ..Default::default()
    };
    let r = run_benchmark(b, ArchVariant::Baseline, &opts);
    assert!(!r.routed_ok);
    assert_eq!(r.failed_seeds, 1);
    assert_eq!(r.errors.len(), 1);
    assert_eq!(r.errors[0].stage, "route");
    assert_eq!(r.errors[0].action, RecoveryAction::LadderExhausted);
    assert!(r.errors[0].cause.contains("escalation rungs"), "{}", r.errors[0].cause);
    assert_eq!(r.escalations, 1, "the exhausted seed still counts as escalated");
}

/// Injected store-time corruption drives the artifact cache's real
/// recovery path: the corrupt file is quarantined, the artifact is
/// recomputed identically, and the violation surfaces through
/// [`ArtifactCache::take_cache_violations`].
#[test]
fn corrupted_disk_cache_quarantines_and_recomputes() {
    let root = std::env::temp_dir()
        .join(format!("dd-fault-recovery-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let b = &benches(1)[0];

    // Pass 1: a faulty handle corrupts the mapped artifact on store.
    let faulty = ArtifactCache::with_disk(DiskCache::with_faults(
        &root,
        FaultPlan::parse("corrupt:cache:map").expect("spec"),
    ));
    let want = faulty.mapped(b);

    // Pass 2: a clean cache on the same root must detect the corruption,
    // quarantine the file, and recompute the identical artifact.
    let clean = ArtifactCache::with_disk(DiskCache::new(&root));
    let got = clean.mapped(b);
    assert_eq!(got.fingerprint, want.fingerprint, "recompute matches the original");
    assert_eq!(got.nl.cells.len(), want.nl.cells.len());

    let quarantined = std::fs::read_dir(&root)
        .expect("cache root exists")
        .flatten()
        .filter(|e| e.path().extension().and_then(|x| x.to_str()) == Some("quarantine"))
        .count();
    assert_eq!(quarantined, 1, "corrupt artifact kept as evidence");
    let vs = clean.take_cache_violations();
    assert_eq!(vs.len(), 1, "{vs:?}");
    assert_eq!(vs[0].code, "flow.cache-integrity");
    assert!(clean.take_cache_violations().is_empty(), "drain is one-shot");
    let _ = std::fs::remove_dir_all(&root);
}
