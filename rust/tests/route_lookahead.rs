//! Lookahead-router contracts, end to end:
//!
//! * with the lookahead on (the default), `Routing` — including the
//!   `astar_pops` work counter — is bit-identical across
//!   `--route-jobs 1/2/8`, congested or not;
//! * `LookaheadMode::Off` *is* the pre-lookahead code path: the legacy
//!   Manhattan heuristic at every seed/expansion and strict in-terms
//!   sink order (the criticality sort is gated on the same flag), so the
//!   off-mode jobs-invariance here pins the PR-6 router bit for bit;
//! * tied per-sink criticalities fall back to index order (stable sort
//!   key), so uniform ties keep `sink_hops` mirroring the net's terms
//!   and stay deterministic across jobs and repeated runs;
//! * a shared map built for a different device grid is rejected loudly
//!   instead of silently mispricing the search.

use double_duty::arch::device::Device;
use double_duty::arch::{Arch, ArchVariant};
use double_duty::pack::{pack, PackOpts, Packing};
use double_duty::place::cost::NetModel;
use double_duty::place::{place, PlaceOpts, Placement};
use double_duty::route::{route, LookaheadMode, RouteOpts, Routing};
use double_duty::rrg::lookahead::Lookahead;
use double_duty::rrg::RrGraph;
use double_duty::synth::circuit::Circuit;
use double_duty::synth::multiplier::{soft_mul, AdderAlgo};
use double_duty::techmap::{map_circuit, MapOpts};
use double_duty::netlist::Netlist;

fn placed_mul(w: usize) -> (Netlist, Packing, Placement, NetModel, Arch) {
    let mut c = Circuit::new("m");
    let x = c.pi_bus("x", w);
    let y = c.pi_bus("y", w);
    let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
    c.po_bus("p", &p);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Dd5);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let pl = place(&nl, &packing, &arch,
                   &PlaceOpts { effort: 0.3, ..Default::default() })
        .expect("placement");
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);
    (nl, packing, pl, model, arch)
}

fn assert_routing_eq(a: &Routing, b: &Routing, tag: &str) {
    assert_eq!(a.success, b.success, "{tag}: success");
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    assert_eq!(a.wirelength, b.wirelength, "{tag}: wirelength");
    assert_eq!(a.overused, b.overused, "{tag}: overused");
    assert_eq!(a.overused_nodes, b.overused_nodes, "{tag}: overused_nodes");
    assert_eq!(a.sink_hops, b.sink_hops, "{tag}: sink_hops");
    assert_eq!(a.net_nodes, b.net_nodes, "{tag}: net_nodes");
    assert_eq!(a.channel_util, b.channel_util, "{tag}: channel_util");
    assert_eq!(a.astar_pops, b.astar_pops, "{tag}: astar_pops");
}

/// Lookahead on (the default): identical `Routing` for every job count,
/// work counter included.
#[test]
fn lookahead_routing_bit_identical_across_job_counts() {
    let (_nl, _packing, pl, model, arch) = placed_mul(6);
    let base = route(&model, &pl, &arch, &RouteOpts { jobs: 1, ..Default::default() });
    assert!(base.success, "baseline route failed ({} overused)", base.overused);
    assert!(base.astar_pops > 0, "pops odometer never moved");
    for jobs in [2, 8] {
        let r = route(&model, &pl, &arch, &RouteOpts { jobs, ..Default::default() });
        assert_routing_eq(&base, &r, &format!("lookahead jobs={jobs}"));
    }
}

/// The contract survives congestion (narrow channel => several
/// negotiation iterations with criticality-ordered trunk reuse in play).
#[test]
fn lookahead_routing_bit_identical_under_congestion() {
    let (_nl, _packing, pl, model, mut arch) = placed_mul(6);
    arch.routing.channel_width = 14;
    let base = route(&model, &pl, &arch, &RouteOpts { jobs: 1, ..Default::default() });
    assert!(base.iterations > 1, "want real negotiation churn");
    for jobs in [2, 8] {
        let r = route(&model, &pl, &arch, &RouteOpts { jobs, ..Default::default() });
        assert_routing_eq(&base, &r, &format!("lookahead congested jobs={jobs}"));
    }
}

/// `LookaheadMode::Off` reproduces the legacy router: the Manhattan
/// heuristic (ASTAR_FAC-free at seeds, exactly as before) and in-terms
/// sink order both sit behind the same flag, so this run *is* the PR-6
/// code path.  Pin that it stays deterministic and jobs-invariant, and
/// that it agrees with itself rep to rep.
#[test]
fn lookahead_off_is_legacy_and_jobs_invariant() {
    let (_nl, _packing, pl, model, arch) = placed_mul(6);
    let mk = |jobs: usize| {
        route(&model, &pl, &arch,
              &RouteOpts { jobs, lookahead: LookaheadMode::Off, ..Default::default() })
    };
    let base = mk(1);
    assert!(base.success, "legacy route failed ({} overused)", base.overused);
    assert_routing_eq(&base, &mk(1), "off repeat");
    for jobs in [2, 8] {
        assert_routing_eq(&base, &mk(jobs), &format!("off jobs={jobs}"));
    }
}

/// Uniform (tied) per-sink criticalities: the descending sort's index
/// tie-break keeps the routing order at identity, so `sink_hops` still
/// mirrors each net's sink terms in order and the result is stable
/// across jobs and repeated runs.
#[test]
fn tied_sink_criticalities_are_stable() {
    let (_nl, _packing, pl, model, arch) = placed_mul(5);
    let ties: Vec<Vec<f64>> = model
        .nets
        .iter()
        .map(|en| vec![0.7; en.terms.len().saturating_sub(1)])
        .collect();
    let mk = |jobs: usize| {
        route(&model, &pl, &arch,
              &RouteOpts { jobs, sink_crit: ties.clone(), ..Default::default() })
    };
    let base = mk(1);
    assert!(base.success, "tied-crit route failed ({} overused)", base.overused);
    for (ni, en) in model.nets.iter().enumerate() {
        let got: Vec<_> = base.sink_hops[ni].iter().map(|&(t, _)| t).collect();
        let want: Vec<_> = en.terms[1..].to_vec();
        assert_eq!(got, want, "net {ni}: sink_hops must mirror terms order");
    }
    assert_routing_eq(&base, &mk(1), "ties repeat");
    for jobs in [2, 8] {
        assert_routing_eq(&base, &mk(jobs), &format!("ties jobs={jobs}"));
    }
}

/// A shared lookahead for the wrong grid is a hard error, not a silent
/// mispricing of every A* estimate.
#[test]
#[should_panic(expected = "lookahead map")]
fn mismatched_shared_lookahead_is_rejected() {
    let (_nl, _packing, pl, model, arch) = placed_mul(5);
    let mut other_arch = Arch::paper(ArchVariant::Baseline);
    other_arch.routing.channel_width = 3;
    let wrong = Lookahead::build(&RrGraph::build(&Device::new(30, 30), &other_arch));
    let _ = route(
        &model,
        &pl,
        &arch,
        &RouteOpts {
            jobs: 1,
            lookahead: LookaheadMode::Shared(std::sync::Arc::new(wrong)),
            ..Default::default()
        },
    );
}
