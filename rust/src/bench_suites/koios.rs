//! Koios-like ML-accelerator benchmark generators: runtime-valued
//! datapaths (soft multipliers with both operands unknown), reductions,
//! and a healthy share of control/steering logic — the ~22% adder share
//! profile of Table III.

use crate::synth::multiplier::{soft_mul, AdderAlgo};
use crate::synth::{reduce_rows, Circuit};
use crate::techmap::aig::Lit;
use crate::util::Rng;

use super::BenchParams;

/// MAC array: grid of soft multipliers + accumulate tree (DLA-style).
pub fn mac_array(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("mac_array", p);
    let n = 2 + p.scale;
    let mut prods = Vec::new();
    for i in 0..n {
        let a = c.pi_bus(&format!("a{i}"), p.width);
        let b = c.pi_bus(&format!("b{i}"), p.width);
        prods.push(soft_mul(&mut c, &a, &b, p.algo));
    }
    let acc = reduce_rows(&mut c, prods, p.algo);
    c.po_bus("acc", &acc);
    c
}

/// LSTM-ish gate stack: elementwise products + sigmoidal LUT gates.
pub fn gate_stack(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("gate_stack", p);
    let n = 2 + p.scale;
    for i in 0..n {
        let x = c.pi_bus(&format!("x{i}"), p.width);
        let h = c.pi_bus(&format!("h{i}"), p.width);
        let g = c.pi_bus(&format!("g{i}"), p.width);
        let xh = soft_mul(&mut c, &x, &h, p.algo);
        // Gate: per-bit mux network keyed on g (control-heavy LUT logic).
        let gated: Vec<Lit> = xh
            .iter()
            .enumerate()
            .map(|(bi, &b)| {
                let sel = g[bi % p.width];
                let alt = g[(bi + 1) % p.width];
                let m = c.aig.mux(sel, b, alt);
                c.aig.xor(m, g[(bi + 2) % p.width])
            })
            .collect();
        let s = c.ripple_add(&gated, &xh);
        c.po_bus(&format!("y{i}"), &s);
    }
    c
}

/// Attention-like: query-key dot products + steering mux tree.
pub fn attention(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("attention", p);
    let heads = 1 + p.scale;
    let dk = 3;
    for h in 0..heads {
        let q: Vec<Vec<Lit>> = (0..dk).map(|i| c.pi_bus(&format!("q{h}_{i}"), p.width)).collect();
        let k: Vec<Vec<Lit>> = (0..dk).map(|i| c.pi_bus(&format!("k{h}_{i}"), p.width)).collect();
        let prods: Vec<Vec<Lit>> = (0..dk)
            .map(|i| soft_mul(&mut c, &q[i], &k[i], p.algo))
            .collect();
        let score = reduce_rows(&mut c, prods, p.algo);
        // Steering: one-hot select of v rows by score top bits (LUT heavy).
        let v: Vec<Vec<Lit>> = (0..4).map(|i| c.pi_bus(&format!("v{h}_{i}"), p.width)).collect();
        let s0 = score[score.len() - 1];
        let s1 = score[score.len() - 2];
        let out: Vec<Lit> = (0..p.width)
            .map(|bi| {
                let m0 = c.aig.mux(s0, v[0][bi], v[1][bi]);
                let m1 = c.aig.mux(s0, v[2][bi], v[3][bi]);
                c.aig.mux(s1, m0, m1)
            })
            .collect();
        c.po_bus(&format!("o{h}"), &out);
        c.po_bus(&format!("score{h}"), &score);
    }
    c
}

/// Systolic-array cell column (TPU-like): chained MACs with registers.
pub fn systolic(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("systolic", p);
    let n = 2 + p.scale;
    let a = c.pi_bus("a", p.width);
    let mut acc: Vec<Lit> = c.pi_bus("psum_in", p.width + 4);
    for i in 0..n {
        let w = c.pi_bus(&format!("w{i}"), p.width);
        let prod = soft_mul(&mut c, &a, &w, p.algo);
        let sum = c.ripple_add(&acc, &prod);
        // Register stage.
        acc = sum
            .iter()
            .take(p.width + 4)
            .map(|&b| {
                let q = c.ff();
                c.set_ff_d(q, b);
                q
            })
            .collect();
    }
    c.po_bus("psum_out", &acc);
    c
}

/// Softmax-ish: max-reduce comparators + subtract + LUT lookup stage.
pub fn softmax(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("softmax", p);
    let n = 3 + p.scale;
    let xs: Vec<Vec<Lit>> = (0..n).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    // Max tree (pure LUT logic).
    let mut cur: Vec<Vec<Lit>> = xs.clone();
    while cur.len() > 1 {
        let mut next = Vec::new();
        for pair in cur.chunks(2) {
            if pair.len() == 1 {
                next.push(pair[0].clone());
                continue;
            }
            let (a, b) = (&pair[0], &pair[1]);
            let mut gt = Lit::FALSE;
            let mut eq = Lit::TRUE;
            for i in (0..p.width).rev() {
                let bit_gt = c.aig.and(a[i], b[i].compl());
                let t = c.aig.and(eq, bit_gt);
                gt = c.aig.or(gt, t);
                let x = c.aig.xor(a[i], b[i]);
                eq = c.aig.and(eq, x.compl());
            }
            next.push((0..p.width).map(|i| c.aig.mux(gt, a[i], b[i])).collect());
        }
        cur = next;
    }
    let mx = cur.pop().unwrap();
    // x - max via x + ~max + 1 on hard chains, then a nonlinear LUT stage.
    for (i, x) in xs.iter().enumerate() {
        let neg: Vec<Lit> = mx.iter().map(|&b| b.compl()).collect();
        let diff = c.ripple_add(x, &neg);
        let nb: Vec<Lit> = diff
            .iter()
            .take(p.width)
            .enumerate()
            .map(|(bi, &b)| {
                let rot = diff[(bi + 1) % p.width];
                c.aig.xor(b, rot)
            })
            .collect();
        c.po_bus(&format!("e{i}"), &nb);
    }
    c
}

/// Convolution layer with runtime weights (unknown x unknown).
pub fn conv_layer(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("conv_layer", p);
    let n = 2 + p.scale;
    let w: Vec<Vec<Lit>> = (0..3).map(|i| c.pi_bus(&format!("w{i}"), p.width)).collect();
    let xs: Vec<Vec<Lit>> = (0..n + 2).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    for o in 0..n {
        let prods: Vec<Vec<Lit>> = (0..3)
            .map(|k| soft_mul(&mut c, &xs[o + k], &w[k], p.algo))
            .collect();
        let y = reduce_rows(&mut c, prods, p.algo);
        c.po_bus(&format!("y{o}"), &y);
    }
    c
}

/// Wide accumulation reduction (gradient-sum style): mostly hard adders.
pub fn reduction(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("reduction", p);
    let n = 6 + 2 * p.scale;
    let rows: Vec<Vec<Lit>> = (0..n).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    let s = reduce_rows(&mut c, rows, AdderAlgo::BinaryTree);
    c.po_bus("sum", &s);
    c
}

/// Normalization-ish: mean (adders) + per-element scale via LUT shifts.
pub fn norm(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("norm", p);
    let n = 4 + p.scale;
    let xs: Vec<Vec<Lit>> = (0..n).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    let mean = reduce_rows(&mut c, xs.clone(), p.algo);
    for (i, x) in xs.iter().enumerate() {
        // Barrel-shift x by mean's low bits (pure mux/LUT logic).
        let s0 = mean[0];
        let s1 = mean[1];
        let sh1: Vec<Lit> = (0..p.width)
            .map(|bi| {
                let from = if bi == 0 { Lit::FALSE } else { x[bi - 1] };
                c.aig.mux(s0, from, x[bi])
            })
            .collect();
        let sh2: Vec<Lit> = (0..p.width)
            .map(|bi| {
                let from = if bi < 2 { Lit::FALSE } else { sh1[bi - 2] };
                c.aig.mux(s1, from, sh1[bi])
            })
            .collect();
        c.po_bus(&format!("y{i}"), &sh2);
    }
    c
}

#[allow(unused)]
fn _rng_guard(p: &BenchParams) -> Rng {
    Rng::new(p.seed)
}
