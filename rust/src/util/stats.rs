//! Statistics helpers: geometric mean (the paper's summary statistic),
//! arithmetic mean, normalization.

/// Geometric mean of positive values. Returns 1.0 on an empty slice so that
/// normalized "no data" rows print as the identity.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean. Returns 0.0 on an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Element-wise ratio `num[i] / den[i]`, the "normalized to baseline" series
/// used by every figure in the paper.
pub fn normalize(num: &[f64], den: &[f64]) -> Vec<f64> {
    assert_eq!(num.len(), den.len());
    num.iter()
        .zip(den)
        .map(|(&n, &d)| if d.abs() < 1e-12 { 1.0 } else { n / d })
        .collect()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_empty_is_identity() {
        assert_eq!(geomean(&[]), 1.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn normalize_guards_zero_denominator() {
        let r = normalize(&[2.0, 3.0], &[4.0, 0.0]);
        assert_eq!(r, vec![0.5, 1.0]);
    }

    #[test]
    fn stddev_basic() {
        assert!(stddev(&[1.0, 1.0, 1.0]) < 1e-12);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }
}
