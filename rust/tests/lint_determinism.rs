//! Source-level determinism lints for the flow-critical modules.
//!
//! The whole pipeline advertises bit-identical results for any worker
//! count (`rust/tests/*_parallel.rs` pin it dynamically); the classic way
//! to lose that property silently is iterating a `HashMap`/`HashSet` in
//! its nondeterministic order and letting that order reach a result.
//! This lint is the static tripwire: it scans `rust/src` for identifiers
//! declared with a hash-container type and flags any line that iterates
//! them (`.iter()`, `.keys()`, `.values()`, `.drain(...)`, `for .. in`),
//! unless the line is in the reviewed allowlist below.
//!
//! It is a line-scoped heuristic, not a prover: multi-line iterator
//! chains escape it, and a `Vec` that shares a flagged identifier's name
//! trips it.  Both are acceptable for a tripwire — the allowlist exists
//! exactly so every hash-order iteration that *does* reach the scanner
//! has been reviewed as order-independent (sorted right after, reduced
//! with `.any()`/`.count()`, or accumulated into another set).
//!
//! The second lint is panic hygiene, and since PR 10 it covers **all**
//! production modules under `rust/src` (it started with the
//! fault-isolated trio `flow`/`route`/`serve` in PR 8): `flow` and
//! `route` advertise that every seed failure becomes a structured
//! [`FlowError`] record, `serve` that every malformed request becomes a
//! 4xx, and `check` (including `check::equiv`) that auditors report
//! [`Violation`]s rather than dying — so a stray `panic!` / `.unwrap()`
//! / `.expect(` on any production path is either mis-reported as an
//! internal fault by the job isolation or kills a caller that was
//! promised a structured answer.  Reviewed sites (poisoned-mutex
//! unwraps, loop-invariant pops, the strict-mode `enforce` contract,
//! deliberate fault injection) live in their own allowlist; every entry
//! must still match a line, so the list cannot rot.
//!
//! The third lint is wall-clock hygiene for the deterministic pipeline
//! stages (`flow`, `route`, `place`, `rrg`): a `Instant::now()` /
//! `SystemTime::now()` read that steers any decision there would make
//! results machine-load-dependent.  Timing belongs to the bench
//! harnesses (`rust/benches`) and the serve daemon, which are outside
//! the scanned directories by design.
//!
//! The last test is the registration guard: `Cargo.toml` sets
//! `autotests = false`, so a test file that is not declared as a
//! `[[test]]` target silently never runs (it happened to
//! `frontend_parallel` before PR 4).

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

/// Reviewed order-independent hash iterations: (path suffix, line
/// substring).  Every entry must still match a flagged line — stale
/// entries fail the lint so the list cannot rot.
const ALLOWLIST: &[(&str, &str)] = &[
    // Serialization helper: collects, then sort_unstable() on the next line.
    ("flow/diskcache.rs", "set.iter().copied().collect()"),
    // alm_nets feeds only a .filter(..).count() reduction (order-free).
    ("pack/cluster.rs", ".chain(alms[ai].outputs.iter())"),
    // Attraction-net gather: nets.sort_unstable() immediately after.
    ("pack/cluster.rs", ".chain(lbs[lb_idx].outputs.iter())"),
    // `Cell::ins` is a Vec (deterministic order); the name `ins` merely
    // collides with a local HashSet elsewhere in the file.
    ("pack/mod.rs", "cell.ins.iter().take(2).enumerate()"),
    // Candidate-net gather: nets.sort_unstable() immediately after.
    ("pack/mod.rs", ".chain(alms[alm_idx].z_inputs.iter())"),
    ("pack/mod.rs", ".chain(alms[alm_idx].outputs.iter())"),
    // Vec field collected *into* a HashSet (source order is the Vec's).
    ("pack/mod.rs", "nl.cells[l as usize].ins.iter().copied().collect()"),
    ("pack/mod.rs", "nl.cells[b as usize].ins.iter().copied().collect()"),
    // Membership predicates: .any() is order-independent.
    ("pack/mod.rs", "ins_b.iter().any("),
    ("place/mod.rs", "grid.values().any("),
    // (PR 7 removed the router's HashMap route tree — the A* scratch now
    // carries a sorted Vec arena, so no route/mod.rs entries remain.)
    // Commutative accumulation into another HashSet (pos_need inserts).
    ("techmap/mapper.rs", "for leaves in selected.values()"),
    // Key gather: order.sort_unstable() on the next line.
    ("techmap/mapper.rs", "selected.keys().copied().collect()"),
];

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Words that can sit left of a `:`/`=` without being a binding name.
const KEYWORDS: &[&str] = &["mut", "let", "pub", "in", "if", "return", "match", "ref"];

/// Identifiers this file declares with a `HashMap`/`HashSet` type:
/// `let [mut] name = HashMap::..`, `name: HashSet<..>` (bindings, struct
/// fields, and fn parameters all share these two shapes).
fn hash_names(lines: &[&str]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in lines {
        for marker in ["HashMap", "HashSet"] {
            let mut start = 0;
            while let Some(off) = line[start..].find(marker) {
                let i = start + off;
                start = i + 1;
                // The marker must be a whole path segment, not a slice of
                // a longer identifier.
                if line[..i].chars().next_back().map_or(false, is_ident)
                    || line[i + marker.len()..].chars().next().map_or(false, is_ident)
                {
                    continue;
                }
                // Walk left over type-position punctuation and `mut` to
                // reach the binder: `x: &mut HashMap<..>` binds `x`.
                let mut b = line[..i].trim_end();
                loop {
                    b = b.trim_end();
                    if b.ends_with('&') || b.ends_with('(') || b.ends_with('<') {
                        b = &b[..b.len() - 1];
                    } else if b.ends_with("mut")
                        && (b.len() == 3 || !is_ident(b.as_bytes()[b.len() - 4] as char))
                    {
                        b = &b[..b.len() - 3];
                    } else {
                        break;
                    }
                }
                let Some(rest) = b.strip_suffix(':').or_else(|| b.strip_suffix('=')) else {
                    continue; // type in return/generic position, `use` path, ...
                };
                let rest = rest.trim_end();
                let tail = rest.len()
                    - rest.chars().rev().take_while(|&c| is_ident(c)).count();
                let name = &rest[tail..];
                if !name.is_empty()
                    && !name.starts_with(|c: char| c.is_ascii_digit())
                    && !KEYWORDS.contains(&name)
                {
                    names.insert(name.to_string());
                }
            }
        }
    }
    names
}

/// Iteration adapters whose visit order is the hash order.
const ADAPTERS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
];

/// 1-based line numbers in `lines` that iterate one of `names`.
fn iteration_hits(lines: &[&str], names: &BTreeSet<String>) -> Vec<usize> {
    let mut out = Vec::new();
    for (ln, line) in lines.iter().enumerate() {
        if line.trim_start().starts_with("//") {
            continue;
        }
        let mut hit = false;
        for name in names {
            for pat in ADAPTERS {
                let needle = format!("{name}{pat}");
                let mut j = 0;
                while let Some(off) = line[j..].find(&needle) {
                    let k = j + off;
                    if !line[..k].chars().next_back().map_or(false, is_ident) {
                        hit = true;
                    }
                    j = k + 1;
                }
            }
            if line.contains("for ") {
                for form in
                    [format!("in &mut {name}"), format!("in &{name}"), format!("in {name}")]
                {
                    let Some(k) = line.find(&form) else { continue };
                    let next = line[k + form.len()..].chars().next();
                    if next.map_or(true, |c| !is_ident(c) && c != '.') {
                        hit = true;
                    }
                    break; // longest matching form decides
                }
            }
        }
        if hit {
            out.push(ln + 1);
        }
    }
    out
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
}

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn no_unreviewed_hash_iteration_in_flow_modules() {
    let src_root = repo_root().join("rust/src");
    let mut files = Vec::new();
    rs_files(&src_root, &mut files);
    assert!(!files.is_empty(), "no sources under {}", src_root.display());

    let mut offenders: Vec<String> = Vec::new();
    let mut matched = vec![false; ALLOWLIST.len()];
    for path in &files {
        let src = fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        // Test modules may iterate hash containers freely — assertions on
        // unordered views are order-independent by construction.
        let body = match src.find("#[cfg(test)]") {
            Some(p) => &src[..p],
            None => &src[..],
        };
        let lines: Vec<&str> = body.lines().collect();
        let names = hash_names(&lines);
        if names.is_empty() {
            continue;
        }
        let rel = path
            .strip_prefix(&src_root)
            .expect("source under src root")
            .to_string_lossy()
            .replace('\\', "/");
        for ln in iteration_hits(&lines, &names) {
            let text = lines[ln - 1].trim();
            let allowed = ALLOWLIST.iter().enumerate().any(|(i, (suffix, pat))| {
                let ok = rel.ends_with(suffix) && text.contains(pat);
                if ok {
                    matched[i] = true;
                }
                ok
            });
            if !allowed {
                offenders.push(format!("rust/src/{rel}:{ln}: {text}"));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "hash-order iteration in flow-critical code (sort the keys, reduce \
         order-independently, or review + allowlist in {}):\n  {}",
        file!(),
        offenders.join("\n  ")
    );
    let stale: Vec<String> = ALLOWLIST
        .iter()
        .zip(&matched)
        .filter(|(_, &m)| !m)
        .map(|((suffix, pat), _)| format!("({suffix:?}, {pat:?})"))
        .collect();
    assert!(
        stale.is_empty(),
        "stale allowlist entries (the code they excused is gone — delete them):\n  {}",
        stale.join("\n  ")
    );
}

/// Reviewed panic sites in `rust/src` production code: (path suffix,
/// line substring).  Same staleness contract as [`ALLOWLIST`].
///
/// A `Mutex::lock().unwrap()` only panics when another thread already
/// panicked while holding the lock — propagating that poison is the
/// correct response, not a recovery gap.
const PANIC_ALLOWLIST: &[(&str, &str)] = &[
    // OnceLock'd COFFE sizing cache: lock-poison propagation (the
    // `.unwrap()` sits on its own line of the builder chain).
    ("arch/mod.rs", ".unwrap();"),
    // Wallace-tree reduction worklist: the surrounding `while` guard
    // proves `cur` non-empty at the pop.
    ("bench_suites/koios.rs", "cur.pop().unwrap()"),
    // `y` is a freshly built non-empty bus (length fixed above).
    ("bench_suites/kratos.rs", "y.last().unwrap()"),
    // The documented CheckMode::Strict contract: enforce() panics so
    // the engine's job isolation converts it into a FlowError.
    ("check/mod.rs", "panic!(\"strict check failed"),
    // Worker-pool result slots: lock-poison propagation.
    ("coordinator/mod.rs", ".lock().unwrap()"),
    // A worker that died mid-job already carries the panic being
    // re-propagated here; the into_inner on a joined pool cannot race.
    ("coordinator/mod.rs", "into_inner().unwrap()"),
    ("flow/diskcache.rs", ".lock().unwrap()"),
    ("flow/engine.rs", ".lock().unwrap()"),
    // Condvar re-acquisition after a wait: the same poison-propagation
    // argument as `lock()` — only a panicking peer poisons the mutex.
    ("flow/engine.rs", "cond.wait(st).unwrap()"),
    // CLI single-cell grid: the plan was built with exactly one bench
    // and one variant two lines above.
    ("main.rs", ".expect(\"one grid cell\")"),
    // Experiment harness grids are built with the popped rows present;
    // a missing row is a harness bug worth dying loudly over.
    ("report/mod.rs", ".expect(\"one variant row\")"),
    ("report/mod.rs", ".expect(\"dd5 row\")"),
    ("report/mod.rs", ".expect(\"baseline row\")"),
    // Kratos table: the looked-up bench name comes from the suite's own
    // name list on the previous line.
    ("report/mod.rs", ".unwrap();"),
    ("route/mod.rs", ".lock().unwrap()"),
    // The scratch lease holds `Some` for its whole lifetime by
    // construction (set in `lease()`, taken only in `drop`).
    ("route/mod.rs", ".expect(\"scratch held for lease lifetime\")"),
    // Lookahead memo-map: lock-poison propagation.
    ("rrg/lookahead.rs", ".lock().unwrap()"),
    // Synthesis-frontend invariants: violating any of these means the
    // circuit builder itself is broken (construction-order contracts),
    // not that an input was malformed — documented panics, pre-flow.
    ("synth/circuit.rs", ".expect(\"not an FF q literal\")"),
    ("synth/circuit.rs", ".expect(\"forward reference in absorb\")"),
    ("synth/circuit.rs", "chain_map[chain as usize].unwrap()"),
    ("synth/circuit.rs", ".expect(\"combinational loop or unresolved chain\")"),
    // Multiplier compressor trees: pops guarded by the length checks of
    // the surrounding reduction loops; `best` is set on iteration 0.
    ("synth/multiplier.rs", "rows.pop().unwrap()"),
    ("synth/multiplier.rs", "best.unwrap()"),
    ("synth/multiplier.rs", "seq.last().unwrap()"),
    ("synth/multiplier.rs", "bits.pop().unwrap()"),
    // Mapper wave invariants: fanin cuts exist because waves are
    // levelized; the cone walk cannot escape enumerated cut leaves.
    ("techmap/mapper.rs", ".expect(\"fanin cuts from lower wave\")"),
    ("techmap/mapper.rs", ".partial_cmp(&y.area_flow).unwrap()"),
    ("techmap/mapper.rs", ".expect(\"every node enumerated\")"),
    ("techmap/mapper.rs", "panic!(\"cone escapes its cut leaves\")"),
    // Deliberate fault injection: panicking is this module's purpose.
    ("util/fault.rs", "panic!("),
];

/// Constructs that turn a recoverable condition into a process panic.
const PANIC_PATTERNS: &[&str] = &["panic!(", ".unwrap()", ".expect("];

#[test]
fn no_unreviewed_panics_in_production_modules() {
    let src_root = repo_root().join("rust/src");
    let mut files = Vec::new();
    rs_files(&src_root, &mut files);
    assert!(!files.is_empty(), "no sources under {}", src_root.display());

    let mut offenders: Vec<String> = Vec::new();
    let mut matched = vec![false; PANIC_ALLOWLIST.len()];
    for path in &files {
        let src = fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        // Tests may panic freely — that is what assertions are.
        let body = match src.find("#[cfg(test)]") {
            Some(p) => &src[..p],
            None => &src[..],
        };
        let rel = path
            .strip_prefix(&src_root)
            .expect("source under src root")
            .to_string_lossy()
            .replace('\\', "/");
        for (ln, line) in body.lines().enumerate() {
            let text = line.trim();
            if text.starts_with("//") {
                continue;
            }
            if !PANIC_PATTERNS.iter().any(|p| text.contains(p)) {
                continue;
            }
            let allowed = PANIC_ALLOWLIST.iter().enumerate().any(|(i, (suffix, pat))| {
                let ok = rel.ends_with(suffix) && text.contains(pat);
                if ok {
                    matched[i] = true;
                }
                ok
            });
            if !allowed {
                offenders.push(format!("rust/src/{rel}:{}: {text}", ln + 1));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "panic-prone construct on a production path \
         (return a FlowError / util::error::Error / check::Violation \
         instead, or review + allowlist in {}):\n  {}",
        file!(),
        offenders.join("\n  ")
    );
    let stale: Vec<String> = PANIC_ALLOWLIST
        .iter()
        .zip(&matched)
        .filter(|(_, &m)| !m)
        .map(|((suffix, pat), _)| format!("({suffix:?}, {pat:?})"))
        .collect();
    assert!(
        stale.is_empty(),
        "stale panic-allowlist entries (the code they excused is gone — delete them):\n  {}",
        stale.join("\n  ")
    );
}

/// Wall-clock reads that would make a deterministic stage's behavior
/// depend on machine load.
const CLOCK_PATTERNS: &[&str] = &["Instant::now", "SystemTime::now"];

/// Reviewed wall-clock reads in the deterministic stages: (path suffix,
/// line substring).  Currently empty — no pipeline stage reads a clock;
/// timing lives in `rust/benches` and `serve`, which are outside the
/// scanned directories.  Same staleness contract as [`ALLOWLIST`].
const CLOCK_ALLOWLIST: &[(&str, &str)] = &[];

/// 1-based line numbers of un-commented wall-clock reads.
fn clock_hits(body: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (ln, line) in body.lines().enumerate() {
        let text = line.trim();
        if text.starts_with("//") {
            continue;
        }
        if CLOCK_PATTERNS.iter().any(|p| text.contains(p)) {
            out.push((ln + 1, text.to_string()));
        }
    }
    out
}

#[test]
fn no_wall_clock_in_deterministic_stages() {
    let src_root = repo_root().join("rust/src");
    let mut files = Vec::new();
    for module in ["flow", "route", "place", "rrg"] {
        rs_files(&src_root.join(module), &mut files);
    }
    assert!(!files.is_empty(), "no sources under rust/src/{{flow,route,place,rrg}}");

    let mut offenders: Vec<String> = Vec::new();
    let mut matched = vec![false; CLOCK_ALLOWLIST.len()];
    for path in &files {
        let src = fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let body = match src.find("#[cfg(test)]") {
            Some(p) => &src[..p],
            None => &src[..],
        };
        let rel = path
            .strip_prefix(&src_root)
            .expect("source under src root")
            .to_string_lossy()
            .replace('\\', "/");
        for (ln, text) in clock_hits(body) {
            let allowed = CLOCK_ALLOWLIST.iter().enumerate().any(|(i, (suffix, pat))| {
                let ok = rel.ends_with(suffix) && text.contains(pat);
                if ok {
                    matched[i] = true;
                }
                ok
            });
            if !allowed {
                offenders.push(format!("rust/src/{rel}:{ln}: {text}"));
            }
        }
    }
    assert!(
        offenders.is_empty(),
        "wall-clock read in a deterministic pipeline stage (derive the \
         decision from the artifact, move timing to rust/benches, or \
         review + allowlist in {}):\n  {}",
        file!(),
        offenders.join("\n  ")
    );
    let stale: Vec<String> = CLOCK_ALLOWLIST
        .iter()
        .zip(&matched)
        .filter(|(_, &m)| !m)
        .map(|((suffix, pat), _)| format!("({suffix:?}, {pat:?})"))
        .collect();
    assert!(
        stale.is_empty(),
        "stale clock-allowlist entries (the code they excused is gone — delete them):\n  {}",
        stale.join("\n  ")
    );
}

/// The clock allowlist is empty, so the stale-entry guard alone cannot
/// prove the detector works — this synthetic probe does.
#[test]
fn wall_clock_detector_fires_on_synthetic_input() {
    let body = "\
fn f() {
    // let t = Instant::now(); (comment — must not fire)
    let t0 = std::time::Instant::now();
    let wall = SystemTime::now();
    let ok = mtime_of(path);
}
";
    let hits = clock_hits(body);
    let lines: Vec<usize> = hits.iter().map(|(ln, _)| *ln).collect();
    assert_eq!(lines, vec![3, 4], "detector must flag exactly the two real reads");
}

#[test]
fn every_test_file_is_registered_in_cargo_toml() {
    let root = repo_root();
    let manifest = fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml");
    let mut tests: Vec<PathBuf> = Vec::new();
    rs_files(&root.join("rust/tests"), &mut tests);
    assert!(!tests.is_empty(), "no files under rust/tests");
    let missing: Vec<String> = tests
        .iter()
        .filter_map(|p| {
            let rel = format!(
                "rust/tests/{}",
                p.file_name().expect("file name").to_string_lossy()
            );
            // A [[test]] stanza must point at the file verbatim.
            (!manifest.contains(&format!("path = \"{rel}\""))).then_some(rel)
        })
        .collect();
    assert!(
        missing.is_empty(),
        "Cargo.toml sets autotests = false, so these test files silently \
         never run until declared as [[test]] targets: {missing:?}"
    );
}
