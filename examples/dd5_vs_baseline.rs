//! Architecture study: sweep all three suites over baseline / DD5 / DD6
//! and print per-suite geomean area / CPD / ADP ratios — a compact version
//! of the paper's Figs. 6 and 7.
//!
//!     cargo run --release --example dd5_vs_baseline

use double_duty::arch::ArchVariant;
use double_duty::bench_suites::{all_suites, BenchParams, Suite};
use double_duty::coordinator::{default_workers, run_jobs, Job};
use double_duty::flow::FlowOpts;
use double_duty::util::stats::geomean;

fn main() {
    let params = BenchParams::default();
    let benches = all_suites(&params);
    let opts = FlowOpts { seeds: vec![1], place_effort: 0.25, ..Default::default() };

    let run = |variant: ArchVariant| {
        let jobs = benches
            .iter()
            .map(|b| Job { bench: b.clone(), variant, opts: opts.clone() })
            .collect();
        run_jobs(jobs, default_workers())
    };
    let base = run(ArchVariant::Baseline);
    let dd5 = run(ArchVariant::Dd5);
    let dd6 = run(ArchVariant::Dd6);

    println!("{:<8} {:<6} {:>10} {:>10} {:>10}", "suite", "arch", "area", "cpd", "adp");
    for suite in [Suite::Vtr, Suite::Koios, Suite::Kratos] {
        for (name, rs) in [("dd5", &dd5), ("dd6", &dd6)] {
            let ratio = |f: &dyn Fn(&double_duty::flow::FlowResult,
                                    &double_duty::flow::FlowResult) -> f64| {
                let v: Vec<f64> = benches
                    .iter()
                    .zip(rs.iter().zip(&base))
                    .filter(|(b, _)| b.suite == suite)
                    .map(|(_, (r, b))| f(r, b))
                    .collect();
                geomean(&v)
            };
            println!(
                "{:<8} {:<6} {:>10.3} {:>10.3} {:>10.3}",
                suite.name(),
                name,
                ratio(&|r, b| r.alm_area_mwta / b.alm_area_mwta),
                ratio(&|r, b| r.cpd_ns / b.cpd_ns),
                ratio(&|r, b| r.adp / b.adp),
            );
        }
    }
}
