//! Experiment coordinator: a thread-pool job runner for benchmark sweeps.
//!
//! The offline environment has no tokio, so this is a std::thread worker
//! pool over an MPSC job queue.  Experiments submit (benchmark, variant,
//! opts) jobs; the coordinator fans them out and collects `FlowResult`s in
//! submission order, so multi-circuit sweeps (Figs. 5–7) saturate whatever
//! cores exist while staying deterministic per job (each job carries its
//! own seeds).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use crate::arch::ArchVariant;
use crate::bench_suites::Benchmark;
use crate::flow::{run_benchmark, FlowOpts, FlowResult};

/// One experiment job.
pub struct Job {
    pub bench: Benchmark,
    pub variant: ArchVariant,
    pub opts: FlowOpts,
}

/// Run all jobs on `workers` threads; results in submission order.
pub fn run_jobs(jobs: Vec<Job>, workers: usize) -> Vec<FlowResult> {
    let workers = workers.max(1).min(jobs.len().max(1));
    if workers <= 1 {
        return jobs
            .into_iter()
            .map(|j| run_benchmark(&j.bench, j.variant, &j.opts))
            .collect();
    }
    let n = jobs.len();
    let queue = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<(usize, Job)>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, FlowResult)>();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = { queue.lock().unwrap().pop() };
            let Some((idx, j)) = job else { break };
            let r = run_benchmark(&j.bench, j.variant, &j.opts);
            if tx.send((idx, r)).is_err() {
                break;
            }
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<FlowResult>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        slots[idx] = Some(r);
    }
    for h in handles {
        let _ = h.join();
    }
    slots.into_iter().map(|s| s.expect("worker died before finishing job")).collect()
}

/// Number of workers: respects DDUTY_WORKERS, else available parallelism.
pub fn default_workers() -> usize {
    if let Ok(w) = std::env::var("DDUTY_WORKERS") {
        if let Ok(n) = w.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{vtr_suite, BenchParams};

    #[test]
    fn jobs_preserve_order_and_complete() {
        let params = BenchParams::default();
        let suite = vtr_suite(&params);
        let opts = FlowOpts {
            seeds: vec![1],
            place_effort: 0.05,
            route: false,
            ..Default::default()
        };
        let jobs: Vec<Job> = suite[..3]
            .iter()
            .map(|b| Job { bench: b.clone(), variant: ArchVariant::Baseline, opts: opts.clone() })
            .collect();
        let names: Vec<String> = jobs.iter().map(|j| j.bench.name.clone()).collect();
        let results = run_jobs(jobs, 2);
        assert_eq!(results.len(), 3);
        for (r, n) in results.iter().zip(&names) {
            assert_eq!(&r.name, n);
        }
    }

    #[test]
    fn single_worker_sequential_path() {
        let params = BenchParams::default();
        let suite = vtr_suite(&params);
        let opts = FlowOpts { seeds: vec![1], place_effort: 0.05, route: false, ..Default::default() };
        let jobs = vec![Job {
            bench: suite[0].clone(),
            variant: ArchVariant::Dd5,
            opts,
        }];
        let results = run_jobs(jobs, 1);
        assert_eq!(results.len(), 1);
    }
}
