//! Semantic equivalence checking (`check::equiv`): mutation tests that
//! prove the checker *fires* (with a replaying counterexample witness)
//! on each corruption class of the map/pack logic-neutrality contract,
//! the suite-wide clean proof over every shipped benchmark, and the
//! `--jobs` bit-identical-report invariant.
//!
//! Mutations edit the mapped netlist directly, keeping `Net::sinks`
//! consistent with `Cell::ins` (the index builder debug-asserts acyclic
//! consistency), so the only thing wrong with the artifact is its
//! *logic* — exactly what the structural auditors cannot see and
//! `equiv.mismatch` must.

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{all_suites, BenchParams};
use double_duty::check::equiv::{equiv_mapped, equiv_packed, EquivOpts, EquivOutcome};
use double_duty::netlist::{CellId, CellKind, NetId, Netlist};
use double_duty::pack::{pack, PackOpts};
use double_duty::synth::Circuit;
use double_duty::techmap::{map_circuit, MapOpts};

/// A small circuit with a real carry chain plus LUT logic: 4+4 ripple
/// adder, a majority cone, and an XOR cone over the PIs.
fn chain_circ() -> Circuit {
    let mut c = Circuit::new("equiv_mut");
    let x = c.pi_bus("x", 4);
    let y = c.pi_bus("y", 4);
    let s = c.ripple_add(&x, &y);
    c.po_bus("s", &s);
    let m = c.aig.maj3(x[0], y[1], x[2]);
    let t = c.aig.xor3(x[3], y[0], m);
    c.po("m", m);
    c.po("t", t);
    c
}

/// Re-point input `pin` of `cell` to `new_net`, keeping sink lists
/// consistent so the netlist stays structurally well-formed.
fn repoint(nl: &mut Netlist, cell: CellId, pin: usize, new_net: NetId) {
    let old = nl.cells[cell as usize].ins[pin];
    nl.cells[cell as usize].ins[pin] = new_net;
    nl.nets[old as usize]
        .sinks
        .retain(|&(c, p)| !(c == cell && p as usize == pin));
    nl.nets[new_net as usize].sinks.push((cell, pin as u8));
}

/// The mutation must produce `equiv.mismatch` findings — nothing else —
/// and every witness must replay to a real spec/impl disagreement
/// through the two independent evaluators.
fn assert_fires_mismatch(outcome: &EquivOutcome, what: &str) {
    assert!(
        !outcome.violations.is_empty(),
        "{what}: corrupted netlist reported clean"
    );
    for v in &outcome.violations {
        assert_eq!(v.code, "equiv.mismatch", "{what}: unexpected finding {v}");
    }
    assert_eq!(
        outcome.violations.len(),
        outcome.mismatches.len(),
        "{what}: every violation carries a witness"
    );
    for mm in &outcome.mismatches {
        assert_ne!(
            mm.spec_val, mm.impl_val,
            "{what}: witness for {} does not replay to a disagreement",
            mm.output
        );
    }
    assert_eq!(outcome.summary.undecided, 0, "{what}: left cones undecided");
}

#[test]
fn healthy_mapped_netlist_is_equivalent() {
    let c = chain_circ();
    let nl = map_circuit(&c, &MapOpts::default());
    let out = equiv_mapped(&c, &nl, &EquivOpts::default());
    assert!(out.is_clean(), "violations: {:?}", out.violations);
    assert!(out.summary.all_proved());
    assert_eq!(out.summary.outputs, c.pos.len());
}

#[test]
fn flipped_lut_truth_bit_fires_mismatch_with_witness() {
    let c = chain_circ();
    let base = map_circuit(&c, &MapOpts::default());
    // Restrict to LUTs fed directly (and only) by PI nets: their input
    // rows are all reachable and independent, so *every* single-bit
    // corruption of the table is observable and must be caught.
    let luts: Vec<usize> = base
        .cells
        .iter()
        .enumerate()
        .filter(|(_, cl)| {
            matches!(cl.kind, CellKind::Lut { .. })
                && cl.ins.iter().all(|&n| {
                    base.nets[n as usize].driver.map_or(false, |(c, _)| {
                        matches!(base.cells[c as usize].kind, CellKind::Input)
                    })
                })
        })
        .map(|(i, _)| i)
        .collect();
    assert!(!luts.is_empty(), "circuit must map at least one PI-fed LUT");
    for &li in &luts {
        let CellKind::Lut { k, .. } = base.cells[li].kind else { unreachable!() };
        for bit in 0..(1u32 << k.min(4)) {
            let mut nl = base.clone();
            let CellKind::Lut { truth, .. } = &mut nl.cells[li].kind else { unreachable!() };
            *truth ^= 1u64 << bit;
            let out = equiv_mapped(&c, &nl, &EquivOpts::default());
            assert_fires_mismatch(&out, &format!("lut {li} bit {bit}"));
        }
    }
}

#[test]
fn repointed_carry_in_fires_mismatch_with_witness() {
    let c = chain_circ();
    let mut nl = map_circuit(&c, &MapOpts::default());
    let chain = nl.chain_cells(0);
    assert!(chain.len() >= 3, "need a real chain, got {} bits", chain.len());
    // Feed bit 2's carry-in from bit 1's *sum* instead of its cout.
    // (Swapping a/b/cin pins would be invisible: xor3/maj3 are
    // symmetric.  Re-pointing the net changes the function.)
    let wrong = nl.cells[chain[1] as usize].outs[0];
    repoint(&mut nl, chain[2], 2, wrong);
    let out = equiv_mapped(&c, &nl, &EquivOpts::default());
    assert_fires_mismatch(&out, "carry-in repoint");
}

#[test]
fn dropped_chain_link_fires_mismatch_with_witness() {
    let c = chain_circ();
    let mut nl = map_circuit(&c, &MapOpts::default());
    let chain = nl.chain_cells(0);
    assert!(chain.len() >= 3);
    // Skip link 1: bit 2 takes its carry from bit 0's cout directly.
    let cout0 = nl.cells[chain[0] as usize].outs[1];
    repoint(&mut nl, chain[2], 2, cout0);
    let out = equiv_mapped(&c, &nl, &EquivOpts::default());
    assert_fires_mismatch(&out, "dropped chain link");
}

#[test]
fn packed_view_of_healthy_netlist_is_equivalent_per_variant() {
    let c = chain_circ();
    let nl = map_circuit(&c, &MapOpts::default());
    for variant in [ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6] {
        let arch = Arch::coffe(variant);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let out = equiv_packed(&c, &nl, &packing, &EquivOpts::default());
        assert!(
            out.is_clean(),
            "[{}] violations: {:?}",
            variant.name(),
            out.violations
        );
        assert!(out.summary.all_proved(), "[{}]", variant.name());
    }
}

/// The acceptance gate: every shipped benchmark proves equivalent after
/// map and after pack, on every architecture variant — zero `equiv.*`
/// findings anywhere.
#[test]
fn all_shipped_suites_prove_clean_post_map_and_post_pack() {
    let params = BenchParams::default();
    let opts = EquivOpts::default();
    for b in all_suites(&params) {
        let circ = b.generate();
        let nl = map_circuit(&circ, &MapOpts::default());
        let out = equiv_mapped(&circ, &nl, &opts);
        assert!(
            out.is_clean() && out.summary.all_proved(),
            "{} post-map: {:?}",
            b.name,
            out.violations
        );
        for variant in [ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6] {
            let arch = Arch::coffe(variant);
            let packing = pack(&nl, &arch, &PackOpts::default());
            let out = equiv_packed(&circ, &nl, &packing, &opts);
            assert!(
                out.is_clean() && out.summary.all_proved(),
                "{} post-pack [{}]: {:?}",
                b.name,
                variant.name(),
                out.violations
            );
        }
    }
}

/// Reports are bit-identical for any `--jobs`: same violations (rendered
/// text included), same witnesses, same summary counters.
#[test]
fn reports_are_bit_identical_for_any_jobs() {
    let c = chain_circ();
    let mut nl = map_circuit(&c, &MapOpts::default());
    // Corrupt two cones so the SAT wave has real work to schedule.
    let luts: Vec<usize> = nl
        .cells
        .iter()
        .enumerate()
        .filter(|(_, cl)| matches!(cl.kind, CellKind::Lut { .. }))
        .map(|(i, _)| i)
        .collect();
    for &li in luts.iter().take(2) {
        let CellKind::Lut { truth, .. } = &mut nl.cells[li].kind else { unreachable!() };
        *truth ^= 1;
    }
    let render = |o: &EquivOutcome| -> Vec<String> {
        o.violations.iter().map(|v| v.to_string()).collect()
    };
    let base = equiv_mapped(&c, &nl, &EquivOpts { jobs: 1, ..Default::default() });
    for jobs in [2usize, 4, 7] {
        let out = equiv_mapped(&c, &nl, &EquivOpts { jobs, ..Default::default() });
        assert_eq!(render(&base), render(&out), "jobs={jobs}");
        assert_eq!(base.mismatches, out.mismatches, "jobs={jobs}");
        assert_eq!(base.summary, out.summary, "jobs={jobs}");
    }
}
