//! Stratix-10-like FPGA architecture model with the Double-Duty variants.
//!
//! Encodes the logic-block microarchitecture from the paper (§II-A, §III):
//! ALMs with four 4-LUTs (fracturable to two 5-LUTs or one 6-LUT), two 1-bit
//! full adders on a carry chain, 8 general inputs (A–H), and — in the
//! Double-Duty variants — four extra adder-bypass inputs (Z1–Z4) fed by a
//! sparsely populated secondary crossbar (the AddMux Crossbar).
//!
//! Three variants:
//! * [`ArchVariant::Baseline`] — adder operands must come from LUT outputs;
//!   using either adder makes the ALM's LUT outputs unavailable.
//! * [`ArchVariant::Dd5`] — AddMux + Z1–Z4 allow the adders to be fed
//!   directly; two ALM output pins stay allocated to the adders (O1, O3)
//!   and two to independent 5-LUT outputs (O2, O4).
//! * [`ArchVariant::Dd6`] — output multiplexing reworked so a 6-LUT can be
//!   used concurrently with both adders (at an output-mux delay cost).

pub mod delays;
pub mod device;

pub use delays::Delays;
pub use device::Device;

/// Logic-element architecture variant under evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArchVariant {
    Baseline,
    Dd5,
    Dd6,
}

impl ArchVariant {
    pub fn name(self) -> &'static str {
        match self {
            ArchVariant::Baseline => "baseline",
            ArchVariant::Dd5 => "dd5",
            ArchVariant::Dd6 => "dd6",
        }
    }

    /// Number of direct adder-bypass inputs per ALM (Z1–Z4).
    pub fn z_inputs(self) -> u8 {
        match self {
            ArchVariant::Baseline => 0,
            ArchVariant::Dd5 | ArchVariant::Dd6 => 4,
        }
    }

    /// May an ALM expose independent LUT outputs while its adders are used?
    pub fn concurrent_lut5(self) -> bool {
        !matches!(self, ArchVariant::Baseline)
    }

    /// May a 6-LUT be used concurrently with the adders?
    pub fn concurrent_lut6(self) -> bool {
        matches!(self, ArchVariant::Dd6)
    }
}

/// Adaptive Logic Module resource budget.
#[derive(Clone, Copy, Debug)]
pub struct AlmSpec {
    /// General-purpose inputs A–H.
    pub general_inputs: u8,
    /// Adder-bypass inputs Z1–Z4 (0 on baseline).
    pub z_inputs: u8,
    /// Output pins (O1–O4).
    pub outputs: u8,
    /// 4-LUT units (two make a 5-LUT, four a 6-LUT).
    pub lut4_units: u8,
    /// 1-bit full adders on the carry chain.
    pub adders: u8,
    /// Flip-flops (packed with either LUT or adder outputs).
    pub ffs: u8,
}

impl AlmSpec {
    pub fn for_variant(v: ArchVariant) -> Self {
        AlmSpec {
            general_inputs: 8,
            z_inputs: v.z_inputs(),
            outputs: 4,
            lut4_units: 4,
            adders: 2,
            ffs: 4,
        }
    }
}

/// Logic block (LAB) organization.
#[derive(Clone, Copy, Debug)]
pub struct LbSpec {
    /// ALMs per logic block (10, as in Stratix 10 and the paper).
    pub alms: u8,
    /// LB input pins from the inter-block routing (60).
    pub inputs: u16,
    /// LB output pins (2 per ALM).
    pub outputs: u16,
    /// Of the 60 LB inputs, how many the AddMux crossbar taps (10 -> ~17%
    /// populated secondary crossbar; §III-A).
    pub addmux_xbar_taps: u16,
    /// Packer external-pin utilization limit (the paper sets VTR's
    /// `target_ext_pin_util` to 0.9).
    pub target_ext_pin_util: f64,
}

impl Default for LbSpec {
    fn default() -> Self {
        LbSpec {
            alms: 10,
            inputs: 60,
            outputs: 40,
            addmux_xbar_taps: 10,
            target_ext_pin_util: 0.9,
        }
    }
}

/// Inter-block routing parameters (scaled from the paper's channel width of
/// 400; see DESIGN.md "Scaling note").
#[derive(Clone, Copy, Debug)]
pub struct RoutingSpec {
    /// Wires per channel (per direction pair, VPR-style total).
    pub channel_width: u16,
    /// Logical wire segment length in tiles.
    pub segment_len: u8,
    /// Input connection-block flexibility: fraction of channel wires an LB
    /// input pin can connect to.
    pub fc_in: f64,
    /// Output connection flexibility.
    pub fc_out: f64,
}

impl Default for RoutingSpec {
    fn default() -> Self {
        RoutingSpec { channel_width: 56, segment_len: 4, fc_in: 0.15, fc_out: 0.1 }
    }
}

/// Area model in minimum-width transistor areas (MWTA).
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// One ALM, including its share of the local crossbar.
    pub alm_mwta: f64,
    /// Per-ALM share of the AddMux (DD variants only).
    pub addmux_mwta: f64,
    /// Per-ALM share of the AddMux crossbar (DD variants only).
    pub addmux_xbar_mwta: f64,
    /// Non-logic tile overhead (routing mux share etc.), per ALM.
    pub tile_overhead_mwta: f64,
}

impl AreaModel {
    /// Paper Table I values (used until `coffe` recomputes them).
    pub fn paper(v: ArchVariant) -> Self {
        let (addmux, xbar) = match v {
            ArchVariant::Baseline => (0.0, 0.0),
            // DD6's extra output muxing is folded into a slightly larger
            // AddMux share (paper evaluates only its delay cost in detail).
            ArchVariant::Dd5 => (1.698, 77.91),
            ArchVariant::Dd6 => (2.5, 77.91),
        };
        let alm = match v {
            ArchVariant::Baseline => 2167.3,
            ArchVariant::Dd5 => 2366.6,
            ArchVariant::Dd6 => 2390.0,
        };
        // Tile overhead calibrated so DD5's +199.3 MWTA/ALM logic delta is
        // +3.72% of the *tile*: total tile/ALM ~= 199.3/0.0372 - 2167.3.
        AreaModel {
            alm_mwta: alm,
            addmux_mwta: addmux,
            addmux_xbar_mwta: xbar,
            tile_overhead_mwta: 3191.0,
        }
    }

    /// Total MWTA per ALM slot, including tile overhead.
    pub fn per_alm_total(&self) -> f64 {
        self.alm_mwta + self.tile_overhead_mwta
    }
}

/// A complete architecture: variant + specs + timing + area.
#[derive(Clone, Debug)]
pub struct Arch {
    pub variant: ArchVariant,
    pub alm: AlmSpec,
    pub lb: LbSpec,
    pub routing: RoutingSpec,
    pub delays: Delays,
    pub area: AreaModel,
}

impl Arch {
    /// Architecture with the paper's published component values.
    pub fn paper(variant: ArchVariant) -> Self {
        Arch {
            variant,
            alm: AlmSpec::for_variant(variant),
            lb: LbSpec::default(),
            routing: RoutingSpec::default(),
            delays: Delays::paper(variant),
            area: AreaModel::paper(variant),
        }
    }

    /// Architecture with component values recomputed by the COFFE-like
    /// sizing engine (ties Tables I/II into the end-to-end flow).
    /// Sizing runs once per variant and is cached.
    pub fn coffe(variant: ArchVariant) -> Self {
        use std::collections::HashMap;
        use std::sync::{Mutex, OnceLock};
        static CACHE: OnceLock<Mutex<HashMap<ArchVariant, Arch>>> = OnceLock::new();
        let mut cache = CACHE
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap();
        cache
            .entry(variant)
            .or_insert_with(|| {
                let mut a = Self::paper(variant);
                let rpt = crate::coffe::model_variant(variant);
                a.delays = rpt.delays;
                a.area = rpt.area;
                a
            })
            .clone()
    }

    /// Logic-cell capacity of one LB for quick sizing estimates.
    pub fn lb_adder_bits(&self) -> usize {
        self.lb.alms as usize * self.alm.adders as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_capabilities() {
        assert_eq!(ArchVariant::Baseline.z_inputs(), 0);
        assert_eq!(ArchVariant::Dd5.z_inputs(), 4);
        assert!(!ArchVariant::Baseline.concurrent_lut5());
        assert!(ArchVariant::Dd5.concurrent_lut5());
        assert!(!ArchVariant::Dd5.concurrent_lut6());
        assert!(ArchVariant::Dd6.concurrent_lut6());
    }

    #[test]
    fn paper_area_delta_matches_table1() {
        let b = AreaModel::paper(ArchVariant::Baseline);
        let d = AreaModel::paper(ArchVariant::Dd5);
        // Table I: 2167.3 -> 2366.6 per ALM; tile delta +3.72%.
        let tile_delta = (d.per_alm_total() / b.per_alm_total() - 1.0) * 100.0;
        assert!((tile_delta - 3.72).abs() < 0.05, "tile delta {tile_delta}");
    }

    #[test]
    fn lb_capacity() {
        let a = Arch::paper(ArchVariant::Baseline);
        assert_eq!(a.lb_adder_bits(), 20);
        assert_eq!(a.lb.inputs, 60);
    }
}
