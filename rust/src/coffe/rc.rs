//! Technology constants and Elmore-delay RC evaluation.
//!
//! Transistors are characterized by width `w` in minimum-width units:
//! on-resistance `R = r_min / w`, gate capacitance `c_gate_min * w`,
//! drain/source junction capacitance `c_drain_min * w`.  Layout area uses
//! COFFE's quadratic MWTA fit `0.447 + 0.128*w + 0.425*w^2` ... we use the
//! published COFFE form `area(w) = 0.447 + 0.660w + 0.150w^2` normalized so
//! `area(1) = 1` MWTA (one minimum-width transistor = 1 MWTA by definition
//! after normalization).

/// Technology parameters (nominally 20 nm, anchored to the paper's
/// published Stratix-10-like component values — see module docs).
#[derive(Clone, Copy, Debug)]
pub struct Tech {
    /// On-resistance of a minimum-width NMOS pass transistor (ohms).
    pub r_min: f64,
    /// Gate capacitance per minimum width (fF).
    pub c_gate_min: f64,
    /// Drain junction capacitance per minimum width (fF).
    pub c_drain_min: f64,
    /// PMOS mobility penalty: a PMOS of width w behaves like NMOS of w/beta.
    pub beta: f64,
    /// Local interconnect wire capacitance per tile-relative unit (fF).
    pub c_wire: f64,
}

impl Tech {
    /// 20 nm-class constants. The absolute values are anchored so the
    /// sized baseline local crossbar lands at Table I's 72.61 ps / 289.6
    /// MWTA; all other components are *predictions* of the model.
    pub fn n20() -> Self {
        Tech {
            r_min: 11_000.0,
            c_gate_min: 0.050,
            c_drain_min: 0.033,
            beta: 1.8,
            c_wire: 0.18,
        }
    }

    /// NMOS on-resistance at width `w` (min-width units).
    #[inline]
    pub fn r_nmos(&self, w: f64) -> f64 {
        self.r_min / w
    }

    /// Inverter equivalent drive resistance at size `w` (averaged
    /// pull-up/pull-down with the PMOS sized beta*w for symmetry).
    #[inline]
    pub fn r_inv(&self, w: f64) -> f64 {
        self.r_min / w
    }

    /// Inverter input gate capacitance at size `w` (NMOS w + PMOS beta*w).
    #[inline]
    pub fn c_inv_in(&self, w: f64) -> f64 {
        self.c_gate_min * w * (1.0 + self.beta)
    }

    /// Inverter output (drain) capacitance at size `w`.
    #[inline]
    pub fn c_inv_out(&self, w: f64) -> f64 {
        self.c_drain_min * w * (1.0 + self.beta)
    }
}

/// MWTA layout area of one transistor of width `w` (COFFE quadratic fit,
/// normalized to `area(1) = 1`).
pub fn transistor_area_mwta(w: f64) -> f64 {
    let raw = |w: f64| 0.447 + 0.660 * w + 0.150 * w * w;
    raw(w) / raw(1.0)
}

/// One node of an RC ladder: series resistance into the node and the
/// capacitance hanging on it.
#[derive(Clone, Copy, Debug)]
pub struct RcStage {
    pub r: f64,
    pub c: f64,
}

/// Elmore delay of a ladder (ps given ohms and fF: R[Ω]·C[fF] = 1e-3 ps...
/// Ω·fF = 1e-15 s·1e0 = fs·1e0; numerically Ω*fF = 1e-3 ps so we scale).
/// delay = 0.69 * sum_i R_upstream(i) * C_i (the 0.69 = ln(2) step factor).
pub fn elmore_ps(stages: &[RcStage]) -> f64 {
    let mut delay = 0.0;
    let mut r_up = 0.0;
    for s in stages {
        r_up += s.r;
        delay += r_up * s.c;
    }
    0.69 * delay * 1e-3 // ohm * fF = 1e-15 s = 1e-3 ps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_normalized() {
        assert!((transistor_area_mwta(1.0) - 1.0).abs() < 1e-12);
        assert!(transistor_area_mwta(2.0) > 1.0);
        // Quadratic growth: doubling width less than quadruples area.
        assert!(transistor_area_mwta(2.0) < 4.0);
    }

    #[test]
    fn elmore_single_stage() {
        // R=1k, C=1fF -> 0.69 * 1000 * 1 * 1e-3 ps = 0.69 ps.
        let d = elmore_ps(&[RcStage { r: 1000.0, c: 1.0 }]);
        assert!((d - 0.69).abs() < 1e-9);
    }

    #[test]
    fn elmore_accumulates_upstream_r() {
        let two = elmore_ps(&[
            RcStage { r: 1000.0, c: 1.0 },
            RcStage { r: 1000.0, c: 1.0 },
        ]);
        // 0.69*(1000*1 + 2000*1)*1e-3 = 2.07
        assert!((two - 2.07).abs() < 1e-9);
    }

    #[test]
    fn wider_transistor_is_faster_into_fixed_load() {
        let t = Tech::n20();
        let d1 = elmore_ps(&[RcStage { r: t.r_nmos(1.0), c: 10.0 }]);
        let d2 = elmore_ps(&[RcStage { r: t.r_nmos(2.0), c: 10.0 }]);
        assert!(d2 < d1);
    }
}
