//! Experiment-engine contracts, end to end:
//!
//! * parallel (jobs=4) and serial (jobs=1) runs of one plan produce
//!   bit-identical `FlowResult` metrics (the determinism contract the
//!   paper's multi-seed methodology depends on),
//! * the engine reproduces the uncached serial `flow::run_benchmark`
//!   path exactly,
//! * cache-served packings are identical to cold recomputation.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{vtr_suite, BenchParams};
use double_duty::flow::engine::{ArtifactCache, Engine, ExperimentPlan};
use double_duty::flow::{run_benchmark, FlowOpts};
use double_duty::pack::{pack, PackOpts, Unrelated};
use double_duty::techmap::{map_circuit, MapOpts};

fn small_plan(route: bool) -> ExperimentPlan {
    let params = BenchParams::default();
    ExperimentPlan {
        benches: vtr_suite(&params)[..3].to_vec(),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.05,
            route,
            ..Default::default()
        },
    }
}

/// jobs=4 must reproduce jobs=1 bit-for-bit, metric by metric.
#[test]
fn parallel_matches_serial_bit_identical() {
    let plan = small_plan(false);
    let serial = Engine::new(1).run(&plan);
    let par = Engine::new(4).run(&plan);
    assert_eq!(serial.len(), par.len());
    for (rs, rp) in serial.iter().flatten().zip(par.iter().flatten()) {
        assert_eq!(rs.name, rp.name);
        assert_eq!(rs.variant, rp.variant);
        assert_eq!(rs.alms, rp.alms);
        assert_eq!(rs.lbs, rp.lbs);
        assert_eq!(rs.concurrent_luts, rp.concurrent_luts);
        assert!(rs.cpd_ns == rp.cpd_ns, "{}: cpd {} vs {}", rs.name, rs.cpd_ns, rp.cpd_ns);
        assert!(rs.adp == rp.adp, "{}: adp {} vs {}", rs.name, rs.adp, rp.adp);
        assert_eq!(rs.routed_ok, rp.routed_ok);
        assert_eq!(rs.channel_util, rp.channel_util);
    }
}

/// The engine (parallel, cached) must equal the uncached serial flow —
/// including on the routed path, whose channel utilization it averages.
#[test]
fn engine_matches_uncached_run_benchmark_routed() {
    let params = BenchParams::default();
    let plan = ExperimentPlan {
        benches: vtr_suite(&params)[..1].to_vec(),
        variants: vec![ArchVariant::Dd5],
        flow: FlowOpts { seeds: vec![3], place_effort: 0.05, ..Default::default() },
    };
    let grid = Engine::new(4).run(&plan);
    let got = &grid[0][0];
    let want = run_benchmark(&plan.benches[0], ArchVariant::Dd5, &plan.flow);
    assert_eq!(got.alms, want.alms);
    assert_eq!(got.lbs, want.lbs);
    assert!(got.cpd_ns == want.cpd_ns, "cpd {} vs {}", got.cpd_ns, want.cpd_ns);
    assert!(got.adp == want.adp);
    assert_eq!(got.routed_ok, want.routed_ok);
    assert!(got.route_iters == want.route_iters);
    assert_eq!(got.channel_util, want.channel_util);
    assert_eq!(got.dedup_hits, want.dedup_hits);
}

/// Closed-timing-loop plans chain seeds — each seed's achieved CPD is the
/// next seed's criticality prior.  The engine must reproduce the uncached
/// serial flow bit-for-bit (any worker count), and record one
/// achieved-CPD prior per chained seed in its artifact cache.
#[test]
fn chained_timing_plan_matches_serial_and_records_priors() {
    let params = BenchParams::default();
    let plan = ExperimentPlan {
        benches: vtr_suite(&params)[..1].to_vec(),
        variants: vec![ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.05,
            route_timing_weights: true,
            sta_every: 2,
            ..Default::default()
        },
    };
    let engine = Engine::new(4);
    let grid = engine.run(&plan);
    let got = &grid[0][0];
    assert!(!got.cpd_trace_ns.is_empty(), "timing-route plans must carry a CPD trace");
    // One prior per (cell, seed) chain link.
    assert_eq!(engine.cache.cpd_priors_recorded(), 2);

    // Bit-identical to the uncached serial path (which runs the same
    // chain in the same seed order).
    let want = run_benchmark(&plan.benches[0], ArchVariant::Dd5, &plan.flow);
    assert_eq!(got.cpd_ns.to_bits(), want.cpd_ns.to_bits(), "chained cpd");
    assert_eq!(got.routed_ok, want.routed_ok);
    assert_eq!(got.channel_util, want.channel_util);
    assert_eq!(got.cpd_trace_ns.len(), want.cpd_trace_ns.len());
    for (a, b) in got.cpd_trace_ns.iter().zip(want.cpd_trace_ns.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "chained cpd trace");
    }

    // And to a single-worker engine run.
    let serial = Engine::new(1).run(&plan);
    assert_eq!(serial[0][0].cpd_ns.to_bits(), got.cpd_ns.to_bits());
    assert_eq!(serial[0][0].channel_util, got.channel_util);
}

/// Artifacts served from the cache are identical to a cold recomputation,
/// and repeat lookups are real hits (same shared instance, no recompute).
#[test]
fn cache_returns_cold_identical_packing() {
    let params = BenchParams::default();
    let b = &vtr_suite(&params)[1];
    let cache = ArtifactCache::new();
    let mapped = cache.mapped(b);
    let arch = Arch::coffe(ArchVariant::Dd5);
    let opts = PackOpts { unrelated: Unrelated::Auto };
    let warm0 = cache.packed(&mapped, &arch, &opts);
    let warm1 = cache.packed(&mapped, &arch, &opts);
    assert!(Arc::ptr_eq(&warm0, &warm1), "second lookup must be cache-served");
    assert_eq!(cache.stats.pack_misses.load(Ordering::Relaxed), 1);
    assert_eq!(cache.stats.pack_hits.load(Ordering::Relaxed), 1);

    // Cold recompute from scratch, bypassing the cache entirely.
    let nl = map_circuit(&b.generate(), &MapOpts::default());
    let cold = pack(&nl, &arch, &opts);
    assert_eq!(warm0.stats.alms, cold.stats.alms);
    assert_eq!(warm0.stats.lbs, cold.stats.lbs);
    assert_eq!(warm0.stats.luts, cold.stats.luts);
    assert_eq!(warm0.stats.adder_bits, cold.stats.adder_bits);
    assert_eq!(warm0.stats.concurrent_luts, cold.stats.concurrent_luts);
    assert_eq!(warm0.stats.absorbed_luts, cold.stats.absorbed_luts);
    assert_eq!(warm0.alms.len(), cold.alms.len());
    assert_eq!(warm0.chain_macros, cold.chain_macros);
}
