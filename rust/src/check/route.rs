//! Route validity: connectivity, overuse, and tree-arena integrity,
//! re-derived on a freshly built RRG.
//!
//! The graph is rebuilt with [`RrGraph::build`] from the device and arch —
//! the same deterministic constructor the router used — and every net's
//! pin taps are re-derived from the router's published salt scheme
//! (source `17 + 131*net`, sink `71 + 131*net`, over `fc_out`/`fc_in`).
//! Connectivity is checked by *directed* reachability: every committed
//! node of a net must be reachable from its source taps, and every sink's
//! tap set must intersect the reachable set.  (Undirected acyclicity is
//! deliberately not an invariant here: RRG turn edges are partially
//! asymmetric and a legal tree brushing two adjacent corners induces
//! undirected cycles.)  Reachability of everything from the source is the
//! sound replacement: it proves the committed set is one source-rooted
//! tree with no orphaned wiring.

use crate::arch::device::Loc;
use crate::arch::Arch;
use crate::place::cost::{NetModel, Term};
use crate::place::Placement;
use crate::route::Routing;
use crate::rrg::RrGraph;

use super::{Severity, Stage, Violation};

fn err(code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Route, Severity::Error, code, location, message)
}

/// Audit a routing of `model` on `placement`.  Scan order: nets ascending
/// (arena shape, sink terms, connectivity), then global overuse.
pub fn audit_routing(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    routing: &Routing,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let graph = RrGraph::build(&placement.device, arch);
    let n_nodes = graph.num_nodes();

    if routing.net_nodes.len() != model.nets.len()
        || routing.sink_hops.len() != model.nets.len()
    {
        out.push(err(
            "route.arity",
            "routing".to_string(),
            format!(
                "{} node lists / {} sink lists for {} external nets",
                routing.net_nodes.len(),
                routing.sink_hops.len(),
                model.nets.len()
            ),
        ));
        return out; // everything below indexes by net; bail before panicking
    }

    let term_loc = |t: Term| -> Option<Loc> {
        match t {
            Term::Lb(i) => placement.lb_loc.get(i).copied(),
            Term::Io(c) => placement.io_loc.get(&c).copied(),
        }
    };

    let mut occ: Vec<u16> = vec![0; n_nodes];
    for (ni, en) in model.nets.iter().enumerate() {
        let loc = |suffix: &str| format!("net {}{suffix}", en.net);
        let nodes = &routing.net_nodes[ni];

        // Tree arena contract: sorted, deduplicated, in bounds.
        let mut arena_ok = true;
        for w in nodes.windows(2) {
            if w[1] <= w[0] {
                out.push(err(
                    "route.arena",
                    loc(""),
                    format!("node arena not strictly increasing at {} -> {}", w[0], w[1]),
                ));
                arena_ok = false;
                break;
            }
        }
        if let Some(&max) = nodes.last() {
            if max >= n_nodes {
                out.push(err(
                    "route.arena",
                    loc(""),
                    format!("node id {max} out of range for a {n_nodes}-node RRG"),
                ));
                arena_ok = false;
            }
        }
        if arena_ok {
            for &n in nodes {
                occ[n] += 1;
            }
        }

        // Sink list must mirror the net's sink terminals in order.
        let hops = &routing.sink_hops[ni];
        let want: &[Term] = en.terms.get(1..).unwrap_or(&[]);
        if hops.len() != want.len() || hops.iter().map(|(t, _)| *t).ne(want.iter().copied()) {
            out.push(err(
                "route.sink-terms",
                loc(""),
                format!(
                    "sink-hop terminals {:?} do not mirror the net's sinks {want:?}",
                    hops.iter().map(|(t, _)| *t).collect::<Vec<_>>()
                ),
            ));
        }

        // Connectivity — only meaningful once the router claims success
        // (a failed run legitimately leaves unroutable sinks pathless).
        if !routing.success || !arena_ok || want.is_empty() {
            continue;
        }
        let Some(src_loc) = term_loc(en.terms[0]) else {
            out.push(err(
                "route.disconnected",
                loc(""),
                format!("source terminal {:?} has no placed location", en.terms[0]),
            ));
            continue;
        };
        let src_taps = graph.pin_nodes(src_loc, arch.routing.fc_out, 17 + 131 * ni as u64);

        // Directed BFS over the committed subgraph, seeded at source taps.
        let mut reached = vec![false; nodes.len()];
        let mut stack: Vec<usize> = Vec::new();
        for &s in &src_taps {
            if let Ok(p) = nodes.binary_search(&s) {
                if !reached[p] {
                    reached[p] = true;
                    stack.push(p);
                }
            }
        }
        while let Some(p) = stack.pop() {
            for &nb in graph.neighbors(nodes[p]) {
                if let Ok(q) = nodes.binary_search(&(nb as usize)) {
                    if !reached[q] {
                        reached[q] = true;
                        stack.push(q);
                    }
                }
            }
        }
        for (si, &sink) in want.iter().enumerate() {
            let Some(dst_loc) = term_loc(sink) else {
                out.push(err(
                    "route.disconnected",
                    loc(&format!(" sink {si}")),
                    format!("sink terminal {sink:?} has no placed location"),
                ));
                continue;
            };
            let dst_taps = graph.pin_nodes(dst_loc, arch.routing.fc_in, 71 + 131 * ni as u64);
            let hit = dst_taps
                .iter()
                .any(|t| nodes.binary_search(t).map_or(false, |p| reached[p]));
            if !hit {
                out.push(err(
                    "route.disconnected",
                    loc(&format!(" sink {si}")),
                    format!(
                        "no directed path from source taps at ({},{}) reaches a sink tap \
                         at ({},{})",
                        src_loc.x, src_loc.y, dst_loc.x, dst_loc.y
                    ),
                ));
            }
        }
        for (p, &n) in nodes.iter().enumerate() {
            if !reached[p] {
                let (d, x, y, t) = graph.decode(n);
                out.push(err(
                    "route.orphan-node",
                    loc(""),
                    format!(
                        "committed node {n} (dir {d}, x {x}, y {y}, track {t}) is not \
                         reachable from the net's source taps"
                    ),
                ));
            }
        }
    }

    // --- Global overuse (after all nets counted). -------------------------
    let recounted = occ.iter().filter(|&&o| o as f64 > crate::rrg::NODE_CAP).count();
    if recounted != routing.overused {
        out.push(err(
            "route.overuse-count",
            "routing".to_string(),
            format!(
                "recounted {recounted} overused node(s) but the router reported {}",
                routing.overused
            ),
        ));
    }
    if routing.success {
        for (n, &o) in occ.iter().enumerate() {
            if o as f64 > crate::rrg::NODE_CAP {
                let (d, x, y, t) = graph.decode(n);
                out.push(err(
                    "route.overuse",
                    format!("node {n}"),
                    format!(
                        "wire (dir {d}, x {x}, y {y}, track {t}) carries {o} nets on a \
                         claimed-legal routing"
                    ),
                ));
            }
        }
    }

    out
}
