//! Parallel-router determinism contract, end to end:
//!
//! * `Routing` is bit-identical across `--route-jobs 1/2/8` — the
//!   snapshot/reduce negotiation scheme (`rrg` module docs) makes phase 2
//!   a pure function of (snapshot, net), so shard assignment is
//!   unobservable;
//! * the contract holds through the flow layer (`FlowOpts::route_jobs`)
//!   for multiple placement seeds;
//! * the placer remains deterministic per seed under the incremental cost
//!   cache and batched move pipeline.

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::flow::{place_route_seed, FlowOpts, SeedCtx};
use double_duty::pack::{pack, PackOpts, Packing};
use double_duty::place::cost::NetModel;
use double_duty::place::{place, PlaceOpts, Placement};
use double_duty::route::{route, RouteOpts, Routing};
use double_duty::synth::circuit::Circuit;
use double_duty::synth::multiplier::{soft_mul, AdderAlgo};
use double_duty::techmap::{map_circuit, MapOpts};
use double_duty::netlist::Netlist;

fn placed_mul(w: usize) -> (Netlist, Packing, Placement, NetModel, Arch) {
    let mut c = Circuit::new("m");
    let x = c.pi_bus("x", w);
    let y = c.pi_bus("y", w);
    let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
    c.po_bus("p", &p);
    let nl = map_circuit(&c, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Dd5);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let pl = place(&nl, &packing, &arch,
                   &PlaceOpts { effort: 0.3, ..Default::default() })
        .expect("placement");
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);
    (nl, packing, pl, model, arch)
}

fn assert_routing_eq(a: &Routing, b: &Routing, tag: &str) {
    assert_eq!(a.success, b.success, "{tag}: success");
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    assert_eq!(a.wirelength, b.wirelength, "{tag}: wirelength");
    assert_eq!(a.overused, b.overused, "{tag}: overused");
    assert_eq!(a.overused_nodes, b.overused_nodes, "{tag}: overused_nodes");
    assert_eq!(a.sink_hops, b.sink_hops, "{tag}: sink_hops");
    assert_eq!(a.net_nodes, b.net_nodes, "{tag}: net_nodes");
    assert_eq!(a.channel_util, b.channel_util, "{tag}: channel_util");
}

/// The core contract: identical `Routing` for every job count.
#[test]
fn routing_bit_identical_across_job_counts() {
    let (_nl, _packing, pl, model, arch) = placed_mul(6);
    let base = route(&model, &pl, &arch, &RouteOpts { jobs: 1, ..Default::default() });
    assert!(base.success, "baseline route failed ({} overused)", base.overused);
    for jobs in [2, 8] {
        let r = route(&model, &pl, &arch, &RouteOpts { jobs, ..Default::default() });
        assert_routing_eq(&base, &r, &format!("jobs={jobs}"));
    }
}

/// The contract survives congestion (narrow channel => many negotiation
/// iterations with real rip-up/re-route churn).
#[test]
fn routing_bit_identical_under_congestion() {
    let (_nl, _packing, pl, model, mut arch) = placed_mul(6);
    arch.routing.channel_width = 14;
    let base = route(&model, &pl, &arch, &RouteOpts { jobs: 1, ..Default::default() });
    assert!(base.iterations > 1, "want real negotiation churn");
    for jobs in [2, 8] {
        let r = route(&model, &pl, &arch, &RouteOpts { jobs, ..Default::default() });
        assert_routing_eq(&base, &r, &format!("congested jobs={jobs}"));
    }
}

/// Flow-level: `route_jobs` does not perturb any reported metric, across
/// placement seeds, on a real benchmark circuit.
#[test]
fn flow_metrics_identical_across_route_jobs() {
    let params = BenchParams::default();
    let b = &kratos_suite(&params)[0];
    let circ = b.generate();
    let nl = map_circuit(&circ, &MapOpts::default());
    let arch = Arch::coffe(ArchVariant::Dd5);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let idx = double_duty::netlist::NetlistIndex::build(&nl);
    let pidx = double_duty::netlist::PackIndex::build(&nl, &packing);
    for seed in [1u64, 2] {
        let mk = |route_jobs: usize| {
            let opts = FlowOpts {
                seeds: vec![seed],
                place_effort: 0.1,
                route_jobs,
                ..Default::default()
            };
            place_route_seed(&nl, &packing, &arch, &opts, seed, &SeedCtx::new(&idx, &pidx))
        };
        let serial = mk(1);
        let parallel = mk(4);
        assert!(serial.cpd_ns == parallel.cpd_ns,
                "seed {seed}: cpd {} vs {}", serial.cpd_ns, parallel.cpd_ns);
        assert_eq!(serial.routed_ok, parallel.routed_ok);
        assert!(serial.route_iters == parallel.route_iters);
        assert_eq!(serial.channel_util, parallel.channel_util);
    }
}

/// Placer determinism under the incremental cost + batched pipeline.
#[test]
fn placer_deterministic_with_incremental_cost() {
    let (nl, packing, _pl, _model, arch) = placed_mul(5);
    let mk = || {
        place(&nl, &packing, &arch, &PlaceOpts { effort: 0.4, seed: 11, ..Default::default() })
            .expect("placement")
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.lb_loc, b.lb_loc);
    assert_eq!(a.cost, b.cost);
    assert_eq!(a.est_cpd_ps, b.est_cpd_ps);
}
