//! Recovery auditor: re-verifies the failure-recovery bookkeeping of a
//! finished flow cell against the per-seed metrics it was reduced from.
//!
//! The fault-tolerant flow turns panics, placement misfits, and
//! unroutable seeds into *data* ([`crate::flow::FlowError`]) instead of
//! crashes — which means the recovery bookkeeping itself is now a
//! correctness surface: a seed rescued at an escalated channel width
//! must never feed the CPD-prior chain, the failure counters must agree
//! with the per-seed error records, and an escalation rung must be one
//! the ladder actually defines.  Like every other auditor, this one
//! re-derives each invariant from the raw artifacts (the
//! [`crate::flow::SeedMetrics`] list) without calling the producer code
//! paths, so a bug in `assemble_result` or `chain_seeds` cannot
//! self-certify.
//!
//! Codes (stable order of checks):
//!
//! * `recovery.escalation-provenance` — per seed: the recorded rung is
//!   within [`crate::flow::ESCALATION_LADDER`]; a seed rescued by the
//!   ladder (`escalation > 0`, routed) carries no error; a seed that
//!   exhausted the ladder sits on the last rung *and* carries the
//!   ladder-exhausted error; a routed seed never carries an error.
//! * `recovery.prior-chaining` — the CPD-prior chain re-walked from
//!   scratch: each seed's consumed prior must be bit-identical
//!   (`f64::to_bits`) to the prior the chain rules predict, and only
//!   healthy, undegraded, routed seeds advance the prediction.
//!   Non-chained runs must consume no priors at all.
//! * `recovery.failure-counts` — the reduced [`crate::flow::FlowResult`]
//!   counters (`failed_seeds`, `escalations`, `errors`, `routed_ok`)
//!   agree with a recount over the seed list.

use crate::flow::{FlowResult, SeedMetrics, ESCALATION_LADDER};

use super::{Severity, Stage, Violation};

fn err(code: &'static str, location: impl Into<String>, message: impl Into<String>) -> Violation {
    Violation::new(Stage::Recovery, Severity::Error, code, location, message)
}

/// Audit one flow cell's recovery bookkeeping.  `result` is the reduced
/// cell result, `seeds` the per-seed metrics it was assembled from (in
/// seed order), and `chained` whether the closed timing loop was on
/// (`route && route_timing_weights`) — the only mode in which seeds may
/// consume CPD priors.
pub fn audit_recovery(
    result: &FlowResult,
    seeds: &[SeedMetrics],
    chained: bool,
) -> Vec<Violation> {
    let mut vs = Vec::new();
    let last_rung = ESCALATION_LADDER.len();

    // 1. Escalation provenance, in seed order.
    for s in seeds {
        let loc = || format!("seed {}", s.seed);
        let rung = s.escalation as usize;
        if rung > last_rung {
            vs.push(err(
                "recovery.escalation-provenance",
                loc(),
                format!("escalation rung {rung} outside the {last_rung}-rung ladder"),
            ));
            continue;
        }
        if s.routed_ok && s.error.is_some() {
            vs.push(err(
                "recovery.escalation-provenance",
                loc(),
                "routed seed carries a failure record",
            ));
        }
        if rung > 0 && !s.routed_ok {
            // The ladder only stops early on success; an unrouted seed
            // must have exhausted every rung and recorded the failure.
            if rung < last_rung {
                vs.push(err(
                    "recovery.escalation-provenance",
                    loc(),
                    format!("unrouted seed stopped at rung {rung} of {last_rung}"),
                ));
            }
            if s.error.is_none() {
                vs.push(err(
                    "recovery.escalation-provenance",
                    loc(),
                    "ladder-exhausted seed carries no failure record",
                ));
            }
        }
    }

    // 2. CPD-prior chaining, re-walked from scratch.  Degraded
    // (escalated), errored, and unrouted seeds must not advance the
    // prior; non-chained runs must consume no priors at all.
    let mut expected: Option<f64> = None;
    for s in seeds {
        let want = if chained { expected } else { None };
        let same = match (s.used_prior_ps, want) {
            (None, None) => true,
            (Some(a), Some(b)) => a.to_bits() == b.to_bits(),
            _ => false,
        };
        if !same {
            vs.push(err(
                "recovery.prior-chaining",
                format!("seed {}", s.seed),
                format!(
                    "consumed prior {:?} ps, chain rules predict {:?} ps",
                    s.used_prior_ps, want
                ),
            ));
        }
        if chained && s.routed_ok && s.error.is_none() && s.escalation == 0 {
            expected = Some(s.cpd_ns * 1000.0);
        }
    }

    // 3. Reduced counters vs a recount over the seed list.
    let n_errors = seeds.iter().filter(|s| s.error.is_some()).count();
    if result.failed_seeds != n_errors || result.errors.len() != n_errors {
        vs.push(err(
            "recovery.failure-counts",
            "result",
            format!(
                "failed_seeds {} / errors {} vs {} seed failure record(s)",
                result.failed_seeds,
                result.errors.len(),
                n_errors
            ),
        ));
    }
    let n_escalated = seeds.iter().filter(|s| s.escalation > 0).count();
    if result.escalations != n_escalated {
        vs.push(err(
            "recovery.failure-counts",
            "result",
            format!(
                "escalations {} vs {} escalated seed(s)",
                result.escalations, n_escalated
            ),
        ));
    }
    let all_routed = seeds.iter().all(|s| s.routed_ok);
    if result.routed_ok != all_routed {
        vs.push(err(
            "recovery.failure-counts",
            "result",
            format!(
                "routed_ok {} vs per-seed conjunction {}",
                result.routed_ok, all_routed
            ),
        ));
    }
    vs
}
