"""AOT-lower the L2 placement cost model to HLO text artifacts.

Emits HLO *text* (NOT ``lowered.compile()`` / proto ``.serialize()``): jax
>= 0.5 writes HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

One artifact per net-count bucket:  artifacts/cost_n{N}.hlo.txt.
``make artifacts`` runs this once; the rust runtime only reads the files.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.hpwl import GRID, NET_BLOCK
from .model import BUCKETS, placement_cost


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    cap = jax.ShapeDtypeStruct((1,), jnp.float32)
    lowered = jax.jit(placement_cost).lower(spec, spec, spec, spec, spec,
                                            spec, cap)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts",
                        help="artifact output directory")
    parser.add_argument("--out", default=None,
                        help="(compat) single-file marker path; ignored "
                             "except for its directory")
    args = parser.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"grid": GRID, "net_block": NET_BLOCK, "buckets": []}
    for n in BUCKETS:
        text = lower_bucket(n)
        name = f"cost_n{n}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append({"nets": n, "file": name})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Compat marker for Makefile dependency tracking.
    marker = args.out or os.path.join(out_dir, "model.hlo.txt")
    with open(marker, "w") as f:
        f.write(open(os.path.join(out_dir,
                                  f"cost_n{BUCKETS[0]}.hlo.txt")).read())
    print(f"wrote {marker} (marker)")


if __name__ == "__main__":
    main()
