//! Soft-multiplier and multi-operand reduction synthesis (paper §IV).
//!
//! Partial-product rows are reduced to a final result with one of:
//!
//! * [`AdderAlgo::VtrBaseline`] — naive binary adder tree with adjacent
//!   pairing (what stock VTR/Parmys does; combined with disabling chain
//!   dedup on the [`Circuit`] this reproduces the paper's baseline).
//! * [`AdderAlgo::Cascade`] — sequential chain accumulation (Fig. 1 left).
//! * [`AdderAlgo::BinaryTree`] — the improved binary adder tree using the
//!   strength heuristic and the Algorithm-1 dynamic program to choose row
//!   pairings that maximize chain reuse.
//! * [`AdderAlgo::Wallace`] / [`AdderAlgo::Dadda`] — compressor trees:
//!   carry-save full/half-adder *gates* (LUT fodder) reduce the rows to
//!   two, which a single hard carry chain then sums (Fig. 1 middle/right).

use crate::techmap::aig::Lit;

use super::circuit::Circuit;

/// One partial-product row: LSB-first literals, `Lit::FALSE` for absent
/// bits.  Rows in a set may have different lengths.
pub type Row = Vec<Lit>;
/// A set of rows to be summed.
pub type Rows = Vec<Row>;

/// Reduction algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdderAlgo {
    VtrBaseline,
    Cascade,
    BinaryTree,
    Wallace,
    Dadda,
}

impl AdderAlgo {
    pub fn name(self) -> &'static str {
        match self {
            AdderAlgo::VtrBaseline => "vtr-baseline",
            AdderAlgo::Cascade => "cascade",
            AdderAlgo::BinaryTree => "binary-tree",
            AdderAlgo::Wallace => "wallace",
            AdderAlgo::Dadda => "dadda",
        }
    }
}

fn bit(row: &Row, i: usize) -> Lit {
    row.get(i).copied().unwrap_or(Lit::FALSE)
}

/// Add two rows on a hard carry chain (trimmed to the occupied span).
fn add_rows(c: &mut Circuit, r1: &Row, r2: &Row) -> Row {
    add_rows_opt(c, r1, r2, true)
}

/// Add two rows; with `trim = false` the chain spans the full bus width —
/// the stock-VTR behaviour (adder inference pads to the declared bus), the
/// baseline the paper's §IV improvements are measured against.
fn add_rows_opt(c: &mut Circuit, r1: &Row, r2: &Row, trim: bool) -> Row {
    let w = r1.len().max(r2.len());
    let ops: Vec<(Lit, Lit)> = (0..w).map(|i| (bit(r1, i), bit(r2, i))).collect();
    let last = if trim {
        // Trim trailing all-zero positions; the cout covers the carry.
        ops.iter()
            .rposition(|&(a, b)| a != Lit::FALSE || b != Lit::FALSE)
            .unwrap_or(0)
    } else {
        w - 1
    };
    let (sums, cout) = if trim {
        c.add_chain(ops[..=last].to_vec(), Lit::FALSE)
    } else {
        c.add_chain_untrimmed(ops, Lit::FALSE)
    };
    let mut out = sums;
    out.push(cout);
    out
}

/// Count of live (non-constant-false) bits in a row.
fn popcount(row: &Row) -> usize {
    row.iter().filter(|&&l| l != Lit::FALSE).count()
}

/// Reduce `rows` to a single row with the chosen algorithm. Returns the
/// result bits (LSB-first).
pub fn reduce_rows(c: &mut Circuit, rows: Rows, algo: AdderAlgo) -> Row {
    let mut rows: Rows = rows.into_iter().filter(|r| popcount(r) > 0).collect();
    match rows.len() {
        0 => return vec![Lit::FALSE],
        1 => return rows.pop().unwrap(),
        _ => {}
    }
    match algo {
        AdderAlgo::Cascade => {
            let mut acc = rows[0].clone();
            for r in &rows[1..] {
                acc = add_rows(c, &acc, r);
            }
            acc
        }
        AdderAlgo::VtrBaseline => binary_tree(c, rows, false),
        AdderAlgo::BinaryTree => binary_tree(c, rows, true),
        AdderAlgo::Wallace => compressor_tree(c, rows, false),
        AdderAlgo::Dadda => compressor_tree(c, rows, true),
    }
}

/// Binary adder tree. With `strength`, each stage's pairing is chosen by
/// the Algorithm-1 DP (maximizing included-inputs / unique-chain-outputs);
/// otherwise rows are paired in order (stock VTR behaviour).
fn binary_tree(c: &mut Circuit, mut rows: Rows, strength: bool) -> Row {
    let trim = strength;
    while rows.len() > 1 {
        let order: Vec<usize> = if strength && rows.len() <= 14 {
            best_placement(c, &rows)
        } else if strength {
            greedy_placement(&rows)
        } else {
            (0..rows.len()).collect()
        };
        let mut next: Rows = Vec::with_capacity(rows.len().div_ceil(2));
        let mut it = order.chunks_exact(2);
        for pair in &mut it {
            next.push(add_rows_opt(c, &rows[pair[0]], &rows[pair[1]], trim));
        }
        // Odd row passes through to the next stage.
        if let [leftover] = it.remainder() {
            next.push(rows[*leftover].clone());
        }
        rows = next;
    }
    rows.pop().unwrap()
}

/// Normalized chain key of a candidate pair, mirroring
/// [`Circuit::add_chain`]'s normalization: for duplicate detection only.
fn pair_key(r1: &Row, r2: &Row) -> Vec<(Lit, Lit)> {
    let w = r1.len().max(r2.len());
    let mut ops: Vec<(Lit, Lit)> = (0..w).map(|i| (bit(r1, i), bit(r2, i))).collect();
    let last = ops
        .iter()
        .rposition(|&(a, b)| a != Lit::FALSE || b != Lit::FALSE)
        .unwrap_or(0);
    ops.truncate(last + 1);
    while ops.len() > 1 && ops[0] == (Lit::FALSE, Lit::FALSE) {
        ops.remove(0);
    }
    ops
}

/// Algorithm 1: adder row selection for maximum strength, as a DP over row
/// subsets (bitmask memo).  Returns the row ordering: consecutive pairs
/// form chains; a trailing single index passes through.
fn best_placement(c: &Circuit, rows: &Rows) -> Vec<usize> {
    use std::collections::HashMap;

    #[derive(Clone)]
    struct Sol {
        pairs: Vec<(usize, usize)>,
        inputs: f64,
        outputs: f64,
        leftover: Option<usize>,
    }
    impl Sol {
        fn strength(&self) -> f64 {
            if self.outputs == 0.0 {
                0.0
            } else {
                self.inputs / self.outputs
            }
        }
    }

    let n = rows.len();
    let full: u32 = if n == 32 { u32::MAX } else { (1u32 << n) - 1 };
    let mut memo: HashMap<u32, Sol> = HashMap::new();

    // Per-pair precomputation: included inputs (by position) and the chain
    // key (by chain) for duplicate detection.
    let mut pair_inputs = vec![vec![0.0f64; n]; n];
    let mut pair_keys: Vec<Vec<Vec<(Lit, Lit)>>> = vec![vec![Vec::new(); n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            pair_inputs[i][j] = (popcount(&rows[i]) + popcount(&rows[j])) as f64;
            pair_keys[i][j] = pair_key(&rows[i], &rows[j]);
        }
    }
    let chain_outputs = |key: &Vec<(Lit, Lit)>| (key.len() + 1) as f64;

    fn solve(
        mask: u32,
        n: usize,
        memo: &mut std::collections::HashMap<u32, Sol>,
        pair_inputs: &Vec<Vec<f64>>,
        pair_keys: &Vec<Vec<Vec<(Lit, Lit)>>>,
        chain_outputs: &dyn Fn(&Vec<(Lit, Lit)>) -> f64,
        c: &Circuit,
    ) -> Sol {
        if let Some(s) = memo.get(&mask) {
            return s.clone();
        }
        let count = mask.count_ones() as usize;
        let members: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        let sol = if count == 0 {
            Sol { pairs: vec![], inputs: 0.0, outputs: 0.0, leftover: None }
        } else if count == 1 {
            Sol { pairs: vec![], inputs: 0.0, outputs: 0.0, leftover: Some(members[0]) }
        } else if count % 2 == 0 {
            // Anchor on the lowest member to avoid enumerating symmetric
            // pairings (every perfect matching pairs it with someone).
            let a = members[0];
            let mut best: Option<Sol> = None;
            for &b in &members[1..] {
                let sub = solve(mask & !(1 << a) & !(1 << b), n, memo,
                                pair_inputs, pair_keys, chain_outputs, c);
                let (lo, hi) = (a.min(b), a.max(b));
                let key = &pair_keys[lo][hi];
                let mut inputs = sub.inputs + pair_inputs[lo][hi];
                let mut outputs = sub.outputs;
                // A duplicate chain (already placed in this solution or in
                // the circuit at large) adds inputs but no new outputs.
                let dup_in_sub = sub
                    .pairs
                    .iter()
                    .any(|&(x, y)| pair_keys[x.min(y)][x.max(y)] == *key);
                let dup_global = c.chain_exists(key, Lit::FALSE);
                if !(dup_in_sub || dup_global) {
                    outputs += chain_outputs(key);
                }
                let _ = &mut inputs;
                let mut pairs = sub.pairs.clone();
                pairs.push((a, b));
                let cand = Sol { pairs, inputs, outputs, leftover: sub.leftover };
                if best.as_ref().map_or(true, |s| cand.strength() > s.strength()) {
                    best = Some(cand);
                }
            }
            best.unwrap()
        } else {
            // Odd: choose which row passes through.
            let mut best: Option<Sol> = None;
            for &r in &members {
                let sub = solve(mask & !(1 << r), n, memo,
                                pair_inputs, pair_keys, chain_outputs, c);
                let cand = Sol { leftover: Some(r), ..sub };
                if best.as_ref().map_or(true, |s| cand.strength() > s.strength()) {
                    best = Some(cand);
                }
            }
            best.unwrap()
        };
        memo.insert(mask, sol.clone());
        sol
    }

    let sol = solve(full, n, &mut memo, &pair_inputs, &pair_keys, &chain_outputs, c);
    let mut order = Vec::with_capacity(n);
    for (a, b) in sol.pairs {
        order.push(a);
        order.push(b);
    }
    if let Some(l) = sol.leftover {
        order.push(l);
    }
    order
}

/// Greedy fallback for wide row sets: pair rows with identical normalized
/// chain keys first (guaranteed dedup), then the rest in order.
fn greedy_placement(rows: &Rows) -> Vec<usize> {
    use std::collections::HashMap;
    let n = rows.len();
    let mut by_key: HashMap<Vec<(Lit, Lit)>, Vec<usize>> = HashMap::new();
    // Normalized single-row signature: rows whose pairwise sums coincide
    // pair best with rows of the same shape; approximate by grouping rows
    // with equal trimmed content.
    for (i, r) in rows.iter().enumerate() {
        let key = pair_key(r, &vec![]);
        by_key.entry(key).or_default().push(i);
    }
    let mut order = Vec::with_capacity(n);
    let mut used = vec![false; n];
    let mut groups: Vec<Vec<usize>> = by_key.into_values().collect();
    groups.sort_by_key(|g| g[0]);
    for g in &groups {
        for &i in g {
            if !used[i] {
                order.push(i);
                used[i] = true;
            }
        }
    }
    order
}

/// Compressor tree (carry-save) reduction. `dadda = false` is Wallace
/// (maximal per-stage compression); `dadda = true` follows the Dadda
/// height sequence (minimal per-stage work).  Final two rows are summed on
/// one hard carry chain.
fn compressor_tree(c: &mut Circuit, rows: Rows, dadda: bool) -> Row {
    let width = rows.iter().map(|r| r.len()).max().unwrap_or(1) + rows.len();
    // Column-major bit matrix.
    let mut cols: Vec<Vec<Lit>> = vec![Vec::new(); width];
    for r in &rows {
        for (i, &b) in r.iter().enumerate() {
            if b != Lit::FALSE {
                cols[i].push(b);
            }
        }
    }

    // Dadda height targets: 2, 3, 4, 6, 9, 13, 19, ...
    let dadda_seq = |max_h: usize| -> Vec<usize> {
        let mut seq = vec![2usize];
        while *seq.last().unwrap() < max_h {
            let d = *seq.last().unwrap();
            seq.push(d * 3 / 2);
        }
        seq
    };

    loop {
        let max_h = cols.iter().map(|c| c.len()).max().unwrap_or(0);
        if max_h <= 2 {
            break;
        }
        let target = if dadda {
            let seq = dadda_seq(max_h);
            // Largest target strictly below the current max height.
            *seq.iter().rev().find(|&&d| d < max_h).unwrap_or(&2)
        } else {
            // Wallace: compress everything maximally this stage.
            2
        };
        let mut next: Vec<Vec<Lit>> = vec![Vec::new(); width + 1];
        for i in 0..width {
            let mut bits = std::mem::take(&mut cols[i]);
            // Carry bits produced into this column during this stage are
            // already in `next[i]`; account for them against the target.
            let carried = next[i].len();
            while bits.len() + carried > target && bits.len() >= 3 {
                let (a, b, d) = (bits.pop().unwrap(), bits.pop().unwrap(), bits.pop().unwrap());
                let s = c.aig.xor3(a, b, d);
                let cy = c.aig.maj3(a, b, d);
                bits.push(s);
                // Full adder: 3 -> 1 here + carry into column i+1.
                next[i + 1].push(cy);
            }
            if bits.len() + carried > target && bits.len() >= 2 {
                let (a, b) = (bits.pop().unwrap(), bits.pop().unwrap());
                let s = c.aig.xor(a, b);
                let cy = c.aig.and(a, b);
                bits.push(s);
                next[i + 1].push(cy);
            }
            next[i].extend(bits);
        }
        next.truncate(width);
        cols = next;
    }

    // Assemble the final two rows and sum them on a hard chain.
    let mut r1 = vec![Lit::FALSE; width];
    let mut r2 = vec![Lit::FALSE; width];
    for (i, col) in cols.iter().enumerate() {
        if let Some(&a) = col.first() {
            r1[i] = a;
        }
        if let Some(&b) = col.get(1) {
            r2[i] = b;
        }
    }
    if popcount(&r2) == 0 {
        return r1;
    }
    add_rows(c, &r1, &r2)
}

/// Unrolled multiplication by a compile-time constant: rows are shifted
/// copies of `x` for each set bit of `konst` (selector-bit elision — zero
/// bits contribute no row).
pub fn unrolled_mul(c: &mut Circuit, x: &[Lit], konst: u64, kbits: usize,
                    algo: AdderAlgo) -> Row {
    let width = x.len() + kbits;
    let mut rows: Rows = Vec::new();
    for j in 0..kbits.min(64) {
        if konst >> j & 1 == 1 {
            let mut row = vec![Lit::FALSE; width];
            for (i, &b) in x.iter().enumerate() {
                row[i + j] = b;
            }
            rows.push(row);
        }
    }
    if rows.is_empty() {
        return vec![Lit::FALSE; width];
    }
    let mut out = reduce_rows(c, rows, algo);
    out.truncate(width);
    out
}

/// General soft multiplication `x * y` (both unknown): AND-gate partial
/// products reduced with the chosen algorithm.
pub fn soft_mul(c: &mut Circuit, x: &[Lit], y: &[Lit], algo: AdderAlgo) -> Row {
    let width = x.len() + y.len();
    let mut rows: Rows = Vec::new();
    for (j, &yj) in y.iter().enumerate() {
        let mut row = vec![Lit::FALSE; width];
        for (i, &xi) in x.iter().enumerate() {
            row[i + j] = c.aig.and(xi, yj);
        }
        rows.push(row);
    }
    let mut out = reduce_rows(c, rows, algo);
    out.truncate(width);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALGOS: [AdderAlgo; 5] = [
        AdderAlgo::VtrBaseline,
        AdderAlgo::Cascade,
        AdderAlgo::BinaryTree,
        AdderAlgo::Wallace,
        AdderAlgo::Dadda,
    ];

    fn check_soft_mul(algo: AdderAlgo, w: usize) {
        let mut c = Circuit::new("mul");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, algo);
        c.po_bus("p", &p);
        let cases: Vec<(u64, u64)> = vec![
            (0, 0), (1, 1), (3, 5), ((1 << w) - 1, (1 << w) - 1),
            (5, (1 << w) - 2), (2, 3),
        ];
        for (a, b) in cases {
            let a = a & ((1 << w) - 1);
            let b = b & ((1 << w) - 1);
            let mut vals = vec![false; 2 * w];
            for i in 0..w {
                vals[i] = a >> i & 1 == 1;
                vals[w + i] = b >> i & 1 == 1;
            }
            let out = c.simulate(&vals, &[]);
            let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
            assert_eq!(got, a * b, "{}x{}: {a}*{b} ({})", w, w, algo.name());
        }
    }

    #[test]
    fn soft_mul_all_algos_4bit() {
        for algo in ALGOS {
            check_soft_mul(algo, 4);
        }
    }

    #[test]
    fn soft_mul_all_algos_6bit() {
        for algo in ALGOS {
            check_soft_mul(algo, 6);
        }
    }

    fn check_unrolled(algo: AdderAlgo, w: usize, k: u64) {
        let mut c = Circuit::new("umul");
        let x = c.pi_bus("x", w);
        let p = unrolled_mul(&mut c, &x, k, w, algo);
        c.po_bus("p", &p);
        for a in [0u64, 1, 3, 7, (1 << w) - 1, 5] {
            let a = a & ((1 << w) - 1);
            let mut vals = vec![false; w];
            for i in 0..w {
                vals[i] = a >> i & 1 == 1;
            }
            let out = c.simulate(&vals, &[]);
            let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
            let mask = (1u64 << (w + w)) - 1;
            assert_eq!(got, (a * k) & mask, "{a}*{k} ({})", algo.name());
        }
    }

    #[test]
    fn unrolled_mul_all_algos() {
        for algo in ALGOS {
            check_unrolled(algo, 6, 0b010101);
            check_unrolled(algo, 6, 0b111111);
            check_unrolled(algo, 4, 0b1001);
        }
    }

    #[test]
    fn unrolled_zero_constant() {
        let mut c = Circuit::new("z");
        let x = c.pi_bus("x", 4);
        let p = unrolled_mul(&mut c, &x, 0, 4, AdderAlgo::Wallace);
        assert!(p.iter().all(|&b| b == Lit::FALSE));
    }

    /// The paper's headline CAD example: an 8-bit multiply by 0b01010101
    /// needs far fewer adders with dedup than the VTR baseline (2.85x).
    #[test]
    fn dedup_saves_adders_on_01010101() {
        let mut base = Circuit::new("b");
        base.disable_dedup();
        let xb = base.pi_bus("x", 8);
        let _ = unrolled_mul(&mut base, &xb, 0b01010101, 8, AdderAlgo::VtrBaseline);

        let mut opt = Circuit::new("o");
        let xo = opt.pi_bus("x", 8);
        let _ = unrolled_mul(&mut opt, &xo, 0b01010101, 8, AdderAlgo::BinaryTree);

        let nb = base.num_adder_bits();
        let no = opt.num_adder_bits();
        assert!(nb as f64 / no as f64 > 1.6,
                "baseline {nb} vs optimized {no} adder bits");
    }

    /// Wallace minimizes stages aggressively; Dadda defers work. Both must
    /// use fewer adder bits than cascade on wide reductions.
    #[test]
    fn compressor_trees_use_fewer_hard_adders_than_cascade() {
        let count = |algo: AdderAlgo| {
            let mut c = Circuit::new("m");
            c.disable_dedup();
            let x = c.pi_bus("x", 8);
            let y = c.pi_bus("y", 8);
            let _ = soft_mul(&mut c, &x, &y, algo);
            c.num_adder_bits()
        };
        let cascade = count(AdderAlgo::Cascade);
        let wallace = count(AdderAlgo::Wallace);
        let dadda = count(AdderAlgo::Dadda);
        assert!(wallace < cascade, "wallace {wallace} vs cascade {cascade}");
        assert!(dadda < cascade, "dadda {dadda} vs cascade {cascade}");
    }

    /// Compressor trees shift work into LUT logic (AIG gates).
    #[test]
    fn compressor_trees_emit_soft_logic() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let before = c.aig.num_ands();
        let _ = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        assert!(c.aig.num_ands() > before + 20);
    }

    #[test]
    fn strength_dp_handles_odd_row_counts() {
        let mut c = Circuit::new("odd");
        let x = c.pi_bus("x", 5);
        // 5 set bits -> 5 rows.
        let p = unrolled_mul(&mut c, &x, 0b11111, 5, AdderAlgo::BinaryTree);
        c.po_bus("p", &p);
        let mut vals = vec![false; 5];
        vals[0] = true;
        vals[2] = true; // x = 5
        let out = c.simulate(&vals, &[]);
        let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
        assert_eq!(got, 5 * 0b11111);
    }
}
