//! PathFinder negotiated-congestion routing over the shared
//! routing-resource graph ([`crate::rrg`]).
//!
//! The RR abstraction (node layout, CSR adjacency, pin connectivity, the
//! congestion cost formula) lives in [`crate::rrg`]; this module owns the
//! negotiation loop.  Each iteration is *deterministic parallel
//! negotiated congestion* in three phases:
//!
//! 1. rip up every congested net in fixed net order (serial),
//! 2. re-route the ripped-up nets by A*, in fixed contiguous *waves* of
//!    [`WAVE`] nets: each wave routes against a read-only snapshot of the
//!    cost state, sharded across `RouteOpts::jobs` workers
//!    ([`crate::coordinator::parallel_indexed_with`], each worker reusing
//!    one set of search arrays), then commits its occupancy in net order
//!    before the next wave starts,
//! 3. bump history costs on overused nodes (serial reduction).
//!
//! A claimed-legal [`Routing`] is independently re-verified (source→sink
//! connectivity, overuse recount, tree-arena integrity) by
//! [`crate::check::audit_routing`] — the check-layer contract.
//!
//! ## Lookahead-guided A* and criticality-ordered trunk reuse
//!
//! By default ([`LookaheadMode::On`]) each sink's A* is guided by the
//! per-device class-distance lookahead ([`crate::rrg::lookahead`]): an
//! *exact* congestion-free hops-to-target bound, computed once per
//! (device, channel width) by backward BFS, memoized process-globally
//! and in the flow's disk cache (keyed by
//! [`crate::rrg::lookahead::cache_key`] — never by the netlist), and a
//! strictly better-informed admissible heuristic than the Manhattan
//! bound it replaces, so the search expands a near-minimal cone.  On
//! top of it, a net's sinks are routed in *descending criticality* order
//! (ties broken by sink index — a fixed total order, so the determinism
//! contract is untouched): the critical sinks lay the route tree's
//! trunk while congestion is fresh, and slack-rich sinks branch off the
//! committed tree with lookahead-priced seeds, which is where Steiner
//! trunk sharing comes from.  Results are still reported in terminal
//! order.  [`LookaheadMode::Off`] (`--lookahead off`) restores the
//! legacy Manhattan heuristic *and* source-order sinks, reproducing the
//! pre-lookahead router bit-for-bit — the escape hatch
//! `rust/tests/route_lookahead.rs` pins.
//!
//! Wave boundaries depend only on the work list — never on the worker
//! count — and routing a net is a pure function of (wave snapshot, net),
//! so results are bit-identical for any `jobs` value — see
//! `rust/tests/route_parallel.rs`.  The wave size trades negotiation
//! fidelity (small waves see fresher occupancy, converging in fewer
//! iterations, like VPR's sequential router) against available
//! parallelism; measurements on synthetic instances put the total-work
//! overhead of 32-net waves at ~1.5x the sequential router versus ~3x for
//! whole-iteration snapshots.  Produces per-sink routed path lengths (for
//! the post-route STA) and the channel-utilization histogram of Fig. 8.
//!
//! ## Closed-loop timing-driven routing
//!
//! [`route_timing`] layers a timing feedback loop over the negotiation:
//!
//! * **per-sink weights** — each sink terminal carries its own
//!   criticality (from a [`crate::timing::SinkCrit`] arena folded onto
//!   routing terminals by [`term_sink_crit`]); the A* toward that sink
//!   prices every node at the blend `(1 - crit) * congestion_cost + crit`
//!   (crit capped at [`CRIT_MAX`]), so a net's critical branch weighs
//!   wire length over congestion while its slack-rich branches still
//!   detour,
//! * **inter-iteration STA** — every [`TimingCtx::sta_every`] iterations
//!   the loop re-runs the wave-parallel STA
//!   ([`crate::timing::sta_with`], over the shared PR-3
//!   `NetlistIndex`/`PackIndex` arenas) against the *current* partial
//!   routing ([`sink_hops_delay`]) and folds the fresh criticalities in
//!   with exponential smoothing `crit' = α·new + (1-α)·old`
//!   ([`TimingCtx::crit_alpha`]), so the weights track the evolving
//!   congestion picture; achieved CPD per refresh lands in
//!   [`Routing::cpd_trace`],
//! * **criticality-weighted history** — the [`CostState`] criticality
//!   lane (rebuilt per iteration from the committed trees) scales the
//!   history bump so congestion parked on critical wiring resolves first,
//! * **criticality rip-up** — a net whose max criticality rose by more
//!   than [`CRIT_RIPUP_DELTA`] since its route was last computed is
//!   ripped up with the congested nets, so refreshed weights re-route
//!   stale legal paths instead of only steering congestion victims.
//!
//! The refresh happens strictly *between* negotiation iterations and the
//! STA itself is bit-identical for any worker count, so the PR-2
//! determinism contract extends to the closed loop: `Routing` (and the
//! final post-route [`crate::timing::TimingReport`]) is bit-identical for
//! any `jobs`/`sta_jobs` — enforced by `rust/tests/timing_route.rs`.
//! With all criticalities zero the blend collapses to exactly the
//! timing-oblivious cost, so untimed runs are unchanged bit-for-bit.

use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use crate::arch::device::Loc;
use crate::arch::Arch;
use crate::coordinator::parallel_indexed_with;
use crate::netlist::{CellId, NetId, Netlist, NetlistIndex, PackIndex};
use crate::pack::Packing;
use crate::place::cost::{NetModel, Term};
use crate::place::Placement;
use crate::rrg::lookahead::{self, Lookahead};
use crate::rrg::{self, CostState, RrGraph, NODE_CAP};
use crate::timing::SinkCrit;

/// VPR's astar_fac: inflate the admissible heuristic for a large
/// search-space cut at bounded routing-cost suboptimality.
const ASTAR_FAC: f64 = 1.3;

/// Nets routed per negotiation wave (see module docs).  Fixed — never
/// derived from the worker count — so wave composition, and therefore the
/// routing result, is identical for any `RouteOpts::jobs`.
pub const WAVE: usize = 32;

/// Criticality cap for the router's cost blend (VPR's `max_criticality`):
/// a sink prices nodes at `(1 - crit) * congestion_cost + crit`, so an
/// uncapped fully-critical sink would ignore congestion entirely and
/// never detour; the cap keeps every connection negotiable.
pub const CRIT_MAX: f64 = 0.95;

/// Criticality-rise rip-up threshold for the closed loop: a net whose max
/// criticality grew by more than this since its route was last computed
/// is ripped up alongside the congested nets, so refreshed weights act on
/// *existing* legal routes too — without it the feedback could only steer
/// nets that happened to be congestion-ripped anyway.  Criticalities
/// change only at STA refreshes, so static-weight runs never trigger it.
const CRIT_RIPUP_DELTA: f32 = 0.1;

/// How the router obtains its A* heuristic (and, with it, the sink
/// routing order — the two ship together so `Off` is a faithful
/// pre-lookahead escape hatch; see the module docs).
#[derive(Clone, Debug, Default)]
pub enum LookaheadMode {
    /// Legacy router: Manhattan heuristic, sinks in terminal order.
    /// Bit-identical to the pre-lookahead router.
    Off,
    /// Build (or fetch from the process-global memo,
    /// [`crate::rrg::lookahead::shared`]) the per-device map.
    #[default]
    On,
    /// Use a prebuilt map — the flow passes the disk-cache-backed
    /// [`crate::flow::engine::ArtifactCache`] artifact through here.
    /// Must match the device grid (checked at route start).
    Shared(Arc<Lookahead>),
}

/// Router options.
#[derive(Clone, Debug)]
pub struct RouteOpts {
    pub max_iters: usize,
    /// Initial present-congestion factor and its per-iteration growth.
    pub pres_fac0: f64,
    pub pres_mult: f64,
    /// History cost increment per overused node per iteration.
    pub hist_fac: f64,
    /// Worker threads sharding the per-net A* searches (1 = serial; the
    /// result is bit-identical for any value).
    pub jobs: usize,
    /// Optional per-net criticality in [0, 1], indexed by [`NetId`]
    /// (typically [`crate::timing::TimingReport::net_crit`]).  Every sink
    /// of the net prices nodes at the blend
    /// `(1 - crit) * congestion_cost + crit` (crit capped at
    /// [`CRIT_MAX`]), so critical nets weigh wire length over congestion
    /// and concede contested nodes to slack-rich nets.  Empty (the
    /// default) blends with 0.0 everywhere — bit-identical to the
    /// timing-oblivious router.  [`RouteOpts::sink_crit`] entries, when
    /// present, override this per-net value per sink.
    pub net_crit: Vec<f64>,
    /// Optional per-*sink* criticality: `sink_crit[i][k]` drives the A*
    /// toward sink terminal `terms[k + 1]` of the model's external net
    /// `i` — the shape [`term_sink_crit`] produces from a per-sink STA
    /// arena ([`crate::timing::SinkCrit`]).  Finer than [`net_crit`]: a
    /// net's slack-rich branches still dodge congestion while its
    /// critical branch routes direct.  Empty = fall back to `net_crit`.
    ///
    /// [`net_crit`]: RouteOpts::net_crit
    pub sink_crit: Vec<Vec<f64>>,
    /// A* lookahead mode (default [`LookaheadMode::On`]; see the module
    /// docs and `--lookahead` on the CLI).
    pub lookahead: LookaheadMode,
    /// Deterministic give-up budget on the A* heap-pop odometer
    /// ([`Routing::astar_pops`]): once the fixed-order pop total reaches
    /// this, the negotiation stops at the end of the iteration and
    /// reports `success: false`.  `0` (default) = unlimited.  A logical
    /// odometer, never a wall clock — the flow's escalation ladder
    /// degrades on it without breaking bit-identity across worker
    /// counts.
    pub pops_budget: usize,
}

impl Default for RouteOpts {
    fn default() -> Self {
        // Snapshot-based negotiation (all ripped-up nets re-route against
        // the frozen iteration-start costs, as in the original PathFinder
        // formulation) can take a few more iterations than VPR's
        // sequential-commit variant to shake out symmetric conflicts, so
        // the cap carries headroom; converged runs exit early regardless.
        RouteOpts {
            max_iters: 64,
            pres_fac0: 0.5,
            pres_mult: 1.6,
            hist_fac: 0.5,
            jobs: 1,
            net_crit: Vec::new(),
            sink_crit: Vec::new(),
            lookahead: LookaheadMode::default(),
            pops_budget: 0,
        }
    }
}

/// Routing result.
#[derive(Clone, Debug)]
pub struct Routing {
    pub success: bool,
    pub iterations: usize,
    /// Per external net: per sink terminal, wire-hop count of its path.
    pub sink_hops: Vec<Vec<(Term, usize)>>,
    /// Occupancy / capacity per channel node (for the Fig. 8 histogram).
    pub channel_util: Vec<f64>,
    /// Total wirelength in hops.
    pub wirelength: usize,
    /// Nodes still overused at exit (0 on success).
    pub overused: usize,
    /// Debug: overused node descriptors (dir, x, y, track, occupancy).
    pub overused_nodes: Vec<(usize, usize, usize, usize, u16)>,
    /// Debug: per-net routed node ids.
    pub net_nodes: Vec<Vec<usize>>,
    /// Achieved critical-path delay (ps) at each inter-iteration STA
    /// refresh of the closed timing loop, in refresh order.  Empty for
    /// timing-oblivious runs and when the router converges before the
    /// first refresh.
    pub cpd_trace: Vec<f64>,
    /// Total A* heap pops across all nets, sinks, and negotiation
    /// iterations — the router's search-effort odometer (with
    /// `iterations`, the evidence counters the perf gate tracks in
    /// `BENCH.json`).  Deterministic: a fixed-order sum of per-net
    /// values that are themselves pure in (snapshot, net).
    pub astar_pops: usize,
}

impl Routing {
    /// Fig. 8 histogram: fraction of channel segments per utilization bin.
    pub fn util_histogram(&self, bins: usize) -> Vec<f64> {
        let mut h = vec![0.0; bins];
        if self.channel_util.is_empty() {
            return h;
        }
        for &u in &self.channel_util {
            let b = ((u * bins as f64) as usize).min(bins - 1);
            h[b] += 1.0;
        }
        let total: f64 = h.iter().sum();
        h.iter_mut().for_each(|v| *v /= total);
        h
    }
}

#[derive(PartialEq)]
struct QItem {
    prio: f64,
    cost: f64,
    node: usize,
}
impl Eq for QItem {}
impl Ord for QItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.prio.partial_cmp(&self.prio).unwrap_or(std::cmp::Ordering::Equal)
    }
}
impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-worker A* search state, reused across the nets a worker routes.
/// The dense arrays reset between searches via the `touched` list, and
/// the per-net/per-sink buffers (`tree`, `heap`, `order`) are cleared
/// before use, so a search's outcome never depends on which worker (or
/// in which order) it ran — and per-sink setup allocates nothing.
struct AStarScratch {
    cost: Vec<f64>,
    prev: Vec<usize>,
    touched: Vec<usize>,
    /// Route tree of the net being routed: `(node, hops)` pairs sorted
    /// by node (nodes are unique), probed by binary search — the seed
    /// iteration order is identical to the sorted seed list the
    /// `HashMap` version collected per sink, without the per-sink
    /// collect + sort.
    tree: Vec<(usize, usize)>,
    /// A* frontier, cleared per sink.
    heap: BinaryHeap<QItem>,
    /// Sink routing order for the net being routed (see `route_net`).
    order: Vec<usize>,
}

impl AStarScratch {
    fn new(n_nodes: usize) -> AStarScratch {
        AStarScratch {
            cost: vec![f64::INFINITY; n_nodes],
            prev: vec![usize::MAX; n_nodes],
            touched: Vec::new(),
            tree: Vec::new(),
            heap: BinaryHeap::new(),
            order: Vec::new(),
        }
    }
}

/// Checks a scratch out of a shared pool for the duration of one wave and
/// returns it on drop, so the O(n_nodes) arrays are allocated at most
/// `jobs` times per `route()` call instead of per wave.  Reuse is safe
/// because every search resets exactly the entries its predecessors
/// touched before reading them.
struct ScratchLease<'a> {
    pool: &'a std::sync::Mutex<Vec<AStarScratch>>,
    scratch: Option<AStarScratch>,
}

impl<'a> ScratchLease<'a> {
    fn take(pool: &'a std::sync::Mutex<Vec<AStarScratch>>, n_nodes: usize) -> ScratchLease<'a> {
        let s = pool.lock().unwrap().pop().unwrap_or_else(|| AStarScratch::new(n_nodes));
        ScratchLease { pool, scratch: Some(s) }
    }
}

impl Drop for ScratchLease<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.lock().unwrap().push(s);
        }
    }
}

/// Route one net against a frozen cost snapshot.  Pure in
/// (graph, snapshot, pres_fac, net, sink criticalities, lookahead): no
/// shared mutable state.  `sink_crit[k]` is the criticality of sink
/// terminal `terms[k + 1]`; the A* toward that sink prices every node at
/// `(1 - crit) * congestion_cost + crit` (0.0 = exactly the
/// timing-oblivious cost; see [`RouteOpts::sink_crit`]).  With a
/// lookahead, sinks route in descending-criticality order (index
/// tie-break) so critical trunks commit first and slack-rich sinks
/// branch off them; `sink_hops` is always reported in terminal order.
/// Returns the net's committed node set (sorted, deduped), per-sink hop
/// counts, and the search's heap-pop count.
#[allow(clippy::too_many_arguments)]
fn route_net<F: Fn(Term) -> Loc>(
    graph: &RrGraph,
    costs: &CostState,
    pres_fac: f64,
    ni: usize,
    terms: &[Term],
    term_loc: &F,
    arch: &Arch,
    sink_crit: &[f64],
    la: Option<&Lookahead>,
    scratch: &mut AStarScratch,
) -> (Vec<usize>, Vec<(Term, usize)>, usize) {
    let src_loc = term_loc(terms[0]);
    let src_nodes = graph.pin_nodes(src_loc, arch.routing.fc_out, 17 + 131 * ni as u64);

    // Split-borrow the scratch so the tree can be read while the search
    // arrays and frontier are written.
    let AStarScratch { cost, prev, touched, tree, heap, order } = scratch;

    // Route tree as `(node, hops-from-source)` pairs, kept sorted by
    // node.  Seeds (source track taps, already sorted + deduped) are
    // search entry points but only nodes actually used by a sink path
    // get committed.
    tree.clear();
    tree.extend(src_nodes.iter().map(|&id| (id, 0usize)));
    let mut used: Vec<usize> = Vec::new();
    let n_sinks = terms.len().saturating_sub(1);
    let mut sink_hops: Vec<(Term, usize)> =
        terms[1..].iter().map(|&t| (t, 0usize)).collect();
    let mut pops = 0usize;

    // Sink routing order: terminal order without a lookahead (the legacy
    // router, preserved bit-for-bit for `--lookahead off`); descending
    // criticality with sink-index tie-break with one — a fixed total
    // order, so determinism is untouched and tied criticalities route
    // stably.
    order.clear();
    order.extend(0..n_sinks);
    if la.is_some() {
        order.sort_by(|&a, &b| {
            let ca = sink_crit.get(a).copied().unwrap_or(0.0);
            let cb = sink_crit.get(b).copied().unwrap_or(0.0);
            cb.total_cmp(&ca).then(a.cmp(&b))
        });
    }

    for oi in 0..order.len() {
        let si = order[oi];
        let sink = terms[si + 1];
        // This sink's criticality blend (0.0 when absent — neutral).
        let c = sink_crit.get(si).copied().unwrap_or(0.0);
        let dst_loc = term_loc(sink);
        // Sorted + deduped; target membership is a binary-search probe.
        let dst_nodes = graph.pin_nodes(dst_loc, arch.routing.fc_in, 71 + 131 * ni as u64);
        let (tx, ty) = (dst_loc.x as usize, dst_loc.y as usize);

        // Reset the search arrays from the previous sink.
        for &n in touched.iter() {
            cost[n] = f64::INFINITY;
            prev[n] = usize::MAX;
        }
        touched.clear();
        heap.clear();

        // A* from the current tree (sorted by node — the same
        // deterministic tie-breaking order as ever).
        for &(n, hops) in tree.iter() {
            // Fresh source taps pay their own congestion cost (otherwise a
            // net would happily start on an occupied tap it never
            // perceives); nodes already on this net's tree re-enter free.
            let entry =
                if hops == 0 { (1.0 - c) * costs.node_cost(n, pres_fac) + c } else { 0.0 };
            cost[n] = entry;
            prev[n] = usize::MAX;
            touched.push(n);
            // Legacy quirk, kept bit-exact for the Off path: seed
            // priorities skip the ASTAR_FAC inflation.
            let h = match la {
                Some(m) => ASTAR_FAC * m.query(n, tx, ty),
                None => graph.heur(n, tx, ty),
            };
            heap.push(QItem { prio: entry + h, cost: entry, node: n });
        }

        let mut found = usize::MAX;
        while let Some(QItem { cost: ncost, node, .. }) = heap.pop() {
            pops += 1;
            if ncost > cost[node] {
                continue;
            }
            if dst_nodes.binary_search(&node).is_ok() {
                found = node;
                break;
            }
            for &nb in graph.neighbors(node) {
                let nid = nb as usize;
                let nc = ncost + (1.0 - c) * costs.node_cost(nid, pres_fac) + c;
                if nc < cost[nid] {
                    if cost[nid].is_infinite() && prev[nid] == usize::MAX {
                        touched.push(nid);
                    }
                    cost[nid] = nc;
                    prev[nid] = node;
                    let h = match la {
                        Some(m) => ASTAR_FAC * m.query(nid, tx, ty),
                        None => ASTAR_FAC * graph.heur(nid, tx, ty),
                    };
                    heap.push(QItem { prio: nc + h, cost: nc, node: nid });
                }
            }
        }

        if found == usize::MAX {
            // Unroutable sink this iteration; count a distance estimate and
            // keep going (pressure will reshape other nets).
            sink_hops[si] = (sink, (src_loc.dist(dst_loc) as usize).max(1));
            continue;
        }
        // Walk back, add path to tree.
        let mut path = Vec::new();
        let mut cur = found;
        while cur != usize::MAX && tree.binary_search_by_key(&cur, |&(n, _)| n).is_err() {
            path.push(cur);
            cur = prev[cur];
        }
        let base_hops = match tree.binary_search_by_key(&cur, |&(n, _)| n) {
            Ok(i) => tree[i].1,
            Err(_) => 0,
        };
        // The attachment node is used (it may be a fresh seed tap).
        if cur != usize::MAX {
            used.push(cur);
        }
        let hops = base_hops + path.len();
        sink_hops[si] = (sink, hops);
        // Path nodes are new to the tree (the walk-back stopped at the
        // first tree node), so append + re-sort keeps nodes unique.
        for (off, &n) in path.iter().rev().enumerate() {
            tree.push((n, base_hops + off + 1));
            used.push(n);
        }
        tree.sort_unstable();
    }

    used.sort_unstable();
    used.dedup();
    (used, sink_hops, pops)
}

/// Route a placed design (timing-oblivious unless `opts` carries static
/// criticalities; see [`route_timing`] for the closed loop).
pub fn route(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    opts: &RouteOpts,
) -> Routing {
    route_inner(model, placement, arch, opts, None)
}

/// Netlist-side context for [`route_timing`]: the dense arenas each STA
/// refresh runs over, plus the feedback schedule.  The arenas are the
/// same `NetlistIndex`/`PackIndex` the placer's periodic STA reuses —
/// build them once per (netlist, packing) and share.
pub struct TimingCtx<'a> {
    pub nl: &'a Netlist,
    pub idx: &'a NetlistIndex,
    pub pidx: &'a PackIndex,
    pub packing: &'a Packing,
    /// Re-run STA against the evolving routing every this many PathFinder
    /// iterations; `0` never refreshes, reproducing the static-weight
    /// router ([`route`] with the same `opts`) bit-for-bit.
    pub sta_every: usize,
    /// Exponential smoothing factor `α` in
    /// `crit' = α * crit_new + (1 - α) * crit_old`.
    pub crit_alpha: f64,
    /// Worker threads for each STA refresh (the report is bit-identical
    /// for any value, so this never perturbs the routing).
    pub sta_jobs: usize,
}

/// Closed-loop timing-driven routing: [`route`], plus an inter-iteration
/// STA feedback that refreshes the per-sink criticality weights while the
/// negotiation runs (see the module docs).  Deterministic: bit-identical
/// `Routing` for any `opts.jobs` / `timing.sta_jobs`.
pub fn route_timing(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    opts: &RouteOpts,
    timing: &TimingCtx,
) -> Routing {
    route_inner(model, placement, arch, opts, Some(timing))
}

/// Per-net max criticality (the value the cost state's crit lane carries
/// for every node of that net's tree).
fn max_crit_per_net(crit: &[Vec<f64>]) -> Vec<f32> {
    crit.iter()
        .map(|v| v.iter().fold(0.0f64, |m, &c| m.max(c)) as f32)
        .collect()
}

fn route_inner(
    model: &NetModel,
    placement: &Placement,
    arch: &Arch,
    opts: &RouteOpts,
    timing: Option<&TimingCtx>,
) -> Routing {
    let device = &placement.device;
    let graph = RrGraph::build(device, arch);
    let n_nodes = graph.num_nodes();

    // Resolve the A* lookahead: `On` builds (or fetches) the per-device
    // map via the process-global memo; `Shared` trusts a prebuilt
    // artifact after a dimension check; `Off` is the legacy router.
    let la: Option<Arc<Lookahead>> = match &opts.lookahead {
        LookaheadMode::Off => None,
        LookaheadMode::On => Some(lookahead::shared(&graph)),
        LookaheadMode::Shared(m) => {
            assert!(
                m.matches(&graph),
                "lookahead map is for a {}x{}xW{} grid, graph is {}x{}xW{}",
                m.width(),
                m.height(),
                m.tracks(),
                graph.width,
                graph.height,
                graph.tracks
            );
            Some(m.clone())
        }
    };

    let term_loc = |t: Term| -> Loc {
        match t {
            Term::Lb(i) => placement.lb_loc[i],
            Term::Io(c) => placement.io_loc[&c],
        }
    };

    // Per-net terminals (source first).
    let nets: Vec<(NetId, Vec<Term>)> = model
        .nets
        .iter()
        .map(|en| (en.net, en.terms.clone()))
        .collect();

    // Per-(net, sink-terminal) criticality state feeding the A* cost
    // blend.  Seeded from `opts` (the per-sink arena when present, else
    // the per-net value for every sink of that net); refreshed in place
    // by the closed timing loop.  All-zero criticality blends to exactly
    // the timing-oblivious node cost (see `route_net`).
    let mut crit: Vec<Vec<f64>> = nets
        .iter()
        .enumerate()
        .map(|(i, (nid, terms))| {
            let net_c = opts
                .net_crit
                .get(*nid as usize)
                .copied()
                .unwrap_or(0.0)
                .clamp(0.0, CRIT_MAX);
            (0..terms.len().saturating_sub(1))
                .map(|k| {
                    opts.sink_crit
                        .get(i)
                        .and_then(|v| v.get(k))
                        .map_or(net_c, |&s| s.clamp(0.0, CRIT_MAX))
                })
                .collect()
        })
        .collect();
    let mut net_max_crit: Vec<f32> = max_crit_per_net(&crit);
    // Per net: its max criticality at the time its current route was
    // computed — the rise `net_max_crit - routed_crit` triggers
    // criticality rip-up (see [`CRIT_RIPUP_DELTA`]).
    let mut routed_crit: Vec<f32> = net_max_crit.clone();
    let mut cpd_trace: Vec<f64> = Vec::new();

    let mut costs = CostState::new(n_nodes);
    // Does the cost state's crit lane hold stale notes from a previous
    // iteration?  Lets the timing-oblivious path skip the O(n_nodes)
    // clear + rebuild entirely.
    let mut lane_dirty = false;
    // Per net: routed node set (tree) and per-sink paths.
    let mut net_nodes: Vec<Vec<usize>> = vec![Vec::new(); nets.len()];
    let mut sink_hops: Vec<Vec<(Term, usize)>> = vec![Vec::new(); nets.len()];

    let mut pres_fac = opts.pres_fac0;
    let mut iterations = 0;
    let mut success = false;
    let mut astar_pops = 0usize;

    // Shared A* scratch pool: at most `jobs` sets of search arrays are
    // ever allocated, leased per wave and reused across waves/iterations.
    let scratch_pool: std::sync::Mutex<Vec<AStarScratch>> = std::sync::Mutex::new(Vec::new());

    for iter in 0..opts.max_iters {
        iterations = iter + 1;
        // Phase 1 — rip-up (serial, fixed order).  First iteration routes
        // everything; later iterations rip up and re-route nets touching
        // overused nodes (VPR's incremental rip-up — the bulk of nets
        // keep their legal routes) plus, in the closed loop, nets whose
        // criticality rose materially since they were last routed — a
        // refreshed weight is useless to a net that never re-routes.
        let work: Vec<usize> = if iter == 0 {
            (0..nets.len()).collect()
        } else {
            (0..nets.len())
                .filter(|&ni| {
                    net_nodes[ni].iter().any(|&n| costs.overused(n))
                        || net_max_crit[ni] - routed_crit[ni] > CRIT_RIPUP_DELTA
                })
                .collect()
        };
        for &ni in &work {
            for &n in &net_nodes[ni] {
                costs.occ[n] = costs.occ[n].saturating_sub(1);
            }
            net_nodes[ni].clear();
            sink_hops[ni].clear();
        }

        // Phase 2 — route the ripped-up nets in fixed waves: each wave
        // runs against the frozen cost snapshot (sharded across workers
        // with per-worker search scratch), then commits occupancy in net
        // order before the next wave sees the graph.
        for wave in work.chunks(WAVE) {
            let costs_ref = &costs;
            let graph_ref = &graph;
            let nets_ref = &nets;
            let crit_ref = &crit;
            let term_loc_ref = &term_loc;
            let pool_ref = &scratch_pool;
            let la_ref = la.as_deref();
            // Small waves (the long tail of late, lightly-congested
            // iterations) run on the calling thread: spawning workers for
            // a handful of nets costs more than it saves, and the result
            // is identical either way (worker count is unobservable).
            let wave_jobs = if wave.len() < 8 { 1 } else { opts.jobs.max(1) };
            let routed: Vec<(Vec<usize>, Vec<(Term, usize)>, usize)> = parallel_indexed_with(
                wave.len(),
                wave_jobs,
                || ScratchLease::take(pool_ref, n_nodes),
                |lease, wi| {
                    let ni = wave[wi];
                    route_net(
                        graph_ref,
                        costs_ref,
                        pres_fac,
                        ni,
                        &nets_ref[ni].1,
                        term_loc_ref,
                        arch,
                        &crit_ref[ni],
                        la_ref,
                        lease.scratch.as_mut().expect("scratch held for lease lifetime"),
                    )
                },
            );
            for ((used, hops, pops), &ni) in routed.into_iter().zip(wave.iter()) {
                for &n in &used {
                    costs.occ[n] += 1;
                }
                net_nodes[ni] = used;
                sink_hops[ni] = hops;
                routed_crit[ni] = net_max_crit[ni];
                // Fixed-order sum of per-net pop counts: identical for
                // any worker count.
                astar_pops += pops;
            }
        }

        // Rebuild the criticality lane from the committed trees so phase 3
        // weighs congestion on critical wiring more heavily.  Fixed net
        // order + max-accumulate keeps it deterministic.  Guarded so the
        // timing-oblivious path (all-zero criticality) never pays the
        // O(n_nodes) clear/rebuild — its bump stays the classic one.
        if lane_dirty {
            costs.clear_crit();
            lane_dirty = false;
        }
        if net_max_crit.iter().any(|&c| c > 0.0) {
            for (ni, &c) in net_max_crit.iter().enumerate() {
                if c > 0.0 {
                    for &n in &net_nodes[ni] {
                        costs.note_crit(n, c);
                    }
                }
            }
            lane_dirty = true;
        }

        // Phase 3 — history accumulation on whatever is still overused.
        let overused = costs.bump_history(opts.hist_fac);
        if overused == 0 {
            success = true;
            break;
        }
        // Deterministic give-up odometer: `astar_pops` is a fixed-order
        // sum of per-net values that are pure in (snapshot, net), so the
        // budget trips at the same iteration for any worker count.
        if opts.pops_budget > 0 && astar_pops >= opts.pops_budget {
            break;
        }
        pres_fac *= opts.pres_mult;

        // Closed timing loop: every `sta_every` iterations, re-run STA
        // against the current partial routing and fold the fresh per-sink
        // criticalities in with exponential smoothing.  The refresh sits
        // strictly between iterations, so every wave of the next
        // iteration still routes against one frozen criticality snapshot
        // and the determinism contract holds (the STA itself is
        // bit-identical for any `sta_jobs`).
        if let Some(tc) = timing {
            if tc.sta_every > 0 && iterations % tc.sta_every == 0 {
                let delay = sink_hops_delay(&sink_hops, model, arch);
                let rpt = crate::timing::sta_with(
                    tc.nl, tc.idx, tc.pidx, tc.packing, arch, delay, tc.sta_jobs,
                );
                cpd_trace.push(rpt.cpd_ps);
                let fresh = term_sink_crit(model, tc.idx, &rpt.sink_crit);
                let alpha = tc.crit_alpha.clamp(0.0, 1.0);
                for (cur, new) in crit.iter_mut().zip(fresh.iter()) {
                    for (cv, &nv) in cur.iter_mut().zip(new.iter()) {
                        *cv = (alpha * nv + (1.0 - alpha) * *cv).clamp(0.0, CRIT_MAX);
                    }
                }
                net_max_crit = max_crit_per_net(&crit);
            }
        }
    }

    let overused = costs.occ.iter().filter(|&&o| o as f64 > NODE_CAP).count();
    let overused_nodes: Vec<(usize, usize, usize, usize, u16)> = costs
        .occ
        .iter()
        .enumerate()
        .filter(|&(_, &o)| o as f64 > NODE_CAP)
        .map(|(id, &o)| {
            let (d, x, y, t) = graph.decode(id);
            (d, x, y, t, o)
        })
        .collect();

    // Channel utilization: average occupancy per channel segment (all W
    // tracks of one direction at one grid point form a "channel").
    let mut channel_util = Vec::with_capacity(2 * graph.width * graph.height);
    for dir in 0..2 {
        for y in 0..graph.height {
            for x in 0..graph.width {
                let used: usize = (0..graph.tracks)
                    .filter(|&t| costs.occ[graph.node_id(dir, x, y, t)] > 0)
                    .count();
                channel_util.push(used as f64 / graph.tracks as f64);
            }
        }
    }

    let wirelength = costs.occ.iter().map(|&o| o as usize).sum();

    Routing {
        success,
        iterations,
        sink_hops,
        channel_util,
        wirelength,
        overused,
        overused_nodes,
        net_nodes,
        cpd_trace,
        astar_pops,
    }
}

/// Fold a per-sink STA arena onto routing terminals: entry `[i][k]`
/// aligns with `model.nets[i].terms[k + 1]` and is the max criticality
/// over the netlist sinks riding that terminal (several cells in one LB
/// can sink the same net).  This is the shape [`RouteOpts::sink_crit`]
/// and the closed loop's refresh consume.  Intra-LB sinks (no routed
/// wire) and sinks sharing the driver's terminal contribute nothing.
///
/// The fold itself lives on the net model
/// ([`NetModel::fold_sink_crit`]) — the placer's per-sink timing lane
/// consumes exactly the same shape, so router and placer share one
/// definition.
pub fn term_sink_crit(
    model: &NetModel,
    idx: &NetlistIndex,
    sc: &SinkCrit,
) -> Vec<Vec<f64>> {
    model.fold_sink_crit(idx, sc)
}

/// Per-net, per-sink interconnect delays from a set of routed sink paths
/// — possibly still mid-negotiation: the closed timing loop runs STA
/// against these between PathFinder iterations, and [`routed_net_delay`]
/// wraps the final result for post-route STA.
pub fn sink_hops_delay<'a>(
    sink_hops: &'a [Vec<(Term, usize)>],
    model: &'a NetModel,
    arch: &'a Arch,
) -> impl Fn(NetId, CellId, u8) -> f64 + Sync + 'a {
    // net -> (ExtNet index) for lookup.
    let mut by_net: HashMap<NetId, usize> = HashMap::new();
    for (i, en) in model.nets.iter().enumerate() {
        by_net.insert(en.net, i);
    }
    move |net: NetId, sink: CellId, _pin: u8| -> f64 {
        let Some(&i) = by_net.get(&net) else { return 0.0 };
        // Per-sink routed hops: the sink cell's terminal identifies which
        // branch of the route tree it rides. Cells without a terminal
        // (intra-LB) and IO sinks fall back to the worst branch.
        let hops = match model.term_of_cell(sink) {
            Some(t) => sink_hops[i]
                .iter()
                .find(|&&(st, _)| st == t)
                .map(|&(_, h)| h)
                .unwrap_or_else(|| {
                    sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0)
                }),
            None => sink_hops[i].iter().map(|&(_, h)| h).max().unwrap_or(0),
        };
        if hops == 0 {
            return 0.0;
        }
        rrg::hop_delay(arch, hops)
    }
}

/// Per-net, per-sink routed delays for post-route STA.
pub fn routed_net_delay<'a>(
    routing: &'a Routing,
    model: &'a NetModel,
    arch: &'a Arch,
) -> impl Fn(NetId, CellId, u8) -> f64 + Sync + 'a {
    sink_hops_delay(&routing.sink_hops, model, arch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::place::{place, PlaceOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn routed(w: usize) -> (Routing, NetModel, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("placement");
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        let r = route(&model, &pl, &arch, &RouteOpts::default());
        (r, model, arch)
    }

    #[test]
    fn routes_small_multiplier() {
        let (r, model, _) = routed(5);
        assert!(r.success, "unrouted after {} iters ({} overused)", r.iterations, r.overused);
        assert_eq!(r.sink_hops.len(), model.num_nets());
        // Every sink of every net has a path.
        for (i, en) in model.nets.iter().enumerate() {
            assert_eq!(r.sink_hops[i].len(), en.terms.len() - 1);
        }
        assert!(r.wirelength > 0);
    }

    #[test]
    fn histogram_normalized() {
        let (r, _, _) = routed(5);
        let h = r.util_histogram(10);
        assert_eq!(h.len(), 10);
        let sum: f64 = h.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tight_channel_increases_congestion() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let mut arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("placement");
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        arch.routing.channel_width = 48;
        let wide = route(&model, &pl, &arch, &RouteOpts::default());
        arch.routing.channel_width = 12;
        let narrow = route(&model, &pl, &arch, &RouteOpts::default());
        let mean_u = |r: &Routing| {
            r.channel_util.iter().sum::<f64>() / r.channel_util.len() as f64
        };
        assert!(mean_u(&narrow) > mean_u(&wide));
    }

    /// `term_sink_crit` aligns with the model's terminal lists and stays
    /// within criticality bounds.
    #[test]
    fn term_sink_crit_shape_and_bounds() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        let idx = crate::netlist::NetlistIndex::build(&nl);
        let pidx = crate::netlist::PackIndex::build(&nl, &packing);
        let rpt =
            crate::timing::sta_with(&nl, &idx, &pidx, &packing, &arch, |_, _, _| 150.0, 1);
        let sc = term_sink_crit(&model, &idx, &rpt.sink_crit);
        assert_eq!(sc.len(), model.num_nets());
        for (en, v) in model.nets.iter().zip(sc.iter()) {
            assert_eq!(v.len(), en.terms.len() - 1);
            assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
        // Some terminal is critical somewhere.
        assert!(sc.iter().flatten().any(|&x| x > 0.5));
    }

    /// Both lookahead modes converge on the same instance, the pop
    /// odometer runs, and per-sink results line up with the terminal
    /// lists in both modes (the Off/On bit-level contracts live in
    /// `rust/tests/route_lookahead.rs`).
    #[test]
    fn lookahead_modes_route_and_count_pops() {
        let (on, model, arch) = routed(5);
        assert!(on.astar_pops > 0, "pop odometer never ran");
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("placement");
        let off = route(&model, &pl, &arch,
                        &RouteOpts { lookahead: LookaheadMode::Off, ..Default::default() });
        assert!(off.success);
        assert!(off.astar_pops > 0);
        for (i, en) in model.nets.iter().enumerate() {
            assert_eq!(off.sink_hops[i].len(), en.terms.len() - 1);
            for (k, &(t, _)) in off.sink_hops[i].iter().enumerate() {
                assert_eq!(t, en.terms[k + 1], "sink order must mirror terms");
            }
            for (k, &(t, _)) in on.sink_hops[i].iter().enumerate() {
                assert_eq!(t, en.terms[k + 1], "sink order must mirror terms");
            }
        }
    }

    /// A tiny pops budget stops the negotiation deterministically (same
    /// iteration for any worker count) and reports non-convergence; a
    /// huge budget never triggers and reproduces the unbudgeted run.
    #[test]
    fn pops_budget_gives_up_deterministically() {
        let (base, model, arch) = routed(5);
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("placement");
        // Starve the router on a too-narrow channel so it cannot converge
        // inside the budget.
        let mut narrow = arch.clone();
        narrow.routing.channel_width = 2;
        let budgeted = |jobs: usize| {
            route(&model, &pl, &narrow,
                  &RouteOpts { jobs, pops_budget: 500, ..Default::default() })
        };
        let b1 = budgeted(1);
        assert!(!b1.success, "budget must stop an unconvergeable run");
        assert!(b1.iterations < RouteOpts::default().max_iters, "gave up via the odometer");
        let b4 = budgeted(4);
        assert_eq!(b1.iterations, b4.iterations);
        assert_eq!(b1.astar_pops, b4.astar_pops);
        assert_eq!(b1.net_nodes, b4.net_nodes);
        // A budget the run never reaches is a no-op.
        let unbudged = route(&model, &pl, &arch,
                             &RouteOpts { pops_budget: usize::MAX, ..Default::default() });
        assert_eq!(unbudged.net_nodes, base.net_nodes);
        assert_eq!(unbudged.iterations, base.iterations);
    }

    /// Timing-driven weights: zero criticalities are exactly the
    /// unweighted router, and real criticalities still converge and stay
    /// deterministic across worker counts.
    #[test]
    fn criticality_weights_neutral_and_deterministic() {
        let (base, model, arch) = routed(5);
        // All-zero criticality == weight 1.0 everywhere == baseline.
        let zeros = RouteOpts { net_crit: vec![0.0; 4096], ..Default::default() };
        // Re-derive placement identically to `routed` for the comparison.
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("placement");
        let r0 = route(&model, &pl, &arch, &zeros);
        assert_eq!(r0.wirelength, base.wirelength);
        assert_eq!(r0.net_nodes, base.net_nodes);
        // Weighted routing: deterministic for any job count and converges.
        let rpt = crate::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
        let weighted = |jobs: usize| {
            route(&model, &pl, &arch,
                  &RouteOpts { jobs, net_crit: rpt.net_crit.clone(), ..Default::default() })
        };
        let w1 = weighted(1);
        assert!(w1.success, "weighted routing failed to converge");
        let w4 = weighted(4);
        assert_eq!(w1.net_nodes, w4.net_nodes);
        assert_eq!(w1.iterations, w4.iterations);
        assert_eq!(w1.wirelength, w4.wirelength);
    }
}
