//! Pre-mapping circuit: AIG soft logic + hard adder-chain macros + FFs.

use std::collections::HashMap;

use crate::techmap::aig::{Aig, LeafKind, Lit};

/// One hard carry chain: per-bit operand literals, plus leaf literals for
/// the sums and the final carry-out that re-enter the AIG.
#[derive(Clone, Debug)]
pub struct AdderChainMacro {
    pub cin: Lit,
    /// Per-bit operands (a, b).
    pub ops: Vec<(Lit, Lit)>,
    /// Sum leaf literals (one per bit).
    pub sums: Vec<Lit>,
    /// Final carry-out leaf literal.
    pub cout: Lit,
}

/// Key identifying a chain's function for deduplication: identical operand
/// literals + carry-in compute identical sums, so a single chain can fan
/// out to every user (§IV "Unrolled Multiplication").
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct ChainKey {
    cin: Lit,
    ops: Vec<(Lit, Lit)>,
}

/// A synthesizable design before technology mapping.
#[derive(Clone, Debug)]
pub struct Circuit {
    pub name: String,
    pub aig: Aig,
    pub chains: Vec<AdderChainMacro>,
    /// FFs: (d literal — set after creation, q leaf literal).
    pub ffs: Vec<(Lit, Lit)>,
    pub pis: Vec<String>,
    pub pos: Vec<(String, Lit)>,
    /// Dedup cache; `None` disables chain deduplication (the VTR-baseline
    /// behaviour the paper improves on).
    chain_cache: Option<HashMap<ChainKey, usize>>,
    /// Count of chain instantiation requests that hit the dedup cache.
    pub dedup_hits: usize,
}

impl Circuit {
    pub fn new(name: &str) -> Self {
        Circuit {
            name: name.to_string(),
            aig: Aig::new(),
            chains: Vec::new(),
            ffs: Vec::new(),
            pis: Vec::new(),
            pos: Vec::new(),
            chain_cache: Some(HashMap::new()),
            dedup_hits: 0,
        }
    }

    /// Disable adder-chain deduplication (baseline-VTR mode for Fig. 5).
    pub fn disable_dedup(&mut self) {
        self.chain_cache = None;
    }

    pub fn pi(&mut self, name: &str) -> Lit {
        self.pis.push(name.to_string());
        self.aig.pi()
    }

    /// An n-bit input bus, LSB-first.
    pub fn pi_bus(&mut self, name: &str, n: usize) -> Vec<Lit> {
        (0..n).map(|i| self.pi(&format!("{name}[{i}]"))).collect()
    }

    pub fn po(&mut self, name: &str, lit: Lit) {
        self.pos.push((name.to_string(), lit));
    }

    pub fn po_bus(&mut self, name: &str, bits: &[Lit]) {
        for (i, &b) in bits.iter().enumerate() {
            self.po(&format!("{name}[{i}]"), b);
        }
    }

    /// Create a flip-flop; returns its q literal. Set d later with
    /// [`Circuit::set_ff_d`].
    pub fn ff(&mut self) -> Lit {
        let idx = self.ffs.len() as u32;
        let q = self.aig.leaf(LeafKind::FfQ(idx));
        self.ffs.push((Lit::FALSE, q));
        q
    }

    pub fn set_ff_d(&mut self, q: Lit, d: Lit) {
        let idx = self
            .ffs
            .iter()
            .position(|&(_, fq)| fq == q)
            .expect("not an FF q literal");
        self.ffs[idx].0 = d;
    }

    /// Instantiate (or reuse) a carry chain over `ops` with carry-in `cin`.
    /// Returns (sum literals, cout literal).
    ///
    /// Chains are *normalized* before dedup lookup: leading `(0, 0)` bit
    /// positions (with a zero carry-in) contribute constant-zero sums and
    /// are stripped, so shift-equivalent chains — e.g. `(x<<1)+(x<<3)`
    /// versus `x+(x<<2)` in an unrolled multiplier — share one chain, the
    /// redundancy the paper's Fig. 4 exploits.
    pub fn add_chain(&mut self, mut ops: Vec<(Lit, Lit)>, cin: Lit) -> (Vec<Lit>, Lit) {
        assert!(!ops.is_empty(), "empty adder chain");
        let mut shift = 0usize;
        if cin == Lit::FALSE {
            while ops.len() > 1 && ops[0] == (Lit::FALSE, Lit::FALSE) {
                ops.remove(0);
                shift += 1;
            }
        }
        if shift > 0 {
            let (sums, cout) = self.add_chain(ops, cin);
            let mut full = vec![Lit::FALSE; shift];
            full.extend(sums);
            return (full, cout);
        }
        let key = ChainKey { cin, ops: ops.clone() };
        if let Some(cache) = &self.chain_cache {
            if let Some(&idx) = cache.get(&key) {
                self.dedup_hits += 1;
                let ch = &self.chains[idx];
                return (ch.sums.clone(), ch.cout);
            }
        }
        let chain_id = self.chains.len() as u32;
        let sums: Vec<Lit> = (0..ops.len())
            .map(|pos| self.aig.leaf(LeafKind::AdderSum { chain: chain_id, pos: pos as u32 }))
            .collect();
        let cout = self.aig.leaf(LeafKind::AdderCout { chain: chain_id });
        self.chains.push(AdderChainMacro { cin, ops, sums: sums.clone(), cout });
        if let Some(cache) = &mut self.chain_cache {
            cache.insert(key, chain_id as usize);
        }
        (sums, cout)
    }

    /// Multi-bit ripple add on a hard chain: `x + y` (widths may differ;
    /// missing bits are zero).  Returns `max(w_x, w_y) + 1` bits.
    pub fn ripple_add(&mut self, x: &[Lit], y: &[Lit]) -> Vec<Lit> {
        let w = x.len().max(y.len());
        let get = |v: &[Lit], i: usize| v.get(i).copied().unwrap_or(Lit::FALSE);
        let ops: Vec<(Lit, Lit)> = (0..w).map(|i| (get(x, i), get(y, i))).collect();
        let (mut sums, cout) = self.add_chain(ops, Lit::FALSE);
        sums.push(cout);
        sums
    }

    /// Instantiate a chain with NO normalization and NO dedup — stock
    /// VTR's behaviour for inferred bus-width adders (baseline mode).
    pub fn add_chain_untrimmed(&mut self, ops: Vec<(Lit, Lit)>, cin: Lit) -> (Vec<Lit>, Lit) {
        assert!(!ops.is_empty(), "empty adder chain");
        let chain_id = self.chains.len() as u32;
        let sums: Vec<Lit> = (0..ops.len())
            .map(|pos| self.aig.leaf(LeafKind::AdderSum { chain: chain_id, pos: pos as u32 }))
            .collect();
        let cout = self.aig.leaf(LeafKind::AdderCout { chain: chain_id });
        self.chains.push(AdderChainMacro { cin, ops, sums: sums.clone(), cout });
        (sums, cout)
    }

    /// Would a chain over `ops`/`cin` hit the dedup cache? (Used by the
    /// Algorithm-1 strength heuristic to reward duplicate placements
    /// without instantiating anything.)
    pub fn chain_exists(&self, ops: &[(Lit, Lit)], cin: Lit) -> bool {
        let Some(cache) = &self.chain_cache else { return false };
        let mut ops = ops.to_vec();
        if cin == Lit::FALSE {
            while ops.len() > 1 && ops[0] == (Lit::FALSE, Lit::FALSE) {
                ops.remove(0);
            }
        }
        cache.contains_key(&ChainKey { cin, ops })
    }

    /// Absorb another circuit into this one (fresh PIs/POs/FFs/chains,
    /// names prefixed) — used to build the Table IV stress designs that
    /// pack a Kratos circuit plus N SHA instances into one netlist.
    pub fn absorb(&mut self, other: &Circuit, prefix: &str) {
        use crate::techmap::aig::{LeafKind, Node};
        let mut lit_map: Vec<Option<Lit>> = vec![None; other.aig.len()];
        lit_map[0] = Some(Lit::FALSE);
        // chain/FF id mapping built lazily as leaves appear.
        let mut chain_map: Vec<Option<usize>> = vec![None; other.chains.len()];
        let mut ff_map: Vec<Option<Lit>> = vec![None; other.ffs.len()];
        let map_lit = |m: &Vec<Option<Lit>>, l: Lit| -> Lit {
            let base = m[l.node() as usize].expect("forward reference in absorb");
            if l.is_compl() { base.compl() } else { base }
        };
        for id in 0..other.aig.len() as u32 {
            let mapped: Lit = match *other.aig.node(id) {
                Node::Const0 => Lit::FALSE,
                Node::And(a, b) => {
                    let ma = map_lit(&lit_map, a);
                    let mb = map_lit(&lit_map, b);
                    self.aig.and(ma, mb)
                }
                Node::Leaf(LeafKind::Pi(i)) => {
                    self.pi(&format!("{prefix}{}", other.pis[i as usize]))
                }
                Node::Leaf(LeafKind::FfQ(i)) => match ff_map[i as usize] {
                    Some(q) => q,
                    None => {
                        let nq = self.ff();
                        ff_map[i as usize] = Some(nq);
                        nq
                    }
                },
                // Chain leaves resolved below, after the chain exists.
                Node::Leaf(LeafKind::AdderSum { .. })
                | Node::Leaf(LeafKind::AdderCout { .. }) => Lit::FALSE,
            };
            lit_map[id as usize] = Some(mapped);
            // Chain leaves: instantiate the chain on first encounter.
            if let Node::Leaf(LeafKind::AdderSum { chain, pos }) = *other.aig.node(id) {
                if chain_map[chain as usize].is_none() {
                    let ch = &other.chains[chain as usize];
                    let ops: Vec<(Lit, Lit)> = ch
                        .ops
                        .iter()
                        .map(|&(a, b)| (map_lit(&lit_map, a), map_lit(&lit_map, b)))
                        .collect();
                    let cin = map_lit(&lit_map, ch.cin);
                    let (_, _) = self.add_chain(ops, cin);
                    chain_map[chain as usize] = Some(self.chains.len() - 1);
                }
                let nch = chain_map[chain as usize].unwrap();
                lit_map[id as usize] = Some(self.chains[nch].sums[pos as usize]);
            }
            if let Node::Leaf(LeafKind::AdderCout { chain }) = *other.aig.node(id) {
                if chain_map[chain as usize].is_none() {
                    let ch = &other.chains[chain as usize];
                    let ops: Vec<(Lit, Lit)> = ch
                        .ops
                        .iter()
                        .map(|&(a, b)| (map_lit(&lit_map, a), map_lit(&lit_map, b)))
                        .collect();
                    let cin = map_lit(&lit_map, ch.cin);
                    let (_, _) = self.add_chain(ops, cin);
                    chain_map[chain as usize] = Some(self.chains.len() - 1);
                }
                let nch = chain_map[chain as usize].unwrap();
                lit_map[id as usize] = Some(self.chains[nch].cout);
            }
        }
        // FF d hookups.
        for (i, &(d, _)) in other.ffs.iter().enumerate() {
            if let Some(q) = ff_map[i] {
                let md = map_lit(&lit_map, d);
                self.set_ff_d(q, md);
            }
        }
        // POs.
        for (name, lit) in &other.pos {
            let ml = map_lit(&lit_map, *lit);
            self.po(&format!("{prefix}{name}"), ml);
        }
    }

    /// Total adder bits across all chains.
    pub fn num_adder_bits(&self) -> usize {
        self.chains.iter().map(|c| c.ops.len()).sum()
    }

    /// Simulate combinationally: FF outputs read `ff_state`, chains are
    /// evaluated as integer adds.  Returns PO values in declaration order.
    /// (Oracle for synthesis/mapping tests; small circuits only.)
    pub fn simulate(&self, pi_vals: &[bool], ff_state: &[bool]) -> Vec<bool> {
        assert_eq!(pi_vals.len(), self.pis.len());
        let mut chain_sums: Vec<Option<(Vec<bool>, bool)>> = vec![None; self.chains.len()];
        // Fixpoint: evaluate chains whose operand cones are ready.
        loop {
            let mut progress = false;
            for (ci, ch) in self.chains.iter().enumerate() {
                if chain_sums[ci].is_some() {
                    continue;
                }
                let leaf = |k: LeafKind| -> Option<bool> {
                    match k {
                        LeafKind::Pi(i) => Some(pi_vals[i as usize]),
                        LeafKind::FfQ(i) => Some(*ff_state.get(i as usize).unwrap_or(&false)),
                        LeafKind::AdderSum { chain, pos } => chain_sums
                            [chain as usize]
                            .as_ref()
                            .map(|(s, _)| s[pos as usize]),
                        LeafKind::AdderCout { chain } => {
                            chain_sums[chain as usize].as_ref().map(|&(_, c)| c)
                        }
                    }
                };
                let try_eval = |l: Lit| self.try_eval(l, &leaf);
                let cin = try_eval(ch.cin);
                let ops: Option<Vec<(bool, bool)>> = ch
                    .ops
                    .iter()
                    .map(|&(a, b)| Some((try_eval(a)?, try_eval(b)?)))
                    .collect();
                if let (Some(mut carry), Some(ops)) = (cin, ops) {
                    let mut sums = Vec::with_capacity(ops.len());
                    for (a, b) in ops {
                        sums.push(a ^ b ^ carry);
                        carry = (a & b) | (a & carry) | (b & carry);
                    }
                    chain_sums[ci] = Some((sums, carry));
                    progress = true;
                }
            }
            if !progress {
                break;
            }
        }
        let leaf = |k: LeafKind| -> Option<bool> {
            match k {
                LeafKind::Pi(i) => Some(pi_vals[i as usize]),
                LeafKind::FfQ(i) => Some(*ff_state.get(i as usize).unwrap_or(&false)),
                LeafKind::AdderSum { chain, pos } => {
                    chain_sums[chain as usize].as_ref().map(|(s, _)| s[pos as usize])
                }
                LeafKind::AdderCout { chain } => {
                    chain_sums[chain as usize].as_ref().map(|&(_, c)| c)
                }
            }
        };
        self.pos
            .iter()
            .map(|&(_, l)| self.try_eval(l, &leaf).expect("combinational loop or unresolved chain"))
            .collect()
    }

    /// Non-panicking sequential-cut replay: evaluate every PO *and* every
    /// FF data input under one input assignment (`pi_vals` + `ff_state`
    /// for the FF q leaves).  Returns `(po_vals, ff_d_vals)`, or `None`
    /// if shapes mismatch or a chain never resolves — never panics, so
    /// it is safe as the witness-replay oracle in `check::equiv`.
    pub fn try_simulate_cut(
        &self,
        pi_vals: &[bool],
        ff_state: &[bool],
    ) -> Option<(Vec<bool>, Vec<bool>)> {
        if pi_vals.len() != self.pis.len() {
            return None;
        }
        let mut chain_sums: Vec<Option<(Vec<bool>, bool)>> = vec![None; self.chains.len()];
        loop {
            let mut progress = false;
            for (ci, ch) in self.chains.iter().enumerate() {
                if chain_sums[ci].is_some() {
                    continue;
                }
                let leaf = |k: LeafKind| -> Option<bool> {
                    match k {
                        LeafKind::Pi(i) => pi_vals.get(i as usize).copied(),
                        LeafKind::FfQ(i) => Some(ff_state.get(i as usize).copied().unwrap_or(false)),
                        LeafKind::AdderSum { chain, pos } => chain_sums
                            .get(chain as usize)?
                            .as_ref()
                            .and_then(|(s, _)| s.get(pos as usize).copied()),
                        LeafKind::AdderCout { chain } => {
                            chain_sums.get(chain as usize)?.as_ref().map(|&(_, c)| c)
                        }
                    }
                };
                let cin = self.try_eval(ch.cin, &leaf);
                let ops: Option<Vec<(bool, bool)>> = ch
                    .ops
                    .iter()
                    .map(|&(a, b)| Some((self.try_eval(a, &leaf)?, self.try_eval(b, &leaf)?)))
                    .collect();
                if let (Some(mut carry), Some(ops)) = (cin, ops) {
                    let mut sums = Vec::with_capacity(ops.len());
                    for (a, b) in ops {
                        sums.push(a ^ b ^ carry);
                        carry = (a & b) | (a & carry) | (b & carry);
                    }
                    chain_sums[ci] = Some((sums, carry));
                    progress = true;
                }
            }
            if !progress {
                break;
            }
        }
        let leaf = |k: LeafKind| -> Option<bool> {
            match k {
                LeafKind::Pi(i) => pi_vals.get(i as usize).copied(),
                LeafKind::FfQ(i) => Some(ff_state.get(i as usize).copied().unwrap_or(false)),
                LeafKind::AdderSum { chain, pos } => chain_sums
                    .get(chain as usize)?
                    .as_ref()
                    .and_then(|(s, _)| s.get(pos as usize).copied()),
                LeafKind::AdderCout { chain } => {
                    chain_sums.get(chain as usize)?.as_ref().map(|&(_, c)| c)
                }
            }
        };
        let pos: Option<Vec<bool>> =
            self.pos.iter().map(|&(_, l)| self.try_eval(l, &leaf)).collect();
        let ffd: Option<Vec<bool>> =
            self.ffs.iter().map(|&(d, _)| self.try_eval(d, &leaf)).collect();
        Some((pos?, ffd?))
    }

    /// Evaluate a literal, returning None if any required leaf is unknown.
    fn try_eval<F: Fn(LeafKind) -> Option<bool>>(&self, lit: Lit, leaf: &F) -> Option<bool> {
        use crate::techmap::aig::Node;
        let mut memo: HashMap<u32, Option<bool>> = HashMap::new();
        let mut stack = vec![lit.node()];
        while let Some(&id) = stack.last() {
            if memo.contains_key(&id) {
                stack.pop();
                continue;
            }
            match *self.aig.node(id) {
                Node::Const0 => {
                    memo.insert(id, Some(false));
                    stack.pop();
                }
                Node::Leaf(k) => {
                    memo.insert(id, leaf(k));
                    stack.pop();
                }
                Node::And(a, b) => {
                    let need_a = !memo.contains_key(&a.node());
                    let need_b = !memo.contains_key(&b.node());
                    if need_a {
                        stack.push(a.node());
                    }
                    if need_b {
                        stack.push(b.node());
                    }
                    if !need_a && !need_b {
                        let v = match (memo[&a.node()], memo[&b.node()]) {
                            (Some(va), Some(vb)) => {
                                Some((va ^ a.is_compl()) && (vb ^ b.is_compl()))
                            }
                            _ => None,
                        };
                        memo.insert(id, v);
                        stack.pop();
                    }
                }
            }
        }
        memo[&lit.node()].map(|v| v ^ lit.is_compl())
    }

    /// Interpret a PO bus as an unsigned integer (LSB-first by PO order of
    /// `name[i]` buses) for arithmetic tests.
    pub fn simulate_uint(&self, pi_bits: &[(usize, u64)], widths: &[usize]) -> u64 {
        // pi_bits: (starting PI index, value) pairs mapped onto the PI list
        // by `widths` — convenience for bus-shaped circuits.
        let _ = widths;
        let mut vals = vec![false; self.pis.len()];
        for &(start, v) in pi_bits {
            let mut i = 0;
            while start + i < vals.len() && i < 64 {
                vals[start + i] = v >> i & 1 == 1;
                i += 1;
            }
        }
        let out = self.simulate(&vals, &[]);
        out.iter()
            .enumerate()
            .fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ripple_add_is_integer_add() {
        let mut c = Circuit::new("add4");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let s = c.ripple_add(&x, &y);
        c.po_bus("s", &s);
        for (a, b) in [(0u64, 0u64), (3, 5), (15, 15), (9, 7), (15, 1)] {
            let mut vals = vec![false; 8];
            for i in 0..4 {
                vals[i] = a >> i & 1 == 1;
                vals[4 + i] = b >> i & 1 == 1;
            }
            let out = c.simulate(&vals, &[]);
            let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
            assert_eq!(got, a + b, "{a}+{b}");
        }
    }

    #[test]
    fn chain_dedup_reuses() {
        let mut c = Circuit::new("dd");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let s1 = c.ripple_add(&x, &y);
        let s2 = c.ripple_add(&x, &y);
        assert_eq!(s1, s2);
        assert_eq!(c.chains.len(), 1);
        assert_eq!(c.dedup_hits, 1);
    }

    #[test]
    fn dedup_disabled_duplicates() {
        let mut c = Circuit::new("nodd");
        c.disable_dedup();
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let _ = c.ripple_add(&x, &y);
        let _ = c.ripple_add(&x, &y);
        assert_eq!(c.chains.len(), 2);
        assert_eq!(c.dedup_hits, 0);
    }

    #[test]
    fn chained_chains_simulate() {
        // (x + y) + z via two chains, second consuming the first's sums.
        let mut c = Circuit::new("add3");
        let x = c.pi_bus("x", 3);
        let y = c.pi_bus("y", 3);
        let z = c.pi_bus("z", 3);
        let s1 = c.ripple_add(&x, &y);
        let s2 = c.ripple_add(&s1, &z);
        c.po_bus("s", &s2);
        for (a, b, d) in [(1u64, 2u64, 3u64), (7, 7, 7), (5, 0, 6)] {
            let mut vals = vec![false; 9];
            for i in 0..3 {
                vals[i] = a >> i & 1 == 1;
                vals[3 + i] = b >> i & 1 == 1;
                vals[6 + i] = d >> i & 1 == 1;
            }
            let out = c.simulate(&vals, &[]);
            let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
            assert_eq!(got, a + b + d);
        }
    }

    #[test]
    fn ff_roundtrip() {
        let mut c = Circuit::new("ff");
        let a = c.pi("a");
        let q = c.ff();
        let d = c.aig.xor(a, q);
        c.set_ff_d(q, d);
        c.po("o", q);
        assert_eq!(c.simulate(&[true], &[false]), vec![false]);
        assert_eq!(c.simulate(&[true], &[true]), vec![true]);
    }
}
