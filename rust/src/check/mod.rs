//! `dduty check`: independent static analysis of every stage artifact.
//!
//! The flow's optimizers (packer, placer, router) each *manipulate* the
//! legality rules they are supposed to respect — an overfilled ALM, a
//! shared wire, or a broken carry chain would silently corrupt every
//! area/delay number reported against the paper's Double Duty claims.
//! This module is the VPR-style `check_place` / `check_route` answer: a
//! read-only audit layer that re-verifies each artifact against the
//! formal invariants of its stage, using only the dense arenas
//! ([`crate::netlist::NetlistIndex`], [`crate::netlist::PackIndex`], the
//! RRG CSR) and **none of the producer code paths**, so a producer bug
//! cannot self-certify.  The one deliberate exception is
//! [`crate::place::macro_windows`]: the fixed-device window rule is
//! *defined* by that function (the placer's initial-placement contract),
//! so the place auditor re-checks fit against the same definition.
//!
//! Auditors (one submodule per stage):
//!
//! * [`netlist::audit_netlist`] — pin shapes, undriven / multi-driven
//!   nets, dangling inputs, carry-chain continuity, and the levelization
//!   re-verified edge-by-edge as the combinational-loop witness;
//! * [`pack::audit_packing`] — ALM 6-LUT half accounting, operand-path
//!   and Z-bypass legality per variant, LB capacity and pin feasibility,
//!   chain macros unsplit across LBs, exactly-once cell coverage;
//! * [`place::audit_placement`] — one block per site, I/O pad capacity,
//!   macro column alignment, and the four-dimensional device-fit
//!   re-check;
//! * [`route::audit_routing`] — every (net, sink) connected source→sink
//!   over the RRG (pin taps re-derived independently), no wire overuse
//!   after the final iteration, and the committed node arenas consistent
//!   with a directed routing tree (no orphan nodes);
//! * [`lookahead::audit_lookahead`] — the router's precomputed
//!   cost-to-target map re-verified admissible (estimate ≤ true hop
//!   distance) against an independent backward BFS for a deterministic
//!   sample of targets, guarding against builder bugs and corrupted
//!   disk-cache artifacts;
//! * [`timing::audit_timing`] — arrival monotonicity along combinational
//!   edges, endpoint arrivals bounded by the reported CPD, `SinkCrit`
//!   values in [0, 1] with per-net max consistency (bitwise);
//! * [`recovery::audit_recovery`] — the failure-recovery bookkeeping of a
//!   finished flow result: escalation rungs within the ladder, degraded
//!   seeds excluded from CPD-prior chaining, failure counters consistent
//!   with the per-seed error records;
//! * [`serve::audit_serve`] — the `dd serve` daemon's job bookkeeping:
//!   lifecycle transitions replayed from each job's event log,
//!   submission-key dedup uniqueness, terminal states consistent with
//!   the results they carry;
//! * [`equiv`] — *semantic* (not structural) verification: SAT-based
//!   combinational equivalence of the mapped and packed netlists against
//!   the source AIG at the sequential cut, enforcing the map/pack
//!   logic-neutrality contract with per-output miters, random-simulation
//!   prefiltering, and an in-crate CDCL solver; inequivalence reports as
//!   `equiv.mismatch` with a replayable input-assignment witness.
//!
//! Every auditor returns a structured [`Violation`] list in a stable,
//! artifact-defined scan order (cells/nets/ALMs/LBs ascending) instead of
//! panicking, so callers can report, count, or gate on them.  The CLI
//! (`dduty check`) runs the auditors over whole benchmark suites;
//! `--check [strict]` on `exp` / `flow` wires them into the flow after
//! each stage ([`crate::flow::FlowOpts::check`]), where
//! [`CheckMode::Strict`] fails the run.  This layer is a *contract*:
//! future stages (capacity-scale packing, service mode) must ship an
//! auditor here before their artifacts feed the flow.

pub mod equiv;
pub mod lookahead;
pub mod netlist;
pub mod pack;
pub mod place;
pub mod recovery;
pub mod route;
pub mod serve;
pub mod timing;

pub use equiv::{equiv_mapped, equiv_packed, EquivOpts, EquivOutcome, EquivSummary};
pub use lookahead::audit_lookahead;
pub use netlist::audit_netlist;
pub use pack::audit_packing;
pub use place::audit_placement;
pub use recovery::audit_recovery;
pub use route::audit_routing;
pub use serve::audit_serve;
pub use timing::audit_timing;

use std::fmt;

use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::Benchmark;
use crate::flow::engine::ArtifactCache;
use crate::flow::{arch_for_run, FlowOpts};
use crate::pack::PackOpts;
use crate::place::{place_with, PlaceOpts};
use crate::route::{route, LookaheadMode, RouteOpts};
use crate::rrg::RrGraph;
use crate::timing::sta_routed;

/// How bad a violation is.  [`CheckMode::Strict`] fails a run on
/// `Error`s only; `Warning`s are documented relaxations the producers
/// intentionally allow (e.g. the packer's VPR-style carry-segment pin
/// exemption).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// Which stage artifact a violation was found in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Netlist,
    Pack,
    Place,
    Lookahead,
    Route,
    Timing,
    /// Failure-recovery bookkeeping: escalation provenance, CPD-prior
    /// chaining hygiene, and cache-integrity quarantines
    /// ([`recovery::audit_recovery`], `flow.cache-integrity`).
    Recovery,
    /// The `dd serve` daemon's job bookkeeping: lifecycle transitions,
    /// submission-key dedup, terminal-state/result agreement
    /// ([`serve::audit_serve`]).
    Serve,
    /// Semantic equivalence of mapped/packed netlists against the source
    /// AIG ([`equiv`]): `equiv.mismatch` carries a counterexample input
    /// assignment, `equiv.shape` a malformed comparison frame,
    /// `equiv.undecided` an exhausted SAT budget.
    Equiv,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Netlist => "netlist",
            Stage::Pack => "pack",
            Stage::Place => "place",
            Stage::Lookahead => "lookahead",
            Stage::Route => "route",
            Stage::Timing => "timing",
            Stage::Recovery => "recovery",
            Stage::Serve => "serve",
            Stage::Equiv => "equiv",
        }
    }
}

/// One audited invariant failure: a stable machine-readable `code`, the
/// artifact location it anchors to, and a human-readable message naming
/// the failing dimension.  Auditors emit violations in a deterministic
/// artifact scan order, so two audits of the same artifact produce
/// identical lists.
#[derive(Clone, Debug)]
pub struct Violation {
    pub stage: Stage,
    pub severity: Severity,
    /// Stable code, `stage.rule` (e.g. `"pack.lb-capacity"`) — what
    /// mutation tests assert on.
    pub code: &'static str,
    /// Location inside the artifact (e.g. `"net 12"`, `"alm 3"`,
    /// `"net 4 sink 1"`).
    pub location: String,
    pub message: String,
}

impl Violation {
    pub fn new(
        stage: Stage,
        severity: Severity,
        code: &'static str,
        location: impl Into<String>,
        message: impl Into<String>,
    ) -> Violation {
        Violation {
            stage,
            severity,
            code,
            location: location.into(),
            message: message.into(),
        }
    }

    /// Wrap a producer-side error (e.g. the placer's fixed-device misfit
    /// or the disk cache's integrity rejection) into the violation shape,
    /// so failure paths that surface as `Err`/`None` upstream report
    /// through the same channel as audited invariants.
    pub fn from_producer_error(
        stage: Stage,
        code: &'static str,
        location: impl Into<String>,
        err: &crate::util::error::Error,
    ) -> Violation {
        Violation::new(stage, Severity::Error, code, location, err.to_string())
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "[{sev}] {} ({}): {}", self.code, self.location, self.message)
    }
}

/// When (and how hard) the flow runs the auditors after each stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CheckMode {
    /// No auditing (the default; audits cost a linear pass per artifact).
    #[default]
    Off,
    /// Audit and report violations on stderr; the run continues.
    Warn,
    /// Audit and fail the run (panic with the violation list) on any
    /// `Error`-severity violation.
    Strict,
}

/// Aggregated audit outcome for one artifact chain.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub violations: Vec<Violation>,
}

impl CheckReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Any `Error`-severity violation present (what strict mode gates on)?
    pub fn has_errors(&self) -> bool {
        self.violations.iter().any(|v| v.severity == Severity::Error)
    }

    /// Violations found in `stage`.
    pub fn stage(&self, stage: Stage) -> impl Iterator<Item = &Violation> {
        self.violations.iter().filter(move |v| v.stage == stage)
    }

    /// `"<errors> error(s), <warnings> warning(s)"`.
    pub fn summary(&self) -> String {
        let e = self.violations.iter().filter(|v| v.severity == Severity::Error).count();
        let w = self.violations.len() - e;
        format!("{e} error(s), {w} warning(s)")
    }
}

/// Enforce a stage audit according to `mode`: `Warn` prints every
/// violation to stderr, `Strict` panics when an `Error`-severity
/// violation is present (warnings still only print).  The flow calls this
/// after each stage ([`crate::flow::place_route_seed`]).
pub fn enforce(mode: CheckMode, what: &str, violations: &[Violation]) {
    if mode == CheckMode::Off || violations.is_empty() {
        return;
    }
    for v in violations {
        eprintln!("check[{what}]: {v}");
    }
    if mode == CheckMode::Strict && violations.iter().any(|v| v.severity == Severity::Error) {
        let list: Vec<String> = violations
            .iter()
            .filter(|v| v.severity == Severity::Error)
            .map(|v| v.to_string())
            .collect();
        panic!("strict check failed for {what}: {}", list.join("; "));
    }
}

/// Run the full audit chain on one benchmark: map → pack → place → route
/// → STA, auditing each artifact as it is produced (through the shared
/// artifact `cache`, so `dduty check` after `dduty exp` audits the cached
/// artifacts rather than recomputing them).  A placement misfit on a
/// caller-fixed device reports as a `place.device-misfit` violation
/// instead of an error — the check CLI's job is to report, not crash.
pub fn check_benchmark(
    cache: &ArtifactCache,
    b: &Benchmark,
    variant: ArchVariant,
    opts: &FlowOpts,
) -> CheckReport {
    let mapped = cache.mapped(b);
    let arch = arch_for_run(&Arch::coffe(variant), opts);
    let pack_opts = PackOpts { unrelated: opts.unrelated };
    let packing = cache.packed(&mapped, &arch, &pack_opts);
    let arenas = cache.indexed(&mapped, &packing, &arch, &pack_opts);
    let nl = &mapped.nl;

    let mut report = CheckReport::default();
    report.violations.extend(audit_netlist(nl, &arenas.idx));
    report.violations.extend(audit_packing(nl, &packing, &arch));

    let seed = opts.seeds.first().copied().unwrap_or(1);
    let pl = match place_with(
        nl,
        &packing,
        &arch,
        &PlaceOpts {
            seed,
            effort: opts.place_effort,
            device: opts.device.clone(),
            ..Default::default()
        },
        &arenas.idx,
        &arenas.pidx,
    ) {
        Ok(pl) => pl,
        Err(e) => {
            report.violations.push(Violation::from_producer_error(
                Stage::Place,
                "place.device-misfit",
                "device",
                &e,
            ));
            return report;
        }
    };
    report.violations.extend(audit_placement(&packing, &pl));

    if opts.route {
        let mut model = crate::place::cost::NetModel::build(nl, &packing);
        model.set_weights(&[], false);
        let la_mode = if opts.lookahead {
            let graph = RrGraph::build(&pl.device, &arch);
            let la = cache.lookahead(&pl.device, &arch);
            report.violations.extend(audit_lookahead(&graph, &la));
            LookaheadMode::Shared(la)
        } else {
            LookaheadMode::Off
        };
        let r = route(
            &model,
            &pl,
            &arch,
            &RouteOpts {
                jobs: opts.route_jobs.max(1),
                lookahead: la_mode,
                ..RouteOpts::default()
            },
        );
        report.violations.extend(audit_routing(&model, &pl, &arch, &r));
        let rpt = sta_routed(nl, &packing, &arch, &r, &model);
        report.violations.extend(audit_timing(nl, &arenas.idx, &rpt));
    } else {
        let rpt = crate::timing::sta_with(
            nl,
            &arenas.idx,
            &arenas.pidx,
            &packing,
            &arch,
            |_, _, _| arch.delays.wire_segment * 2.0,
            1,
        );
        report.violations.extend(audit_timing(nl, &arenas.idx, &rpt));
    }
    report
}

/// Outcomes of [`check_equiv_benchmark`]: the mapped netlist checked
/// against the source AIG, and the packed view checked on top of it.
pub struct EquivBenchReport {
    pub mapped: EquivOutcome,
    pub packed: EquivOutcome,
}

impl EquivBenchReport {
    pub fn is_clean(&self) -> bool {
        self.mapped.is_clean() && self.packed.is_clean()
    }

    pub fn has_errors(&self) -> bool {
        self.mapped
            .violations
            .iter()
            .chain(self.packed.violations.iter())
            .any(|v| v.severity == Severity::Error)
    }
}

/// Run semantic equivalence on one benchmark through the artifact cache:
/// regenerate the source circuit, check the cached mapped netlist against
/// it, then re-pack (cached) and check the packed view.  This is what
/// `dduty check --equiv` runs per (benchmark, variant) pair.
pub fn check_equiv_benchmark(
    cache: &ArtifactCache,
    b: &Benchmark,
    variant: ArchVariant,
    opts: &FlowOpts,
    eopts: &EquivOpts,
) -> EquivBenchReport {
    let circ = b.generate();
    let mapped = cache.mapped(b);
    let arch = arch_for_run(&Arch::coffe(variant), opts);
    let pack_opts = PackOpts { unrelated: opts.unrelated };
    let packing = cache.packed(&mapped, &arch, &pack_opts);
    EquivBenchReport {
        mapped: equiv_mapped(&circ, &mapped.nl, eopts),
        packed: equiv_packed(&circ, &mapped.nl, &packing, eopts),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violation_display_names_code_and_location() {
        let v = Violation::new(
            Stage::Pack,
            Severity::Error,
            "pack.lb-capacity",
            "lb 3",
            "11 ALMs exceed the 10-ALM LB capacity",
        );
        let s = v.to_string();
        assert!(s.contains("pack.lb-capacity") && s.contains("lb 3"), "{s}");
        assert!(s.contains("error"), "{s}");
    }

    #[test]
    fn report_summary_counts_severities() {
        let mut r = CheckReport::default();
        assert!(r.is_clean() && !r.has_errors());
        r.violations.push(Violation::new(
            Stage::Route,
            Severity::Warning,
            "route.x",
            "net 0",
            "w",
        ));
        assert!(!r.is_clean() && !r.has_errors());
        r.violations.push(Violation::new(
            Stage::Route,
            Severity::Error,
            "route.y",
            "net 1",
            "e",
        ));
        assert!(r.has_errors());
        assert_eq!(r.summary(), "1 error(s), 1 warning(s)");
        assert_eq!(r.stage(Stage::Route).count(), 2);
        assert_eq!(r.stage(Stage::Pack).count(), 0);
    }

    #[test]
    fn enforce_warn_does_not_panic() {
        let v = vec![Violation::new(Stage::Netlist, Severity::Error, "netlist.x", "net 0", "m")];
        enforce(CheckMode::Off, "t", &v);
        enforce(CheckMode::Warn, "t", &v);
    }

    #[test]
    #[should_panic(expected = "strict check failed")]
    fn enforce_strict_panics_on_error() {
        let v = vec![Violation::new(Stage::Netlist, Severity::Error, "netlist.x", "net 0", "m")];
        enforce(CheckMode::Strict, "t", &v);
    }

    #[test]
    fn enforce_strict_tolerates_warnings() {
        let v =
            vec![Violation::new(Stage::Pack, Severity::Warning, "pack.lb-pins", "lb 0", "m")];
        enforce(CheckMode::Strict, "t", &v);
    }
}
