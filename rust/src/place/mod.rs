//! Timing-driven simulated-annealing placement (the VPR substitute).
//!
//! Blocks are packed LBs plus I/O pads; carry chains spanning multiple LBs
//! are vertical macros that move as units.  Cost is the two-lane model of
//! [`cost`]: criticality-weighted HPWL plus a *per-sink* timing lane in
//! which every (net, sink) connection is weighted by its own smoothed
//! `1 - slack/cpd` from the STA's [`crate::timing::SinkCrit`] arena —
//! the placer consumes the same per-sink criticality subsystem as the
//! closed-loop router, refreshed periodically during annealing with
//! exponential smoothing `crit' = α·new + (1-α)·old`
//! ([`PlaceOpts::crit_alpha`], the `--place-crit-alpha` CLI knob) and
//! optionally re-normalized against the routed CPD a previous seed
//! actually achieved ([`PlaceOpts::cpd_prior_ps`] — the cross-seed
//! place↔route feedback the flow engine drives).
//!
//! ## Move-type diversity
//!
//! Moves flow through a batched proposal pipeline — randomness is drawn
//! per batch, then each candidate is scored against the incremental
//! per-net cost cache ([`cost::IncrementalCost`]) and committed in order,
//! so the result is a pure function of the seed.  Three proposal kinds
//! mix on a temperature schedule ([`MoveKind`], counts reported in
//! [`Placement::move_stats`]):
//!
//! * **uniform** — the classic random swap/displace within the range
//!   limit,
//! * **macro column shift** — a chain macro slides vertically within its
//!   own column (chains are column-locked, so uniform swaps rarely
//!   propose useful macro moves once the range limit shrinks),
//! * **median region** — a block jumps near the median of its connected
//!   nets' cached bounding boxes (VPR's median move), increasingly
//!   favored as the anneal cools and local refinement dominates.
//!
//! The batched full-cost + congestion evaluation runs through the
//! AOT-compiled JAX/Pallas kernel via PJRT ([`kernel_accel`]), fed
//! straight from the cached boxes — python never executes at placement
//! time; the kernel validates the wirelength lane
//! ([`cost::IncrementalCost::wl_total`]).
//!
//! ## Device-sizing contract
//!
//! A caller-fixed [`PlaceOpts::device`] is a hard constraint: if the
//! design does not fit — too few LB slots or I/O sites, or a chain macro
//! taller than the grid — [`place`] returns an error instead of silently
//! growing the device (Table-IV-style fixed-device stress runs must never
//! quietly measure a larger grid).  Auto-sizing (`device: None`) still
//! grows the grid until the tallest macro fits.
//!
//! Placement legality (site exclusivity, macro column alignment, device
//! fit) is independently re-audited by [`crate::check::audit_placement`];
//! misfit errors surface through the same violation channel
//! (`place.device-misfit`) in `dduty check`.

pub mod cost;
pub mod kernel_accel;

use std::collections::HashMap;

use crate::arch::device::{Device, Loc};
use crate::arch::Arch;
use crate::netlist::{CellId, NetId, Netlist, NetlistIndex, PackIndex};
use crate::pack::Packing;
use crate::timing;
use crate::util::error::Result;
use crate::util::Rng;

pub use cost::{IncrementalCost, NetModel, PlacementCost};

/// Placement result: locations for every LB and I/O cell.
#[derive(Clone, Debug)]
pub struct Placement {
    pub device: Device,
    /// Location of each packed LB (index parallel to `Packing::lbs`).
    pub lb_loc: Vec<Loc>,
    /// Location of each I/O cell.
    pub io_loc: HashMap<CellId, Loc>,
    /// Final placement cost (weighted HPWL + per-sink timing lane).
    pub cost: f64,
    /// Post-placement estimated critical path (ps).
    pub est_cpd_ps: f64,
    /// Per-kind proposal/acceptance counts of the annealing run.
    pub move_stats: MoveStats,
}

/// Annealing move kinds (see module docs).  The discriminants index
/// [`MoveStats`] arrays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MoveKind {
    Uniform = 0,
    MacroShift = 1,
    Median = 2,
}

/// Number of [`MoveKind`] variants.
pub const NUM_MOVE_KINDS: usize = 3;

/// Per-kind move counters, indexed by `MoveKind as usize`.
#[derive(Clone, Copy, Debug, Default)]
pub struct MoveStats {
    pub proposed: [usize; NUM_MOVE_KINDS],
    pub accepted: [usize; NUM_MOVE_KINDS],
}

/// Placer options.
#[derive(Clone, Debug)]
pub struct PlaceOpts {
    pub seed: u64,
    /// Moves per temperature = `effort * blocks^(4/3)` (VPR's inner_num).
    pub effort: f64,
    /// Timing-driven (per-sink criticality lane) vs pure wirelength.
    pub timing_driven: bool,
    /// Exponential smoothing factor α for the periodic criticality
    /// refresh (`--place-crit-alpha`): `crit' = α·new + (1-α)·old`,
    /// matching the closed-loop router's recurrence.
    pub crit_alpha: f64,
    /// Timing-lane gain g: each (net, sink) connection is charged
    /// `g * crit^2 * dist`.  `0.0` reduces the timing-driven placer to
    /// the wirelength-only one bit-for-bit (the determinism suite pins
    /// this).
    pub crit_gain: f64,
    /// Move-type mix scale in [0, 1]: scales the temperature-scheduled
    /// macro-shift and median-move probabilities; `0.0` proposes uniform
    /// swaps only (the pre-diversity pipeline).
    pub move_mix: f64,
    /// Achieved routed CPD (ps) from a previous seed, fed back by the
    /// flow engine: criticalities are re-normalized against it
    /// ([`crate::timing::rescale_crit`]) so placement optimizes toward
    /// the CPD routing will actually see.  `None` uses the pre-route
    /// estimate alone.
    pub cpd_prior_ps: Option<f64>,
    /// Worker threads for the placer's periodic STA refreshes (the report
    /// is bit-identical for any value, so this never perturbs placement).
    pub sta_jobs: usize,
    /// Evaluate the full cost + congestion map through the PJRT kernel at
    /// each temperature (validated against the incremental Rust cost).
    pub use_kernel: bool,
    /// Fix the device size (Table IV stress tests); `None` auto-sizes.
    /// A fixed device that cannot fit the design is an error — see the
    /// module docs.
    pub device: Option<Device>,
}

impl Default for PlaceOpts {
    fn default() -> Self {
        PlaceOpts {
            seed: 1,
            effort: 1.0,
            timing_driven: true,
            crit_alpha: 0.5,
            crit_gain: 8.0,
            move_mix: 1.0,
            cpd_prior_ps: None,
            sta_jobs: 1,
            use_kernel: false,
            device: None,
        }
    }
}

/// Net -> placement delay estimate: connection block + wire segments.
pub fn est_net_delay(arch: &Arch, src: Loc, dst: Loc) -> f64 {
    if src == dst {
        return 0.0; // intra-LB feedback (local crossbar charged in STA)
    }
    let d = src.dist(dst);
    let segs = (d as f64 / arch.routing.segment_len as f64).ceil().max(1.0);
    arch.delays.conn_block + segs * arch.delays.wire_segment
}

/// Greedy column-major vertical-window assignment for the multi-LB chain
/// macros of `packing` on `device` — the placer's initial-placement rule,
/// exposed so fixed-device callers (Table IV's stress loop) can pre-check
/// the fourth fit dimension, window availability, alongside LB/IO
/// capacity and macro height.  Entry `k` is the `(column, first row)` of
/// the `k`-th chain macro spanning more than one LB, in
/// `Packing::chain_macros` order; `None` when some macro finds no free
/// window.
pub fn macro_windows(packing: &Packing, device: &Device) -> Option<Vec<(u16, u16)>> {
    let mut col_fill: Vec<u16> = vec![1; device.lb_cols as usize + 1]; // next free y per col
    let mut out = Vec::new();
    for m in packing.chain_macros.iter().filter(|m| m.len() > 1) {
        let len = m.len() as u16;
        let mut placed = None;
        for x in 1..=device.lb_cols {
            let y0 = col_fill[x as usize];
            if y0 + len - 1 <= device.lb_rows {
                col_fill[x as usize] = y0 + len;
                placed = Some((x, y0));
                break;
            }
        }
        out.push(placed?);
    }
    Some(out)
}

/// Place a packed design.  Builds the dense index arenas itself; hot
/// callers that already share them per (netlist, packing) — the flow
/// engine's seed jobs — use [`place_with`].
pub fn place(nl: &Netlist, packing: &Packing, arch: &Arch, opts: &PlaceOpts) -> Result<Placement> {
    let idx = NetlistIndex::build(nl);
    let pidx = PackIndex::build(nl, packing);
    place_with(nl, packing, arch, opts, &idx, &pidx)
}

/// [`place`] over prebuilt index arenas (shared read-only across seeds by
/// the flow engine, like packings).  Deterministic in (inputs, seed);
/// bit-identical for any [`PlaceOpts::sta_jobs`].
pub fn place_with(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &PlaceOpts,
    idx: &NetlistIndex,
    pidx: &PackIndex,
) -> Result<Placement> {
    let mut rng = Rng::new(opts.seed);

    // --- Device sizing. ----------------------------------------------------
    // Tallest chain macro constrains the minimum grid height.  A fixed
    // device is a contract: misfits error out (module docs); only the
    // auto-sized path may grow the grid.
    let max_macro = packing
        .chain_macros
        .iter()
        .map(|m| m.len())
        .max()
        .unwrap_or(1)
        .max(1);
    let device = match &opts.device {
        Some(d) => {
            crate::ensure!(
                (d.lb_rows as usize) >= max_macro,
                "fixed device {}x{} cannot host a {max_macro}-LB chain macro \
                 (needs lb_rows >= {max_macro})",
                d.lb_cols,
                d.lb_rows
            );
            crate::ensure!(
                d.lb_capacity() >= packing.lbs.len(),
                "fixed device too small: {} LB slots for {} LBs",
                d.lb_capacity(),
                packing.lbs.len()
            );
            crate::ensure!(
                d.io_capacity() >= packing.ios.len(),
                "fixed device has {} I/O sites for {} I/Os",
                d.io_capacity(),
                packing.ios.len()
            );
            d.clone()
        }
        None => {
            let mut d = Device::auto_size(packing.lbs.len(), packing.ios.len(), 1.15);
            while (d.lb_rows as usize) < max_macro {
                d = Device::new(d.lb_cols + 1, d.lb_rows + 1);
            }
            d
        }
    };

    // --- Macro identification. ---------------------------------------------
    // lb -> macro id; macros are vertically-consecutive LB lists.
    let mut lb_macro: Vec<Option<usize>> = vec![None; packing.lbs.len()];
    let mut macros: Vec<Vec<usize>> = Vec::new();
    for m in &packing.chain_macros {
        if m.len() > 1 {
            let id = macros.len();
            for &lb in m {
                // An LB can belong to at most one macro (chains packed into
                // the same LBs merge their macros).
                if lb_macro[lb].is_none() {
                    lb_macro[lb] = Some(id);
                }
            }
            macros.push(m.clone());
        }
    }

    // --- Initial placement. --------------------------------------------------
    let mut grid: HashMap<Loc, usize> = HashMap::new(); // loc -> lb index
    let mut lb_loc: Vec<Loc> = vec![Loc::new(0, 0); packing.lbs.len()];
    let lb_locs = device.lb_locs();
    // Macros first: each into a free vertical window ([`macro_windows`] —
    // the same rule fixed-device callers pre-check fit with).
    let Some(windows) = macro_windows(packing, &device) else {
        crate::bail!(
            "no vertical window for every chain macro on device {}x{}",
            device.lb_cols,
            device.lb_rows
        );
    };
    for (m, &(x, y0)) in macros.iter().zip(windows.iter()) {
        for (i, &lb) in m.iter().enumerate() {
            let loc = Loc::new(x, y0 + i as u16);
            grid.insert(loc, lb);
            lb_loc[lb] = loc;
        }
    }
    // Singles into remaining slots.
    let mut free: Vec<Loc> = lb_locs
        .iter()
        .copied()
        .filter(|l| !grid.contains_key(l))
        .collect();
    rng.shuffle(&mut free);
    let mut fi = 0;
    for lb in 0..packing.lbs.len() {
        if lb_macro[lb].is_some() && grid.values().any(|&v| v == lb) {
            continue;
        }
        if lb_macro[lb].is_some() {
            continue; // already placed with macro
        }
        let loc = free[fi];
        fi += 1;
        grid.insert(loc, lb);
        lb_loc[lb] = loc;
    }
    // I/Os round-robin over pad sites.
    let io_sites = device.io_locs();
    let mut io_loc: HashMap<CellId, Loc> = HashMap::new();
    let mut io_fill: HashMap<Loc, u16> = HashMap::new();
    let mut site_i = 0usize;
    for &io in &packing.ios {
        loop {
            let s = io_sites[site_i % io_sites.len()];
            let f = io_fill.entry(s).or_insert(0);
            if *f < device.io_per_tile {
                *f += 1;
                io_loc.insert(io, s);
                break;
            }
            site_i += 1;
        }
        site_i += 1;
    }

    // --- Net model. -----------------------------------------------------------
    // STA runs repeatedly during annealing (initial, every 4th temperature,
    // final) over the shared dense index arenas — built once per
    // (netlist, packing) by the caller (or by [`place`]) instead of per
    // call, and shared read-only across seeds by the flow engine.
    let sta_jobs = opts.sta_jobs.max(1);
    let mut model = cost::NetModel::build(nl, packing);
    // Smoothed per-terminal criticality state (the per-sink lane's α
    // recurrence runs over this, mirroring the router's).
    let mut sink_state: Vec<Vec<f64>> = Vec::new();
    if opts.timing_driven {
        let rpt = timing::sta_with(
            nl,
            idx,
            pidx,
            packing,
            arch,
            |_, _, _| arch.delays.wire_segment * 2.0,
            sta_jobs,
        );
        sink_state = model.fold_sink_crit(idx, &rpt.sink_crit);
        timing::rescale_crit(&mut sink_state, rpt.cpd_ps, opts.cpd_prior_ps);
        model.set_sink_crit(&sink_state, opts.crit_gain);
    }
    // Incremental cost cache: per-net bbox + two-lane cost, refreshed per
    // temperature (after weight updates) and updated per accepted move.
    let mut inc = cost::IncrementalCost::new(&model, &lb_loc, &io_loc);

    // Optional PJRT kernel evaluator.
    let mut kernel = if opts.use_kernel {
        kernel_accel::KernelCost::try_new(model.num_nets()).ok()
    } else {
        None
    };

    // --- Annealing schedule (VPR-style adaptive). -------------------------------
    let n_blocks = packing.lbs.len().max(2);
    let n_lb = lb_loc.len();
    let moves_per_t = ((opts.effort * (n_blocks as f64).powf(4.0 / 3.0)) as usize).max(64);
    // Initial temperature: 20x the std-dev of random move deltas (uniform
    // probes only — they are not counted in the move stats).
    let mut t = {
        let mut deltas = Vec::with_capacity(64);
        if n_lb >= 2 {
            let rmax = device.lb_cols.max(device.lb_rows);
            for _ in 0..64 {
                let p = propose_move(&mut rng, n_lb, rmax, 0.0, 0.0, &macros);
                if let Some(dc) = apply_proposal(&p, &device, &mut grid, &mut lb_loc,
                                                 &lb_macro, &macros, &model, &mut inc,
                                                 &io_loc, f64::INFINITY)
                {
                    deltas.push(dc.abs());
                }
            }
        }
        let m = crate::util::stats::mean(&deltas);
        (20.0 * m).max(1.0)
    };
    let t0 = t;
    let mut rlim = device.lb_cols.max(device.lb_rows);
    let mut temp_idx = 0usize;
    let t_min = 0.005 * inc.total().max(1.0) / model.num_nets().max(1) as f64;
    let mut move_stats = MoveStats::default();

    // Batched move-proposal pipeline: each batch draws all its randomness
    // up front, then evaluates the candidates against the incremental cost
    // cache and commits them in order.  Today the evaluation stage scores
    // candidates one at a time (bit-identical to an interleaved loop); the
    // split exists so a batch evaluator — e.g. scoring a whole batch
    // through the PJRT kernel — can replace the inner stage without
    // touching proposal generation or the RNG stream.
    const MOVE_BATCH: usize = 32;
    let mut batch: Vec<MoveProposal> = Vec::with_capacity(MOVE_BATCH);

    while t > t_min {
        // Temperature-scheduled move mix: `cold` sweeps 0 -> 1 over the
        // anneal (log scale, matching the multiplicative cooling), so
        // exploration starts on uniform swaps and shifts toward targeted
        // median / macro moves as local refinement starts to dominate.
        let mix = opts.move_mix.clamp(0.0, 1.0);
        let cold = if t0 > t_min && t > 0.0 {
            ((t0 / t).ln() / (t0 / t_min).ln()).clamp(0.0, 1.0)
        } else {
            1.0
        };
        let p_macro = if macros.is_empty() { 0.0 } else { 0.10 * mix };
        let p_median = mix * (0.05 + 0.35 * cold);

        let mut accepted = 0usize;
        let mut done = 0usize;
        while done < moves_per_t && n_lb >= 2 {
            let take = MOVE_BATCH.min(moves_per_t - done);
            batch.clear();
            for _ in 0..take {
                batch.push(propose_move(&mut rng, n_lb, rlim, p_macro, p_median, &macros));
            }
            for p in &batch {
                move_stats.proposed[p.kind as usize] += 1;
                if apply_proposal(p, &device, &mut grid, &mut lb_loc, &lb_macro,
                                  &macros, &model, &mut inc, &io_loc, t)
                    .is_some()
                {
                    accepted += 1;
                    move_stats.accepted[p.kind as usize] += 1;
                }
            }
            done += take;
        }
        let alpha = {
            let r = accepted as f64 / moves_per_t as f64;
            // VPR's adaptive alpha.
            if r > 0.96 { 0.5 } else if r > 0.8 { 0.9 } else if r > 0.15 { 0.95 } else { 0.8 }
        };
        t *= alpha;
        // Adapt range limit toward 44% acceptance.
        let r = accepted as f64 / moves_per_t as f64;
        let new_rlim = (rlim as f64 * (1.0 - 0.44 + r)).clamp(1.0, device.lb_cols.max(device.lb_rows) as f64);
        rlim = new_rlim.round() as u16;
        // Refresh per-sink criticalities + rebuild the cost cache (weights
        // feed the cached per-net costs, and the re-sum caps f64 drift).
        // STA is the placer's most expensive periodic step; every 4th
        // temperature tracks criticality closely enough (perf pass,
        // EXPERIMENTS.md §Perf).  The refresh folds in with the α
        // recurrence, so one noisy estimate cannot whipsaw the weights.
        temp_idx += 1;
        if opts.timing_driven && temp_idx % 4 == 0 {
            let rpt = timing::sta_with(nl, idx, pidx, packing, arch,
                                       |net, sink, _| {
                net_endpoint_delay(&model, &lb_loc, &io_loc, arch, net, sink)
            }, sta_jobs);
            let mut fresh = model.fold_sink_crit(idx, &rpt.sink_crit);
            timing::rescale_crit(&mut fresh, rpt.cpd_ps, opts.cpd_prior_ps);
            let a = opts.crit_alpha.clamp(0.0, 1.0);
            for (cur, new) in sink_state.iter_mut().zip(fresh.iter()) {
                for (cv, &nv) in cur.iter_mut().zip(new.iter()) {
                    *cv = a * nv + (1.0 - a) * *cv;
                }
            }
            model.set_sink_crit(&sink_state, opts.crit_gain);
        }
        inc.refresh(&model, &lb_loc, &io_loc);
        // Kernel-evaluated full cost from the cached boxes: consistency
        // check on the wirelength lane (the kernel never sees the
        // per-sink timing lane) + congestion signal.
        if let Some(k) = kernel.as_mut() {
            if let Ok(kc) = k.evaluate_cached(&model, &inc, &device) {
                // Within float tolerance of the Rust wirelength cost.
                let wl = inc.wl_total();
                debug_assert!((kc.whpwl - wl).abs() <= 1e-3 * wl.max(1.0) + 1.0,
                              "kernel {} vs rust {}", kc.whpwl, wl);
            }
        }
    }

    // Final STA with placed delays.
    let rpt = timing::sta_with(nl, idx, pidx, packing, arch, |net, sink, _| {
        net_endpoint_delay(&model, &lb_loc, &io_loc, arch, net, sink)
    }, sta_jobs);

    let cost = inc.refresh(&model, &lb_loc, &io_loc);
    Ok(Placement { device, lb_loc, io_loc, cost, est_cpd_ps: rpt.cpd_ps, move_stats })
}

/// Estimated interconnect delay for one net sink given current locations.
pub fn net_endpoint_delay(
    model: &cost::NetModel,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    arch: &Arch,
    net: NetId,
    sink_cell: CellId,
) -> f64 {
    let Some((src, dst)) = model.endpoint_locs(net, sink_cell, lb_loc, io_loc) else {
        return 0.0;
    };
    est_net_delay(arch, src, dst)
}

/// One pre-drawn SA move candidate: a kind, a block pick, a displacement
/// (or, for median moves, a jitter around the computed target), and the
/// Metropolis uniform.  All randomness is drawn at proposal time so
/// evaluation/commit is a deterministic pipeline over the batch.
#[derive(Clone, Copy, Debug)]
struct MoveProposal {
    kind: MoveKind,
    block: usize,
    dx: i32,
    dy: i32,
    accept_draw: f64,
}

/// Draw one move proposal within range limit `rlim`.  `p_macro` /
/// `p_median` are the scheduled probabilities of the diverse kinds (both
/// 0.0 reproduces the uniform-only pipeline; the kind draw is still
/// consumed, keeping the RNG stream independent of the mix outcome).
fn propose_move(
    rng: &mut Rng,
    n_blocks: usize,
    rlim: u16,
    p_macro: f64,
    p_median: f64,
    macros: &[Vec<usize>],
) -> MoveProposal {
    let kind_draw = rng.f64();
    if kind_draw < p_macro && !macros.is_empty() {
        // Shift one macro within its column: pick the macro directly (a
        // uniform block pick almost never lands on one) and displace
        // vertically only.
        let block = macros[rng.below(macros.len())][0];
        MoveProposal {
            kind: MoveKind::MacroShift,
            block,
            dx: 0,
            dy: rng.range(-(rlim as i64), rlim as i64) as i32,
            accept_draw: rng.f64(),
        }
    } else if kind_draw < p_macro + p_median {
        // Median-region move: dx/dy are jitter around the target computed
        // at evaluation time from the cached net boxes.
        MoveProposal {
            kind: MoveKind::Median,
            block: rng.below(n_blocks),
            dx: rng.range(-1, 1) as i32,
            dy: rng.range(-1, 1) as i32,
            accept_draw: rng.f64(),
        }
    } else {
        MoveProposal {
            kind: MoveKind::Uniform,
            block: rng.below(n_blocks),
            dx: rng.range(-(rlim as i64), rlim as i64) as i32,
            dy: rng.range(-(rlim as i64), rlim as i64) as i32,
            accept_draw: rng.f64(),
        }
    }
}

/// Metropolis acceptance with a pre-drawn uniform.
#[inline]
fn accepts(p: &MoveProposal, delta: f64, t: f64) -> bool {
    delta <= 0.0 || (t > 0.0 && p.accept_draw < (-delta / t).exp())
}

/// Median-region target for `block`: the median of its connected nets'
/// bounding-box edges computed *excluding the block itself* (as in VPR's
/// median move — including it would bias every net's box toward the
/// block's current location, collapsing the move into a no-op on
/// low-fanout nets), plus the proposal's jitter, clamped into the logic
/// grid.  `None` when no connected net has another terminal (nothing
/// pulls the block anywhere).
fn median_target(
    model: &cost::NetModel,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    block: usize,
    device: &Device,
    jx: i32,
    jy: i32,
) -> Option<Loc> {
    let nets = model.nets_of_lb(block);
    if nets.is_empty() {
        return None;
    }
    let mut xs: Vec<u16> = Vec::with_capacity(nets.len() * 2);
    let mut ys: Vec<u16> = Vec::with_capacity(nets.len() * 2);
    for &ni in nets {
        let en = &model.nets[ni];
        let mut xmin = u16::MAX;
        let mut xmax = 0u16;
        let mut ymin = u16::MAX;
        let mut ymax = 0u16;
        let mut any = false;
        for &t in &en.terms {
            let l = match t {
                cost::Term::Lb(i) => {
                    if i == block {
                        continue;
                    }
                    lb_loc[i]
                }
                cost::Term::Io(c) => io_loc[&c],
            };
            xmin = xmin.min(l.x);
            xmax = xmax.max(l.x);
            ymin = ymin.min(l.y);
            ymax = ymax.max(l.y);
            any = true;
        }
        if any {
            xs.push(xmin);
            xs.push(xmax);
            ys.push(ymin);
            ys.push(ymax);
        }
    }
    if xs.is_empty() {
        return None;
    }
    xs.sort_unstable();
    ys.sort_unstable();
    let tx = (xs[xs.len() / 2] as i32 + jx).clamp(1, device.lb_cols as i32) as u16;
    let ty = (ys[ys.len() / 2] as i32 + jy).clamp(1, device.lb_rows as i32) as u16;
    Some(Loc::new(tx, ty))
}

/// Evaluate and (maybe) commit one proposal: resolve the proposal kind
/// into a displacement, resolve the target window for the picked block
/// (macro or single LB), score the affected nets against the incremental
/// cost cache, accept by Metropolis, and on acceptance update
/// grid/locations and the cache. Returns the accepted cost delta.
#[allow(clippy::too_many_arguments)]
fn apply_proposal(
    p: &MoveProposal,
    device: &Device,
    grid: &mut HashMap<Loc, usize>,
    lb_loc: &mut Vec<Loc>,
    lb_macro: &[Option<usize>],
    macros: &[Vec<usize>],
    model: &cost::NetModel,
    inc: &mut cost::IncrementalCost,
    io_loc: &HashMap<CellId, Loc>,
    t: f64,
) -> Option<f64> {
    let n = lb_loc.len();
    if n < 2 {
        return None;
    }
    let a = p.block;
    let a_loc = lb_loc[a];
    let (dx, dy) = match p.kind {
        MoveKind::Uniform | MoveKind::MacroShift => (p.dx, p.dy),
        MoveKind::Median => {
            let target = median_target(model, lb_loc, io_loc, a, device, p.dx, p.dy)?;
            (
                target.x as i32 - a_loc.x as i32,
                target.y as i32 - a_loc.y as i32,
            )
        }
    };

    if let Some(mid) = lb_macro[a] {
        // Macro move: shift the whole vertical run to a new column window.
        let m = &macros[mid];
        let len = m.len() as u16;
        let base = lb_loc[m[0]];
        let nx = (base.x as i32 + dx).clamp(1, device.lb_cols as i32) as u16;
        let ny = (base.y as i32 + dy).clamp(1, (device.lb_rows - len + 1).max(1) as i32) as u16;
        if nx == base.x && ny == base.y {
            return None;
        }
        // Target window must be empty or contain only single (non-macro) LBs
        // we can swap out.
        let mut displaced: Vec<(usize, Loc)> = Vec::new();
        for i in 0..len {
            let tgt = Loc::new(nx, ny + i);
            if let Some(&occ) = grid.get(&tgt) {
                if lb_macro[occ].is_some() && !m.contains(&occ) {
                    return None; // macro collision: reject
                }
                if !m.contains(&occ) {
                    displaced.push((occ, Loc::new(0, 0)));
                }
            }
        }
        // Rehouse displaced singles in slots the macro actually vacates:
        // old slots outside the new window.  When the move overlaps its own
        // footprint (a small same-column shift), the overlapping old slots
        // stay macro-occupied — handing one to a displaced single would put
        // two blocks on one tile.
        let vacated: Vec<Loc> = (0..len)
            .map(|i| Loc::new(base.x, base.y + i))
            .filter(|l| l.x != nx || l.y < ny || l.y >= ny + len)
            .collect();
        if displaced.len() > vacated.len() {
            return None; // not enough freed slots to rehouse everyone
        }
        for (d, &slot) in displaced.iter_mut().zip(vacated.iter()) {
            d.1 = slot;
        }
        // Compute delta over affected nets.
        let mut moved: Vec<(usize, Loc)> = Vec::new();
        for (i, &lb) in m.iter().enumerate() {
            moved.push((lb, Loc::new(nx, ny + i as u16)));
        }
        for &(lb, loc) in &displaced {
            moved.push((lb, loc));
        }
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            for &(lb, _) in &moved {
                grid.remove(&lb_loc[lb]);
            }
            for &(lb, loc) in &moved {
                grid.insert(loc, lb);
                lb_loc[lb] = loc;
            }
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
        return None;
    }

    // Single LB: swap with another location (occupied by single or empty).
    let nx = (a_loc.x as i32 + dx).clamp(1, device.lb_cols as i32) as u16;
    let ny = (a_loc.y as i32 + dy).clamp(1, device.lb_rows as i32) as u16;
    let b_loc = Loc::new(nx, ny);
    if b_loc == a_loc {
        return None;
    }
    let occupant = grid.get(&b_loc).copied();
    if let Some(b) = occupant {
        if lb_macro[b].is_some() {
            return None;
        }
        let moved = [(a, b_loc), (b, a_loc)];
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            grid.insert(a_loc, b);
            grid.insert(b_loc, a);
            lb_loc[a] = b_loc;
            lb_loc[b] = a_loc;
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
    } else {
        let moved = [(a, b_loc)];
        let delta = inc.move_delta(model, lb_loc, io_loc, &moved);
        if accepts(p, delta, t) {
            grid.remove(&a_loc);
            grid.insert(b_loc, a);
            lb_loc[a] = b_loc;
            inc.apply_move(model, lb_loc, io_loc, &moved);
            return Some(delta);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchVariant;
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn setup() -> (Netlist, Packing, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        (nl, packing, arch)
    }

    #[test]
    fn placement_is_legal() {
        let (nl, packing, arch) = setup();
        let p = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("auto-sized placement");
        // Every LB on a distinct logic tile.
        let mut seen = std::collections::HashSet::new();
        for &loc in &p.lb_loc {
            assert!(p.device.is_lb(loc), "LB off-grid at {loc:?}");
            assert!(seen.insert(loc), "two LBs at {loc:?}");
        }
        // IOs on the periphery.
        for loc in p.io_loc.values() {
            assert!(p.device.is_io(*loc));
        }
        assert!(p.est_cpd_ps > 0.0);
        // The pipeline really ran a mix of move kinds.
        assert!(p.move_stats.proposed.iter().sum::<usize>() > 0);
    }

    #[test]
    fn chain_macros_stay_vertical() {
        let (nl, packing, arch) = setup();
        let p = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() })
            .expect("auto-sized placement");
        for m in &packing.chain_macros {
            if m.len() < 2 {
                continue;
            }
            for w in m.windows(2) {
                let a = p.lb_loc[w[0]];
                let b = p.lb_loc[w[1]];
                assert_eq!(a.x, b.x, "macro not in one column");
                assert_eq!(b.y, a.y + 1, "macro not vertically consecutive");
            }
        }
    }

    #[test]
    fn annealing_improves_over_initial() {
        let (nl, packing, arch) = setup();
        // Effort 0 -> essentially initial placement.
        let rough = place(&nl, &packing, &arch,
                          &PlaceOpts { effort: 0.05, seed: 3, ..Default::default() })
            .expect("rough placement");
        let tuned = place(&nl, &packing, &arch,
                          &PlaceOpts { effort: 1.5, seed: 3, ..Default::default() })
            .expect("tuned placement");
        assert!(tuned.cost <= rough.cost * 1.05,
                "tuned {} vs rough {}", tuned.cost, rough.cost);
    }

    #[test]
    fn deterministic_per_seed() {
        let (nl, packing, arch) = setup();
        let mk = || {
            place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, seed: 7, ..Default::default() })
                .expect("placement")
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.lb_loc, b.lb_loc);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.move_stats.proposed, b.move_stats.proposed);
        assert_eq!(a.move_stats.accepted, b.move_stats.accepted);
    }

    /// A fixed device whose rows cannot host the tallest chain macro (or
    /// whose capacity is short) must error — never silently resize.
    #[test]
    fn fixed_device_misfit_errors() {
        use crate::techmap::aig::Lit;
        // One long carry chain (64 bits >> the 20 adder bits per LB), so
        // the packing is guaranteed to contain a multi-LB chain macro.
        let mut c = Circuit::new("chain");
        let x = c.pi_bus("x", 64);
        let y = c.pi_bus("y", 64);
        let ops: Vec<(Lit, Lit)> = x.iter().copied().zip(y.iter().copied()).collect();
        let (sums, cout) = c.add_chain(ops, Lit::FALSE);
        c.po_bus("s", &sums);
        c.po("co", cout);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let max_macro = packing.chain_macros.iter().map(|m| m.len()).max().unwrap_or(1);
        assert!(max_macro >= 2, "want a multi-LB chain macro in the fixture");
        // Wide enough for every LB, but too short for the macro.
        let short = Device::new(packing.lbs.len() as u16 + 2, max_macro as u16 - 1);
        let err = place(&nl, &packing, &arch, &PlaceOpts {
            effort: 0.05,
            device: Some(short),
            ..Default::default()
        });
        let msg = format!("{}", err.expect_err("macro-misfit device must error"));
        assert!(msg.contains("chain macro"), "unexpected error: {msg}");
        // Too few LB slots.
        let tiny = Device::new(1, max_macro as u16);
        let err = place(&nl, &packing, &arch, &PlaceOpts {
            effort: 0.05,
            device: Some(tiny),
            ..Default::default()
        });
        assert!(err.is_err(), "capacity-misfit device must error");
        // Auto-sizing still grows the grid for the same design.
        assert!(place(&nl, &packing, &arch, &PlaceOpts { effort: 0.05, ..Default::default() })
            .is_ok());
    }
}
