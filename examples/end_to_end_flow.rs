//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Runs the complete stack — Rust CAD flow (L3) with the placer's batched
//! cost model evaluated through the AOT-compiled JAX/Pallas kernel (L2/L1)
//! via PJRT — over a mixed workload (one circuit per suite, baseline vs
//! DD5), cross-checking the kernel cost against the Rust incremental cost
//! and reporting the paper's headline metrics. Recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example end_to_end_flow

use std::time::Instant;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{koios_suite, kratos_suite, vtr_suite, BenchParams};
use double_duty::flow::{run_flow, FlowOpts};
use double_duty::pack::{pack, PackOpts};
use double_duty::place::cost::NetModel;
use double_duty::place::kernel_accel::KernelCost;
use double_duty::place::{place, PlaceOpts};
use double_duty::techmap::{map_circuit, MapOpts};

fn main() {
    let params = BenchParams::default();
    let picks = vec![
        kratos_suite(&params)[2].clone(), // gemmt
        koios_suite(&params)[0].clone(),  // dla-like
        vtr_suite(&params)[0].clone(),    // sha-like
    ];

    // 1) Kernel-in-the-loop placement on the first circuit, with an
    //    explicit Rust-vs-PJRT consistency check.
    println!("== L1/L2/L3 composition check (PJRT kernel in the placer) ==");
    let circ = picks[0].generate();
    let nl = map_circuit(&circ, &MapOpts::default());
    let arch = Arch::coffe(ArchVariant::Baseline);
    let packing = pack(&nl, &arch, &PackOpts::default());
    let t0 = Instant::now();
    let pl = place(&nl, &packing, &arch,
                   &PlaceOpts { effort: 0.3, use_kernel: true, ..Default::default() })
        .expect("placement");
    let place_ms = t0.elapsed().as_millis();
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);
    let rust_cost = model.full_cost(&pl.lb_loc, &pl.io_loc);
    match KernelCost::try_new(model.num_nets()) {
        Ok(mut k) => {
            let t1 = Instant::now();
            let eval = k.evaluate(&model, &pl.lb_loc, &pl.io_loc, &pl.device).unwrap();
            let kernel_us = t1.elapsed().as_micros();
            let err = (eval.whpwl - rust_cost).abs() / rust_cost.max(1.0);
            println!("  rust wHPWL   : {rust_cost:.2}");
            println!("  kernel wHPWL : {:.2}  (rel err {:.2e}, {} us/eval)",
                     eval.whpwl, err, kernel_us);
            println!("  congestion   : peak {:.3}, overflow {:.3}",
                     eval.congestion.iter().cloned().fold(0.0f32, f32::max),
                     eval.overflow);
            assert!(err < 1e-3, "kernel/rust cost mismatch");
        }
        Err(e) => {
            println!("  (PJRT kernel unavailable: {e}; run `make artifacts`)");
        }
    }
    println!("  placement    : {} LBs in {} ms", packing.lbs.len(), place_ms);
    println!();

    // 2) Full flow on one circuit per suite, baseline vs DD5 — the
    //    paper's headline comparison end to end.
    println!("== full flow: baseline vs DD5, one circuit per suite ==");
    println!("{:<16} {:>9} {:>9} {:>7} {:>9} {:>8} {:>8}",
             "circuit", "base ALM", "dd5 ALM", "conc", "area r", "cpd r", "adp r");
    let opts = FlowOpts { seeds: vec![1], place_effort: 0.3, ..Default::default() };
    for b in &picks {
        let circ = b.generate();
        let base = run_flow(&circ, &Arch::coffe(ArchVariant::Baseline), &opts);
        let dd5 = run_flow(&circ, &Arch::coffe(ArchVariant::Dd5), &opts);
        assert!(base.routed_ok && dd5.routed_ok, "{} failed routing", b.name);
        println!("{:<16} {:>9} {:>9} {:>7} {:>9.3} {:>8.3} {:>8.3}",
                 b.name, base.alms, dd5.alms, dd5.concurrent_luts,
                 dd5.alm_area_mwta / base.alm_area_mwta,
                 dd5.cpd_ns / base.cpd_ns,
                 dd5.adp / base.adp);
    }
    println!();
    println!("end_to_end_flow OK: three layers composed (pallas kernel -> HLO -> PJRT -> placer).");
}
