//! Deterministic fault injection for the flow engine.
//!
//! A [`FaultPlan`] is a parsed `--inject-faults <spec>` string: a
//! comma-separated list of faults, each naming a site in the flow where
//! a failure is forced.  Injection is *deterministic by construction*:
//! a fault either always fires at its site or never does — there is no
//! randomness and no wall clock — so a faulted run is exactly as
//! bit-reproducible as a clean one, and `rust/tests/fault_recovery.rs`
//! can assert byte-equal artifacts across `--jobs` / `--route-jobs`
//! with faults active.  The plan also participates in cache keying
//! (it is hashed into [`crate::flow::engine::ArtifactCache::cpd_prior_key`]),
//! so faulted results never alias clean ones.
//!
//! ## Spec grammar
//!
//! ```text
//! spec    := fault ("," fault)*
//! fault   := "panic:" stage [":" bench [":" seed]]
//!          | "noconverge:route" [":" bench [":" seed]]
//!          | "noconverge-all:route" [":" bench [":" seed]]
//!          | "corrupt:cache" [":" kind]
//! stage   := "map" | "pack" | "place" | "route"
//! kind    := "map" | "pack" | "look" | "*"
//! bench   := benchmark name | "*"        (default "*")
//! seed    := integer | "*"               (default "*")
//! ```
//!
//! `panic` raises a real Rust panic at the named stage for matching
//! (bench, seed) jobs — the payload the engine's `catch_unwind`
//! isolation must convert into a [`crate::flow::FlowError`].
//! `noconverge` forces the *base* route attempt of matching seeds to
//! report `success: false` (the escalation ladder, if enabled, then
//! rescues it); `noconverge-all` forces every ladder rung to fail too,
//! exercising the ladder-exhausted path.  `corrupt:cache` truncates
//! matching disk-cache artifacts *at store time* (magic line intact,
//! body replaced), so the next load exercises the real integrity-check
//! → quarantine path.
//!
//! Example: `panic:place:gemmt-FU-mini:2,noconverge:route:*:1`.

use crate::util::error::Result;

/// One injected fault (see the module docs for the grammar).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Fault {
    /// Panic at `stage` for matching (bench, seed) jobs.
    Panic { stage: String, bench: String, seed: Option<u64> },
    /// Force the base route attempt to report non-convergence.
    NoConverge { bench: String, seed: Option<u64> },
    /// Force the base attempt *and* every escalation rung to fail.
    NoConvergeAll { bench: String, seed: Option<u64> },
    /// Corrupt disk-cache artifacts of `kind` at store time.
    CorruptCache { kind: String },
}

/// A parsed, deterministic fault-injection plan.  `Default` is the
/// empty plan (no faults).  Hash/Eq derive so the plan can participate
/// in cache keys.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

/// Stages that accept an injected panic.
const PANIC_STAGES: &[&str] = &["map", "pack", "place", "route"];
/// Disk-cache artifact kinds that accept injected corruption.
const CACHE_KINDS: &[&str] = &["map", "pack", "look", "*"];

fn parse_seed(s: &str) -> Result<Option<u64>> {
    if s == "*" {
        return Ok(None);
    }
    s.parse::<u64>()
        .map(Some)
        .map_err(|_| crate::util::error::Error::msg(format!("bad fault seed: {s:?}")))
}

fn matches_bench(pat: &str, bench: &str) -> bool {
    pat == "*" || pat == bench
}

fn matches_seed(pat: Option<u64>, seed: Option<u64>) -> bool {
    match pat {
        None => true,
        Some(p) => seed == Some(p),
    }
}

impl FaultPlan {
    /// Parse a `--inject-faults` spec (see the module docs).  Errors on
    /// unknown fault types, stages, or cache kinds — a mistyped spec
    /// must never silently inject nothing.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let parts: Vec<&str> = tok.split(':').collect();
            let bench_at = |i: usize| parts.get(i).copied().unwrap_or("*").to_string();
            let seed_at = |i: usize| parse_seed(parts.get(i).copied().unwrap_or("*"));
            match parts[0] {
                "panic" => {
                    let stage = parts.get(1).copied().unwrap_or("");
                    crate::ensure!(
                        PANIC_STAGES.contains(&stage),
                        "panic fault needs a stage in {PANIC_STAGES:?}, got {tok:?}"
                    );
                    crate::ensure!(parts.len() <= 4, "too many fields in fault {tok:?}");
                    faults.push(Fault::Panic {
                        stage: stage.to_string(),
                        bench: bench_at(2),
                        seed: seed_at(3)?,
                    });
                }
                "noconverge" | "noconverge-all" => {
                    crate::ensure!(
                        parts.get(1) == Some(&"route"),
                        "{} fault only supports the route stage, got {tok:?}",
                        parts[0]
                    );
                    crate::ensure!(parts.len() <= 4, "too many fields in fault {tok:?}");
                    let (bench, seed) = (bench_at(2), seed_at(3)?);
                    faults.push(if parts[0] == "noconverge" {
                        Fault::NoConverge { bench, seed }
                    } else {
                        Fault::NoConvergeAll { bench, seed }
                    });
                }
                "corrupt" => {
                    crate::ensure!(
                        parts.get(1) == Some(&"cache"),
                        "corrupt fault only supports cache, got {tok:?}"
                    );
                    crate::ensure!(parts.len() <= 3, "too many fields in fault {tok:?}");
                    let kind = parts.get(2).copied().unwrap_or("*");
                    crate::ensure!(
                        CACHE_KINDS.contains(&kind),
                        "corrupt:cache kind must be in {CACHE_KINDS:?}, got {tok:?}"
                    );
                    faults.push(Fault::CorruptCache { kind: kind.to_string() });
                }
                other => crate::bail!("unknown fault type {other:?} in {tok:?}"),
            }
        }
        Ok(FaultPlan { faults })
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Canonical round-trippable spec string (for display / summaries).
    pub fn spec(&self) -> String {
        let fmt_seed = |s: Option<u64>| match s {
            Some(v) => v.to_string(),
            None => "*".to_string(),
        };
        self.faults
            .iter()
            .map(|f| match f {
                Fault::Panic { stage, bench, seed } => {
                    format!("panic:{stage}:{bench}:{}", fmt_seed(*seed))
                }
                Fault::NoConverge { bench, seed } => {
                    format!("noconverge:route:{bench}:{}", fmt_seed(*seed))
                }
                Fault::NoConvergeAll { bench, seed } => {
                    format!("noconverge-all:route:{bench}:{}", fmt_seed(*seed))
                }
                Fault::CorruptCache { kind } => format!("corrupt:cache:{kind}"),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Panic if the plan injects a panic at this site.  `seed` is `None`
    /// for per-bench stages (map/pack), in which case only wildcard-seed
    /// faults match.  The panic payload carries the injection marker the
    /// engine's isolation layer surfaces through `FlowError`.
    pub fn fire_panic(&self, stage: &str, bench: &str, seed: Option<u64>) {
        for f in &self.faults {
            if let Fault::Panic { stage: s, bench: b, seed: sd } = f {
                if s == stage && matches_bench(b, bench) && matches_seed(*sd, seed) {
                    panic!(
                        "injected fault: {stage} panic (bench {bench:?}, seed {seed:?})"
                    );
                }
            }
        }
    }

    /// Does the plan force route non-convergence for this (bench, seed)
    /// at escalation rung `rung` (0 = the base attempt)?
    pub fn forces_noconverge(&self, bench: &str, seed: u64, rung: u8) -> bool {
        self.faults.iter().any(|f| match f {
            Fault::NoConverge { bench: b, seed: sd } => {
                rung == 0 && matches_bench(b, bench) && matches_seed(*sd, Some(seed))
            }
            Fault::NoConvergeAll { bench: b, seed: sd } => {
                matches_bench(b, bench) && matches_seed(*sd, Some(seed))
            }
            _ => false,
        })
    }

    /// Does the plan corrupt disk-cache artifacts of `kind`
    /// (`"map"` / `"pack"` / `"look"`)?
    pub fn corrupts(&self, kind: &str) -> bool {
        self.faults.iter().any(|f| match f {
            Fault::CorruptCache { kind: k } => k == "*" || k == kind,
            _ => false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_canonical_spec() {
        let spec = "panic:place:gemmt:2,noconverge:route:*:1,noconverge-all:route:m:*,corrupt:cache:map";
        let plan = FaultPlan::parse(spec).expect("parse");
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(plan.spec(), spec);
        let again = FaultPlan::parse(&plan.spec()).expect("reparse");
        assert_eq!(plan, again);
    }

    #[test]
    fn parse_defaults_are_wildcards() {
        let plan = FaultPlan::parse("panic:map").expect("parse");
        assert_eq!(
            plan.faults[0],
            Fault::Panic { stage: "map".into(), bench: "*".into(), seed: None }
        );
        let plan = FaultPlan::parse("corrupt:cache").expect("parse");
        assert_eq!(plan.faults[0], Fault::CorruptCache { kind: "*".into() });
        assert!(FaultPlan::parse("").expect("empty").is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "panic",
            "panic:sta",
            "panic:place:b:notanumber",
            "panic:place:b:1:extra",
            "noconverge:place",
            "noconverge-all:pack",
            "corrupt:prior",
            "corrupt:cache:netlist",
            "frobnicate:route",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should fail to parse");
        }
    }

    #[test]
    fn matching_semantics() {
        let plan =
            FaultPlan::parse("noconverge:route:m:1,noconverge-all:route:n:*").expect("parse");
        // NoConverge matches rung 0 only, exact bench + seed.
        assert!(plan.forces_noconverge("m", 1, 0));
        assert!(!plan.forces_noconverge("m", 1, 1));
        assert!(!plan.forces_noconverge("m", 2, 0));
        assert!(!plan.forces_noconverge("x", 1, 0));
        // NoConvergeAll matches every rung.
        assert!(plan.forces_noconverge("n", 7, 0));
        assert!(plan.forces_noconverge("n", 7, 3));

        let plan = FaultPlan::parse("corrupt:cache:look").expect("parse");
        assert!(plan.corrupts("look"));
        assert!(!plan.corrupts("map"));
        let plan = FaultPlan::parse("corrupt:cache:*").expect("parse");
        assert!(plan.corrupts("map") && plan.corrupts("pack") && plan.corrupts("look"));
    }

    #[test]
    fn fire_panic_only_on_match() {
        let plan = FaultPlan::parse("panic:place:m:2").expect("parse");
        // Non-matching sites are no-ops.
        plan.fire_panic("place", "m", Some(1));
        plan.fire_panic("place", "x", Some(2));
        plan.fire_panic("map", "m", None);
        let hit = std::panic::catch_unwind(|| plan.fire_panic("place", "m", Some(2)));
        let msg = *hit.expect_err("must panic").downcast::<String>().expect("string payload");
        assert!(msg.contains("injected fault"), "payload: {msg}");
    }

    #[test]
    fn wildcard_seed_matches_seedless_sites() {
        let plan = FaultPlan::parse("panic:map:m").expect("parse");
        assert!(std::panic::catch_unwind(|| plan.fire_panic("map", "m", None)).is_err());
        // A seed-specific fault never fires at a seedless (per-bench) site.
        let plan = FaultPlan::parse("panic:map:m:3").expect("parse");
        plan.fire_panic("map", "m", None);
    }
}
