//! Netlist statistics: the per-circuit numbers Table III reports.

use super::{CellKind, Netlist};

/// Summary statistics of a mapped netlist.
#[derive(Clone, Debug, Default)]
pub struct NetlistStats {
    pub luts: usize,
    pub adders: usize,
    pub ffs: usize,
    pub ios: usize,
    pub nets: usize,
    pub chains: usize,
    /// Length of the longest carry chain in bits.
    pub max_chain_len: usize,
    /// Fraction of logic cells (LUTs + adder bits) that are adder bits —
    /// the "Adder Percent" column of Table III.
    pub adder_fraction: f64,
}

impl NetlistStats {
    pub fn of(nl: &Netlist) -> Self {
        let luts = nl.num_luts();
        let adders = nl.num_adders();
        let ffs = nl.num_ffs();
        let ios = nl.inputs.len() + nl.outputs.len();
        let mut max_chain_len = 0usize;
        for ch in 0..nl.num_chains {
            let len = nl
                .cells
                .iter()
                .filter(|c| matches!(c.kind, CellKind::AdderBit { chain, .. } if chain == ch))
                .count();
            max_chain_len = max_chain_len.max(len);
        }
        let logic = luts + adders;
        NetlistStats {
            luts,
            adders,
            ffs,
            ios,
            nets: nl.nets.len(),
            chains: nl.num_chains as usize,
            max_chain_len,
            adder_fraction: if logic == 0 { 0.0 } else { adders as f64 / logic as f64 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::CellKind;

    #[test]
    fn stats_count_kinds() {
        let mut nl = Netlist::new("s");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let y = nl.add_net("y");
        nl.add_cell(CellKind::Lut { k: 2, truth: 0b0110 }, "xor", vec![a, b], vec![y]);
        let g = nl.add_net("g");
        nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![g]);
        let s = nl.add_net("s");
        let c = nl.add_net("c");
        nl.add_cell(CellKind::AdderBit { chain: 0, pos: 0 }, "fa",
                    vec![a, b, g], vec![s, c]);
        nl.num_chains = 1;
        nl.add_output("o", y);
        let st = NetlistStats::of(&nl);
        assert_eq!(st.luts, 1);
        assert_eq!(st.adders, 1);
        assert_eq!(st.ios, 3);
        assert_eq!(st.chains, 1);
        assert_eq!(st.max_chain_len, 1);
        assert!((st.adder_fraction - 0.5).abs() < 1e-12);
    }
}
