//! Bench harness regenerating the paper's Table III (suite statistics).
//! Run: cargo bench --bench table3_stats   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    report::table3(&opts).print();
    println!();
    println!("[table3_stats] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
