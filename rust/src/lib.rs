//! # double-duty
//!
//! Reproduction of *"Double Duty: FPGA Architecture to Enable Concurrent
//! LUT and Adder Chain Usage"* (CS.AR 2025): a Stratix-10-like FPGA
//! architecture model with the DD5/DD6 Double-Duty logic-element variants,
//! a COFFE-2-like circuit-level modeling engine, and a complete VTR-like
//! CAD flow — arithmetic-aware synthesis, LUT technology mapping, ALM/LB
//! packing, timing-driven placement, PathFinder routing, and static timing
//! analysis — plus generators for the Kratos/Koios/VTR-style benchmark
//! suites and a harness that regenerates every table and figure of the
//! paper's evaluation.
//!
//! The placer's batched cost model (weighted HPWL + RUDY congestion) is a
//! JAX/Pallas kernel AOT-compiled to HLO and executed from Rust through
//! PJRT (`runtime`); Python never runs at flow time.

pub mod arch;
pub mod coffe;
pub mod netlist;
pub mod util;

pub mod synth;
pub mod techmap;

pub mod pack;

pub mod timing;

pub mod place;
pub mod runtime;

pub mod route;

pub mod bench_suites;

pub mod coordinator;
pub mod flow;
pub mod report;
