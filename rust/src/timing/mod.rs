//! Static timing analysis over a packed (and optionally placed/routed)
//! netlist.
//!
//! The graph is the mapped netlist itself; per-variant component delays
//! come from [`crate::arch::Delays`] (COFFE-calibrated).  Net delays are
//! supplied by the caller — the placer passes a distance-based estimate,
//! the router passes actual per-sink routed-wire delays — so one STA
//! serves both pre- and post-route analysis.
//!
//! Adder operand sinks are the paths that differentiate the
//! architectures: on the baseline every operand takes
//! `crossbar + (LUT ->) adder` (133.4 ps class); on DD variants a
//! Z-bypassed operand takes `AddMux crossbar + AddMux` (77.05 + 68.77 ps)
//! — the ~48% cut of Table II that shows up as the Table IV CPD gains.

use std::collections::HashMap;

use crate::arch::Arch;
use crate::netlist::{CellId, CellKind, Netlist, NetId};
use crate::pack::{OperandPath, Packing};

/// STA result.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Critical path delay in picoseconds.
    pub cpd_ps: f64,
    /// Per-net criticality in [0, 1] (max over the net's sinks).
    pub net_crit: Vec<f64>,
    /// Cell arrival times (at outputs), for debugging / reports.
    pub arrival: Vec<f64>,
}

impl TimingReport {
    pub fn fmax_mhz(&self) -> f64 {
        if self.cpd_ps <= 0.0 {
            return f64::INFINITY;
        }
        1e6 / self.cpd_ps
    }
}

/// Sink-kind classification for input-path delays.
fn sink_input_delay(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    cell: CellId,
    pin: u8,
    alm_of_cell: &HashMap<CellId, usize>,
) -> f64 {
    let d = &arch.delays;
    match nl.cells[cell as usize].kind {
        CellKind::Lut { k, .. } => {
            // Local crossbar + LUT read.
            let lut_d = if k <= 5 { d.lut5 } else { d.lut6 };
            d.lb_in_to_alm_in + lut_d + d.alm_out_to_lb_out + d.dd6_outmux_extra
        }
        CellKind::AdderBit { .. } => {
            if pin == 2 {
                // Carry-in: handled as a carry edge, no input network.
                0.0
            } else {
                // Operand entry: depends on the packed path.
                let path = alm_of_cell
                    .get(&cell)
                    .and_then(|&ai| {
                        let alm = &packing.alms[ai];
                        alm.adder_bits
                            .iter()
                            .position(|&b| b == cell)
                            .map(|bi| alm.operand_paths[bi][pin as usize])
                    })
                    .unwrap_or(OperandPath::RouteThrough);
                match path {
                    OperandPath::ZBypass => d.lb_in_to_z + d.z_to_adder,
                    OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough => {
                        d.lb_in_to_alm_in + d.alm_in_to_adder
                    }
                    OperandPath::Const => 0.0,
                }
            }
        }
        CellKind::Ff => d.lb_in_to_alm_in + d.ff_setup,
        CellKind::Output => d.io,
        CellKind::Input | CellKind::Const(_) => 0.0,
    }
}

/// Output launch delay of a cell (applied once at its output).
fn cell_output_delay(nl: &Netlist, arch: &Arch, cell: CellId, pin: u8) -> f64 {
    let d = &arch.delays;
    match nl.cells[cell as usize].kind {
        CellKind::Input => d.io,
        CellKind::Ff => d.ff_clk_q,
        CellKind::AdderBit { .. } => {
            if pin == 0 {
                d.adder_sum + d.alm_out_to_lb_out + d.dd6_outmux_extra
            } else {
                d.carry_hop
            }
        }
        // LUT logic delay is charged at the sink (crossbar+LUT), output
        // driver at the sink computation; avoid double counting.
        CellKind::Lut { .. } | CellKind::Const(_) | CellKind::Output => 0.0,
    }
}

/// Post-route STA: net delays come from the routed trees over the
/// routing-resource graph — each sink is charged for the wire hops of its
/// branch ([`crate::rrg::hop_delay`]), so the critical path reflects the
/// actual negotiated routes rather than placement distance estimates.
pub fn sta_routed(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    routing: &crate::route::Routing,
    model: &crate::place::cost::NetModel,
) -> TimingReport {
    let delay = crate::route::routed_net_delay(routing, model, arch);
    sta(nl, packing, arch, delay)
}

/// Run STA.  `net_delay(net, sink_cell, sink_pin)` gives the interconnect
/// delay from the net's driver LB pin to the sink LB pin (0 for intra-LB
/// feedback).
pub fn sta<F>(nl: &Netlist, packing: &Packing, arch: &Arch, net_delay: F) -> TimingReport
where
    F: Fn(NetId, CellId, u8) -> f64,
{
    let n = nl.cells.len();
    // Map cells to ALMs for operand-path lookup.
    let mut alm_of_cell: HashMap<CellId, usize> = HashMap::new();
    for (ai, alm) in packing.alms.iter().enumerate() {
        for &c in alm.adder_bits.iter().chain(alm.logic_luts.iter()).chain(alm.ffs.iter()) {
            alm_of_cell.insert(c, ai);
        }
    }

    // Topological order over combinational edges (FF q and PI are sources;
    // FF d and PO are sinks). Cells are already in a topological-ish order
    // from construction, but chains and LUT interleavings make that
    // unreliable -> Kahn.
    let mut indeg = vec![0u32; n];
    // Precompute ALM -> LB for carry-hop classification.
    let mut alm_lb: HashMap<usize, usize> = HashMap::new();
    for (li, lb) in packing.lbs.iter().enumerate() {
        for &ai in &lb.alms {
            alm_lb.insert(ai, li);
        }
    }
    // indeg counts combinational fanins.
    for (ci, cell) in nl.cells.iter().enumerate() {
        if matches!(cell.kind, CellKind::Ff) {
            continue;
        }
        let mut cnt = 0;
        for &net in &cell.ins {
            if let Some((drv, _)) = nl.nets[net as usize].driver {
                if !matches!(nl.cells[drv as usize].kind, CellKind::Ff) {
                    cnt += 1;
                }
            }
        }
        indeg[ci] = cnt;
    }

    let mut arrival = vec![0.0f64; n];
    let mut queue: Vec<CellId> = (0..n as CellId)
        .filter(|&c| indeg[c as usize] == 0 || matches!(nl.cells[c as usize].kind, CellKind::Ff))
        .collect();
    let mut head = 0;
    let mut processed = vec![false; n];
    while head < queue.len() {
        let c = queue[head];
        head += 1;
        if processed[c as usize] {
            continue;
        }
        processed[c as usize] = true;
        let cell = &nl.cells[c as usize];
        // Arrival at the cell's outputs.
        let in_arr = if matches!(cell.kind, CellKind::Ff) {
            0.0 // launch from the clock edge
        } else {
            let mut a: f64 = 0.0;
            for (pin, &net) in cell.ins.iter().enumerate() {
                if let Some((drv, dpin)) = nl.nets[net as usize].driver {
                    let src = if matches!(nl.cells[drv as usize].kind, CellKind::Ff) {
                        arch.delays.ff_clk_q
                    } else {
                        arrival[drv as usize] + cell_output_delay(nl, arch, drv, dpin)
                    };
                    let is_carry = matches!(cell.kind, CellKind::AdderBit { .. }) && pin == 2;
                    let wire = if is_carry {
                        // Carry chain: dedicated path; LB hop cost if the
                        // previous bit sits in another LB.
                        let same_lb = alm_of_cell.get(&c).zip(alm_of_cell.get(&drv))
                            .map(|(&x, &y)| alm_lb.get(&x) == alm_lb.get(&y))
                            .unwrap_or(true);
                        if same_lb { 0.0 } else { arch.delays.carry_lb_hop }
                    } else {
                        net_delay(net, c, pin as u8)
                    };
                    let input = sink_input_delay(nl, packing, arch, c, pin as u8, &alm_of_cell);
                    a = a.max(src + wire + input);
                }
            }
            a
        };
        arrival[c as usize] = in_arr;
        // Release fanouts.
        for &net in &cell.outs {
            for &(sink, _) in &nl.nets[net as usize].sinks {
                if matches!(nl.cells[sink as usize].kind, CellKind::Ff) {
                    continue;
                }
                indeg[sink as usize] = indeg[sink as usize].saturating_sub(1);
                if indeg[sink as usize] == 0 {
                    queue.push(sink);
                }
            }
        }
    }

    // CPD: max arrival at POs and FF d inputs (+ their sink input delays,
    // already folded into `arrival` of Output cells and below for FFs).
    let mut cpd = 0.0f64;
    for (ci, cell) in nl.cells.iter().enumerate() {
        match cell.kind {
            CellKind::Output => cpd = cpd.max(arrival[ci]),
            CellKind::Ff => {
                let net = cell.ins[0];
                if let Some((drv, dpin)) = nl.nets[net as usize].driver {
                    let src = arrival[drv as usize] + cell_output_delay(nl, arch, drv, dpin);
                    let wire = net_delay(net, ci as CellId, 0);
                    let input =
                        sink_input_delay(nl, packing, arch, ci as CellId, 0, &alm_of_cell);
                    cpd = cpd.max(src + wire + input);
                }
            }
            _ => {}
        }
    }
    if cpd <= 0.0 {
        cpd = 1.0;
    }

    // Backward pass: required times -> per-net criticality.
    let mut required = vec![f64::INFINITY; n];
    for (ci, cell) in nl.cells.iter().enumerate() {
        if matches!(cell.kind, CellKind::Output | CellKind::Ff) {
            required[ci] = cpd;
        }
    }
    // Process in reverse topological order (queue order reversed).
    for &c in queue.iter().rev() {
        let cell = &nl.cells[c as usize];
        if matches!(cell.kind, CellKind::Ff) {
            continue;
        }
        for (pin, &net) in cell.ins.iter().enumerate() {
            if let Some((drv, _)) = nl.nets[net as usize].driver {
                let wire = net_delay(net, c, pin as u8);
                let input = sink_input_delay(nl, packing, arch, c, pin as u8, &alm_of_cell);
                let req_here = required[c as usize] - wire - input;
                if req_here < required[drv as usize] {
                    required[drv as usize] = req_here;
                }
            }
        }
    }

    // Net criticality = max over sinks of (1 - slack/cpd).
    let mut net_crit = vec![0.0f64; nl.nets.len()];
    for (ni, net) in nl.nets.iter().enumerate() {
        let Some((drv, dpin)) = net.driver else { continue };
        let drv_arr = arrival[drv as usize] + cell_output_delay(nl, arch, drv, dpin);
        for &(sink, pin) in &net.sinks {
            let wire = net_delay(ni as NetId, sink, pin);
            let input = sink_input_delay(nl, packing, arch, sink, pin, &alm_of_cell);
            let slack = required[sink as usize] - (drv_arr + wire + input);
            let crit = (1.0 - slack / cpd).clamp(0.0, 1.0);
            if crit > net_crit[ni] {
                net_crit[ni] = crit;
            }
        }
    }

    TimingReport { cpd_ps: cpd, net_crit, arrival }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchVariant;
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn mul_setup(v: ArchVariant) -> (Netlist, Packing, Arch) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(v);
        let packing = pack(&nl, &arch, &PackOpts::default());
        (nl, packing, arch)
    }

    #[test]
    fn cpd_positive_and_finite() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Baseline);
        let rpt = sta(&nl, &packing, &arch, |_, _, _| 200.0);
        assert!(rpt.cpd_ps > 0.0 && rpt.cpd_ps.is_finite());
        assert!(rpt.fmax_mhz() > 0.0);
    }

    #[test]
    fn criticalities_bounded() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Dd5);
        let rpt = sta(&nl, &packing, &arch, |_, _, _| 150.0);
        assert!(rpt.net_crit.iter().all(|&c| (0.0..=1.0).contains(&c)));
        // At least one net is fully critical.
        assert!(rpt.net_crit.iter().any(|&c| c > 0.99));
    }

    #[test]
    fn longer_wires_increase_cpd() {
        let (nl, packing, arch) = mul_setup(ArchVariant::Baseline);
        let short = sta(&nl, &packing, &arch, |_, _, _| 50.0).cpd_ps;
        let long = sta(&nl, &packing, &arch, |_, _, _| 500.0).cpd_ps;
        assert!(long > short);
    }

    /// Adder-dominated path: DD5's Z bypass must not be slower than the
    /// baseline LUT feed (paper Table IV observes CPD *improvements*).
    #[test]
    fn dd5_adder_feed_not_slower() {
        let (nl_b, pk_b, arch_b) = mul_setup(ArchVariant::Baseline);
        let (nl_d, pk_d, arch_d) = mul_setup(ArchVariant::Dd5);
        let b = sta(&nl_b, &pk_b, &arch_b, |_, _, _| 200.0).cpd_ps;
        let d = sta(&nl_d, &pk_d, &arch_d, |_, _, _| 200.0).cpd_ps;
        // Same netlist structure; DD5 operand entries are never slower.
        assert!(d <= b * 1.02, "dd5 {d} vs baseline {b}");
    }

    #[test]
    fn dd6_output_mux_penalty_shows() {
        let (nl_d, pk_d, arch_d) = mul_setup(ArchVariant::Dd5);
        let (nl_6, pk_6, arch_6) = mul_setup(ArchVariant::Dd6);
        let d5 = sta(&nl_d, &pk_d, &arch_d, |_, _, _| 200.0).cpd_ps;
        let d6 = sta(&nl_6, &pk_6, &arch_6, |_, _, _| 200.0).cpd_ps;
        assert!(d6 >= d5, "dd6 {d6} vs dd5 {d5}");
    }
}
