//! Smoke tests over the experiment harness: every table/figure function
//! runs end to end in quick mode and produces sane, paper-shaped output.
//! (The full runs live in `cargo bench`; these keep `cargo test` fast.)

use double_duty::report::{self, ExpOpts};

#[test]
fn table1_and_2_shape() {
    let t1 = report::table1().render();
    // Calibrated model sits next to the paper anchors.
    assert!(t1.contains("Baseline Crossbar"));
    assert!(t1.contains("AddMux"));
    let t2 = report::table2().render();
    assert!(t2.contains("Double-Duty"));
}

#[test]
fn fig5_improved_algos_beat_vtr_baseline() {
    let (_, series) = report::fig5(&ExpOpts::quick());
    let base = series["vtr-baseline"];
    assert!((base[0] - 1.0).abs() < 1e-9, "baseline normalizes to 1");
    // Every improved algorithm uses fewer adders than stock VTR.
    for algo in ["cascade", "binary-tree", "wallace", "dadda"] {
        assert!(series[algo][0] < 1.0, "{algo} adders {}", series[algo][0]);
    }
    // Compressor trees reduce hard-adder usage the most (paper Fig. 5).
    assert!(series["wallace"][0] <= series["cascade"][0] + 0.05);
    // ADP improves for the best algorithm.
    let best_adp = ["cascade", "binary-tree", "wallace", "dadda"]
        .iter()
        .map(|a| series[a][3])
        .fold(f64::INFINITY, f64::min);
    assert!(best_adp < 1.0, "best ADP {best_adp}");
}

#[test]
fn fig6_dd5_saves_area_where_it_matters() {
    let (_, rows) = report::fig6(&ExpOpts::quick());
    use double_duty::bench_suites::Suite;
    let geo = |suite: Suite, f: &dyn Fn(&(String, Suite, f64, f64, f64)) -> f64| {
        let v: Vec<f64> = rows.iter().filter(|r| r.1 == suite).map(f).collect();
        double_duty::util::stats::geomean(&v)
    };
    let kr_area = geo(Suite::Kratos, &|r| r.2);
    let all_area: Vec<f64> = rows.iter().map(|r| r.2).collect();
    let avg = double_duty::util::stats::geomean(&all_area);
    // Paper shape: Kratos benefits most; overall area improves.
    assert!(kr_area < 1.0, "kratos area ratio {kr_area}");
    assert!(avg < 1.0, "overall area ratio {avg}");
    assert!(kr_area <= avg + 0.02, "kratos ({kr_area}) should lead ({avg})");
}

#[test]
fn fig8_histogram_shifts_right_under_dd5() {
    let (_, hb, hd) = report::fig8(&ExpOpts::quick());
    let mean_bin = |h: &[f64]| -> f64 {
        h.iter().enumerate().map(|(i, &v)| v * (i as f64 + 0.5) / 10.0).sum()
    };
    // Denser packing -> higher average channel utilization (paper Fig. 8).
    assert!(mean_bin(&hd) >= mean_bin(&hb) * 0.95,
            "dd5 {:.3} vs base {:.3}", mean_bin(&hd), mean_bin(&hb));
}

#[test]
fn fig9_saturation_behaviour() {
    let (_, rows) = report::fig9();
    // DD5 area stays ~flat while LUTs are absorbed: area at K=250 within
    // 12% of area at K=0.
    let a0 = rows.iter().find(|r| r.0 == 0).unwrap().2;
    let a250 = rows.iter().find(|r| r.0 == 250).unwrap().2;
    assert!(a250 < a0 * 1.12, "dd5 area grew {a0} -> {a250}");
    // Baseline grows markedly by K=500.
    let b0 = rows.iter().find(|r| r.0 == 0).unwrap().1;
    let b500 = rows.iter().find(|r| r.0 == 500).unwrap().1;
    assert!(b500 > b0 * 1.25, "baseline area {b0} -> {b500}");
}
