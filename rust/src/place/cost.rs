//! Placement cost model: two-lane criticality-aware HPWL, evaluated
//! incrementally per move.
//!
//! * **Wirelength lane** — the classic VPR formulation: per net,
//!   `weight * q(n_terms) * bbox_span` ([`net_bbox`] + [`bbox_cost`]).
//! * **Timing lane** — a *per-sink* criticality term: each (net, sink)
//!   connection is charged `sink_w[k] * manhattan(src, sink_k)`, where
//!   `sink_w[k] = gain * crit_k^2` comes from the STA's per-sink
//!   [`SinkCrit`] arena ([`NetModel::fold_sink_crit`] +
//!   [`NetModel::set_sink_crit`]) — not the per-net max, so a net's one
//!   critical connection pulls its endpoints together while its
//!   slack-rich sinks keep annealing on wirelength alone.
//!
//! With the timing lane empty (or `gain == 0`) every cost is *bit-equal*
//! to the wirelength-only model — the placer's all-zero-criticality
//! determinism contract rides on that (`rust/tests/place_timing.rs`).

use std::collections::HashMap;

use crate::arch::device::Loc;
use crate::netlist::{CellId, CellKind, Netlist, NetId, NetlistIndex};
use crate::pack::Packing;
use crate::timing::SinkCrit;

/// A placeable terminal of a net.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Term {
    Lb(usize),
    Io(CellId),
}

/// One external (inter-block) net.
#[derive(Clone, Debug)]
pub struct ExtNet {
    pub net: NetId,
    pub terms: Vec<Term>,
    /// Wirelength-lane weight (1 + criticality amplification when the
    /// legacy per-net weighting is used; 1.0 under the per-sink lane).
    pub weight: f64,
    /// Timing-lane weights, one per sink terminal (`terms[1..]`, same
    /// order): `gain * crit^2` from [`NetModel::set_sink_crit`].  Empty =
    /// lane off (pure wirelength cost).
    pub sink_w: Vec<f64>,
}

/// VPR's crossing-count correction for multi-terminal nets.
fn q_factor(n_terms: usize) -> f64 {
    const Q: [f64; 10] = [1.0, 1.0, 1.0, 1.0828, 1.1536, 1.2206, 1.2823, 1.3385, 1.3991, 1.4493];
    if n_terms <= 10 {
        Q[n_terms.saturating_sub(1)]
    } else {
        1.4493 + 0.02616 * (n_terms as f64 - 10.0)
    }
}

/// Net model for placement: external nets, terminal lookup, weights.
#[derive(Clone, Debug)]
pub struct NetModel {
    pub nets: Vec<ExtNet>,
    /// For each LB: indices of nets touching it.
    lb_nets: Vec<Vec<usize>>,
    /// NetId -> ExtNet index.
    net_index: HashMap<NetId, usize>,
    /// Cell -> LB index (for endpoint queries).
    cell_lb: HashMap<CellId, usize>,
}

/// Aggregate placement cost snapshot.
#[derive(Clone, Copy, Debug)]
pub struct PlacementCost {
    pub whpwl: f64,
}

impl NetModel {
    /// Identify external nets: nets whose terminals span >= 2 blocks.
    pub fn build(nl: &Netlist, packing: &Packing) -> NetModel {
        // Cell -> block mapping.
        let mut cell_lb: HashMap<CellId, usize> = HashMap::new();
        for (li, lb) in packing.lbs.iter().enumerate() {
            for &ai in &lb.alms {
                let alm = &packing.alms[ai];
                for &c in alm
                    .adder_bits
                    .iter()
                    .chain(alm.logic_luts.iter())
                    .chain(alm.ffs.iter())
                {
                    cell_lb.insert(c, li);
                }
                for paths in &alm.operand_paths {
                    for p in paths {
                        if let crate::pack::OperandPath::AbsorbedLut(l) = p {
                            cell_lb.insert(*l, li);
                        }
                    }
                }
            }
        }

        let mut nets = Vec::new();
        let mut net_index = HashMap::new();
        let mut lb_nets: Vec<Vec<usize>> = vec![Vec::new(); packing.lbs.len()];

        for (ni, net) in nl.nets.iter().enumerate() {
            let mut terms: Vec<Term> = Vec::new();
            let mut push = |t: Term, terms: &mut Vec<Term>| {
                if !terms.contains(&t) {
                    terms.push(t);
                }
            };
            if let Some((drv, _)) = net.driver {
                match nl.cells[drv as usize].kind {
                    CellKind::Input => push(Term::Io(drv), &mut terms),
                    _ => {
                        if let Some(&lb) = cell_lb.get(&drv) {
                            push(Term::Lb(lb), &mut terms);
                        }
                    }
                }
            }
            for &(sink, _) in &net.sinks {
                match nl.cells[sink as usize].kind {
                    CellKind::Output => push(Term::Io(sink), &mut terms),
                    _ => {
                        if let Some(&lb) = cell_lb.get(&sink) {
                            push(Term::Lb(lb), &mut terms);
                        }
                    }
                }
            }
            if terms.len() < 2 {
                continue; // intra-block or dangling
            }
            let idx = nets.len();
            for t in &terms {
                if let Term::Lb(lb) = t {
                    lb_nets[*lb].push(idx);
                }
            }
            net_index.insert(ni as NetId, idx);
            nets.push(ExtNet { net: ni as NetId, terms, weight: 1.0, sink_w: Vec::new() });
        }

        NetModel { nets, lb_nets, net_index, cell_lb }
    }

    pub fn num_nets(&self) -> usize {
        self.nets.len()
    }

    /// Set legacy *per-net* timing weights on the wirelength lane:
    /// `w = 1 + 8*crit^2` (sharp criticality emphasis).  Clears the
    /// per-sink lane — the two weighting schemes are exclusive.
    pub fn set_weights(&mut self, net_crit: &[f64], timing_driven: bool) {
        for en in &mut self.nets {
            let c = if timing_driven {
                net_crit.get(en.net as usize).copied().unwrap_or(0.0)
            } else {
                0.0
            };
            en.weight = 1.0 + 8.0 * c * c;
            en.sink_w.clear();
        }
    }

    /// Set the per-sink timing lane from per-terminal criticalities (the
    /// shape [`Self::fold_sink_crit`] produces): `sink_w[k] = gain *
    /// crit[i][k]^2`.  The wirelength-lane weight is reset to 1.0 — under
    /// the per-sink lane, criticality is charged per connection, not per
    /// net.  `gain == 0` (or all-zero criticality) makes every cost
    /// bit-equal to the wirelength-only model.
    pub fn set_sink_crit(&mut self, crit: &[Vec<f64>], gain: f64) {
        debug_assert_eq!(crit.len(), self.nets.len());
        for (en, c) in self.nets.iter_mut().zip(crit.iter()) {
            debug_assert_eq!(c.len(), en.terms.len().saturating_sub(1));
            en.weight = 1.0;
            en.sink_w.clear();
            en.sink_w.extend(c.iter().map(|&x| gain * x * x));
        }
    }

    /// Fold a per-sink STA arena onto this model's terminals: entry
    /// `[i][k]` aligns with `nets[i].terms[k + 1]` and is the max
    /// criticality over the netlist sinks riding that terminal (several
    /// cells in one LB can sink the same net).  This is the shape both
    /// [`Self::set_sink_crit`] and the router's per-sink weights
    /// ([`crate::route::RouteOpts::sink_crit`]) consume.  Intra-LB sinks
    /// (no routed wire) and sinks sharing the driver's terminal
    /// contribute nothing.
    pub fn fold_sink_crit(&self, idx: &NetlistIndex, sc: &SinkCrit) -> Vec<Vec<f64>> {
        self.nets
            .iter()
            .map(|en| {
                let sinks = &en.terms[1..];
                let mut out = vec![0.0f64; sinks.len()];
                // Terminal-position lookup: linear scan for typical small
                // nets, hashed for fanout-heavy ones (this runs on every
                // criticality refresh, and a linear scan per netlist sink
                // would be O(fanout^2) per net).  Terminal lists are
                // deduped by [`NetModel::build`], so the map is
                // well-defined.
                let by_term: Option<HashMap<Term, usize>> = if sinks.len() > 16 {
                    Some(sinks.iter().enumerate().map(|(k, &t)| (t, k)).collect())
                } else {
                    None
                };
                for ((cell, _pin), &c) in idx.sinks(en.net).zip(sc.net(en.net).iter()) {
                    let term = self.term_of_cell(cell).unwrap_or(Term::Io(cell));
                    let k = match &by_term {
                        Some(m) => m.get(&term).copied(),
                        None => sinks.iter().position(|&t| t == term),
                    };
                    if let Some(k) = k {
                        if c > out[k] {
                            out[k] = c;
                        }
                    }
                }
                out
            })
            .collect()
    }

    /// Zero per-terminal criticalities in the [`Self::fold_sink_crit`]
    /// shape — the smoothing state's starting point.
    pub fn zero_sink_crit(&self) -> Vec<Vec<f64>> {
        self.nets
            .iter()
            .map(|en| vec![0.0f64; en.terms.len().saturating_sub(1)])
            .collect()
    }

    /// Indices (into [`Self::nets`]) of the external nets touching LB
    /// `lb` — the median-region move's net window.
    #[inline]
    pub fn nets_of_lb(&self, lb: usize) -> &[usize] {
        &self.lb_nets[lb]
    }

    #[inline]
    fn term_loc(
        &self,
        t: Term,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> Loc {
        match t {
            Term::Lb(i) => lb_loc[i],
            Term::Io(c) => io_loc[&c],
        }
    }

    /// Full cost of one net: wirelength lane + per-sink timing lane
    /// (single source of the cost formula — [`net_bbox`] + [`bbox_cost`]
    /// + [`timing_cost`] — shared with [`IncrementalCost`]).
    #[inline]
    pub fn net_cost(&self, en: &ExtNet, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> f64 {
        bbox_cost(en, net_bbox(en, lb_loc, io_loc, &[])) + timing_cost(en, lb_loc, io_loc, &[])
    }

    /// Total cost from scratch.
    pub fn full_cost(&self, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> f64 {
        self.nets.iter().map(|en| self.net_cost(en, lb_loc, io_loc)).sum()
    }

    /// Cost delta if `moved` blocks relocate (positions not yet applied).
    pub fn move_delta(
        &self,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) -> f64 {
        let mut delta = 0.0;
        for ni in self.affected_nets(moved) {
            let en = &self.nets[ni];
            let before = bbox_cost(en, net_bbox(en, lb_loc, io_loc, &[]))
                + timing_cost(en, lb_loc, io_loc, &[]);
            let after = bbox_cost(en, net_bbox(en, lb_loc, io_loc, moved))
                + timing_cost(en, lb_loc, io_loc, moved);
            delta += after - before;
        }
        delta
    }

    /// Indices of the nets touching any moved block, deduped, in first-seen
    /// order (deterministic).
    fn affected_nets(&self, moved: &[(usize, Loc)]) -> Vec<usize> {
        let mut affected: Vec<usize> = Vec::with_capacity(16);
        for &(lb, _) in moved {
            for &ni in &self.lb_nets[lb] {
                if !affected.contains(&ni) {
                    affected.push(ni);
                }
            }
        }
        affected
    }

    /// The placeable terminal a cell belongs to (LB or its own IO pad).
    pub fn term_of_cell(&self, cell: CellId) -> Option<Term> {
        if let Some(&lb) = self.cell_lb.get(&cell) {
            return Some(Term::Lb(lb));
        }
        None
    }

    /// Source/sink locations of a net endpoint for delay estimation.
    pub fn endpoint_locs(
        &self,
        net: NetId,
        sink_cell: CellId,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> Option<(Loc, Loc)> {
        let &idx = self.net_index.get(&net)?;
        let en = &self.nets[idx];
        let src = en.terms.first()?;
        let src_loc = self.term_loc(*src, lb_loc, io_loc);
        let dst_loc = if let Some(&lb) = self.cell_lb.get(&sink_cell) {
            lb_loc[lb]
        } else if let Some(&l) = io_loc.get(&sink_cell) {
            l
        } else {
            return None;
        };
        Some((src_loc, dst_loc))
    }

    /// Export per-net bounding boxes for the PJRT kernel (bin coordinates
    /// scaled to the kernel's fixed grid).
    pub fn export_bboxes(
        &self,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        scale: f64,
        grid_max: f64,
    ) -> Vec<[f32; 5]> {
        self.nets
            .iter()
            .map(|en| {
                let mut xmin = f64::INFINITY;
                let mut xmax = 0.0f64;
                let mut ymin = f64::INFINITY;
                let mut ymax = 0.0f64;
                for &t in &en.terms {
                    let l = self.term_loc(t, lb_loc, io_loc);
                    xmin = xmin.min(l.x as f64);
                    xmax = xmax.max(l.x as f64);
                    ymin = ymin.min(l.y as f64);
                    ymax = ymax.max(l.y as f64);
                }
                [
                    ((xmin * scale).min(grid_max)) as f32,
                    ((xmax * scale).min(grid_max)) as f32,
                    ((ymin * scale).min(grid_max)) as f32,
                    ((ymax * scale).min(grid_max)) as f32,
                    (en.weight * q_factor(en.terms.len())) as f32,
                ]
            })
            .collect()
    }
}

/// Bounding box `[xmin, xmax, ymin, ymax]` of one net, with optional
/// pending-location overrides for moved blocks.
fn net_bbox(
    en: &ExtNet,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    moved: &[(usize, Loc)],
) -> [u16; 4] {
    let mut xmin = u16::MAX;
    let mut xmax = 0u16;
    let mut ymin = u16::MAX;
    let mut ymax = 0u16;
    for &t in &en.terms {
        let l = match t {
            Term::Lb(i) => moved
                .iter()
                .find(|&&(m, _)| m == i)
                .map(|&(_, l)| l)
                .unwrap_or(lb_loc[i]),
            Term::Io(c) => io_loc[&c],
        };
        xmin = xmin.min(l.x);
        xmax = xmax.max(l.x);
        ymin = ymin.min(l.y);
        ymax = ymax.max(l.y);
    }
    [xmin, xmax, ymin, ymax]
}

/// Weighted HPWL of a net given its bounding box (the wirelength lane).
#[inline]
fn bbox_cost(en: &ExtNet, bb: [u16; 4]) -> f64 {
    let span = (bb[1] - bb[0]) as f64 + (bb[3] - bb[2]) as f64;
    en.weight * q_factor(en.terms.len()) * span
}

/// Per-sink timing lane of a net: each sink terminal is charged its own
/// criticality weight times the source→sink Manhattan distance, with
/// optional pending-location overrides for moved blocks.  Exactly 0.0
/// when the lane is off (empty `sink_w`) or every weight is zero — the
/// bit-equality the all-zero-criticality contract needs.
fn timing_cost(
    en: &ExtNet,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    moved: &[(usize, Loc)],
) -> f64 {
    if en.sink_w.is_empty() {
        return 0.0;
    }
    let loc_of = |t: Term| -> Loc {
        match t {
            Term::Lb(i) => moved
                .iter()
                .find(|&&(m, _)| m == i)
                .map(|&(_, l)| l)
                .unwrap_or(lb_loc[i]),
            Term::Io(c) => io_loc[&c],
        }
    };
    let src = loc_of(en.terms[0]);
    let mut t = 0.0;
    for (&term, &w) in en.terms[1..].iter().zip(en.sink_w.iter()) {
        if w > 0.0 {
            t += w * src.dist(loc_of(term)) as f64;
        }
    }
    t
}

/// Incrementally maintained placement cost.
///
/// Caches every net's bounding box and weighted cost so a move proposal
/// evaluates only the *after* state of its affected nets against the cache
/// — [`NetModel::move_delta`] recomputes both sides per proposal, which
/// doubles the work on the (dominant at low temperature) rejected moves.
/// The cache also feeds the PJRT kernel's batched evaluation
/// ([`crate::place::kernel_accel`]) without a per-call bbox rebuild.
///
/// Contract: [`Self::total`] equals [`NetModel::full_cost`] up to f64
/// accumulation order; [`Self::refresh`] re-sums from scratch (run it
/// after weight changes, and periodically to cap drift).  Enforced by the
/// `incremental_matches_scratch_after_many_moves` test below.
#[derive(Clone, Debug)]
pub struct IncrementalCost {
    bbox: Vec<[u16; 4]>,
    /// Wirelength-lane cost per net.
    wl: Vec<f64>,
    /// Per-sink timing-lane cost per net (0.0 with the lane off).
    timing: Vec<f64>,
    wl_total: f64,
    timing_total: f64,
}

impl IncrementalCost {
    pub fn new(model: &NetModel, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> Self {
        let n = model.nets.len();
        let mut ic = IncrementalCost {
            bbox: vec![[0; 4]; n],
            wl: vec![0.0; n],
            timing: vec![0.0; n],
            wl_total: 0.0,
            timing_total: 0.0,
        };
        ic.refresh(model, lb_loc, io_loc);
        ic
    }

    /// Current total cost (wirelength lane + timing lane).
    #[inline]
    pub fn total(&self) -> f64 {
        self.wl_total + self.timing_total
    }

    /// Current wirelength-lane total alone — what the PJRT kernel's
    /// bbox-based wHPWL is comparable to (the kernel never sees the
    /// per-sink timing lane).
    #[inline]
    pub fn wl_total(&self) -> f64 {
        self.wl_total
    }

    /// Cached bounding box of net `ni`.
    #[inline]
    pub fn bbox(&self, ni: usize) -> [u16; 4] {
        self.bbox[ni]
    }

    /// Recompute every net from scratch; returns the new total.  Needed
    /// after [`NetModel::set_weights`] / [`NetModel::set_sink_crit`]
    /// (cached costs embed the weights).
    pub fn refresh(
        &mut self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> f64 {
        self.wl_total = 0.0;
        self.timing_total = 0.0;
        for (ni, en) in model.nets.iter().enumerate() {
            let bb = net_bbox(en, lb_loc, io_loc, &[]);
            let w = bbox_cost(en, bb);
            let t = timing_cost(en, lb_loc, io_loc, &[]);
            self.bbox[ni] = bb;
            self.wl[ni] = w;
            self.timing[ni] = t;
            self.wl_total += w;
            self.timing_total += t;
        }
        self.total()
    }

    /// Cost delta if `moved` blocks relocate (positions not yet applied):
    /// affected nets' new cost against the cached current cost.
    pub fn move_delta(
        &self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) -> f64 {
        let mut delta = 0.0;
        for ni in model.affected_nets(moved) {
            let en = &model.nets[ni];
            let new = bbox_cost(en, net_bbox(en, lb_loc, io_loc, moved))
                + timing_cost(en, lb_loc, io_loc, moved);
            delta += new - (self.wl[ni] + self.timing[ni]);
        }
        delta
    }

    /// Commit an accepted move.  `lb_loc` must already hold the new
    /// positions; `moved` identifies which blocks changed (their stored
    /// locations are ignored — positions are read from `lb_loc`).
    pub fn apply_move(
        &mut self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) {
        for ni in model.affected_nets(moved) {
            let en = &model.nets[ni];
            let bb = net_bbox(en, lb_loc, io_loc, &[]);
            let w = bbox_cost(en, bb);
            let t = timing_cost(en, lb_loc, io_loc, &[]);
            self.wl_total += w - self.wl[ni];
            self.timing_total += t - self.timing[ni];
            self.bbox[ni] = bb;
            self.wl[ni] = w;
            self.timing[ni] = t;
        }
    }

    /// Per-net kernel boxes from the cache (bin coordinates scaled to the
    /// kernel's fixed grid) — the batched-evaluation feed.
    pub fn export_bboxes(&self, model: &NetModel, scale: f64, grid_max: f64) -> Vec<[f32; 5]> {
        model
            .nets
            .iter()
            .zip(self.bbox.iter())
            .map(|(en, bb)| {
                [
                    ((bb[0] as f64 * scale).min(grid_max)) as f32,
                    ((bb[1] as f64 * scale).min(grid_max)) as f32,
                    ((bb[2] as f64 * scale).min(grid_max)) as f32,
                    ((bb[3] as f64 * scale).min(grid_max)) as f32,
                    (en.weight * q_factor(en.terms.len())) as f32,
                ]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn model() -> (NetModel, usize) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Cascade);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &Arch::paper(ArchVariant::Baseline), &PackOpts::default());
        let n_lbs = packing.lbs.len();
        (NetModel::build(&nl, &packing), n_lbs)
    }

    #[test]
    fn q_factor_monotone() {
        assert_eq!(q_factor(2), 1.0);
        assert!(q_factor(5) > q_factor(3));
        assert!(q_factor(20) > q_factor(10));
    }

    #[test]
    fn move_delta_matches_full_recompute() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        // Synthetic locations.
        let mut lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        let before = m.full_cost(&lb_loc, &io_loc);
        if n_lbs >= 2 {
            let moved = [(0usize, Loc::new(9, 9)), (1usize, lb_loc[0])];
            let delta = m.move_delta(&lb_loc, &io_loc, &moved);
            lb_loc[0] = Loc::new(9, 9);
            lb_loc[1] = moved[1].1;
            let after = m.full_cost(&lb_loc, &io_loc);
            assert!((before + delta - after).abs() < 1e-9,
                    "delta {delta} vs {}", after - before);
        }
    }

    /// The cached kernel-box export must match the from-scratch export the
    /// PJRT bridge used before the incremental cache existed.
    #[test]
    fn cached_bbox_export_matches_scratch() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        let lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 4 + 1) as u16, (i / 4 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 5 + 1) as u16));
                }
            }
        }
        let inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        let a = m.export_bboxes(&lb_loc, &io_loc, 1.5, 63.0);
        let b = inc.export_bboxes(&m, 1.5, 63.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            for k in 0..5 {
                assert!((x[k] - y[k]).abs() < 1e-6, "box field {k}: {} vs {}", x[k], y[k]);
            }
        }
    }

    /// The incremental cache must track a from-scratch recompute through a
    /// long random move sequence (the placer's correctness backbone).
    #[test]
    fn incremental_matches_scratch_after_many_moves() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        let mut lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        let mut inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        assert!((inc.total() - m.full_cost(&lb_loc, &io_loc)).abs() < 1e-9);
        if n_lbs == 0 {
            return;
        }
        let mut rng = crate::util::Rng::new(42);
        let mut predicted = inc.total();
        for step in 0..10_000 {
            let lb = rng.below(n_lbs);
            let to = Loc::new(rng.below(9) as u16 + 1, rng.below(9) as u16 + 1);
            let moved = [(lb, to)];
            let delta = inc.move_delta(&m, &lb_loc, &io_loc, &moved);
            lb_loc[lb] = to;
            inc.apply_move(&m, &lb_loc, &io_loc, &moved);
            predicted += delta;
            if step % 1000 == 0 {
                let scratch = m.full_cost(&lb_loc, &io_loc);
                let tol = 1e-6 * scratch.abs().max(1.0);
                assert!((inc.total() - scratch).abs() < tol,
                        "step {step}: incremental {} vs scratch {scratch}", inc.total());
                assert!((predicted - scratch).abs() < tol,
                        "step {step}: summed deltas {predicted} vs scratch {scratch}");
            }
        }
        let scratch = m.full_cost(&lb_loc, &io_loc);
        assert!((inc.total() - scratch).abs() < 1e-6 * scratch.abs().max(1.0));
        // refresh() lands on the exact scratch sum.
        let refreshed = inc.refresh(&m, &lb_loc, &io_loc);
        assert_eq!(refreshed, scratch);
    }

    /// Synthetic per-terminal criticalities in the
    /// [`NetModel::fold_sink_crit`] shape, varied per (net, sink).
    fn synth_sink_crit(m: &NetModel) -> Vec<Vec<f64>> {
        m.nets
            .iter()
            .enumerate()
            .map(|(i, en)| {
                (0..en.terms.len().saturating_sub(1))
                    .map(|k| (((i * 7 + k * 3) % 10) as f64) / 10.0)
                    .collect()
            })
            .collect()
    }

    /// The per-sink lane at zero gain — or with all-zero criticality — is
    /// *bit-equal* to the wirelength-only model (the placer's all-zero
    /// determinism contract).
    #[test]
    fn zero_sink_lane_is_wirelength_only_bitwise() {
        let (mut m, n_lbs) = model();
        let lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        m.set_weights(&[], false);
        let base = m.full_cost(&lb_loc, &io_loc);
        // Real criticalities, zero gain.
        m.set_sink_crit(&synth_sink_crit(&m), 0.0);
        assert_eq!(m.full_cost(&lb_loc, &io_loc).to_bits(), base.to_bits());
        // Zero criticalities, real gain.
        m.set_sink_crit(&m.zero_sink_crit(), 8.0);
        assert_eq!(m.full_cost(&lb_loc, &io_loc).to_bits(), base.to_bits());
        // And the incremental cache agrees lane-by-lane.
        let inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        assert_eq!(inc.total().to_bits(), base.to_bits());
        assert_eq!(inc.wl_total().to_bits(), base.to_bits());
    }

    /// With the per-sink lane on, the incremental cache still tracks the
    /// from-scratch recompute through a long random move sequence.
    #[test]
    fn incremental_tracks_scratch_with_sink_lane() {
        let (mut m, n_lbs) = model();
        if n_lbs == 0 {
            return;
        }
        let crit = synth_sink_crit(&m);
        m.set_sink_crit(&crit, 8.0);
        let mut lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        let mut inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        // The lane is actually live: timing adds cost over the wl lane.
        assert!(inc.total() > inc.wl_total(), "timing lane contributed nothing");
        let mut rng = crate::util::Rng::new(7);
        let mut predicted = inc.total();
        for step in 0..4_000 {
            let lb = rng.below(n_lbs);
            let to = Loc::new(rng.below(9) as u16 + 1, rng.below(9) as u16 + 1);
            let moved = [(lb, to)];
            let delta = inc.move_delta(&m, &lb_loc, &io_loc, &moved);
            lb_loc[lb] = to;
            inc.apply_move(&m, &lb_loc, &io_loc, &moved);
            predicted += delta;
            if step % 500 == 0 {
                let scratch = m.full_cost(&lb_loc, &io_loc);
                let tol = 1e-6 * scratch.abs().max(1.0);
                assert!((inc.total() - scratch).abs() < tol,
                        "step {step}: incremental {} vs scratch {scratch}", inc.total());
                assert!((predicted - scratch).abs() < tol,
                        "step {step}: summed deltas {predicted} vs scratch {scratch}");
            }
        }
    }

    #[test]
    fn weights_scale_cost() {
        let (mut m, n_lbs) = model();
        let lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        m.set_weights(&[], false);
        let base = m.full_cost(&lb_loc, &io_loc);
        let crit = vec![1.0; 10_000];
        m.set_weights(&crit, true);
        let weighted = m.full_cost(&lb_loc, &io_loc);
        assert!(weighted > base * 5.0);
    }
}
