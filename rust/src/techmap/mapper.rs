//! Priority-cuts K-LUT technology mapping (the ABC substitute).
//!
//! Depth-oriented cut-based mapping with area-flow tie-breaking, the
//! standard FPGA mapping recipe: enumerate up to `cuts_per_node` K-feasible
//! cuts per AND node (merging fanin cut sets), rank by (depth, area-flow),
//! then select cuts top-down from the mapping roots (POs, FF data inputs,
//! adder operands, chain carry-ins).  Selected cones become LUT cells whose
//! truth tables are computed by simulating the cone over its cut leaves.
//!
//! ## Levelized wave-parallel cut enumeration
//!
//! Cut enumeration dominates mapping time and is embarrassingly parallel
//! *within* an AIG level: a node's candidate cuts are a pure function of
//! its fanins' cut sets, and fanins always sit at strictly lower levels
//! ([`Aig::levelize`](super::aig::Aig::levelize)).  [`map_circuit_with`]
//! therefore runs one wave per level on the shared worker pool
//! ([`crate::coordinator::parallel_waves_with`]): each node merges, ranks
//! and truncates its own cut set (writes go to per-node slots), and the
//! inter-wave barrier publishes a level's results before the next level
//! reads them.  Per-node work is deterministic (stable sort over a fixed
//! candidate order), so the selected mapping — and hence the emitted
//! [`Netlist`] — is bit-identical for any worker count (enforced by
//! `rust/tests/frontend_parallel.rs`).  Cut *selection* and netlist
//! construction stay serial: they are a small top-down sweep with
//! order-dependent net numbering.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::coordinator::parallel_waves_with;
use crate::netlist::{CellKind, Netlist, NetId};
use crate::synth::circuit::Circuit;

use super::aig::{LeafKind, Lit, Node, NodeId};

/// Minimum AIG size before cut enumeration spins up worker threads;
/// smaller graphs run the waves on the calling thread (identical result).
const PAR_MIN_NODES: usize = 512;

/// Mapping options.
#[derive(Clone, Copy, Debug)]
pub struct MapOpts {
    /// Maximum LUT input count (6 for the fracturable Stratix ALM).
    pub k: u8,
    /// Priority cuts kept per node.
    pub cuts_per_node: usize,
}

impl Default for MapOpts {
    fn default() -> Self {
        MapOpts { k: 6, cuts_per_node: 8 }
    }
}

/// One cut: sorted leaf node ids (<= K of them).
#[derive(Clone, Debug, PartialEq)]
struct Cut {
    leaves: Vec<NodeId>,
    depth: u32,
    area_flow: f64,
}

/// Merge two sorted leaf sets; None if the union exceeds `k`.
fn merge_leaves(a: &[NodeId], b: &[NodeId], k: usize) -> Option<Vec<NodeId>> {
    let mut out = Vec::with_capacity(k);
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let next = match (a.get(i), b.get(j)) {
            (Some(&x), Some(&y)) if x == y => {
                i += 1;
                j += 1;
                x
            }
            (Some(&x), Some(&y)) if x < y => {
                i += 1;
                x
            }
            (Some(_), Some(&y)) => {
                j += 1;
                y
            }
            (Some(&x), None) => {
                i += 1;
                x
            }
            (None, Some(&y)) => {
                j += 1;
                y
            }
            (None, None) => break,
        };
        if out.len() == k {
            return None;
        }
        out.push(next);
    }
    Some(out)
}

/// Map a synthesized circuit to a technology-mapped netlist (serial
/// convenience wrapper over [`map_circuit_with`]).
pub fn map_circuit(circ: &Circuit, opts: &MapOpts) -> Netlist {
    map_circuit_with(circ, opts, 1)
}

/// [`map_circuit`] with cut enumeration sharded over `jobs` workers in
/// levelized waves.  Bit-identical output for any `jobs` value.
pub fn map_circuit_with(circ: &Circuit, opts: &MapOpts, jobs: usize) -> Netlist {
    let aig = &circ.aig;
    let k = opts.k as usize;
    let n = aig.len();

    // --- Mapping roots: every literal that must exist as a net. ---------
    let mut roots: Vec<Lit> = Vec::new();
    roots.extend(circ.pos.iter().map(|&(_, l)| l));
    roots.extend(circ.ffs.iter().map(|&(d, _)| d));
    for ch in &circ.chains {
        roots.push(ch.cin);
        for &(a, b) in &ch.ops {
            roots.push(a);
            roots.push(b);
        }
    }

    let fanout = aig.fanout_counts(&roots);

    // --- Cut enumeration in levelized waves (see module docs). -----------
    // Per-node results live in dense slots: a OnceLock cut set plus the
    // best (depth, area-flow) as atomics, written by the node's own job
    // and read only by strictly later waves — the inter-wave barrier
    // makes each level's writes visible before the next level runs.
    let lv = aig.levelize();
    let cuts: Vec<OnceLock<Vec<Cut>>> = (0..n).map(|_| OnceLock::new()).collect();
    let best_depth: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let best_flow: Vec<AtomicU64> =
        (0..n).map(|_| AtomicU64::new(0.0f64.to_bits())).collect();
    let depth_of = |l: NodeId| best_depth[l as usize].load(Ordering::Relaxed);
    let flow_of = |l: NodeId| f64::from_bits(best_flow[l as usize].load(Ordering::Relaxed));
    let workers = if n >= PAR_MIN_NODES { jobs.max(1) } else { 1 };
    parallel_waves_with(&lv.offsets, workers, || (), |_, i| {
        let id = lv.order[i];
        match *aig.node(id) {
            Node::Const0 | Node::Leaf(_) => {
                let _ = cuts[id as usize]
                    .set(vec![Cut { leaves: vec![id], depth: 0, area_flow: 0.0 }]);
            }
            Node::And(a, b) => {
                let ca = cuts[a.node() as usize].get().expect("fanin cuts from lower wave");
                let cb = cuts[b.node() as usize].get().expect("fanin cuts from lower wave");
                let mut cand: Vec<Cut> = Vec::with_capacity(opts.cuts_per_node * 4);
                for cut_a in ca {
                    for cut_b in cb {
                        if let Some(leaves) = merge_leaves(&cut_a.leaves, &cut_b.leaves, k) {
                            let depth =
                                1 + leaves.iter().map(|&l| depth_of(l)).max().unwrap_or(0);
                            let flow_sum: f64 = leaves.iter().map(|&l| flow_of(l)).sum();
                            let fo = fanout[id as usize].max(1) as f64;
                            cand.push(Cut {
                                leaves,
                                depth,
                                area_flow: (1.0 + flow_sum) / fo,
                            });
                        }
                    }
                }
                // The {a, b} fanin cut is always 2-feasible and guarantees
                // a non-empty candidate set even when all merges overflow K.
                {
                    let mut leaves = vec![a.node(), b.node()];
                    leaves.sort_unstable();
                    leaves.dedup();
                    let depth = 1 + leaves.iter().map(|&l| depth_of(l)).max().unwrap_or(0);
                    let flow_sum: f64 = leaves.iter().map(|&l| flow_of(l)).sum();
                    let fo = fanout[id as usize].max(1) as f64;
                    cand.push(Cut { leaves, depth, area_flow: (1.0 + flow_sum) / fo });
                }
                cand.sort_by(|x, y| {
                    x.depth
                        .cmp(&y.depth)
                        .then(x.area_flow.partial_cmp(&y.area_flow).unwrap())
                        .then(x.leaves.len().cmp(&y.leaves.len()))
                });
                cand.dedup_by(|a, b| a.leaves == b.leaves);
                cand.truncate(opts.cuts_per_node);
                best_depth[id as usize].store(cand[0].depth, Ordering::Relaxed);
                best_flow[id as usize].store(cand[0].area_flow.to_bits(), Ordering::Relaxed);
                let _ = cuts[id as usize].set(cand);
            }
        }
    });

    // --- Top-down cut selection (serial: numbering is order-dependent). --
    let mut selected: HashMap<NodeId, Vec<NodeId>> = HashMap::new();
    let mut stack: Vec<NodeId> = roots
        .iter()
        .map(|l| l.node())
        .filter(|&id| matches!(aig.node(id), Node::And(..)))
        .collect();
    while let Some(id) = stack.pop() {
        if selected.contains_key(&id) {
            continue;
        }
        let leaves =
            cuts[id as usize].get().expect("every node enumerated")[0].leaves.clone();
        for &l in &leaves {
            if matches!(aig.node(l), Node::And(..)) {
                stack.push(l);
            }
        }
        selected.insert(id, leaves);
    }

    // --- Netlist construction. -------------------------------------------
    let mut nl = Netlist::new(&circ.name);

    let mut node_net: HashMap<NodeId, NetId> = HashMap::new();
    let mut const0_net: Option<NetId> = None;
    let mut const1_net: Option<NetId> = None;
    let mut inv_net: HashMap<NodeId, NetId> = HashMap::new();

    // Primary inputs.
    let mut pi_nets: Vec<NetId> = Vec::with_capacity(circ.pis.len());
    for name in &circ.pis {
        pi_nets.push(nl.add_input(name));
    }
    // FF outputs.
    let mut ff_q_nets: Vec<NetId> = Vec::with_capacity(circ.ffs.len());
    for i in 0..circ.ffs.len() {
        ff_q_nets.push(nl.add_net(format!("ff{}__q", i)));
    }
    // Chain outputs.
    let mut chain_sum_nets: Vec<Vec<NetId>> = Vec::with_capacity(circ.chains.len());
    let mut chain_cout_nets: Vec<NetId> = Vec::with_capacity(circ.chains.len());
    for (ci, ch) in circ.chains.iter().enumerate() {
        chain_sum_nets.push(
            (0..ch.ops.len())
                .map(|p| nl.add_net(format!("ch{}_s{}", ci, p)))
                .collect(),
        );
        chain_cout_nets.push(nl.add_net(format!("ch{}_cout", ci)));
    }

    for id in 0..n as NodeId {
        if let Node::Leaf(kind) = *aig.node(id) {
            let net = match kind {
                LeafKind::Pi(i) => pi_nets[i as usize],
                LeafKind::FfQ(i) => ff_q_nets[i as usize],
                LeafKind::AdderSum { chain, pos } => {
                    chain_sum_nets[chain as usize][pos as usize]
                }
                LeafKind::AdderCout { chain } => chain_cout_nets[chain as usize],
            };
            node_net.insert(id, net);
        }
    }

    // Polarity analysis: a selected node needs its positive net when it is
    // a cut leaf of another cone or a positive root; a complemented root
    // usage gets a dedicated LUT with the complemented truth table (ABC's
    // polarity-aware mapping), not an inverter chain.
    let mut pos_need: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
    let mut neg_need: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
    for leaves in selected.values() {
        for &l in leaves {
            if matches!(aig.node(l), Node::And(..)) {
                pos_need.insert(l);
            }
        }
    }
    for r in &roots {
        if matches!(aig.node(r.node()), Node::And(..)) {
            if r.is_compl() {
                neg_need.insert(r.node());
            } else {
                pos_need.insert(r.node());
            }
        }
    }

    // Selected AND nodes in topological order get LUT cells.
    let mut order: Vec<NodeId> = selected.keys().copied().collect();
    order.sort_unstable();
    let mut neg_net: HashMap<NodeId, NetId> = HashMap::new();
    for &id in &order {
        if pos_need.contains(&id) {
            let net = nl.add_net(format!("n{}", id));
            node_net.insert(id, net);
        }
        if neg_need.contains(&id) {
            let net = nl.add_net(format!("n{}_neg", id));
            neg_net.insert(id, net);
        }
    }
    for &id in &order {
        let leaves = &selected[&id];
        let kk = leaves.len();
        let truth = cone_truth(aig, id, leaves);
        let rows = 1u32 << kk;
        let tmask: u64 = if rows == 64 { u64::MAX } else { (1u64 << rows) - 1 };
        let ins: Vec<NetId> = leaves.iter().map(|l| node_net[l]).collect();
        if let Some(&out) = node_net.get(&id).filter(|_| pos_need.contains(&id)) {
            nl.add_cell(
                CellKind::Lut { k: kk as u8, truth },
                format!("lut_n{}", id),
                ins.clone(),
                vec![out],
            );
        }
        if let Some(&out) = neg_net.get(&id) {
            nl.add_cell(
                CellKind::Lut { k: kk as u8, truth: !truth & tmask },
                format!("lut_n{}_neg", id),
                ins,
                vec![out],
            );
        }
    }

    // Materialize a net for an arbitrary literal.
    let mut net_of_lit = |nl: &mut Netlist, lit: Lit| -> NetId {
        if lit == Lit::FALSE {
            return *const0_net.get_or_insert_with(|| {
                let net = nl.add_net("const0");
                nl.add_cell(CellKind::Const(false), "gnd", vec![], vec![net]);
                net
            });
        }
        if lit == Lit::TRUE {
            return *const1_net.get_or_insert_with(|| {
                let net = nl.add_net("const1");
                nl.add_cell(CellKind::Const(true), "vcc", vec![], vec![net]);
                net
            });
        }
        if !lit.is_compl() {
            return node_net[&lit.node()];
        }
        // Complemented AND-node roots have a dedicated complement LUT.
        if let Some(&net) = neg_net.get(&lit.node()) {
            return net;
        }
        let base = node_net[&lit.node()];
        *inv_net.entry(lit.node()).or_insert_with(|| {
            let net = nl.add_net(format!("n{}_inv", lit.node()));
            nl.add_cell(
                CellKind::Lut { k: 1, truth: 0b01 },
                format!("inv_n{}", lit.node()),
                vec![base],
                vec![net],
            );
            net
        })
    };

    // Adder chains.
    for (ci, ch) in circ.chains.iter().enumerate() {
        let mut carry = net_of_lit(&mut nl, ch.cin);
        for (pos, &(a, b)) in ch.ops.iter().enumerate() {
            let a_net = net_of_lit(&mut nl, a);
            let b_net = net_of_lit(&mut nl, b);
            let sum = chain_sum_nets[ci][pos];
            let cout = if pos + 1 == ch.ops.len() {
                chain_cout_nets[ci]
            } else {
                nl.add_net(format!("ch{}_c{}", ci, pos))
            };
            nl.add_cell(
                CellKind::AdderBit { chain: ci as u32, pos: pos as u32 },
                format!("fa_{}_{}", ci, pos),
                vec![a_net, b_net, carry],
                vec![sum, cout],
            );
            carry = cout;
        }
    }
    nl.num_chains = circ.chains.len() as u32;

    // FFs.
    for (i, &(d, _)) in circ.ffs.iter().enumerate() {
        let d_net = net_of_lit(&mut nl, d);
        nl.add_cell(CellKind::Ff, format!("ff{}", i), vec![d_net], vec![ff_q_nets[i]]);
    }

    // POs.
    for (name, lit) in &circ.pos {
        let net = net_of_lit(&mut nl, *lit);
        nl.add_output(name, net);
    }

    nl
}

/// Truth table of the cone rooted at `root` over ordered cut `leaves`
/// (up to 6 leaves -> u64 truth table, leaf i = variable i).
fn cone_truth(aig: &super::aig::Aig, root: NodeId, leaves: &[NodeId]) -> u64 {
    let k = leaves.len();
    debug_assert!(k <= 6);
    let rows = 1usize << k;
    let mask: u64 = if rows == 64 { u64::MAX } else { (1u64 << rows) - 1 };
    let mut memo: HashMap<NodeId, u64> = HashMap::new();
    for (i, &l) in leaves.iter().enumerate() {
        let mut t = 0u64;
        for r in 0..rows {
            if r >> i & 1 == 1 {
                t |= 1 << r;
            }
        }
        memo.insert(l, t);
    }
    fn eval(aig: &super::super::techmap::aig::Aig, id: NodeId,
            memo: &mut HashMap<NodeId, u64>, mask: u64) -> u64 {
        if let Some(&t) = memo.get(&id) {
            return t;
        }
        let t = match *aig.node(id) {
            Node::Const0 => 0,
            Node::Leaf(_) => panic!("cone escapes its cut leaves"),
            Node::And(a, b) => {
                let ta = eval(aig, a.node(), memo, mask);
                let tb = eval(aig, b.node(), memo, mask);
                let ta = if a.is_compl() { !ta & mask } else { ta };
                let tb = if b.is_compl() { !tb & mask } else { tb };
                ta & tb
            }
        };
        memo.insert(id, t);
        t
    }
    eval(aig, root, &mut memo, mask) & mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::util::Rng;

    /// Evaluate a mapped netlist combinationally (FF-free test circuits).
    fn netlist_eval(nl: &Netlist, pi_vals: &HashMap<NetId, bool>) -> Vec<bool> {
        let mut vals: HashMap<NetId, bool> = pi_vals.clone();
        loop {
            let mut progress = false;
            let mut all_done = true;
            for cell in &nl.cells {
                if cell.outs.iter().all(|n| vals.contains_key(n)) {
                    continue;
                }
                all_done = false;
                let ins: Option<Vec<bool>> =
                    cell.ins.iter().map(|n| vals.get(n).copied()).collect();
                let Some(ins) = ins else { continue };
                match cell.kind {
                    CellKind::Lut { truth, .. } => {
                        let idx = ins
                            .iter()
                            .enumerate()
                            .fold(0usize, |acc, (i, &b)| acc | ((b as usize) << i));
                        vals.insert(cell.outs[0], truth >> idx & 1 == 1);
                    }
                    CellKind::AdderBit { .. } => {
                        let (a, b, c) = (ins[0], ins[1], ins[2]);
                        vals.insert(cell.outs[0], a ^ b ^ c);
                        vals.insert(cell.outs[1], (a & b) | (a & c) | (b & c));
                    }
                    CellKind::Const(v) => {
                        vals.insert(cell.outs[0], v);
                    }
                    CellKind::Input | CellKind::Output | CellKind::Ff => continue,
                }
                progress = true;
            }
            if all_done {
                break;
            }
            assert!(progress, "netlist evaluation stuck (combinational loop?)");
        }
        nl.outputs
            .iter()
            .map(|&c| vals[&nl.cells[c as usize].ins[0]])
            .collect()
    }

    fn check_equiv(circ: &Circuit, nl: &Netlist, samples: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let n_pi = circ.pis.len();
        for _ in 0..samples {
            let pi_vals: Vec<bool> = (0..n_pi).map(|_| rng.chance(0.5)).collect();
            let want = circ.simulate(&pi_vals, &[]);
            let mut net_vals = HashMap::new();
            for (i, &c) in nl.inputs.iter().enumerate() {
                net_vals.insert(nl.cells[c as usize].outs[0], pi_vals[i]);
            }
            let got = netlist_eval(nl, &net_vals);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn maps_xor_tree() {
        let mut c = Circuit::new("xt");
        let xs = c.pi_bus("x", 9);
        let mut acc = xs[0];
        for &x in &xs[1..] {
            acc = c.aig.xor(acc, x);
        }
        c.po("parity", acc);
        let nl = map_circuit(&c, &MapOpts::default());
        assert!(nl.check().is_empty(), "{:?}", nl.check());
        // 9-input parity in 6-LUTs: 2 LUTs.
        assert!(nl.num_luts() <= 3, "{} luts", nl.num_luts());
        check_equiv(&c, &nl, 40, 1);
    }

    #[test]
    fn maps_multiplier_all_algos() {
        for algo in [AdderAlgo::Cascade, AdderAlgo::Wallace, AdderAlgo::Dadda,
                     AdderAlgo::BinaryTree] {
            let mut c = Circuit::new("m");
            let x = c.pi_bus("x", 4);
            let y = c.pi_bus("y", 4);
            let p = soft_mul(&mut c, &x, &y, algo);
            c.po_bus("p", &p);
            let nl = map_circuit(&c, &MapOpts::default());
            assert!(nl.check().is_empty(), "{:?} ({})", nl.check(), algo.name());
            check_equiv(&c, &nl, 60, 7);
        }
    }

    #[test]
    fn respects_k_limit() {
        let mut c = Circuit::new("wide");
        let xs = c.pi_bus("x", 16);
        let mut acc = xs[0];
        for &x in &xs[1..] {
            acc = c.aig.or(acc, x);
        }
        c.po("any", acc);
        for k in [4u8, 5, 6] {
            let nl = map_circuit(&c, &MapOpts { k, cuts_per_node: 8 });
            for cell in &nl.cells {
                if let CellKind::Lut { k: kk, .. } = cell.kind {
                    assert!(kk <= k);
                }
            }
            check_equiv(&c, &nl, 20, 3);
        }
    }

    #[test]
    fn inverted_po_gets_inverter() {
        let mut c = Circuit::new("inv");
        let a = c.pi("a");
        c.po("na", a.compl());
        let nl = map_circuit(&c, &MapOpts::default());
        assert_eq!(nl.num_luts(), 1);
        check_equiv(&c, &nl, 4, 5);
    }

    #[test]
    fn shared_logic_is_not_duplicated() {
        let mut c = Circuit::new("share");
        let a = c.pi("a");
        let b = c.pi("b");
        let x = c.aig.xor(a, b);
        c.po("o1", x);
        c.po("o2", x);
        let nl = map_circuit(&c, &MapOpts::default());
        assert_eq!(nl.num_luts(), 1);
    }

    #[test]
    fn ff_boundary_maps() {
        let mut c = Circuit::new("ffb");
        let a = c.pi("a");
        let q = c.ff();
        let d = c.aig.xor(a, q);
        c.set_ff_d(q, d);
        c.po("o", q);
        let nl = map_circuit(&c, &MapOpts::default());
        assert!(nl.check().is_empty());
        assert_eq!(nl.num_ffs(), 1);
        assert_eq!(nl.num_luts(), 1);
    }
}
