//! Integration tests: cross-module behaviour over the whole flow,
//! equivalence through synth -> map, BLIF round trips on real benchmark
//! netlists, and the paper's architectural invariants end to end.

use std::collections::HashMap;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{all_suites, kratos_suite, vtr_suite, BenchParams};
use double_duty::flow::{run_flow, FlowOpts};
use double_duty::netlist::{blif, CellKind, Netlist, NetId};
use double_duty::pack::{pack, PackOpts, Unrelated};
use double_duty::place::{place, PlaceOpts};
use double_duty::report::stress_circuit;
use double_duty::synth::multiplier::{soft_mul, unrolled_mul, AdderAlgo};
use double_duty::synth::Circuit;
use double_duty::techmap::{map_circuit, MapOpts};
use double_duty::util::Rng;

/// Evaluate a combinational mapped netlist (oracle used across tests).
fn netlist_eval(nl: &Netlist, pi_vals: &HashMap<NetId, bool>) -> Vec<bool> {
    let mut vals: HashMap<NetId, bool> = pi_vals.clone();
    loop {
        let mut progress = false;
        let mut all_done = true;
        for cell in &nl.cells {
            if cell.outs.iter().all(|n| vals.contains_key(n)) {
                continue;
            }
            all_done = false;
            let ins: Option<Vec<bool>> = cell.ins.iter().map(|n| vals.get(n).copied()).collect();
            let Some(ins) = ins else { continue };
            match cell.kind {
                CellKind::Lut { truth, .. } => {
                    let idx = ins
                        .iter()
                        .enumerate()
                        .fold(0usize, |acc, (i, &b)| acc | ((b as usize) << i));
                    vals.insert(cell.outs[0], truth >> idx & 1 == 1);
                }
                CellKind::AdderBit { .. } => {
                    let (a, b, c) = (ins[0], ins[1], ins[2]);
                    vals.insert(cell.outs[0], a ^ b ^ c);
                    vals.insert(cell.outs[1], (a & b) | (a & c) | (b & c));
                }
                CellKind::Const(v) => {
                    vals.insert(cell.outs[0], v);
                }
                CellKind::Input | CellKind::Output | CellKind::Ff => continue,
            }
            progress = true;
        }
        if all_done {
            break;
        }
        assert!(progress, "stuck evaluation");
    }
    nl.outputs.iter().map(|&c| vals[&nl.cells[c as usize].ins[0]]).collect()
}

/// Property: synth -> map preserves function for every reduction algorithm
/// on randomized multiplier shapes.
#[test]
fn property_mapping_preserves_multiplier_function() {
    let mut rng = Rng::new(99);
    for trial in 0..6 {
        let w = 3 + (trial % 3);
        let algo = *rng.choose(&[
            AdderAlgo::Cascade,
            AdderAlgo::BinaryTree,
            AdderAlgo::Wallace,
            AdderAlgo::Dadda,
        ]);
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let konst = 1 + rng.below((1 << w) - 1) as u64;
        let p = unrolled_mul(&mut c, &x, konst, w, algo);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        for _ in 0..16 {
            let a = rng.below(1 << w) as u64;
            let mut pis = HashMap::new();
            for (i, &cell) in nl.inputs.iter().enumerate() {
                pis.insert(nl.cells[cell as usize].outs[0], a >> i & 1 == 1);
            }
            let out = netlist_eval(&nl, &pis);
            let got = out.iter().enumerate().fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
            let mask = (1u64 << (2 * w)) - 1;
            assert_eq!(got, (a * konst) & mask, "{a}*{konst} algo {}", algo.name());
        }
    }
}

/// Property: the baseline packer never exposes LUT outputs from adder ALMs;
/// DD5 ALM resources stay within budget on every suite circuit.
#[test]
fn property_packing_legality_across_suites() {
    let params = BenchParams::default();
    for b in all_suites(&params).into_iter().take(10) {
        let nl = map_circuit(&b.generate(), &MapOpts::default());
        for v in [ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6] {
            let p = pack(&nl, &Arch::paper(v), &PackOpts::default());
            for alm in &p.alms {
                assert!(alm.gen_inputs.len() <= 8, "{}: inputs", b.name);
                assert!(alm.z_inputs.len() <= 4, "{}: z inputs", b.name);
                assert!(alm.lut_units() <= 4, "{}: units", b.name);
                if v == ArchVariant::Baseline && alm.uses_adders() {
                    assert!(alm.logic_luts.is_empty(),
                            "{}: baseline concurrent LUT", b.name);
                }
                if v == ArchVariant::Dd5 {
                    for lut in &alm.logic_luts {
                        if let CellKind::Lut { k, .. } = nl.cells[*lut as usize].kind {
                            assert!(k <= 5 || !alm.uses_adders(),
                                    "{}: 6-LUT concurrent on DD5", b.name);
                        }
                    }
                }
            }
        }
    }
}

/// BLIF round trip over a real benchmark netlist.
#[test]
fn blif_round_trip_on_benchmark() {
    let params = BenchParams::default();
    let b = &vtr_suite(&params)[1]; // alu-like
    let nl = map_circuit(&b.generate(), &MapOpts::default());
    let text = blif::write_blif(&nl);
    let back = blif::read_blif(&text).unwrap();
    assert_eq!(back.num_luts(), nl.num_luts());
    assert_eq!(back.num_adders(), nl.num_adders());
    assert_eq!(back.num_chains, nl.num_chains);
    assert!(back.check().is_empty(), "{:?}", back.check());
}

/// Functional equivalence through Circuit::absorb (Table IV construction).
#[test]
fn absorb_preserves_function() {
    let params = BenchParams::default();
    let mut host = Circuit::new("host");
    let x = host.pi_bus("x", 3);
    let y = host.pi_bus("y", 3);
    let p = soft_mul(&mut host, &x, &y, AdderAlgo::Wallace);
    host.po_bus("p", &p);
    let n_host_pis = host.pis.len();
    let n_host_pos = host.pos.len();

    let sha = double_duty::bench_suites::vtr::sha_rounds(&params);
    let sha_pos = sha.pos.len();
    host.absorb(&sha, "sha_");
    assert_eq!(host.pos.len(), n_host_pos + sha_pos);

    // Host part still multiplies correctly with absorbed SHA present.
    let mut rng = Rng::new(5);
    for _ in 0..8 {
        let a = rng.below(8) as u64;
        let b = rng.below(8) as u64;
        let mut vals = vec![false; host.pis.len()];
        for i in 0..3 {
            vals[i] = a >> i & 1 == 1;
            vals[3 + i] = b >> i & 1 == 1;
        }
        for v in vals.iter_mut().skip(n_host_pis) {
            *v = rng.chance(0.5);
        }
        let out = host.simulate(&vals, &vec![false; host.ffs.len()]);
        let got = out[..n_host_pos]
            .iter()
            .enumerate()
            .fold(0u64, |acc, (i, &v)| acc | ((v as u64) << i));
        assert_eq!(got, a * b);
    }
}

/// Full-flow invariant: DD5 never *increases* ALM count, and concurrent
/// LUTs appear only on DD variants.
#[test]
fn flow_dd5_never_worse_in_alms() {
    let params = BenchParams::default();
    let opts = FlowOpts { seeds: vec![1], place_effort: 0.1, route: false, ..Default::default() };
    for b in kratos_suite(&params).iter().take(3) {
        let circ = b.generate();
        let base = run_flow(&circ, &Arch::coffe(ArchVariant::Baseline), &opts);
        let dd5 = run_flow(&circ, &Arch::coffe(ArchVariant::Dd5), &opts);
        assert!(dd5.alms <= base.alms, "{}: {} vs {}", b.name, dd5.alms, base.alms);
        assert_eq!(base.concurrent_luts, 0);
    }
}

/// Failure injection: placement on a device with exactly-capacity LBs must
/// still be legal (fixed-device *misfits* error instead of resizing — see
/// `rust/tests/place_timing.rs`).
#[test]
fn placement_edge_devices() {
    let circ = stress_circuit(40, 10);
    let nl = map_circuit(&circ, &MapOpts::default());
    let arch = Arch::paper(ArchVariant::Dd5);
    let packing = pack(&nl, &arch, &PackOpts { unrelated: Unrelated::On });
    // Exact-fit-ish device.
    let dev = double_duty::arch::Device::auto_size(packing.lbs.len(), packing.ios.len(), 1.0);
    let pl = place(&nl, &packing, &arch, &PlaceOpts {
        effort: 0.05,
        device: Some(dev),
        ..Default::default()
    })
    .expect("exact-fit fixed device must place legally");
    let mut seen = std::collections::HashSet::new();
    for &loc in &pl.lb_loc {
        assert!(seen.insert(loc));
    }
}

/// Determinism: identical flow options give identical results.
#[test]
fn flow_deterministic() {
    let params = BenchParams::default();
    let b = &vtr_suite(&params)[0];
    let opts = FlowOpts { seeds: vec![7], place_effort: 0.1, ..Default::default() };
    let circ = b.generate();
    let r1 = run_flow(&circ, &Arch::coffe(ArchVariant::Dd5), &opts);
    let r2 = run_flow(&circ, &Arch::coffe(ArchVariant::Dd5), &opts);
    assert_eq!(r1.alms, r2.alms);
    assert_eq!(r1.cpd_ns, r2.cpd_ns);
    assert_eq!(r1.adp, r2.adp);
}
