//! PR-3 determinism contract: the levelized wave-parallel front-end
//! (mapper cut enumeration, packer attraction scoring, STA forward /
//! backward passes) must produce bit-identical artifacts for any worker
//! count — `--jobs` is a pure scheduling knob, never a result knob.
//!
//! Also covers the levelization primitives the waves are scheduled on:
//! AIG depth grouping ([`Aig::levelize`]) and the netlist's combinational
//! level index ([`NetlistIndex`]).

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::netlist::{Netlist, NetlistIndex, PackIndex};
use double_duty::pack::{pack_with, PackOpts, Packing};
use double_duty::synth::circuit::Circuit;
use double_duty::techmap::aig::Node;
use double_duty::techmap::{map_circuit_with, MapOpts};
use double_duty::timing::sta_with;

/// The mapped representative: a real Kratos circuit, large enough that
/// the parallel paths actually engage their worker pools.
fn big_kratos() -> (Circuit, Netlist) {
    let params = BenchParams::default();
    let suite = kratos_suite(&params);
    let circ = suite[2].generate(); // gemmt
    let nl = map_circuit_with(&circ, &MapOpts::default(), 1);
    (circ, nl)
}

fn assert_netlists_identical(a: &Netlist, b: &Netlist, tag: &str) {
    assert_eq!(a.num_chains, b.num_chains, "{tag}: num_chains");
    assert_eq!(a.inputs, b.inputs, "{tag}: inputs");
    assert_eq!(a.outputs, b.outputs, "{tag}: outputs");
    assert_eq!(a.cells.len(), b.cells.len(), "{tag}: cell count");
    assert_eq!(a.nets.len(), b.nets.len(), "{tag}: net count");
    for (i, (x, y)) in a.cells.iter().zip(b.cells.iter()).enumerate() {
        assert_eq!(x.kind, y.kind, "{tag}: cell {i} kind");
        assert_eq!(x.name, y.name, "{tag}: cell {i} name");
        assert_eq!(x.ins, y.ins, "{tag}: cell {i} ins");
        assert_eq!(x.outs, y.outs, "{tag}: cell {i} outs");
    }
    for (i, (x, y)) in a.nets.iter().zip(b.nets.iter()).enumerate() {
        assert_eq!(x.name, y.name, "{tag}: net {i} name");
        assert_eq!(x.driver, y.driver, "{tag}: net {i} driver");
        assert_eq!(x.sinks, y.sinks, "{tag}: net {i} sinks");
    }
}

fn assert_packings_identical(a: &Packing, b: &Packing, tag: &str) {
    assert_eq!(a.variant, b.variant, "{tag}: variant");
    assert_eq!(a.chain_macros, b.chain_macros, "{tag}: chain_macros");
    assert_eq!(a.ios, b.ios, "{tag}: ios");
    assert_eq!(a.alms.len(), b.alms.len(), "{tag}: alm count");
    assert_eq!(a.lbs.len(), b.lbs.len(), "{tag}: lb count");
    for (i, (x, y)) in a.alms.iter().zip(b.alms.iter()).enumerate() {
        assert_eq!(x.adder_bits, y.adder_bits, "{tag}: alm {i} adder_bits");
        assert_eq!(x.operand_paths, y.operand_paths, "{tag}: alm {i} operand_paths");
        assert_eq!(x.logic_luts, y.logic_luts, "{tag}: alm {i} logic_luts");
        assert_eq!(x.logic_halves, y.logic_halves, "{tag}: alm {i} logic_halves");
        assert_eq!(x.ffs, y.ffs, "{tag}: alm {i} ffs");
        assert_eq!(x.gen_inputs, y.gen_inputs, "{tag}: alm {i} gen_inputs");
        assert_eq!(x.z_inputs, y.z_inputs, "{tag}: alm {i} z_inputs");
        assert_eq!(x.outputs, y.outputs, "{tag}: alm {i} outputs");
        assert_eq!(x.chain, y.chain, "{tag}: alm {i} chain");
    }
    for (i, (x, y)) in a.lbs.iter().zip(b.lbs.iter()).enumerate() {
        assert_eq!(x.alms, y.alms, "{tag}: lb {i} alms");
        assert_eq!(x.inputs, y.inputs, "{tag}: lb {i} inputs");
        assert_eq!(x.outputs, y.outputs, "{tag}: lb {i} outputs");
        assert_eq!(x.chains, y.chains, "{tag}: lb {i} chains");
    }
    assert_eq!(a.stats.alms, b.stats.alms, "{tag}: stats.alms");
    assert_eq!(a.stats.concurrent_luts, b.stats.concurrent_luts,
               "{tag}: stats.concurrent_luts");
    assert_eq!(a.stats.absorbed_luts, b.stats.absorbed_luts,
               "{tag}: stats.absorbed_luts");
}

/// Mapper: bit-identical netlist for jobs = 1 / 2 / 8.
#[test]
fn mapper_is_jobs_invariant() {
    let (circ, base) = big_kratos();
    assert!(base.cells.len() > 128, "representative too small to exercise waves");
    for jobs in [2usize, 8] {
        let nl = map_circuit_with(&circ, &MapOpts::default(), jobs);
        assert_netlists_identical(&base, &nl, &format!("map jobs={jobs}"));
    }
}

/// Packer: bit-identical packing for jobs = 1 / 2 / 8 on every variant.
#[test]
fn packer_is_jobs_invariant() {
    let (_, nl) = big_kratos();
    for variant in [ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6] {
        let arch = Arch::paper(variant);
        let base = pack_with(&nl, &arch, &PackOpts::default(), 1);
        for jobs in [2usize, 8] {
            let p = pack_with(&nl, &arch, &PackOpts::default(), jobs);
            assert_packings_identical(&base, &p, &format!("{variant:?} jobs={jobs}"));
        }
    }
}

/// STA: bit-identical report (cpd, arrivals, criticalities) for
/// jobs = 1 / 2 / 8, both with a synthetic and a net-dependent delay model.
#[test]
fn sta_is_jobs_invariant() {
    let (_, nl) = big_kratos();
    let arch = Arch::paper(ArchVariant::Dd5);
    let packing = pack_with(&nl, &arch, &PackOpts::default(), 1);
    let idx = NetlistIndex::build(&nl);
    let pidx = PackIndex::build(&nl, &packing);
    let delay = |net: u32, sink: u32, pin: u8| {
        90.0 + (net % 11) as f64 * 3.0 + (sink % 7) as f64 + pin as f64
    };
    let base = sta_with(&nl, &idx, &pidx, &packing, &arch, delay, 1);
    assert!(base.cpd_ps > 0.0 && base.cpd_ps.is_finite());
    for jobs in [2usize, 8] {
        let r = sta_with(&nl, &idx, &pidx, &packing, &arch, delay, jobs);
        assert_eq!(r.cpd_ps.to_bits(), base.cpd_ps.to_bits(), "cpd jobs={jobs}");
        assert_eq!(r.arrival.len(), base.arrival.len());
        for (i, (x, y)) in r.arrival.iter().zip(base.arrival.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "arrival {i} jobs={jobs}");
        }
        for (i, (x, y)) in r.net_crit.iter().zip(base.net_crit.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "net_crit {i} jobs={jobs}");
        }
        assert_eq!(r.sink_crit.len(), base.sink_crit.len());
        for (i, (x, y)) in r
            .sink_crit
            .values()
            .iter()
            .zip(base.sink_crit.values().iter())
            .enumerate()
        {
            assert_eq!(x.to_bits(), y.to_bits(), "sink_crit {i} jobs={jobs}");
        }
    }
}

/// The serial `sta` convenience wrapper and the indexed path agree.
#[test]
fn sta_wrapper_matches_indexed_path() {
    let (_, nl) = big_kratos();
    let arch = Arch::paper(ArchVariant::Baseline);
    let packing = pack_with(&nl, &arch, &PackOpts::default(), 1);
    let idx = NetlistIndex::build(&nl);
    let pidx = PackIndex::build(&nl, &packing);
    let a = double_duty::timing::sta(&nl, &packing, &arch, |_, _, _| 175.0);
    let b = sta_with(&nl, &idx, &pidx, &packing, &arch, |_, _, _| 175.0, 4);
    assert_eq!(a.cpd_ps.to_bits(), b.cpd_ps.to_bits());
    for (x, y) in a.net_crit.iter().zip(b.net_crit.iter()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// Levelization on a known AIG: a 4-input xor tree has the textbook
/// depth profile, and every wave only references strictly lower waves.
#[test]
fn levelization_on_known_aig() {
    let mut c = Circuit::new("xt");
    let pis = c.pi_bus("x", 4);
    // Balanced tree: depth(xor) = 2 AND levels per stage.
    let ab = c.aig.xor(pis[0], pis[1]);
    let cd = c.aig.xor(pis[2], pis[3]);
    let root = c.aig.xor(ab, cd);
    c.po("parity", root);
    let lv = c.aig.levelize();
    // Const0 + 4 PIs at level 0.
    assert_eq!(lv.level_nodes(0).len(), 5);
    assert_eq!(lv.level_of[ab.node() as usize], 2);
    assert_eq!(lv.level_of[cd.node() as usize], 2);
    assert_eq!(lv.level_of[root.node() as usize], 4);
    assert_eq!(lv.num_levels(), 5);
    assert_eq!(lv.order.len(), c.aig.len());
    // Wave soundness: an AND's fanins always sit in earlier waves.
    for l in 0..lv.num_levels() {
        for &id in lv.level_nodes(l) {
            if let Node::And(a, b) = *c.aig.node(id) {
                assert!((lv.level_of[a.node() as usize] as usize) < l);
                assert!((lv.level_of[b.node() as usize] as usize) < l);
            }
        }
    }
    // And on the real representative: offsets are monotone and cover.
    let (circ, nl) = big_kratos();
    let lv = circ.aig.levelize();
    assert_eq!(*lv.offsets.last().unwrap(), circ.aig.len());
    for w in lv.offsets.windows(2) {
        assert!(w[0] <= w[1]);
    }
    // Netlist-side levelization: comb edges strictly ascend.
    let idx = NetlistIndex::build(&nl);
    use double_duty::netlist::CellKind;
    for (ci, cell) in nl.cells.iter().enumerate() {
        if matches!(cell.kind, CellKind::Ff) {
            continue;
        }
        for &net in &cell.ins {
            if let Some((drv, _)) = idx.driver(net) {
                if !matches!(nl.cells[drv as usize].kind, CellKind::Ff) {
                    assert!(idx.level(drv) < idx.level(ci as u32),
                            "comb edge {drv} -> {ci} does not ascend");
                }
            }
        }
    }
}
