//! The specific circuit components of the baseline and Double-Duty ALMs.
//!
//! Each component has an explicit transistor-level structure (mux levels,
//! pass trees, buffers) sized by [`super::sizing`].  The technology unit
//! constants in [`super::rc::Tech`] are anchored so the baseline local
//! crossbar reproduces Table I (72.61 ps / 289.6 MWTA); every other number
//! is a prediction of the structural model.  Residual structure constants
//! (driver strengths, load capacitances) were tuned once against the
//! paper's published component values and are documented inline.

use super::mux::{Mux, SRAM_MWTA};
use super::rc::{elmore_ps, transistor_area_mwta, RcStage, Tech};
use super::sizing::{size_circuit, Objective};
use crate::arch::ArchVariant;

/// A sized component: its worst-path delay and per-ALM area share.
#[derive(Clone, Debug)]
pub struct SizedComponent {
    pub delay_ps: f64,
    pub area_mwta: f64,
    pub widths: Vec<f64>,
}

/// Upstream driver resistance seen by LB-input muxes (connection-block
/// output buffer, size-4 inverter).
fn xbar_drive(tech: &Tech) -> f64 {
    tech.r_inv(4.0)
}

/// Load presented by an ALM input (LUT input buffer gate + local wire).
fn alm_input_load(tech: &Tech) -> f64 {
    tech.c_inv_in(2.0) + 2.0 * tech.c_wire
}

/// Load presented by a full-adder operand input (two XOR gate fanins plus
/// the carry-generate gate — ~6 min-width gates — plus local wire).
fn adder_input_load(tech: &Tech) -> f64 {
    14.0 * tech.c_gate_min + 2.0 * tech.c_wire
}

/// Baseline local crossbar, per-ALM share: 8 general-input muxes.  The LB
/// has 60 external inputs + 40 local feedback lines at >50% population;
/// each ALM input mux spans 16 of them (two-level 4x4).  Sized for delay —
/// it sits on every LUT path.
pub fn local_crossbar(tech: &Tech) -> SizedComponent {
    let r_drv = xbar_drive(tech);
    let c_load = alm_input_load(tech);
    let eval = |w: &[f64]| {
        let mut m = Mux::new(16);
        m.w = [w[0], w[1], w[2], w[3]];
        (m.delay_ps(tech, r_drv, c_load), m.area_mwta(tech))
    };
    let w = size_circuit(4, Objective::Delay, eval);
    let (d, a_one) = eval(&w);
    SizedComponent { delay_ps: d, area_mwta: 8.0 * a_one, widths: w }
}

/// AddMux crossbar, per-ALM share: 4 Z-input muxes tapping 10 of the 60 LB
/// inputs (~17% populated).  Sized lazily (area·delay²): the Z path has
/// slack, so COFFE lets it be small and slow — the paper's Table II
/// footnote effect.
pub fn addmux_crossbar(tech: &Tech) -> SizedComponent {
    let r_drv = xbar_drive(tech);
    // Z wires feed the AddMux pass input directly, but run the full ALM
    // column height (the four Z taps serve both adder operand pairs), so
    // they carry noticeably more wire than a general input.
    let c_load = tech.c_drain_min * 1.0 + 2.5 * tech.c_wire;
    let eval = |w: &[f64]| {
        let mut m = Mux::new(10);
        m.w = [w[0], w[1], w[2], w[3]];
        (m.delay_ps(tech, r_drv, c_load), m.area_mwta(tech))
    };
    let w = size_circuit(4, Objective::AreaDelaySq, eval);
    let (d, a_one) = eval(&w);
    SizedComponent { delay_ps: d, area_mwta: 4.0 * a_one, widths: w }
}

/// The AddMux itself: per adder operand, one extra pass input onto the
/// existing adder-feed node steering Z past the LUT (4 per ALM, but the
/// incremental transistor count is tiny — the select reuses the output
/// multiplexing config).  Delay path: pass transistor from the Z wire into
/// the full-adder operand input.
pub fn addmux(tech: &Tech) -> SizedComponent {
    let c_load = adder_input_load(tech);
    // The bypass pass transistor stays minimum width — its incremental
    // cheapness is the architectural point; COFFE would not upsize a
    // device whose path (the short Z feed) has slack.
    let wp = 1.0;
    let stages = [
        // Z-wire driver (the AddMux crossbar buffer) charges the pass
        // source junction.
        RcStage { r: tech.r_inv(1.0), c: tech.c_drain_min * wp + tech.c_wire },
        // Through the pass transistor into the adder input.
        RcStage { r: tech.r_nmos(wp), c: tech.c_drain_min * wp + c_load },
    ];
    let d = elmore_ps(&stages);
    // One incremental pass transistor per adder operand (4 per ALM,
    // quarter-shared layout with the existing feed node), with the select
    // config shared across the ALM's AddMuxes and the LAB-wide arithmetic
    // mode bit (~1/20 SRAM cell attributable per ALM).
    let a = 4.0 * transistor_area_mwta(wp) * 0.25 + 0.05 * SRAM_MWTA;
    SizedComponent { delay_ps: d, area_mwta: a, widths: vec![wp] }
}

/// ALM output multiplexing, sized like every other component rather than
/// hand-widthed: the baseline pin mux is 4:1; the DD-widened pins (two on
/// DD5, all four on DD6) grow to 6:1 to expose LUT outputs concurrently
/// with the adders.  The 6:1 mux sits on every ALM output path and is
/// sized for delay; the 4:1 baseline is evaluated at the *same* widths —
/// the upgrade adds pass inputs to an existing mux whose drive sizing is
/// shared — so the returned pair's area/delay deltas isolate exactly the
/// cost of the extra inputs.  Driver: the ALM-internal output node; load:
/// the LB output driver gate plus local wire.  Returns `(4:1, 6:1)`.
pub fn output_mux_pair(tech: &Tech) -> (SizedComponent, SizedComponent) {
    let r_drv = tech.r_inv(2.0);
    let c_load = tech.c_inv_in(4.0) + 4.0 * tech.c_wire;
    let eval6 = |w: &[f64]| {
        let mut m = Mux::new(6);
        m.w = [w[0], w[1], w[2], w[3]];
        (m.delay_ps(tech, r_drv, c_load), m.area_mwta(tech))
    };
    let w = size_circuit(4, Objective::Delay, eval6);
    let (d6, a6) = eval6(&w);
    let mut m4 = Mux::new(4);
    m4.w = [w[0], w[1], w[2], w[3]];
    let d4 = m4.delay_ps(tech, r_drv, c_load);
    let a4 = m4.area_mwta(tech);
    (
        SizedComponent { delay_ps: d4, area_mwta: a4, widths: w.clone() },
        SizedComponent { delay_ps: d6, area_mwta: a6, widths: w },
    )
}

/// Raw area of the DD-variant additions *other than* the AddMux and its
/// crossbar: Z-wire restoring drivers and the reworked output muxes.
/// DD6 widens all four output muxes instead of two.  The paper publishes
/// only DD6's output-mux *delay* cost; its area contribution here is
/// derived from the sized 6:1-vs-4:1 mux pair ([`output_mux_pair`]) at
/// the same modeling detail as the DD5 components.
pub fn dd_extra_area(tech: &Tech, variant: ArchVariant) -> f64 {
    if matches!(variant, ArchVariant::Baseline) {
        return 0.0;
    }
    let t2 = transistor_area_mwta(2.0);
    let z_wiring = 4.0 * (t2 + transistor_area_mwta(tech.beta * 2.0));
    let (m4, m6) = output_mux_pair(tech);
    let per_upgrade = m6.area_mwta - m4.area_mwta;
    let n_upgrades = if matches!(variant, ArchVariant::Dd6) { 4.0 } else { 2.0 };
    z_wiring + n_upgrades * per_upgrade
}

/// Baseline ALM-input -> adder-operand path: through the feeding 4-LUT
/// (input buffer, two 2:1 pass levels, mid buffer, two more pass levels,
/// output buffer) into the adder input.  Table II path (2): 133.4 ps.
pub fn lut_to_adder_path(tech: &Tech) -> SizedComponent {
    let c_load = adder_input_load(tech);
    let eval = |w: &[f64]| {
        let [wb_in, wp_a, wb_mid, wp_b, wb_out] = [w[0], w[1], w[2], w[3], w[4]];
        let pass = |wp: f64, c_extra: f64| RcStage {
            r: tech.r_nmos(wp),
            c: 2.0 * tech.c_drain_min * wp + c_extra,
        };
        let stages = [
            // Input buffer drives the first pass level.
            RcStage { r: tech.r_inv(wb_in),
                      c: tech.c_inv_out(wb_in) + tech.c_drain_min * wp_a },
            pass(wp_a, 0.0),
            pass(wp_a, tech.c_inv_in(wb_mid)),
            // Mid buffer restores the level.
            RcStage { r: tech.r_inv(wb_mid),
                      c: tech.c_inv_out(wb_mid) + tech.c_drain_min * wp_b },
            pass(wp_b, 0.0),
            pass(wp_b, tech.c_inv_in(wb_out)),
            // Output buffer into the adder.
            RcStage { r: tech.r_inv(wb_out), c: tech.c_inv_out(wb_out) + c_load },
        ];
        let d = elmore_ps(&stages);
        // Area of the path transistors (the full LUT area is counted in
        // `alm_area`; this is only for the sizing objective).
        let a: f64 = w.iter().map(|&x| transistor_area_mwta(x)).sum();
        (d, a)
    };
    let w = size_circuit(5, Objective::Delay, eval);
    let (d, a) = eval(&w);
    SizedComponent { delay_ps: d, area_mwta: a, widths: w }
}

/// Whole-ALM area from a parts inventory.
///
/// Parts (per ALM): 4x 4-LUT (16 SRAM + 15-transistor pass tree + 3
/// buffers each), fracturing muxes, 2 full adders (28 T each), 4 FFs
/// (~24 T each), 4 output muxes, and the per-ALM local crossbar share.
/// DD variants add the AddMux, the AddMux crossbar share, Z-input wiring,
/// and wider output multiplexing (DD6 wider still).
pub fn alm_area(tech: &Tech, variant: ArchVariant) -> SizedComponent {
    let t1 = transistor_area_mwta(1.0);
    let t2 = transistor_area_mwta(2.0);

    let lut4 = 16.0 * SRAM_MWTA + 15.0 * t1 + 3.0 * (t2 + transistor_area_mwta(tech.beta * 2.0));
    let frac_muxes = 6.0 * t1 + 2.0 * SRAM_MWTA; // 5/6-LUT combining muxes
    let full_adder = 28.0 * t1;
    let ff = 24.0 * t1;
    let out_mux_base = {
        // 4:1 output mux + driver per output pin.
        let m = Mux { n_inputs: 4, n_per_group: 2, n_groups: 2, w: [1.0, 1.0, 2.0, 4.0] };
        m.area_mwta(tech)
    };
    let xbar = local_crossbar(tech).area_mwta;

    let base = 4.0 * lut4 + frac_muxes + 2.0 * full_adder + 4.0 * ff
        + 4.0 * out_mux_base + xbar;
    // DD additions (AddMux + crossbar) are calibrated per class in
    // `model_variant`; here we only report the BASE inventory plus the
    // non-anchored extras so the composition can apply class scales.
    let area = base + dd_extra_area(tech, variant);
    let _ = t2;

    SizedComponent { delay_ps: f64::NAN, area_mwta: area, widths: vec![] }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diagnostic: print raw component values (run with --nocapture while
    /// tuning technology constants).
    #[test]
    fn print_component_values() {
        let t = Tech::n20();
        let lx = local_crossbar(&t);
        let ax = addmux_crossbar(&t);
        let am = addmux(&t);
        let lp = lut_to_adder_path(&t);
        let ab = alm_area(&t, ArchVariant::Baseline);
        let a5 = alm_area(&t, ArchVariant::Dd5);
        let a6 = alm_area(&t, ArchVariant::Dd6);
        println!("local_xbar  delay {:7.2} ps  area {:8.2} (paper 72.61 / 289.6)",
                 lx.delay_ps, lx.area_mwta);
        println!("addmux_xbar delay {:7.2} ps  area {:8.2} (paper 77.05 / 77.91)",
                 ax.delay_ps, ax.area_mwta);
        println!("addmux      delay {:7.2} ps  area {:8.2} (paper 68.77 / 1.698)",
                 am.delay_ps, am.area_mwta);
        println!("lut->adder  delay {:7.2} ps              (paper 133.4)", lp.delay_ps);
        println!("alm base    area {:8.2} (paper 2167.3)", ab.area_mwta);
        println!("alm dd5     area {:8.2} (paper 2366.6)", a5.area_mwta);
        println!("alm dd6     area {:8.2}", a6.area_mwta);
    }

    #[test]
    fn dd_order_base_lt_dd5_lt_dd6() {
        let t = Tech::n20();
        let b = alm_area(&t, ArchVariant::Baseline).area_mwta;
        let d5 = alm_area(&t, ArchVariant::Dd5).area_mwta;
        let d6 = alm_area(&t, ArchVariant::Dd6).area_mwta;
        assert!(b < d5 && d5 < d6);
    }

    /// DD6 derives its output-mux area from the sized 6:1 / 4:1 pair: the
    /// wider mux must cost both area and delay, and the DD6 upgrade (4
    /// muxes) must cost exactly twice the DD5 upgrade (2 muxes) on top of
    /// the shared Z wiring.
    #[test]
    fn dd6_output_mux_sized_area_and_delay() {
        let t = Tech::n20();
        let (m4, m6) = output_mux_pair(&t);
        assert_eq!(m4.widths, m6.widths, "pair shares one drive sizing");
        assert!(m6.area_mwta > m4.area_mwta,
                "6:1 {} vs 4:1 {}", m6.area_mwta, m4.area_mwta);
        assert!(m6.delay_ps > m4.delay_ps,
                "6:1 {} ps vs 4:1 {} ps", m6.delay_ps, m4.delay_ps);
        let d5 = dd_extra_area(&t, ArchVariant::Dd5);
        let d6 = dd_extra_area(&t, ArchVariant::Dd6);
        let per_upgrade = m6.area_mwta - m4.area_mwta;
        assert!((d6 - d5 - 2.0 * per_upgrade).abs() < 1e-9);
    }

    #[test]
    fn addmux_xbar_smaller_but_slower_than_local() {
        let t = Tech::n20();
        let lx = local_crossbar(&t);
        let ax = addmux_crossbar(&t);
        assert!(ax.area_mwta < 0.5 * lx.area_mwta);
        assert!(ax.delay_ps > lx.delay_ps);
    }
}
