//! COFFE-2-like circuit-level modeling: transistor sizing over Elmore-delay
//! RC networks with minimum-width-transistor-area (MWTA) accounting.
//!
//! The real COFFE 2 sizes transistors against HSPICE on foundry models; we
//! do not have HSPICE or 20 nm decks (repro band 0/5), so this engine
//! substitutes an Elmore-delay RC evaluator with a coordinate-descent sizing
//! loop, and anchors its technology constants to the paper's published
//! component values (Table I).  Component *structures* (mux levels, LUT pass
//! trees, buffer chains) are modeled explicitly, so relative results — the
//! Z-path speedup, the DD5 area delta, the "AddMux crossbar slower than the
//! local crossbar because sizing can afford smaller transistors" effect —
//! come out of the model rather than being hard-coded.
//!
//! Regenerates Table I (component area/delay) and Table II (path delays).

pub mod mux;
pub mod rc;
pub mod sizing;
pub mod subcircuits;

use crate::arch::{AreaModel, ArchVariant, Delays};
use crate::util::Table;

pub use rc::Tech;
pub use sizing::{size_circuit, Objective};

/// Result of modeling one architecture variant.
#[derive(Clone, Debug)]
pub struct CoffeReport {
    pub variant: ArchVariant,
    pub delays: Delays,
    pub area: AreaModel,
    /// (component name, area MWTA per ALM, delay ps) — Table I rows.
    pub components: Vec<(String, f64, f64)>,
}

/// Calibration scales anchoring the Elmore/MWTA model to the paper's
/// published reference points (see module docs).  Two classes:
/// interconnect muxes (anchored on the baseline local crossbar) and
/// ALM-internal paths (anchored on the baseline LUT->adder path delay and
/// the baseline ALM area).  Everything not anchored — the AddMux, the
/// AddMux crossbar, every DD5/DD6 composition — is a *prediction*.
#[derive(Clone, Copy, Debug)]
struct Calibration {
    d_int: f64,
    d_alm: f64,
    a_int: f64,
    a_alm: f64,
}

/// Paper anchor values (Table I / Table II, baseline architecture only).
const ANCHOR_XBAR_DELAY_PS: f64 = 72.61;
const ANCHOR_XBAR_AREA_MWTA: f64 = 289.6;
const ANCHOR_LUT_ADDER_DELAY_PS: f64 = 133.4;
const ANCHOR_ALM_AREA_MWTA: f64 = 2167.3;

fn calibration(tech: &Tech) -> Calibration {
    let lx = subcircuits::local_crossbar(tech);
    let lp = subcircuits::lut_to_adder_path(tech);
    let ab = subcircuits::alm_area(tech, ArchVariant::Baseline);
    Calibration {
        d_int: ANCHOR_XBAR_DELAY_PS / lx.delay_ps,
        d_alm: ANCHOR_LUT_ADDER_DELAY_PS / lp.delay_ps,
        a_int: ANCHOR_XBAR_AREA_MWTA / lx.area_mwta,
        a_alm: ANCHOR_ALM_AREA_MWTA / ab.area_mwta,
    }
}

/// Model one architecture variant: size every subcircuit, calibrate, and
/// compose the `Delays`/`AreaModel` the CAD flow consumes.
pub fn model_variant(variant: ArchVariant) -> CoffeReport {
    let tech = Tech::n20();
    let cal = calibration(&tech);

    // Size the components (raw Elmore/MWTA values).
    let local_xbar = subcircuits::local_crossbar(&tech);
    let addmux_xbar = subcircuits::addmux_crossbar(&tech);
    let addmux = subcircuits::addmux(&tech);
    let lut_path = subcircuits::lut_to_adder_path(&tech);
    let alm = subcircuits::alm_area(&tech, variant);

    // Apply class calibration.
    let lx_d = local_xbar.delay_ps * cal.d_int;
    let lx_a = local_xbar.area_mwta * cal.a_int;
    let ax_d = addmux_xbar.delay_ps * cal.d_int;
    let ax_a = addmux_xbar.area_mwta * cal.a_int;
    let am_d = addmux.delay_ps * cal.d_alm;
    let am_a = addmux.area_mwta * cal.a_alm;
    let lp_d = lut_path.delay_ps * cal.d_alm;

    let dd = !matches!(variant, ArchVariant::Baseline);

    // Compose Table II paths.
    let mut delays = Delays::paper(variant);
    delays.lb_in_to_alm_in = lx_d;
    delays.lb_in_to_z = if dd { ax_d } else { f64::INFINITY };
    // On DD variants every LUT->adder operand additionally traverses the
    // AddMux; on baseline it does not exist.
    delays.alm_in_to_adder = if dd { lp_d + am_d } else { lp_d };
    delays.z_to_adder = if dd { am_d } else { f64::INFINITY };

    // ALM area: base inventory (+ Z wiring / output-mux rework) in the
    // ALM class, plus the interconnect-class AddMux crossbar share and the
    // AddMux itself.
    let alm_mwta = alm.area_mwta * cal.a_alm + if dd { am_a + ax_a } else { 0.0 };

    let area = AreaModel {
        alm_mwta,
        addmux_mwta: if dd { am_a } else { 0.0 },
        addmux_xbar_mwta: if dd { ax_a } else { 0.0 },
        tile_overhead_mwta: AreaModel::paper(variant).tile_overhead_mwta,
    };

    let mut components = vec![
        ("Baseline Crossbar".to_string(), lx_a, lx_d),
    ];
    if dd {
        components.push(("AddMux".to_string(), am_a, am_d));
        components.push(("AddMux Crossbar".to_string(), ax_a, ax_d));
    }
    if matches!(variant, ArchVariant::Dd6) {
        // The paper gives only DD6's output-mux delay penalty; the sized
        // 6:1 / 4:1 mux pair predicts the matching area cost (the delay
        // delta is reported for diagnosis, the STA keeps the published
        // `dd6_outmux_extra`).
        let (m4, m6) = subcircuits::output_mux_pair(&tech);
        components.push((
            "DD6 OutMux upgrade".to_string(),
            (m6.area_mwta - m4.area_mwta) * cal.a_alm,
            (m6.delay_ps - m4.delay_ps) * cal.d_alm,
        ));
    }
    components.push((format!("{} ALM", variant.name()), alm_mwta, f64::NAN));

    CoffeReport { variant, delays, area, components }
}

/// Render Table I: area and delay of the added circuit components.
pub fn table1() -> Table {
    let base = model_variant(ArchVariant::Baseline);
    let dd5 = model_variant(ArchVariant::Dd5);
    let mut t = Table::new(
        "Table I: area and delay of added circuit components (per ALM)",
        &["Circuit", "Area (MWTA)", "Delay (ps)", "Paper area", "Paper delay"],
    );
    let find = |r: &CoffeReport, name: &str| -> (f64, f64) {
        r.components
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|&(_, a, d)| (a, d))
            .unwrap_or((f64::NAN, f64::NAN))
    };
    let (am_a, am_d) = find(&dd5, "AddMux");
    let (bx_a, bx_d) = find(&base, "Baseline Crossbar");
    let (ax_a, ax_d) = find(&dd5, "AddMux Crossbar");
    t.row(&["AddMux".into(), format!("{am_a:.3}"), format!("{am_d:.2}"),
            "1.698".into(), "68.77".into()]);
    t.row(&["Baseline Crossbar".into(), format!("{bx_a:.1}"), format!("{bx_d:.2}"),
            "289.6".into(), "72.61".into()]);
    t.row(&["AddMux Crossbar".into(), format!("{ax_a:.2}"), format!("{ax_d:.2}"),
            "77.91".into(), "77.05".into()]);
    t.row(&["Baseline ALM".into(), format!("{:.1}", base.area.alm_mwta), "-".into(),
            "2167.3".into(), "-".into()]);
    let delta = (dd5.area.alm_mwta / base.area.alm_mwta - 1.0) * 100.0;
    t.row(&["DD5 ALM".into(),
            format!("{:.1} ({:+.2}% logic)", dd5.area.alm_mwta, delta),
            "-".into(), "2366.6".into(), "-".into()]);
    let tile_delta = (dd5.area.per_alm_total() / base.area.per_alm_total() - 1.0) * 100.0;
    t.row(&["DD5 tile".into(), format!("{tile_delta:+.2}%"), "-".into(),
            "+3.72%".into(), "-".into()]);
    t
}

/// Render Table II: delay impact on the named data paths.
pub fn table2() -> Table {
    let base = model_variant(ArchVariant::Baseline);
    let dd5 = model_variant(ArchVariant::Dd5);
    let mut t = Table::new(
        "Table II: delay impact of added circuits on data paths",
        &["Architecture", "Path", "Delay (ps)", "Paper (ps)"],
    );
    t.row(&["Baseline".into(), "LB input -> ALM inputs A-H".into(),
            format!("{:.2}", base.delays.lb_in_to_alm_in), "72.61".into()]);
    t.row(&["Baseline".into(), "ALM inputs A-H -> Adder input".into(),
            format!("{:.1}", base.delays.alm_in_to_adder), "133.4".into()]);
    t.row(&["Double-Duty".into(), "LB input -> ALM inputs Z1-Z4".into(),
            format!("{:.2}", dd5.delays.lb_in_to_z), "77.05".into()]);
    t.row(&["Double-Duty".into(), "ALM inputs A-H -> Adder input".into(),
            format!("{:.1}", dd5.delays.alm_in_to_adder), "202.2".into()]);
    t.row(&["Double-Duty".into(), "ALM inputs Z1-Z4 -> Adder input".into(),
            format!("{:.2}", dd5.delays.z_to_adder), "68.77".into()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The calibrated model must land near the paper's Table I/II numbers.
    #[test]
    fn near_paper_component_values() {
        let base = model_variant(ArchVariant::Baseline);
        let dd5 = model_variant(ArchVariant::Dd5);
        let close = |got: f64, want: f64, tol: f64| {
            assert!((got / want - 1.0).abs() < tol,
                    "got {got:.2}, want {want:.2}");
        };
        close(base.delays.lb_in_to_alm_in, 72.61, 0.10);
        close(base.delays.alm_in_to_adder, 133.4, 0.10);
        close(dd5.delays.lb_in_to_z, 77.05, 0.10);
        close(dd5.delays.z_to_adder, 68.77, 0.10);
        close(dd5.delays.alm_in_to_adder, 202.2, 0.10);
        close(base.area.alm_mwta, 2167.3, 0.10);
        close(dd5.area.alm_mwta, 2366.6, 0.10);
    }

    /// Structural effects the paper calls out must hold.
    #[test]
    fn structural_effects() {
        let base = model_variant(ArchVariant::Baseline);
        let dd5 = model_variant(ArchVariant::Dd5);
        // Z path roughly halves the adder feed delay.
        assert!(dd5.delays.z_to_adder < 0.6 * base.delays.alm_in_to_adder);
        // DD5 ALM is bigger, but by less than 10%.
        let ratio = dd5.area.alm_mwta / base.area.alm_mwta;
        assert!(ratio > 1.0 && ratio < 1.12, "ratio {ratio}");
        // AddMux crossbar is much smaller than the local crossbar yet slower
        // (COFFE sizes it lazily because the Z path has slack).
        let (_, bx_a, bx_d) = &base.components[0];
        let ax = dd5.components.iter().find(|(n, _, _)| n == "AddMux Crossbar").unwrap();
        assert!(ax.1 < 0.5 * bx_a);
        assert!(ax.2 > *bx_d);
    }

    /// DD6's refined output-mux modeling: the sized-mux area/delay deltas
    /// are reported as a component, at DD5's level of detail.
    #[test]
    fn dd6_outmux_component_reported() {
        let dd6 = model_variant(ArchVariant::Dd6);
        let c = dd6
            .components
            .iter()
            .find(|(n, _, _)| n == "DD6 OutMux upgrade")
            .expect("DD6 reports its output-mux upgrade");
        assert!(c.1 > 0.0, "area delta {}", c.1);
        assert!(c.2 > 0.0, "delay delta {}", c.2);
        let dd5 = model_variant(ArchVariant::Dd5);
        assert!(dd5.components.iter().all(|(n, _, _)| n != "DD6 OutMux upgrade"));
        // DD6's ALM stays bigger than DD5's under the refined model.
        assert!(dd6.area.alm_mwta > dd5.area.alm_mwta);
    }

    #[test]
    fn tables_render() {
        let t1 = table1().render();
        assert!(t1.contains("AddMux"));
        let t2 = table2().render();
        assert!(t2.contains("Z1-Z4"));
    }
}
