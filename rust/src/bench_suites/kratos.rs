//! Kratos-like unrolled-DNN benchmark generators.
//!
//! Kratos circuits are fully-unrolled DNN layers: every weight is a
//! compile-time constant, so each multiply becomes shifted partial-product
//! rows (selector-bit elision drops zero rows), and sparsity simply removes
//! multiplies.  This makes the circuits adder-chain dominated — exactly the
//! profile Double-Duty targets.

use crate::synth::multiplier::unrolled_mul;
use crate::synth::{reduce_rows, Circuit};
use crate::techmap::aig::Lit;
use crate::util::Rng;

use super::BenchParams;

/// Random non-zero `w`-bit weight, or 0 with probability `sparsity`.
fn weight(rng: &mut Rng, p: &BenchParams) -> u64 {
    if rng.chance(p.sparsity) {
        0
    } else {
        1 + rng.below((1 << p.width) - 1) as u64
    }
}

/// Multiply-accumulate a set of (input bus, weight) pairs into one output.
fn mac(c: &mut Circuit, taps: &[(Vec<Lit>, u64)], p: &BenchParams) -> Vec<Lit> {
    let rows: Vec<Vec<Lit>> = taps
        .iter()
        .filter(|(_, w)| *w != 0)
        .map(|(x, w)| unrolled_mul(c, x, *w, p.width, p.algo))
        .collect();
    if rows.is_empty() {
        return vec![Lit::FALSE];
    }
    reduce_rows(c, rows, p.algo)
}

/// 1-D convolution layer: `ch` channels, kernel size 3, `n` output taps.
pub fn conv1d(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("conv1d", p);
    let mut rng = Rng::new(p.seed);
    let n = 6 * p.scale;
    let ch = 2;
    let ksize = 3;
    let inputs: Vec<Vec<Lit>> = (0..n + ksize - 1)
        .map(|i| c.pi_bus(&format!("x{i}"), p.width))
        .collect();
    for o in 0..n {
        for chan in 0..ch {
            let taps: Vec<(Vec<Lit>, u64)> = (0..ksize)
                .map(|k| (inputs[o + k].clone(), weight(&mut rng, p)))
                .collect();
            let y = mac(&mut c, &taps, p);
            c.po_bus(&format!("y{o}_{chan}"), &y);
        }
    }
    c
}

/// 2-D convolution: 3x3 kernel over a small feature map, 2 filters.
pub fn conv2d(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("conv2d", p);
    let mut rng = Rng::new(p.seed ^ 0xc2d);
    let side = 3 + p.scale;
    let filters = 2;
    let img: Vec<Vec<Vec<Lit>>> = (0..side + 2)
        .map(|r| {
            (0..side + 2)
                .map(|cc| c.pi_bus(&format!("px{r}_{cc}"), p.width))
                .collect()
        })
        .collect();
    for f in 0..filters {
        let kernel: Vec<u64> = (0..9).map(|_| weight(&mut rng, p)).collect();
        for r in 0..side {
            for col in 0..side {
                let taps: Vec<(Vec<Lit>, u64)> = (0..9)
                    .map(|k| (img[r + k / 3][col + k % 3].clone(), kernel[k]))
                    .collect();
                let y = mac(&mut c, &taps, p);
                c.po_bus(&format!("f{f}_y{r}_{col}"), &y);
            }
        }
    }
    c
}

/// GEMM with transposed (constant) weight matrix: y = W x.
pub fn gemmt(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("gemmt", p);
    let mut rng = Rng::new(p.seed ^ 0x6e44);
    let n = 4 + 2 * p.scale; // output rows
    let m = 6; // input length
    let x: Vec<Vec<Lit>> = (0..m).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    for r in 0..n {
        let taps: Vec<(Vec<Lit>, u64)> =
            (0..m).map(|i| (x[i].clone(), weight(&mut rng, p))).collect();
        let y = mac(&mut c, &taps, p);
        c.po_bus(&format!("y{r}"), &y);
    }
    c
}

/// GEMM, smaller/denser variant (gemms in Kratos).
pub fn gemms(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("gemms", p);
    let mut rng = Rng::new(p.seed ^ 0x6e55);
    let n = 3 + p.scale;
    let m = 4;
    let x: Vec<Vec<Lit>> = (0..m).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    for r in 0..n {
        for r2 in 0..2 {
            let taps: Vec<(Vec<Lit>, u64)> =
                (0..m).map(|i| (x[i].clone(), weight(&mut rng, p))).collect();
            let y = mac(&mut c, &taps, p);
            c.po_bus(&format!("y{r}_{r2}"), &y);
        }
    }
    c
}

/// Depthwise convolution: one kernel per channel, no cross-channel sum.
pub fn dwconv(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("dwconv", p);
    let mut rng = Rng::new(p.seed ^ 0xd3c);
    let chans = 3 + p.scale;
    let taps_n = 3;
    for ch in 0..chans {
        let xs: Vec<Vec<Lit>> = (0..taps_n + 2)
            .map(|i| c.pi_bus(&format!("c{ch}x{i}"), p.width))
            .collect();
        for o in 0..3 {
            let taps: Vec<(Vec<Lit>, u64)> = (0..taps_n)
                .map(|k| (xs[o + k].clone(), weight(&mut rng, p)))
                .collect();
            let y = mac(&mut c, &taps, p);
            c.po_bus(&format!("c{ch}y{o}"), &y);
        }
    }
    c
}

/// Tiny MLP layer: dense matrix then ReLU-ish threshold logic.
pub fn mlp(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("mlp", p);
    let mut rng = Rng::new(p.seed ^ 0x3117);
    let n_in = 5;
    let n_out = 3 + p.scale;
    let x: Vec<Vec<Lit>> = (0..n_in).map(|i| c.pi_bus(&format!("x{i}"), p.width)).collect();
    for o in 0..n_out {
        let taps: Vec<(Vec<Lit>, u64)> =
            (0..n_in).map(|i| (x[i].clone(), weight(&mut rng, p))).collect();
        let y = mac(&mut c, &taps, p);
        // ReLU on the sign-ish MSB: mask outputs by NOT(msb).
        let msb = *y.last().unwrap();
        let gated: Vec<Lit> = y.iter().map(|&b| c.aig.and(b, msb.compl())).collect();
        c.po_bus(&format!("y{o}"), &gated);
    }
    c
}

/// Max-pool-ish reduction: comparators + adders (mixed profile).
pub fn pool(p: &BenchParams) -> Circuit {
    let mut c = super::new_circuit("pool", p);
    let n = 4 * p.scale;
    for g in 0..n {
        let a = c.pi_bus(&format!("a{g}"), p.width);
        let b = c.pi_bus(&format!("b{g}"), p.width);
        // a + b (hard chain) and max(a, b) (LUT logic).
        let s = c.ripple_add(&a, &b);
        c.po_bus(&format!("sum{g}"), &s);
        // Greater-than comparator chain in soft logic.
        let mut gt = Lit::FALSE;
        let mut eq = Lit::TRUE;
        for i in (0..p.width).rev() {
            let bit_gt = c.aig.and(a[i], b[i].compl());
            let t = c.aig.and(eq, bit_gt);
            gt = c.aig.or(gt, t);
            let x = c.aig.xor(a[i], b[i]);
            eq = c.aig.and(eq, x.compl());
        }
        let mx: Vec<Lit> = (0..p.width).map(|i| c.aig.mux(gt, a[i], b[i])).collect();
        c.po_bus(&format!("max{g}"), &mx);
    }
    c
}
