//! End-to-end CAD flow orchestration: synth -> map -> pack -> place ->
//! route -> STA, with multi-seed averaging (the paper runs 3 seeds per
//! experiment) and the metric set every table/figure consumes.
//!
//! The flow is factored into grid-job primitives so the serial path here
//! and the parallel experiment engine ([`engine`]) share one code path and
//! therefore produce bit-identical results:
//!
//! * [`arch_for_run`] — per-run architecture overrides,
//! * [`place_route_seed`] — one (circuit, variant, seed) cell, reading
//!   the shared dense index arenas (and, in the closed timing loop, the
//!   previous seed's achieved-CPD prior) through a [`SeedCtx`],
//! * [`assemble_result`] — fixed-order seed reduction into a
//!   [`FlowResult`].
//!
//! ## Cross-seed place↔route feedback
//!
//! With `--timing-route`, seeds of one (circuit, variant) cell form a
//! chain: each seed's achieved post-route CPD feeds the *next* seed as a
//! criticality prior ([`SeedCtx::cpd_prior_ps`] →
//! [`crate::timing::rescale_crit`]), so both the placer's per-sink lane
//! and the router's seed weights optimize toward the CPD routing actually
//! delivers rather than the pre-route estimate.  The chain runs in fixed
//! seed order in both the serial path and the engine, so results stay
//! bit-identical between them.

pub mod diskcache;
pub mod engine;

use crate::arch::device::Device;
use crate::arch::{Arch, ArchVariant};
use crate::bench_suites::Benchmark;
use crate::check::{self, CheckMode};
use crate::netlist::{Netlist, NetlistIndex, PackIndex};
use crate::pack::{pack, PackOpts, Packing, Unrelated};
use crate::place::{place_with, PlaceOpts};
use crate::route::{
    route, route_timing, routed_net_delay, term_sink_crit, LookaheadMode, RouteOpts, TimingCtx,
};
use crate::rrg::{lookahead::Lookahead, RrGraph};
use crate::synth::Circuit;
use crate::techmap::{map_circuit, MapOpts};
use crate::timing::sta_routed;
use crate::util::stats::mean;

/// Flow options.
#[derive(Clone, Debug)]
pub struct FlowOpts {
    pub seeds: Vec<u64>,
    pub place_effort: f64,
    pub unrelated: Unrelated,
    pub route: bool,
    /// Worker threads inside each PathFinder run (`--route-jobs`; results
    /// are bit-identical for any value — see `rust/tests/route_parallel.rs`).
    pub route_jobs: usize,
    /// Timing-driven routing (`--timing-route`): seed the router with
    /// per-sink criticalities from a pre-route STA and, with
    /// [`FlowOpts::sta_every`] > 0, close the loop by re-running STA
    /// against the evolving routing between PathFinder iterations.  Off
    /// by default: figures are unchanged unless requested.
    pub route_timing_weights: bool,
    /// With `route_timing_weights`: refresh criticalities from an STA
    /// over the partial routing every this many PathFinder iterations
    /// (`--sta-every K`; `0` keeps the static pre-route weights).
    pub sta_every: usize,
    /// Criticality smoothing factor for the closed loop
    /// (`--crit-alpha A`; `crit' = A*new + (1-A)*old`).
    pub crit_alpha: f64,
    /// Smoothing factor for the *placer's* per-sink criticality refresh
    /// (`--place-crit-alpha`), matching the router's recurrence.
    pub place_crit_alpha: f64,
    /// Annealer move-type mix scale in [0, 1] (`--move-mix`): scales the
    /// temperature-scheduled macro-shift / median-move probabilities;
    /// `0.0` proposes uniform swaps only.
    pub move_mix: f64,
    pub use_kernel: bool,
    /// Fixed device (Table IV stress); `None` auto-sizes per design.
    pub device: Option<Device>,
    pub channel_width: Option<u16>,
    /// Run the stage auditors ([`crate::check`]) on each artifact as the
    /// flow produces it (`--check [strict]`).  [`CheckMode::Warn`] prints
    /// violations and continues; [`CheckMode::Strict`] fails the run.
    /// Deliberately *not* part of the engine's cache keys: auditing never
    /// changes an artifact, so checked and unchecked runs may share them.
    pub check: CheckMode,
    /// Router A* lookahead (`--lookahead on|off`, default on): guide each
    /// sink's search with the per-device class-distance map and route
    /// sinks in criticality order (see [`crate::rrg::lookahead`]).  `false`
    /// reproduces the pre-lookahead router bit-for-bit.  Part of the
    /// engine's CPD-prior cache key — the two modes route differently.
    pub lookahead: bool,
}

impl Default for FlowOpts {
    fn default() -> Self {
        FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.5,
            unrelated: Unrelated::Auto,
            route: true,
            route_jobs: 1,
            route_timing_weights: false,
            sta_every: 4,
            crit_alpha: 0.5,
            place_crit_alpha: 0.5,
            move_mix: 1.0,
            use_kernel: false,
            device: None,
            channel_width: None,
            check: CheckMode::Off,
            lookahead: true,
        }
    }
}

/// Metrics of one flow run (averaged over seeds).
#[derive(Clone, Debug)]
pub struct FlowResult {
    pub name: String,
    pub variant: ArchVariant,
    pub luts: usize,
    pub adder_bits: usize,
    pub alms: usize,
    pub lbs: usize,
    pub concurrent_luts: usize,
    /// ALM area in MWTA (alms x per-variant ALM area — the paper's "Total
    /// ALM Area" of Table IV).
    pub alm_area_mwta: f64,
    /// Critical path delay, ns (post-route when routed).
    pub cpd_ns: f64,
    /// Area-delay product (MWTA x ns).
    pub adp: f64,
    pub fmax_mhz: f64,
    pub routed_ok: bool,
    pub route_iters: f64,
    /// Channel-utilization samples for Fig. 8: per routing channel, the
    /// utilization averaged element-wise across seeds (every seed routes
    /// the same deterministic device, so the sample vectors align).
    pub channel_util: Vec<f64>,
    /// Closed-loop timing trajectory (ns): achieved critical-path delay
    /// at each inter-iteration STA refresh, with the final post-route CPD
    /// appended — averaged element-wise across seeds when the per-seed
    /// traces align, else the first seed's trace.  Empty unless
    /// [`FlowOpts::route_timing_weights`] is on.
    pub cpd_trace_ns: Vec<f64>,
    pub dedup_hits: usize,
}

/// Outcome of the place/route stage for one seed — the unit of work the
/// experiment engine schedules.
#[derive(Clone, Debug)]
pub struct SeedMetrics {
    pub seed: u64,
    /// Critical-path delay in ns (post-route when routed, else the
    /// placer's estimate).
    pub cpd_ns: f64,
    pub routed_ok: bool,
    /// Router convergence iterations (`None` when routing was skipped).
    pub route_iters: Option<f64>,
    /// Per-channel utilization samples (empty when routing was skipped).
    pub channel_util: Vec<f64>,
    /// Closed-loop CPD trajectory in ns (refresh points + final; empty
    /// for timing-oblivious runs).
    pub cpd_trace_ns: Vec<f64>,
}

/// Apply per-run architecture overrides (channel width).  Shared by the
/// serial flow and the experiment engine so both pack and route against
/// identical architectures.
pub fn arch_for_run(arch: &Arch, opts: &FlowOpts) -> Arch {
    let mut arch = arch.clone();
    if let Some(w) = opts.channel_width {
        arch.routing.channel_width = w;
    }
    arch
}

/// Per-seed shared context: the dense index arenas (built once per
/// (netlist, packing) and shared read-only across seeds — by the engine,
/// through its artifact cache) plus the cross-seed feedback prior.
pub struct SeedCtx<'a> {
    pub idx: &'a NetlistIndex,
    pub pidx: &'a PackIndex,
    /// Achieved post-route CPD (ps) of the previous seed in the cell's
    /// chain; `None` for the first seed or timing-oblivious runs.  Fed to
    /// the placer ([`PlaceOpts::cpd_prior_ps`]) and into the router's
    /// seed criticalities via [`crate::timing::rescale_crit`].
    pub cpd_prior_ps: Option<f64>,
    /// Artifact cache to fetch the router's per-device lookahead map
    /// through (memo + disk; see [`engine::ArtifactCache::lookahead`]).
    /// `None` falls back to the process-global memo — results are
    /// identical either way, the cache only adds the on-disk layer.
    pub la_cache: Option<&'a engine::ArtifactCache>,
}

impl<'a> SeedCtx<'a> {
    /// Context with no feedback prior and no artifact cache.
    pub fn new(idx: &'a NetlistIndex, pidx: &'a PackIndex) -> SeedCtx<'a> {
        SeedCtx { idx, pidx, cpd_prior_ps: None, la_cache: None }
    }
}

/// Place (and optionally route + STA) one seed of an already-packed
/// design.  Deterministic in (inputs, seed, prior): the only RNG is
/// constructed here from `seed`, so scheduling order cannot perturb
/// results.  Panics if a caller-fixed device cannot fit the design — the
/// placer's hardened sizing contract surfaces instead of quietly
/// measuring a larger grid.
pub fn place_route_seed(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &FlowOpts,
    seed: u64,
    ctx: &SeedCtx,
) -> SeedMetrics {
    // `--check`: audit the upstream artifacts once per seed cell (cheap
    // linear scans), then each artifact this cell produces right after
    // its stage.  Strict mode panics inside `enforce`.
    if opts.check != CheckMode::Off {
        check::enforce(opts.check, "netlist", &check::audit_netlist(nl, ctx.idx));
        check::enforce(opts.check, "pack", &check::audit_packing(nl, packing, arch));
    }
    let pl = place_with(
        nl,
        packing,
        arch,
        &PlaceOpts {
            seed,
            effort: opts.place_effort,
            timing_driven: true,
            crit_alpha: opts.place_crit_alpha,
            move_mix: opts.move_mix,
            cpd_prior_ps: ctx.cpd_prior_ps,
            sta_jobs: opts.route_jobs.max(1),
            use_kernel: opts.use_kernel,
            device: opts.device.clone(),
            ..Default::default()
        },
        ctx.idx,
        ctx.pidx,
    )
    .unwrap_or_else(|e| panic!("placement failed (seed {seed}): {e}"));
    if opts.check != CheckMode::Off {
        check::enforce(opts.check, "place", &check::audit_placement(packing, &pl));
    }
    if opts.route {
        let mut model = crate::place::cost::NetModel::build(nl, packing);
        model.set_weights(&[], false);
        let route_jobs = opts.route_jobs.max(1);
        // Resolve the router lookahead once per seed, against the now
        // known device: through the engine's artifact cache when one is
        // plumbed (adds the disk layer), else the process-global memo.
        // Either way the map is built at most once per (device, arch).
        let la: Option<std::sync::Arc<Lookahead>> = if opts.lookahead {
            Some(match ctx.la_cache {
                Some(cache) => cache.lookahead(&pl.device, arch),
                None => crate::rrg::lookahead::shared(&RrGraph::build(&pl.device, arch)),
            })
        } else {
            None
        };
        if opts.check != CheckMode::Off {
            if let Some(m) = &la {
                let graph = RrGraph::build(&pl.device, arch);
                check::enforce(
                    opts.check,
                    "lookahead",
                    &check::audit_lookahead(&graph, m),
                );
            }
        }
        let la_mode = match &la {
            Some(m) => LookaheadMode::Shared(m.clone()),
            None => LookaheadMode::Off,
        };
        let (r, rpt) = if opts.route_timing_weights {
            // Timing-driven: a pre-route STA over the placed distance
            // estimates seeds per-sink criticality weights — re-normalized
            // against the previous seed's achieved CPD when the chain
            // carries one — and (with sta_every > 0) the router closes the
            // loop by refreshing them from STA runs against the evolving
            // routing.  The index arenas come prebuilt through `ctx` and
            // are shared with every refresh.
            let idx = ctx.idx;
            let pidx = ctx.pidx;
            let rpt = crate::timing::sta_with(
                nl,
                idx,
                pidx,
                packing,
                arch,
                |net, sink, _| {
                    crate::place::net_endpoint_delay(
                        &model, &pl.lb_loc, &pl.io_loc, arch, net, sink,
                    )
                },
                route_jobs,
            );
            let mut sink_crit = term_sink_crit(&model, idx, &rpt.sink_crit);
            crate::timing::rescale_crit(&mut sink_crit, rpt.cpd_ps, ctx.cpd_prior_ps);
            let ropts = RouteOpts {
                jobs: route_jobs,
                sink_crit,
                lookahead: la_mode.clone(),
                ..RouteOpts::default()
            };
            let ctx = TimingCtx {
                nl,
                idx,
                pidx,
                packing,
                sta_every: opts.sta_every,
                crit_alpha: opts.crit_alpha,
                sta_jobs: route_jobs,
            };
            let r = route_timing(&model, &pl, arch, &ropts, &ctx);
            // Final post-route report over the SAME prebuilt arenas (and
            // sharded like the refreshes) — `sta_routed` would rebuild
            // both indexes from scratch per seed.  Identical result: the
            // index build is deterministic and STA is jobs-invariant.
            let rpt = crate::timing::sta_with(
                nl,
                idx,
                pidx,
                packing,
                arch,
                routed_net_delay(&r, &model, arch),
                route_jobs,
            );
            (r, rpt)
        } else {
            let ropts = RouteOpts {
                jobs: route_jobs,
                lookahead: la_mode.clone(),
                ..RouteOpts::default()
            };
            let r = route(&model, &pl, arch, &ropts);
            let rpt = sta_routed(nl, packing, arch, &r, &model);
            (r, rpt)
        };
        if opts.check != CheckMode::Off {
            check::enforce(opts.check, "route", &check::audit_routing(&model, &pl, arch, &r));
            check::enforce(opts.check, "timing", &check::audit_timing(nl, ctx.idx, &rpt));
        }
        let cpd_trace_ns = if opts.route_timing_weights {
            let mut t: Vec<f64> = r.cpd_trace.iter().map(|c| c / 1000.0).collect();
            t.push(rpt.cpd_ps / 1000.0);
            t
        } else {
            Vec::new()
        };
        SeedMetrics {
            seed,
            cpd_ns: rpt.cpd_ps / 1000.0,
            routed_ok: r.success,
            route_iters: Some(r.iterations as f64),
            channel_util: r.channel_util,
            cpd_trace_ns,
        }
    } else {
        SeedMetrics {
            seed,
            cpd_ns: pl.est_cpd_ps / 1000.0,
            routed_ok: true,
            route_iters: None,
            channel_util: Vec::new(),
            cpd_trace_ns: Vec::new(),
        }
    }
}

/// Run every seed of one (netlist, packing, arch) cell in fixed seed
/// order over shared index arenas, chaining each seed's achieved
/// post-route CPD into the next seed's criticality prior when the closed
/// timing loop is on (`route && route_timing_weights`; timing-oblivious
/// runs carry no prior).  This is the single definition of the cross-seed
/// feedback chain — the serial flow, the cached benchmark runner, and the
/// engine's cell jobs all call it, so the bit-identity contract between
/// them cannot drift.  `record(si, cpd_ps)` observes each *successfully
/// routed* chained seed's achieved CPD (the engine writes these into its
/// artifact cache as the provenance trail; pass a no-op elsewhere);
/// failed routes neither feed the chain nor get recorded.
#[allow(clippy::too_many_arguments)]
pub fn chain_seeds(
    nl: &Netlist,
    packing: &Packing,
    arch: &Arch,
    opts: &FlowOpts,
    idx: &NetlistIndex,
    pidx: &PackIndex,
    la_cache: Option<&engine::ArtifactCache>,
    mut record: impl FnMut(usize, f64),
) -> Vec<SeedMetrics> {
    let chained = opts.route && opts.route_timing_weights;
    let mut prior: Option<f64> = None;
    let mut out = Vec::with_capacity(opts.seeds.len());
    for (si, &seed) in opts.seeds.iter().enumerate() {
        let ctx = SeedCtx { idx, pidx, cpd_prior_ps: prior, la_cache };
        let m = place_route_seed(nl, packing, arch, opts, seed, &ctx);
        // Only a *legally routed* seed feeds the chain: a CPD measured
        // over a failed (still-overused) routing is not an achieved
        // result and must not poison the next seed's criticalities or
        // the provenance record.
        if chained && m.routed_ok {
            let achieved = m.cpd_ns * 1000.0;
            record(si, achieved);
            prior = Some(achieved);
        }
        out.push(m);
    }
    out
}

/// Reduce per-seed metrics (in seed order) into the averaged result.
pub fn assemble_result(
    name: &str,
    arch: &Arch,
    packing: &Packing,
    seeds: &[SeedMetrics],
    dedup_hits: usize,
) -> FlowResult {
    let cpds: Vec<f64> = seeds.iter().map(|s| s.cpd_ns).collect();
    let iters: Vec<f64> = seeds.iter().filter_map(|s| s.route_iters).collect();
    let routed_ok = seeds.iter().all(|s| s.routed_ok);

    // Channel utilization: element-wise mean across seeds.  All seeds
    // route the same (deterministically sized) device, so sample vectors
    // align; if they ever did not, fall back to pooling the raw samples
    // rather than silently dropping data.
    let with_samples: Vec<&Vec<f64>> = seeds
        .iter()
        .map(|s| &s.channel_util)
        .filter(|v| !v.is_empty())
        .collect();
    let channel_util = match with_samples.first() {
        None => Vec::new(),
        Some(first) if with_samples.iter().all(|v| v.len() == first.len()) => {
            let mut acc = vec![0.0f64; first.len()];
            for v in &with_samples {
                for (a, &x) in acc.iter_mut().zip(v.iter()) {
                    *a += x;
                }
            }
            let n = with_samples.len() as f64;
            acc.iter_mut().for_each(|x| *x /= n);
            acc
        }
        Some(_) => with_samples.iter().flat_map(|v| v.iter().copied()).collect(),
    };

    // Closed-loop CPD trajectory: element-wise mean across seeds when the
    // per-seed traces align (same refresh count), else the first seed's.
    let with_traces: Vec<&Vec<f64>> = seeds
        .iter()
        .map(|s| &s.cpd_trace_ns)
        .filter(|v| !v.is_empty())
        .collect();
    let cpd_trace_ns = match with_traces.first() {
        None => Vec::new(),
        Some(first) if with_traces.iter().all(|v| v.len() == first.len()) => {
            let mut acc = vec![0.0f64; first.len()];
            for v in &with_traces {
                for (a, &x) in acc.iter_mut().zip(v.iter()) {
                    *a += x;
                }
            }
            let n = with_traces.len() as f64;
            acc.iter_mut().for_each(|x| *x /= n);
            acc
        }
        Some(first) => (*first).clone(),
    };

    let cpd_ns = mean(&cpds);
    let alm_area_mwta = packing.stats.alms as f64 * arch.area.alm_mwta;
    FlowResult {
        name: name.to_string(),
        variant: arch.variant,
        luts: packing.stats.luts,
        adder_bits: packing.stats.adder_bits,
        alms: packing.stats.alms,
        lbs: packing.stats.lbs,
        concurrent_luts: packing.stats.concurrent_luts,
        alm_area_mwta,
        cpd_ns,
        adp: alm_area_mwta * cpd_ns,
        fmax_mhz: if cpd_ns > 0.0 { 1000.0 / cpd_ns } else { f64::INFINITY },
        routed_ok,
        route_iters: mean(&iters),
        channel_util,
        cpd_trace_ns,
        dedup_hits,
    }
}

/// Run the mapped portion once (deterministic), then place/route per seed.
pub fn run_flow(circ: &Circuit, arch: &Arch, opts: &FlowOpts) -> FlowResult {
    let nl = map_circuit(circ, &MapOpts::default());
    run_flow_mapped(&circ.name, &nl, arch, opts, circ.dedup_hits)
}

/// Flow from an already-mapped netlist.  Builds the dense index arenas
/// once and shares them across every seed; with the closed timing loop
/// on, seeds chain their achieved CPDs (see the module docs).
pub fn run_flow_mapped(
    name: &str,
    nl: &Netlist,
    arch: &Arch,
    opts: &FlowOpts,
    dedup_hits: usize,
) -> FlowResult {
    let arch = arch_for_run(arch, opts);
    let packing = pack(nl, &arch, &PackOpts { unrelated: opts.unrelated });
    let idx = NetlistIndex::build(nl);
    let pidx = PackIndex::build(nl, &packing);
    let seeds = chain_seeds(nl, &packing, &arch, opts, &idx, &pidx, None, |_, _| {});
    assemble_result(name, &arch, &packing, &seeds, dedup_hits)
}

/// Run a benchmark on one architecture variant.
pub fn run_benchmark(b: &Benchmark, variant: ArchVariant, opts: &FlowOpts) -> FlowResult {
    let circ = b.generate();
    let arch = Arch::coffe(variant);
    let mut r = run_flow(&circ, &arch, opts);
    r.name = b.name.clone();
    r
}

/// Pack-only fast path (Fig. 9 and quick stats).
pub fn pack_only(circ: &Circuit, variant: ArchVariant, unrelated: Unrelated) -> Packing {
    let nl = map_circuit(circ, &MapOpts::default());
    let arch = Arch::coffe(variant);
    pack(&nl, &arch, &PackOpts { unrelated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suites::{kratos_suite, BenchParams};
    use crate::synth::multiplier::{soft_mul, AdderAlgo};

    #[test]
    fn full_flow_on_kratos_circuit() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[2]; // gemmt
        let opts = FlowOpts { seeds: vec![1], place_effort: 0.2, ..Default::default() };
        let base = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(base.alms > 0 && base.cpd_ns > 0.0 && base.adp > 0.0);
        assert!(base.routed_ok, "routing failed");
        let dd5 = run_benchmark(b, ArchVariant::Dd5, &opts);
        // The paper's core claim: DD5 uses no more ALMs on adder circuits.
        assert!(dd5.alms <= base.alms, "dd5 {} vs base {}", dd5.alms, base.alms);
    }

    #[test]
    fn multi_seed_averaging_runs() {
        let params = BenchParams::default();
        let b = &kratos_suite(&params)[0];
        let opts = FlowOpts {
            seeds: vec![1, 2],
            place_effort: 0.1,
            route: false,
            ..Default::default()
        };
        let r = run_benchmark(b, ArchVariant::Baseline, &opts);
        assert!(r.cpd_ns > 0.0);
    }

    /// Multi-seed channel utilization is the element-wise mean of the
    /// single-seed runs (not silently the last seed's samples).
    #[test]
    fn channel_util_is_seed_mean() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let arch = Arch::paper(ArchVariant::Baseline);
        let mk = |seeds: Vec<u64>| {
            run_flow(&c, &arch, &FlowOpts { seeds, place_effort: 0.1, ..Default::default() })
        };
        let s1 = mk(vec![1]);
        let s2 = mk(vec![2]);
        let both = mk(vec![1, 2]);
        assert!(!both.channel_util.is_empty());
        assert_eq!(both.channel_util.len(), s1.channel_util.len());
        for i in 0..both.channel_util.len() {
            let want = (s1.channel_util[i] + s2.channel_util[i]) / 2.0;
            assert!(
                (both.channel_util[i] - want).abs() < 1e-12,
                "sample {i}: {} vs {}",
                both.channel_util[i],
                want
            );
        }
    }
}
