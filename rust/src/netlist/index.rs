//! Dense, cache-friendly index arenas over a [`Netlist`] and a
//! [`Packing`](crate::pack::Packing) — the netlist-layer analogue of the
//! router's [`crate::rrg`] subsystem.
//!
//! The netlist IR itself stays pointer-rich and editable (`Vec<Cell>`,
//! per-net `Vec<(CellId, u8)>` sink lists, name strings); every *hot*
//! consumer — STA's forward/backward passes, the packer's attraction
//! scoring, criticality extraction — used to chase those heap cells and
//! rebuild `HashMap`s per call.  [`NetlistIndex`] flattens what they
//! actually read into CSR arrays built once per netlist:
//!
//! * **CSR fanout**: per net, sink `(cell, pin)` pairs as two flat arrays
//!   sliced by `sink_start` (stored sink order is preserved),
//! * **dense drivers**: per net, driver cell/pin as flat arrays with a
//!   [`NO_CELL`] sentinel (no `Option<(CellId, u8)>` unwrapping),
//! * **combinational levelization**: per cell, its topological level over
//!   combinational edges (FF outputs, primary inputs and constants are
//!   level-0 sources; an edge whose driver is a FF is *not* combinational),
//!   plus the cells grouped level-by-level (`level_start` / `order`, ids
//!   ascending within a level).  Cells within one level have no
//!   combinational dependencies on each other, so each level is a wave of
//!   independent jobs — the schedule
//!   [`coordinator::parallel_waves_with`](crate::coordinator::parallel_waves_with)
//!   executes for the parallel STA and that the mapper mirrors over the AIG.
//!
//! [`PackIndex`] is the per-packing companion: dense cell→ALM and ALM→LB
//! maps that replace the `HashMap`s STA used to rebuild on every call
//! (they are now built once per packing and taken by reference).
//!
//! Both structures are immutable snapshots: rebuild after any netlist or
//! packing edit.  Construction is deterministic (plain counting sorts, no
//! hash iteration), so every derived schedule is too.

use super::{CellId, CellKind, Netlist, NetId};
use crate::pack::Packing;

/// Sentinel for "no cell" in dense driver/owner arrays.
pub const NO_CELL: CellId = CellId::MAX;

/// Sentinel for "not packed / no owner" in [`PackIndex`] arrays.
pub const NO_SLOT: u32 = u32::MAX;

/// Flattened adjacency + levelization of one netlist (see module docs).
#[derive(Clone, Debug)]
pub struct NetlistIndex {
    /// CSR offsets into `sink_cell` / `sink_pin`; length `nets + 1`.
    sink_start: Vec<u32>,
    sink_cell: Vec<CellId>,
    sink_pin: Vec<u8>,
    /// Per net: driving cell ([`NO_CELL`] for floating nets) and pin.
    driver_cell: Vec<CellId>,
    driver_pin: Vec<u8>,
    /// Per cell: combinational topological level.
    level_of: Vec<u32>,
    /// CSR offsets into `order`; length `num_levels + 1`.
    level_start: Vec<usize>,
    /// Cells grouped by level, ids ascending within each level.
    order: Vec<CellId>,
}

impl NetlistIndex {
    /// Build the index.  O(cells + nets + pins); deterministic.
    pub fn build(nl: &Netlist) -> NetlistIndex {
        let nc = nl.cells.len();
        let nn = nl.nets.len();

        // --- CSR fanout + dense drivers. ---------------------------------
        let mut sink_start = vec![0u32; nn + 1];
        for (ni, net) in nl.nets.iter().enumerate() {
            sink_start[ni + 1] = net.sinks.len() as u32;
        }
        for ni in 0..nn {
            sink_start[ni + 1] += sink_start[ni];
        }
        let total_sinks = sink_start[nn] as usize;
        let mut sink_cell = vec![0 as CellId; total_sinks];
        let mut sink_pin = vec![0u8; total_sinks];
        let mut driver_cell = vec![NO_CELL; nn];
        let mut driver_pin = vec![0u8; nn];
        for (ni, net) in nl.nets.iter().enumerate() {
            let base = sink_start[ni] as usize;
            for (si, &(c, p)) in net.sinks.iter().enumerate() {
                sink_cell[base + si] = c;
                sink_pin[base + si] = p;
            }
            if let Some((c, p)) = net.driver {
                driver_cell[ni] = c;
                driver_pin[ni] = p;
            }
        }

        // --- Combinational levelization (Kahn over comb edges). ----------
        // An input edge is combinational unless its driver is a FF; FFs
        // themselves are level-0 sources (their data input is a timing
        // endpoint, not a dependency).
        let is_ff = |c: CellId| matches!(nl.cells[c as usize].kind, CellKind::Ff);
        let mut indeg = vec![0u32; nc];
        for (ci, cell) in nl.cells.iter().enumerate() {
            if matches!(cell.kind, CellKind::Ff) {
                continue;
            }
            let mut cnt = 0u32;
            for &net in &cell.ins {
                let drv = driver_cell[net as usize];
                if drv != NO_CELL && !is_ff(drv) {
                    cnt += 1;
                }
            }
            indeg[ci] = cnt;
        }
        let mut level_of = vec![0u32; nc];
        let mut queue: Vec<CellId> =
            (0..nc as CellId).filter(|&c| indeg[c as usize] == 0).collect();
        let mut head = 0usize;
        while head < queue.len() {
            let c = queue[head];
            head += 1;
            if is_ff(c) {
                // FF fanouts are not combinational edges: consumers of the
                // q output were never counted in `indeg`, so there is
                // nothing to release and no level to propagate.
                continue;
            }
            let lvl = level_of[c as usize];
            for &net in &nl.cells[c as usize].outs {
                let base = sink_start[net as usize] as usize;
                let end = sink_start[net as usize + 1] as usize;
                for &s in &sink_cell[base..end] {
                    if is_ff(s) {
                        continue;
                    }
                    let su = s as usize;
                    if level_of[su] < lvl + 1 {
                        level_of[su] = lvl + 1;
                    }
                    indeg[su] = indeg[su].saturating_sub(1);
                    if indeg[su] == 0 {
                        queue.push(s);
                    }
                }
            }
        }
        debug_assert_eq!(queue.len(), nc, "combinational cycle in netlist");

        // --- Group cells by level (counting sort keeps id order). --------
        let num_levels = level_of.iter().copied().max().map_or(0, |m| m as usize + 1);
        let mut level_start = vec![0usize; num_levels + 1];
        for &l in &level_of {
            level_start[l as usize + 1] += 1;
        }
        for l in 0..num_levels {
            level_start[l + 1] += level_start[l];
        }
        let mut cursor = level_start.clone();
        let mut order = vec![0 as CellId; nc];
        for c in 0..nc {
            let l = level_of[c] as usize;
            order[cursor[l]] = c as CellId;
            cursor[l] += 1;
        }

        NetlistIndex {
            sink_start,
            sink_cell,
            sink_pin,
            driver_cell,
            driver_pin,
            level_of,
            level_start,
            order,
        }
    }

    /// Driver of `net`, or `None` for floating nets.
    #[inline]
    pub fn driver(&self, net: NetId) -> Option<(CellId, u8)> {
        let c = self.driver_cell[net as usize];
        if c == NO_CELL {
            None
        } else {
            Some((c, self.driver_pin[net as usize]))
        }
    }

    /// Sink cells of `net` (stored order).
    #[inline]
    pub fn sink_cells(&self, net: NetId) -> &[CellId] {
        let (a, b) = self.sink_range(net);
        &self.sink_cell[a..b]
    }

    /// Sink `(cell, pin)` pairs of `net` (stored order).
    #[inline]
    pub fn sinks(&self, net: NetId) -> impl Iterator<Item = (CellId, u8)> + '_ {
        let (a, b) = self.sink_range(net);
        self.sink_cell[a..b]
            .iter()
            .zip(self.sink_pin[a..b].iter())
            .map(|(&c, &p)| (c, p))
    }

    #[inline]
    fn sink_range(&self, net: NetId) -> (usize, usize) {
        (
            self.sink_start[net as usize] as usize,
            self.sink_start[net as usize + 1] as usize,
        )
    }

    /// Combinational level of `cell` (0 = source wave).
    #[inline]
    pub fn level(&self, cell: CellId) -> u32 {
        self.level_of[cell as usize]
    }

    /// Number of levels (0 for an empty netlist).
    #[inline]
    pub fn num_levels(&self) -> usize {
        self.level_start.len() - 1
    }

    /// Cells of level `l`, ids ascending.
    #[inline]
    pub fn level_cells(&self, l: usize) -> &[CellId] {
        &self.order[self.level_start[l]..self.level_start[l + 1]]
    }

    /// All cells in (level, id) order — the forward wave schedule.
    #[inline]
    pub fn topo_order(&self) -> &[CellId] {
        &self.order
    }

    /// Wave offsets into [`Self::topo_order`] (length `num_levels + 1`),
    /// in the shape [`crate::coordinator::parallel_waves_with`] consumes.
    #[inline]
    pub fn wave_offsets(&self) -> &[usize] {
        &self.level_start
    }

    /// CSR sink offsets (length `nets + 1`): net `n`'s sinks occupy slots
    /// `sink_offsets()[n] .. sink_offsets()[n + 1]` of the flat fanout
    /// arena, in stored sink order.  Per-sink side arenas (e.g.
    /// [`crate::timing::SinkCrit`]) mirror exactly this layout.
    #[inline]
    pub fn sink_offsets(&self) -> &[u32] {
        &self.sink_start
    }

    /// Total sink slots across all nets (the fanout arena length).
    #[inline]
    pub fn num_sink_slots(&self) -> usize {
        *self.sink_start.last().unwrap_or(&0) as usize
    }
}

/// Dense cell→ALM and ALM→LB ownership maps for one [`Packing`] — built
/// once per packing instead of per `sta()` call.
///
/// `alm_of_cell` covers the cells a [`PackedAlm`](crate::pack::PackedAlm)
/// *hosts* (adder bits, independent logic LUTs, FFs); absorbed feeder LUTs
/// are intentionally not included, matching the lookup semantics STA has
/// always used (a feeder's delay is charged on its adder operand path, not
/// via its own ALM membership).
#[derive(Clone, Debug)]
pub struct PackIndex {
    alm_of_cell: Vec<u32>,
    lb_of_alm: Vec<u32>,
}

impl PackIndex {
    /// Build the dense maps.  O(cells + alms).
    pub fn build(nl: &Netlist, packing: &Packing) -> PackIndex {
        let mut alm_of_cell = vec![NO_SLOT; nl.cells.len()];
        for (ai, alm) in packing.alms.iter().enumerate() {
            for &c in alm
                .adder_bits
                .iter()
                .chain(alm.logic_luts.iter())
                .chain(alm.ffs.iter())
            {
                alm_of_cell[c as usize] = ai as u32;
            }
        }
        let mut lb_of_alm = vec![NO_SLOT; packing.alms.len()];
        for (li, lb) in packing.lbs.iter().enumerate() {
            for &ai in &lb.alms {
                lb_of_alm[ai] = li as u32;
            }
        }
        PackIndex { alm_of_cell, lb_of_alm }
    }

    /// ALM hosting `cell`, if any.
    #[inline]
    pub fn alm_of(&self, cell: CellId) -> Option<usize> {
        let a = self.alm_of_cell[cell as usize];
        (a != NO_SLOT).then_some(a as usize)
    }

    /// LB containing ALM `alm`, if any.
    #[inline]
    pub fn lb_of(&self, alm: usize) -> Option<usize> {
        let l = self.lb_of_alm[alm];
        (l != NO_SLOT).then_some(l as usize)
    }

    /// Do two cells sit in the same LB?  `true` when either side has no
    /// ALM (the permissive default carry-hop classification STA uses).
    #[inline]
    pub fn same_lb(&self, a: CellId, b: CellId) -> bool {
        match (self.alm_of(a), self.alm_of(b)) {
            (Some(x), Some(y)) => self.lb_of(x) == self.lb_of(y),
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// a, b -> LUT x; x, ff.q -> LUT y -> FF d; y also -> output.
    fn leveled() -> Netlist {
        let mut nl = Netlist::new("lv");
        let a = nl.add_input("a");
        let b = nl.add_input("b");
        let x = nl.add_net("x");
        nl.add_cell(CellKind::Lut { k: 2, truth: 0b1000 }, "lx", vec![a, b], vec![x]);
        let q = nl.add_net("q");
        let y = nl.add_net("y");
        nl.add_cell(CellKind::Lut { k: 2, truth: 0b0110 }, "ly", vec![x, q], vec![y]);
        nl.add_cell(CellKind::Ff, "ff", vec![y], vec![q]);
        nl.add_output("o", y);
        nl
    }

    #[test]
    fn csr_matches_netlist() {
        let nl = leveled();
        let idx = NetlistIndex::build(&nl);
        for (ni, net) in nl.nets.iter().enumerate() {
            let ni = ni as NetId;
            assert_eq!(idx.driver(ni), net.driver);
            let got: Vec<(CellId, u8)> = idx.sinks(ni).collect();
            assert_eq!(got, net.sinks);
            assert_eq!(idx.sink_cells(ni).len(), net.sinks.len());
        }
    }

    #[test]
    fn levels_respect_comb_edges_and_ff_cuts() {
        let nl = leveled();
        let idx = NetlistIndex::build(&nl);
        let by_name = |n: &str| -> CellId {
            nl.cells.iter().position(|c| c.name == n).unwrap() as CellId
        };
        // PIs level 0; lx = 1; ly = 2 (x at 1, q edge cut by the FF);
        // ff level 0 (source); output cell after ly.
        assert_eq!(idx.level(by_name("a")), 0);
        assert_eq!(idx.level(by_name("ff")), 0);
        assert_eq!(idx.level(by_name("lx")), 1);
        assert_eq!(idx.level(by_name("ly")), 2);
        assert_eq!(idx.level(by_name("o")), 3);
        // Schedule covers every cell exactly once, levels ascending.
        assert_eq!(idx.topo_order().len(), nl.cells.len());
        assert_eq!(idx.wave_offsets().len(), idx.num_levels() + 1);
        let mut seen = vec![false; nl.cells.len()];
        for l in 0..idx.num_levels() {
            for &c in idx.level_cells(l) {
                assert_eq!(idx.level(c) as usize, l);
                assert!(!seen[c as usize]);
                seen[c as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // Every comb edge goes strictly up-level.
        for (ci, cell) in nl.cells.iter().enumerate() {
            if matches!(cell.kind, CellKind::Ff) {
                continue;
            }
            for &net in &cell.ins {
                if let Some((drv, _)) = idx.driver(net) {
                    if !matches!(nl.cells[drv as usize].kind, CellKind::Ff) {
                        assert!(idx.level(drv) < idx.level(ci as CellId));
                    }
                }
            }
        }
    }

    #[test]
    fn pack_index_matches_packing() {
        use crate::arch::{Arch, ArchVariant};
        use crate::pack::{pack, PackOpts};
        use crate::synth::circuit::Circuit;
        use crate::synth::multiplier::{soft_mul, AdderAlgo};
        use crate::techmap::{map_circuit, MapOpts};

        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 5);
        let y = c.pi_bus("y", 5);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &Arch::paper(ArchVariant::Dd5), &PackOpts::default());
        let pidx = PackIndex::build(&nl, &packing);
        for (ai, alm) in packing.alms.iter().enumerate() {
            for &cell in alm
                .adder_bits
                .iter()
                .chain(alm.logic_luts.iter())
                .chain(alm.ffs.iter())
            {
                assert_eq!(pidx.alm_of(cell), Some(ai));
            }
        }
        for (li, lb) in packing.lbs.iter().enumerate() {
            for &ai in &lb.alms {
                assert_eq!(pidx.lb_of(ai), Some(li));
            }
        }
    }
}
