//! The paper's Fig. 9 packing stress test as a runnable example: 500
//! adders + an increasing number of 5-LUTs, packed with unrelated
//! clustering, baseline vs DD5.
//!
//!     cargo run --release --example packing_stress

use double_duty::report;

fn main() {
    let (table, rows) = report::fig9();
    table.print();
    let max_conc = rows.iter().map(|r| r.3).max().unwrap_or(0);
    println!();
    println!("saturation: {} concurrent 5-LUTs ({}% of the 500-LUT theoretical max; paper: 375 = 75%)",
             max_conc, max_conc * 100 / 500);
}
