//! Bench harness regenerating the paper's Fig. 9 (packing stress test).
//! Run: cargo bench --bench fig9_packing   (DDUTY_FULL=1 for full effort)
use std::time::Instant;
use double_duty::report::{self, ExpOpts};

fn main() {
    let opts = if std::env::var("DDUTY_FULL").is_ok() {
        ExpOpts::default()
    } else {
        ExpOpts::quick()
    };
    let t0 = Instant::now();
    let _ = &opts; report::fig9().0.print();
    println!();
    println!("[fig9_packing] regenerated in {:.1} s", t0.elapsed().as_secs_f64());
}
