//! Placer <-> PJRT kernel bridge: batched full-cost + congestion
//! evaluation through the AOT-compiled JAX/Pallas artifact.
//!
//! The kernel works on a fixed 64x64 bin grid; device coordinates are
//! scaled into it and the returned wHPWL is unscaled back, so the value is
//! directly comparable to the Rust incremental cost (the placer
//! debug-asserts consistency every temperature).

use std::collections::HashMap;

use crate::util::error::Result;

use crate::arch::device::{Device, Loc};
use crate::netlist::CellId;
use crate::runtime::{CostEval, CostKernel, GRID};

use super::cost::{IncrementalCost, NetModel};

/// Kernel-backed cost evaluator.
pub struct KernelCost {
    kernel: CostKernel,
}

/// Kernel evaluation mapped back to device units.
#[derive(Clone, Debug)]
pub struct KernelPlacementEval {
    pub whpwl: f64,
    pub congestion: Vec<f32>,
    pub overflow: f64,
}

impl KernelCost {
    /// Load the artifact set; fails if artifacts are missing or the design
    /// has more external nets than the largest bucket.
    pub fn try_new(num_nets: usize) -> Result<KernelCost> {
        let kernel = CostKernel::load_default()?;
        crate::ensure!(
            num_nets <= kernel.max_nets(),
            "{num_nets} nets exceeds kernel bucket {}",
            kernel.max_nets()
        );
        Ok(KernelCost { kernel })
    }

    /// Evaluate the full placement cost + RUDY congestion map.
    pub fn evaluate(
        &mut self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        device: &Device,
    ) -> Result<KernelPlacementEval> {
        let extent = device.width().max(device.height()) as f64;
        let scale = (GRID as f64 - 1.0) / extent.max(1.0);
        let boxes = model.export_bboxes(lb_loc, io_loc, scale, GRID as f64 - 1.0);
        // Per-bin capacity scaled with channel demand density; for the
        // consistency/diagnostic path an uncapped evaluation is fine.
        let CostEval { whpwl, congestion, overflow } =
            self.kernel.evaluate(&boxes, f32::MAX)?;
        Ok(KernelPlacementEval { whpwl: whpwl / scale, congestion, overflow })
    }

    /// Batched evaluation from the placer's incremental cost cache: the
    /// per-net boxes come straight out of [`IncrementalCost`] (no bbox
    /// rebuild over every terminal), so the kernel consistency check and
    /// congestion signal cost one device call per batch.
    pub fn evaluate_cached(
        &mut self,
        model: &NetModel,
        inc: &IncrementalCost,
        device: &Device,
    ) -> Result<KernelPlacementEval> {
        let extent = device.width().max(device.height()) as f64;
        let scale = (GRID as f64 - 1.0) / extent.max(1.0);
        let boxes = inc.export_bboxes(model, scale, GRID as f64 - 1.0);
        let CostEval { whpwl, congestion, overflow } =
            self.kernel.evaluate(&boxes, f32::MAX)?;
        Ok(KernelPlacementEval { whpwl: whpwl / scale, congestion, overflow })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::place::{place, PlaceOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    /// End-to-end: kernel full cost must match the Rust incremental cost.
    #[test]
    fn kernel_matches_rust_cost() {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 6);
        let y = c.pi_bus("y", 6);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let arch = Arch::paper(ArchVariant::Baseline);
        let packing = pack(&nl, &arch, &PackOpts::default());
        let pl = place(&nl, &packing, &arch,
                       &PlaceOpts { effort: 0.2, timing_driven: false, ..Default::default() })
            .expect("placement");

        let mut model = NetModel::build(&nl, &packing);
        model.set_weights(&[], false);
        let rust_cost = model.full_cost(&pl.lb_loc, &pl.io_loc);

        let Ok(mut k) = KernelCost::try_new(model.num_nets()) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let eval = k.evaluate(&model, &pl.lb_loc, &pl.io_loc, &pl.device).unwrap();
        let err = (eval.whpwl - rust_cost).abs() / rust_cost.max(1.0);
        assert!(err < 1e-3, "kernel {} vs rust {} (err {err})", eval.whpwl, rust_cost);
        assert!(eval.congestion.iter().any(|&c| c > 0.0));
    }
}
