//! Placement cost model: criticality-weighted HPWL with VPR's fanout
//! correction factor, evaluated incrementally per move.

use std::collections::HashMap;

use crate::arch::device::Loc;
use crate::netlist::{CellId, CellKind, Netlist, NetId};
use crate::pack::Packing;

/// A placeable terminal of a net.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Term {
    Lb(usize),
    Io(CellId),
}

/// One external (inter-block) net.
#[derive(Clone, Debug)]
pub struct ExtNet {
    pub net: NetId,
    pub terms: Vec<Term>,
    /// Timing weight (1 + criticality amplification).
    pub weight: f64,
}

/// VPR's crossing-count correction for multi-terminal nets.
fn q_factor(n_terms: usize) -> f64 {
    const Q: [f64; 10] = [1.0, 1.0, 1.0, 1.0828, 1.1536, 1.2206, 1.2823, 1.3385, 1.3991, 1.4493];
    if n_terms <= 10 {
        Q[n_terms.saturating_sub(1)]
    } else {
        1.4493 + 0.02616 * (n_terms as f64 - 10.0)
    }
}

/// Net model for placement: external nets, terminal lookup, weights.
#[derive(Clone, Debug)]
pub struct NetModel {
    pub nets: Vec<ExtNet>,
    /// For each LB: indices of nets touching it.
    lb_nets: Vec<Vec<usize>>,
    /// NetId -> ExtNet index.
    net_index: HashMap<NetId, usize>,
    /// Cell -> LB index (for endpoint queries).
    cell_lb: HashMap<CellId, usize>,
}

/// Aggregate placement cost snapshot.
#[derive(Clone, Copy, Debug)]
pub struct PlacementCost {
    pub whpwl: f64,
}

impl NetModel {
    /// Identify external nets: nets whose terminals span >= 2 blocks.
    pub fn build(nl: &Netlist, packing: &Packing) -> NetModel {
        // Cell -> block mapping.
        let mut cell_lb: HashMap<CellId, usize> = HashMap::new();
        for (li, lb) in packing.lbs.iter().enumerate() {
            for &ai in &lb.alms {
                let alm = &packing.alms[ai];
                for &c in alm
                    .adder_bits
                    .iter()
                    .chain(alm.logic_luts.iter())
                    .chain(alm.ffs.iter())
                {
                    cell_lb.insert(c, li);
                }
                for paths in &alm.operand_paths {
                    for p in paths {
                        if let crate::pack::OperandPath::AbsorbedLut(l) = p {
                            cell_lb.insert(*l, li);
                        }
                    }
                }
            }
        }

        let mut nets = Vec::new();
        let mut net_index = HashMap::new();
        let mut lb_nets: Vec<Vec<usize>> = vec![Vec::new(); packing.lbs.len()];

        for (ni, net) in nl.nets.iter().enumerate() {
            let mut terms: Vec<Term> = Vec::new();
            let mut push = |t: Term, terms: &mut Vec<Term>| {
                if !terms.contains(&t) {
                    terms.push(t);
                }
            };
            if let Some((drv, _)) = net.driver {
                match nl.cells[drv as usize].kind {
                    CellKind::Input => push(Term::Io(drv), &mut terms),
                    _ => {
                        if let Some(&lb) = cell_lb.get(&drv) {
                            push(Term::Lb(lb), &mut terms);
                        }
                    }
                }
            }
            for &(sink, _) in &net.sinks {
                match nl.cells[sink as usize].kind {
                    CellKind::Output => push(Term::Io(sink), &mut terms),
                    _ => {
                        if let Some(&lb) = cell_lb.get(&sink) {
                            push(Term::Lb(lb), &mut terms);
                        }
                    }
                }
            }
            if terms.len() < 2 {
                continue; // intra-block or dangling
            }
            let idx = nets.len();
            for t in &terms {
                if let Term::Lb(lb) = t {
                    lb_nets[*lb].push(idx);
                }
            }
            net_index.insert(ni as NetId, idx);
            nets.push(ExtNet { net: ni as NetId, terms, weight: 1.0 });
        }

        NetModel { nets, lb_nets, net_index, cell_lb }
    }

    pub fn num_nets(&self) -> usize {
        self.nets.len()
    }

    /// Set timing weights: `w = 1 + 8*crit^2` (sharp criticality emphasis).
    pub fn set_weights(&mut self, net_crit: &[f64], timing_driven: bool) {
        for en in &mut self.nets {
            let c = if timing_driven {
                net_crit.get(en.net as usize).copied().unwrap_or(0.0)
            } else {
                0.0
            };
            en.weight = 1.0 + 8.0 * c * c;
        }
    }

    #[inline]
    fn term_loc(
        &self,
        t: Term,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> Loc {
        match t {
            Term::Lb(i) => lb_loc[i],
            Term::Io(c) => io_loc[&c],
        }
    }

    /// Weighted HPWL of one net (single source of the cost formula:
    /// [`net_bbox`] + [`bbox_cost`], shared with [`IncrementalCost`]).
    #[inline]
    pub fn net_cost(&self, en: &ExtNet, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> f64 {
        bbox_cost(en, net_bbox(en, lb_loc, io_loc, &[]))
    }

    /// Total cost from scratch.
    pub fn full_cost(&self, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> f64 {
        self.nets.iter().map(|en| self.net_cost(en, lb_loc, io_loc)).sum()
    }

    /// Cost delta if `moved` blocks relocate (positions not yet applied).
    pub fn move_delta(
        &self,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) -> f64 {
        let mut delta = 0.0;
        for ni in self.affected_nets(moved) {
            let en = &self.nets[ni];
            let before = bbox_cost(en, net_bbox(en, lb_loc, io_loc, &[]));
            let after = bbox_cost(en, net_bbox(en, lb_loc, io_loc, moved));
            delta += after - before;
        }
        delta
    }

    /// Indices of the nets touching any moved block, deduped, in first-seen
    /// order (deterministic).
    fn affected_nets(&self, moved: &[(usize, Loc)]) -> Vec<usize> {
        let mut affected: Vec<usize> = Vec::with_capacity(16);
        for &(lb, _) in moved {
            for &ni in &self.lb_nets[lb] {
                if !affected.contains(&ni) {
                    affected.push(ni);
                }
            }
        }
        affected
    }

    /// The placeable terminal a cell belongs to (LB or its own IO pad).
    pub fn term_of_cell(&self, cell: CellId) -> Option<Term> {
        if let Some(&lb) = self.cell_lb.get(&cell) {
            return Some(Term::Lb(lb));
        }
        None
    }

    /// Source/sink locations of a net endpoint for delay estimation.
    pub fn endpoint_locs(
        &self,
        net: NetId,
        sink_cell: CellId,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> Option<(Loc, Loc)> {
        let &idx = self.net_index.get(&net)?;
        let en = &self.nets[idx];
        let src = en.terms.first()?;
        let src_loc = self.term_loc(*src, lb_loc, io_loc);
        let dst_loc = if let Some(&lb) = self.cell_lb.get(&sink_cell) {
            lb_loc[lb]
        } else if let Some(&l) = io_loc.get(&sink_cell) {
            l
        } else {
            return None;
        };
        Some((src_loc, dst_loc))
    }

    /// Export per-net bounding boxes for the PJRT kernel (bin coordinates
    /// scaled to the kernel's fixed grid).
    pub fn export_bboxes(
        &self,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        scale: f64,
        grid_max: f64,
    ) -> Vec<[f32; 5]> {
        self.nets
            .iter()
            .map(|en| {
                let mut xmin = f64::INFINITY;
                let mut xmax = 0.0f64;
                let mut ymin = f64::INFINITY;
                let mut ymax = 0.0f64;
                for &t in &en.terms {
                    let l = self.term_loc(t, lb_loc, io_loc);
                    xmin = xmin.min(l.x as f64);
                    xmax = xmax.max(l.x as f64);
                    ymin = ymin.min(l.y as f64);
                    ymax = ymax.max(l.y as f64);
                }
                [
                    ((xmin * scale).min(grid_max)) as f32,
                    ((xmax * scale).min(grid_max)) as f32,
                    ((ymin * scale).min(grid_max)) as f32,
                    ((ymax * scale).min(grid_max)) as f32,
                    (en.weight * q_factor(en.terms.len())) as f32,
                ]
            })
            .collect()
    }
}

/// Bounding box `[xmin, xmax, ymin, ymax]` of one net, with optional
/// pending-location overrides for moved blocks.
fn net_bbox(
    en: &ExtNet,
    lb_loc: &[Loc],
    io_loc: &HashMap<CellId, Loc>,
    moved: &[(usize, Loc)],
) -> [u16; 4] {
    let mut xmin = u16::MAX;
    let mut xmax = 0u16;
    let mut ymin = u16::MAX;
    let mut ymax = 0u16;
    for &t in &en.terms {
        let l = match t {
            Term::Lb(i) => moved
                .iter()
                .find(|&&(m, _)| m == i)
                .map(|&(_, l)| l)
                .unwrap_or(lb_loc[i]),
            Term::Io(c) => io_loc[&c],
        };
        xmin = xmin.min(l.x);
        xmax = xmax.max(l.x);
        ymin = ymin.min(l.y);
        ymax = ymax.max(l.y);
    }
    [xmin, xmax, ymin, ymax]
}

/// Weighted HPWL of a net given its bounding box.
#[inline]
fn bbox_cost(en: &ExtNet, bb: [u16; 4]) -> f64 {
    let span = (bb[1] - bb[0]) as f64 + (bb[3] - bb[2]) as f64;
    en.weight * q_factor(en.terms.len()) * span
}

/// Incrementally maintained placement cost.
///
/// Caches every net's bounding box and weighted cost so a move proposal
/// evaluates only the *after* state of its affected nets against the cache
/// — [`NetModel::move_delta`] recomputes both sides per proposal, which
/// doubles the work on the (dominant at low temperature) rejected moves.
/// The cache also feeds the PJRT kernel's batched evaluation
/// ([`crate::place::kernel_accel`]) without a per-call bbox rebuild.
///
/// Contract: [`Self::total`] equals [`NetModel::full_cost`] up to f64
/// accumulation order; [`Self::refresh`] re-sums from scratch (run it
/// after weight changes, and periodically to cap drift).  Enforced by the
/// `incremental_matches_scratch_after_many_moves` test below.
#[derive(Clone, Debug)]
pub struct IncrementalCost {
    bbox: Vec<[u16; 4]>,
    cost: Vec<f64>,
    total: f64,
}

impl IncrementalCost {
    pub fn new(model: &NetModel, lb_loc: &[Loc], io_loc: &HashMap<CellId, Loc>) -> Self {
        let n = model.nets.len();
        let mut ic = IncrementalCost { bbox: vec![[0; 4]; n], cost: vec![0.0; n], total: 0.0 };
        ic.refresh(model, lb_loc, io_loc);
        ic
    }

    /// Current total weighted HPWL.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Recompute every net from scratch; returns the new total.  Needed
    /// after [`NetModel::set_weights`] (cached costs embed the weights).
    pub fn refresh(
        &mut self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
    ) -> f64 {
        self.total = 0.0;
        for (ni, en) in model.nets.iter().enumerate() {
            let bb = net_bbox(en, lb_loc, io_loc, &[]);
            let c = bbox_cost(en, bb);
            self.bbox[ni] = bb;
            self.cost[ni] = c;
            self.total += c;
        }
        self.total
    }

    /// Cost delta if `moved` blocks relocate (positions not yet applied):
    /// affected nets' new cost against the cached current cost.
    pub fn move_delta(
        &self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) -> f64 {
        let mut delta = 0.0;
        for ni in model.affected_nets(moved) {
            let en = &model.nets[ni];
            delta += bbox_cost(en, net_bbox(en, lb_loc, io_loc, moved)) - self.cost[ni];
        }
        delta
    }

    /// Commit an accepted move.  `lb_loc` must already hold the new
    /// positions; `moved` identifies which blocks changed (their stored
    /// locations are ignored — positions are read from `lb_loc`).
    pub fn apply_move(
        &mut self,
        model: &NetModel,
        lb_loc: &[Loc],
        io_loc: &HashMap<CellId, Loc>,
        moved: &[(usize, Loc)],
    ) {
        for ni in model.affected_nets(moved) {
            let en = &model.nets[ni];
            let bb = net_bbox(en, lb_loc, io_loc, &[]);
            let c = bbox_cost(en, bb);
            self.total += c - self.cost[ni];
            self.bbox[ni] = bb;
            self.cost[ni] = c;
        }
    }

    /// Per-net kernel boxes from the cache (bin coordinates scaled to the
    /// kernel's fixed grid) — the batched-evaluation feed.
    pub fn export_bboxes(&self, model: &NetModel, scale: f64, grid_max: f64) -> Vec<[f32; 5]> {
        model
            .nets
            .iter()
            .zip(self.bbox.iter())
            .map(|(en, bb)| {
                [
                    ((bb[0] as f64 * scale).min(grid_max)) as f32,
                    ((bb[1] as f64 * scale).min(grid_max)) as f32,
                    ((bb[2] as f64 * scale).min(grid_max)) as f32,
                    ((bb[3] as f64 * scale).min(grid_max)) as f32,
                    (en.weight * q_factor(en.terms.len())) as f32,
                ]
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, ArchVariant};
    use crate::pack::{pack, PackOpts};
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn model() -> (NetModel, usize) {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", 4);
        let y = c.pi_bus("y", 4);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Cascade);
        c.po_bus("p", &p);
        let nl = map_circuit(&c, &MapOpts::default());
        let packing = pack(&nl, &Arch::paper(ArchVariant::Baseline), &PackOpts::default());
        let n_lbs = packing.lbs.len();
        (NetModel::build(&nl, &packing), n_lbs)
    }

    #[test]
    fn q_factor_monotone() {
        assert_eq!(q_factor(2), 1.0);
        assert!(q_factor(5) > q_factor(3));
        assert!(q_factor(20) > q_factor(10));
    }

    #[test]
    fn move_delta_matches_full_recompute() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        // Synthetic locations.
        let mut lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        let before = m.full_cost(&lb_loc, &io_loc);
        if n_lbs >= 2 {
            let moved = [(0usize, Loc::new(9, 9)), (1usize, lb_loc[0])];
            let delta = m.move_delta(&lb_loc, &io_loc, &moved);
            lb_loc[0] = Loc::new(9, 9);
            lb_loc[1] = moved[1].1;
            let after = m.full_cost(&lb_loc, &io_loc);
            assert!((before + delta - after).abs() < 1e-9,
                    "delta {delta} vs {}", after - before);
        }
    }

    /// The cached kernel-box export must match the from-scratch export the
    /// PJRT bridge used before the incremental cache existed.
    #[test]
    fn cached_bbox_export_matches_scratch() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        let lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 4 + 1) as u16, (i / 4 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 5 + 1) as u16));
                }
            }
        }
        let inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        let a = m.export_bboxes(&lb_loc, &io_loc, 1.5, 63.0);
        let b = inc.export_bboxes(&m, 1.5, 63.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            for k in 0..5 {
                assert!((x[k] - y[k]).abs() < 1e-6, "box field {k}: {} vs {}", x[k], y[k]);
            }
        }
    }

    /// The incremental cache must track a from-scratch recompute through a
    /// long random move sequence (the placer's correctness backbone).
    #[test]
    fn incremental_matches_scratch_after_many_moves() {
        let (mut m, n_lbs) = model();
        m.set_weights(&[], false);
        let mut lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        let mut inc = IncrementalCost::new(&m, &lb_loc, &io_loc);
        assert!((inc.total() - m.full_cost(&lb_loc, &io_loc)).abs() < 1e-9);
        if n_lbs == 0 {
            return;
        }
        let mut rng = crate::util::Rng::new(42);
        let mut predicted = inc.total();
        for step in 0..10_000 {
            let lb = rng.below(n_lbs);
            let to = Loc::new(rng.below(9) as u16 + 1, rng.below(9) as u16 + 1);
            let moved = [(lb, to)];
            let delta = inc.move_delta(&m, &lb_loc, &io_loc, &moved);
            lb_loc[lb] = to;
            inc.apply_move(&m, &lb_loc, &io_loc, &moved);
            predicted += delta;
            if step % 1000 == 0 {
                let scratch = m.full_cost(&lb_loc, &io_loc);
                let tol = 1e-6 * scratch.abs().max(1.0);
                assert!((inc.total() - scratch).abs() < tol,
                        "step {step}: incremental {} vs scratch {scratch}", inc.total());
                assert!((predicted - scratch).abs() < tol,
                        "step {step}: summed deltas {predicted} vs scratch {scratch}");
            }
        }
        let scratch = m.full_cost(&lb_loc, &io_loc);
        assert!((inc.total() - scratch).abs() < 1e-6 * scratch.abs().max(1.0));
        // refresh() lands on the exact scratch sum.
        let refreshed = inc.refresh(&m, &lb_loc, &io_loc);
        assert_eq!(refreshed, scratch);
    }

    #[test]
    fn weights_scale_cost() {
        let (mut m, n_lbs) = model();
        let lb_loc: Vec<Loc> = (0..n_lbs)
            .map(|i| Loc::new((i % 5 + 1) as u16, (i / 5 + 1) as u16))
            .collect();
        let mut io_loc = HashMap::new();
        for en in &m.nets {
            for &t in &en.terms {
                if let Term::Io(c) = t {
                    io_loc.insert(c, Loc::new(0, (c % 7 + 1) as u16));
                }
            }
        }
        m.set_weights(&[], false);
        let base = m.full_cost(&lb_loc, &io_loc);
        let crit = vec![1.0; 10_000];
        m.set_weights(&crit, true);
        let weighted = m.full_cost(&lb_loc, &io_loc);
        assert!(weighted > base * 5.0);
    }
}
