//! 64-way word-parallel random simulation over the miter AIG.
//!
//! Before any cone goes to the SAT solver, a few rounds of random
//! simulation evaluate the whole miter under 64 input vectors at a time
//! (one bit lane per vector, one `u64` word per node).  Any miter output
//! whose word is non-zero is *refuted on the spot* — the lowest set bit
//! of the first failing round is extracted as a concrete counterexample
//! assignment, which is much cheaper than a SAT call and catches the
//! common corruption cases (flipped truth bits, swapped carries)
//! immediately.  Vectors come from the crate's deterministic
//! [`crate::util::Rng`] with a fixed seed, so the witness an output gets
//! is a pure function of the miter — bit-identical for any worker count.

use crate::techmap::aig::{Aig, LeafKind, Lit, Node};
use crate::util::Rng;

/// Fixed seed for the prefilter's input vectors (deterministic reports).
const SIM_SEED: u64 = 0x5EED_0E0D_D0D0_0001;

/// Evaluate every node of `aig` under one 64-lane input batch.
/// `input_words[i]` carries the 64 values of miter input `i`.
fn eval_words(aig: &Aig, input_words: &[u64]) -> Vec<u64> {
    let mut words = vec![0u64; aig.len()];
    for id in 0..aig.len() {
        words[id] = match *aig.node(id as u32) {
            Node::Const0 => 0,
            Node::Leaf(LeafKind::Pi(i)) => input_words.get(i as usize).copied().unwrap_or(0),
            // The miter builder only creates Pi leaves; anything else
            // evaluates as 0 and the SAT stage (which rejects such cones
            // explicitly) stays the arbiter.
            Node::Leaf(_) => 0,
            Node::And(a, b) => {
                let wa = words[a.node() as usize] ^ if a.is_compl() { u64::MAX } else { 0 };
                let wb = words[b.node() as usize] ^ if b.is_compl() { u64::MAX } else { 0 };
                wa & wb
            }
        };
    }
    words
}

#[inline]
fn word_of(words: &[u64], l: Lit) -> u64 {
    let w = words.get(l.node() as usize).copied().unwrap_or(0);
    if l.is_compl() {
        !w
    } else {
        w
    }
}

/// Run `rounds` simulation batches over the miter; for each output literal
/// in `outputs` return the first counterexample input assignment found
/// (`None` = survived simulation).  Round 0 is the structured batch
/// (all-zeros, all-ones, and single-input walking patterns in the first
/// lanes); later rounds are uniform random.
pub fn prefilter(
    aig: &Aig,
    n_inputs: usize,
    outputs: &[Lit],
    rounds: usize,
) -> Vec<Option<Vec<bool>>> {
    let mut found: Vec<Option<Vec<bool>>> = vec![None; outputs.len()];
    let mut rng = Rng::new(SIM_SEED);
    let mut input_words = vec![0u64; n_inputs];
    for round in 0..rounds.max(1) {
        for (i, w) in input_words.iter_mut().enumerate() {
            *w = if round == 0 {
                // Lane 0: all inputs 0.  Lane 1: all inputs 1.  Lanes
                // 2..64: walking one-hot over the first 62 inputs.
                let walking = if i + 2 < 64 { 1u64 << (i + 2) } else { 0 };
                0x2 | walking
            } else {
                rng.next_u64()
            };
        }
        let words = eval_words(aig, &input_words);
        let mut all_done = true;
        for (oi, &out) in outputs.iter().enumerate() {
            if found[oi].is_some() {
                continue;
            }
            let w = word_of(&words, out);
            if w != 0 {
                let lane = w.trailing_zeros();
                let assignment: Vec<bool> =
                    input_words.iter().map(|&iw| iw >> lane & 1 == 1).collect();
                found[oi] = Some(assignment);
            } else {
                all_done = false;
            }
        }
        if all_done {
            break;
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_and_vs_or_counterexample() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let f1 = g.and(a, b);
        let f2 = g.or(a, b);
        let m = g.xor(f1, f2);
        let hits = prefilter(&g, 2, &[m], 4);
        let cex = hits[0].as_ref().expect("sim must refute and-vs-or");
        // Replay: the assignment must make the two sides disagree.
        let eval = |l: Lit, pis: &[bool]| {
            g.eval(l, |k| match k {
                LeafKind::Pi(i) => pis[i as usize],
                _ => unreachable!(),
            })
        };
        assert_ne!(eval(f1, cex), eval(f2, cex));
    }

    #[test]
    fn equivalent_pair_survives() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let f1 = g.and(a, b);
        let na_or_nb = g.or(a.compl(), b.compl());
        let m = g.xor(f1, na_or_nb.compl());
        let hits = prefilter(&g, 2, &[m], 8);
        assert!(hits[0].is_none(), "equivalent cone must survive simulation");
    }

    #[test]
    fn deterministic_witnesses() {
        let mut g = Aig::new();
        let a = g.pi();
        let b = g.pi();
        let c = g.pi();
        let f1 = g.maj3(a, b, c);
        let f2 = g.xor3(a, b, c);
        let m = g.xor(f1, f2);
        let h1 = prefilter(&g, 3, &[m], 4);
        let h2 = prefilter(&g, 3, &[m], 4);
        assert_eq!(h1, h2);
        assert!(h1[0].is_some());
    }

    #[test]
    fn constant_true_miter_caught_in_round_zero() {
        let g = {
            let mut g = Aig::new();
            let _ = g.pi();
            g
        };
        // Miter literal TRUE: differs everywhere; lane 0 (all zeros) hits.
        let hits = prefilter(&g, 1, &[Lit::TRUE], 1);
        let cex = hits[0].as_ref().expect("constant-true miter");
        assert!(cex.iter().all(|&v| !v), "lane 0 is the all-zero vector");
    }
}
