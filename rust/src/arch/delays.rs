//! Timing parameters (picoseconds) for the STA and the placer's delay
//! estimator.  The named paths mirror Table II of the paper; the remaining
//! parameters come from the Stratix-10-like VTR capture the paper builds on.

use super::ArchVariant;

/// All component delays in picoseconds.
#[derive(Clone, Copy, Debug)]
pub struct Delays {
    /// LB input pin -> ALM general input (A–H) through the local crossbar.
    /// Table II path (1): 72.61 ps baseline.
    pub lb_in_to_alm_in: f64,
    /// LB input pin -> ALM Z input through the AddMux crossbar (DD only).
    /// Table II: 77.05 ps.
    pub lb_in_to_z: f64,
    /// ALM general input -> adder operand, through the feeding LUT (and,
    /// on DD variants, the AddMux). Table II path (2): 133.4 ps baseline,
    /// 202.2 ps Double-Duty.
    pub alm_in_to_adder: f64,
    /// ALM Z input -> adder operand via the AddMux only (DD): 68.77 ps.
    pub z_to_adder: f64,
    /// ALM input -> 5-LUT output (logic mode).
    pub lut5: f64,
    /// ALM input -> 6-LUT output.
    pub lut6: f64,
    /// Adder operand -> sum output.
    pub adder_sum: f64,
    /// Carry propagation per adder bit along the chain.
    pub carry_hop: f64,
    /// Carry hop across an LB boundary (chain continuation).
    pub carry_lb_hop: f64,
    /// LUT/adder output -> LB output pin (output mux + driver).
    pub alm_out_to_lb_out: f64,
    /// Extra output-mux delay on every ALM output in DD6 (the source of
    /// the ~8% frequency penalty the paper measures).
    pub dd6_outmux_extra: f64,
    /// One routing wire segment (length `segment_len` tiles), incl. switch.
    pub wire_segment: f64,
    /// Connection block: channel wire -> LB input pin mux.
    pub conn_block: f64,
    /// LB-to-LB direct link (adjacent blocks, bypassing general routing).
    pub direct_link: f64,
    /// FF clock-to-q and setup.
    pub ff_clk_q: f64,
    pub ff_setup: f64,
    /// I/O pad delay.
    pub io: f64,
}

impl Delays {
    /// Paper-published values (Table II) plus Stratix-10-like VTR-capture
    /// estimates for the paths the paper does not tabulate.
    pub fn paper(v: ArchVariant) -> Self {
        let dd = !matches!(v, ArchVariant::Baseline);
        Delays {
            lb_in_to_alm_in: 72.61,
            lb_in_to_z: if dd { 77.05 } else { f64::INFINITY },
            alm_in_to_adder: if dd { 202.2 } else { 133.4 },
            z_to_adder: if dd { 68.77 } else { f64::INFINITY },
            lut5: 260.0,
            lut6: 290.0,
            adder_sum: 85.0,
            carry_hop: 16.0,
            carry_lb_hop: 45.0,
            alm_out_to_lb_out: 60.0,
            dd6_outmux_extra: if matches!(v, ArchVariant::Dd6) { 25.0 } else { 0.0 },
            wire_segment: 180.0,
            conn_block: 95.0,
            direct_link: 75.0,
            ff_clk_q: 90.0,
            ff_setup: 60.0,
            io: 500.0,
        }
    }

    /// Delay of an adder operand arriving at an ALM, by entry path.
    /// `via_z` selects the Z bypass (DD only); `through_lut` means the
    /// operand passes through (or is computed in) the feeding LUT.
    pub fn adder_operand_entry(&self, via_z: bool) -> f64 {
        if via_z {
            self.lb_in_to_z + self.z_to_adder
        } else {
            self.lb_in_to_alm_in + self.alm_in_to_adder
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        let b = Delays::paper(ArchVariant::Baseline);
        assert!((b.lb_in_to_alm_in - 72.61).abs() < 1e-9);
        assert!((b.alm_in_to_adder - 133.4).abs() < 1e-9);
        let d = Delays::paper(ArchVariant::Dd5);
        assert!((d.lb_in_to_z - 77.05).abs() < 1e-9);
        assert!((d.z_to_adder - 68.77).abs() < 1e-9);
        // Paper: Z path is ~48% faster than the baseline LUT path.
        let cut = 1.0 - d.z_to_adder / b.alm_in_to_adder;
        assert!((cut - 0.484).abs() < 0.01, "cut {cut}");
    }

    #[test]
    fn z_entry_beats_lut_entry_on_dd5() {
        let d = Delays::paper(ArchVariant::Dd5);
        assert!(d.adder_operand_entry(true) < d.adder_operand_entry(false));
    }

    #[test]
    fn dd6_pays_output_mux() {
        assert_eq!(Delays::paper(ArchVariant::Dd5).dd6_outmux_extra, 0.0);
        assert!(Delays::paper(ArchVariant::Dd6).dd6_outmux_extra > 0.0);
    }
}
