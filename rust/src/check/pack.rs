//! Pack legality: ALM half accounting, LB capacity and pin feasibility,
//! chain-macro integrity, and exact cell coverage.
//!
//! Everything here is recomputed from the [`Packing`] artifact and the
//! netlist — the packer's own accounting (`lut_units`, `free_halves`, the
//! incremental LB input sets) is never consulted, so a bookkeeping bug in
//! the producer cannot self-certify.
//!
//! One deliberate severity split: LBs hosting carry-chain segments are
//! exempt from the external-pin budget by design (VPR-style carry-macro
//! exemption — see `cluster::cluster_lbs`), so a pin overflow there is a
//! [`Severity::Warning`]; on any other LB it is an [`Severity::Error`].

use std::collections::HashMap;

use crate::arch::Arch;
use crate::netlist::{CellId, CellKind, NetId, Netlist};
use crate::pack::{OperandPath, Packing};

use super::{Severity, Stage, Violation};

fn v(sev: Severity, code: &'static str, location: String, message: String) -> Violation {
    Violation::new(Stage::Pack, sev, code, location, message)
}

fn err(code: &'static str, location: String, message: String) -> Violation {
    v(Severity::Error, code, location, message)
}

/// Audit a packing against `nl` and the per-variant ALM/LB legality rules
/// in `arch`.  Scan order: ALMs ascending, LBs ascending, chains
/// ascending, then coverage.
pub fn audit_packing(nl: &Netlist, packing: &Packing, arch: &Arch) -> Vec<Violation> {
    let mut out = Vec::new();
    let baseline = arch.alm.z_inputs == 0;

    // --- Per-ALM legality (ALMs ascending). ------------------------------
    for (ai, alm) in packing.alms.iter().enumerate() {
        let loc = format!("alm {ai}");

        // Adder bits: count, kind, one chain, consecutive positions.
        if alm.adder_bits.len() > arch.alm.adders as usize {
            out.push(err(
                "pack.alm-adders",
                loc.clone(),
                format!(
                    "{} adder bits exceed the {} per-ALM adders",
                    alm.adder_bits.len(),
                    arch.alm.adders
                ),
            ));
        }
        if alm.operand_paths.len() != alm.adder_bits.len() {
            out.push(err(
                "pack.alm-adders",
                loc.clone(),
                format!(
                    "{} operand-path entries for {} adder bits",
                    alm.operand_paths.len(),
                    alm.adder_bits.len()
                ),
            ));
        }
        let mut bit_pos: Vec<(u32, u32)> = Vec::new(); // (chain, pos)
        for &b in &alm.adder_bits {
            match nl.cells.get(b as usize).map(|c| &c.kind) {
                Some(&CellKind::AdderBit { chain, pos }) => bit_pos.push((chain, pos)),
                other => out.push(err(
                    "pack.alm-adders",
                    loc.clone(),
                    format!("adder slot holds cell {b} of kind {other:?}"),
                )),
            }
        }
        if let Some(&(ch0, _)) = bit_pos.first() {
            if alm.chain != Some(ch0) || bit_pos.iter().any(|&(ch, _)| ch != ch0) {
                out.push(err(
                    "pack.alm-adders",
                    loc.clone(),
                    format!("chain tag {:?} does not match hosted bits {bit_pos:?}", alm.chain),
                ));
            }
            for w in bit_pos.windows(2) {
                if w[1].1 != w[0].1 + 1 {
                    out.push(err(
                        "pack.alm-adders",
                        loc.clone(),
                        format!("non-consecutive chain positions {} and {}", w[0].1, w[1].1),
                    ));
                }
            }
        } else if alm.chain.is_some() {
            out.push(err(
                "pack.alm-adders",
                loc.clone(),
                format!("chain tag {:?} on an ALM with no adder bits", alm.chain),
            ));
        }

        // Input budgets.
        if alm.gen_inputs.len() > arch.alm.general_inputs as usize {
            out.push(err(
                "pack.alm-inputs",
                loc.clone(),
                format!(
                    "{} general inputs exceed the A-H budget of {}",
                    alm.gen_inputs.len(),
                    arch.alm.general_inputs
                ),
            ));
        }
        if alm.z_inputs.len() > arch.alm.z_inputs as usize {
            out.push(err(
                "pack.alm-inputs",
                loc.clone(),
                format!(
                    "{} Z inputs exceed the Z1-Z4 budget of {}",
                    alm.z_inputs.len(),
                    arch.alm.z_inputs
                ),
            ));
        }

        // Baseline must not use the DD bypass at all.
        let z_paths = alm
            .operand_paths
            .iter()
            .flatten()
            .filter(|p| matches!(p, OperandPath::ZBypass))
            .count();
        if baseline && (z_paths > 0 || !alm.z_inputs.is_empty()) {
            out.push(err(
                "pack.z-on-baseline",
                loc.clone(),
                format!(
                    "baseline ALM uses {} Z-bypass operand(s) and {} Z input net(s)",
                    z_paths,
                    alm.z_inputs.len()
                ),
            ));
        }

        // Half accounting, recomputed from scratch.  A half is busy iff its
        // adder bit has an operand entering through a 4-LUT; logic LUTs may
        // only occupy free halves (a 6-LUT fractures across both).
        let mut recomputed_halves = 0usize;
        for &l in &alm.logic_luts {
            match nl.cells.get(l as usize).map(|c| &c.kind) {
                Some(&CellKind::Lut { k, .. }) if k <= 6 => {
                    recomputed_halves += if k == 6 { 2 } else { 1 };
                }
                other => out.push(err(
                    "pack.lut-halves",
                    loc.clone(),
                    format!("logic-LUT slot holds cell {l} of kind {other:?}"),
                )),
            }
        }
        if recomputed_halves != alm.logic_halves {
            out.push(err(
                "pack.lut-halves",
                loc.clone(),
                format!(
                    "stored logic_halves {} but hosted LUT widths need {}",
                    alm.logic_halves, recomputed_halves
                ),
            ));
        }
        let busy_halves = alm
            .operand_paths
            .iter()
            .filter(|paths| {
                paths.iter().any(|p| {
                    matches!(p, OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough)
                })
            })
            .count();
        if busy_halves + recomputed_halves > 2 {
            out.push(err(
                "pack.lut-halves",
                loc.clone(),
                format!(
                    "{busy_halves} feeder-busy half(s) + {recomputed_halves} logic half(s) \
                     exceed the 2 ALM halves"
                ),
            ));
        }
        let feeders = alm
            .operand_paths
            .iter()
            .flatten()
            .filter(|p| matches!(p, OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough))
            .count();
        if feeders + recomputed_halves * 2 > arch.alm.lut4_units as usize {
            out.push(err(
                "pack.lut-halves",
                loc.clone(),
                format!(
                    "{} feeder + {} logic 4-LUT units exceed the {} available",
                    feeders,
                    recomputed_halves * 2,
                    arch.alm.lut4_units
                ),
            ));
        }
        if baseline && alm.uses_adders() && !alm.logic_luts.is_empty() {
            out.push(err(
                "pack.concurrent-on-baseline",
                loc.clone(),
                format!(
                    "baseline ALM hosts {} adder bit(s) concurrently with {} logic LUT(s)",
                    alm.adder_bits.len(),
                    alm.logic_luts.len()
                ),
            ));
        }
        if alm.ffs.len() > arch.alm.ffs as usize {
            out.push(err(
                "pack.alm-ffs",
                loc.clone(),
                format!("{} FFs exceed the {} per-ALM registers", alm.ffs.len(), arch.alm.ffs),
            ));
        }
    }

    // --- Per-LB legality (LBs ascending). --------------------------------
    // Which ALM drives each net (recomputed; mirrors nothing stored in the
    // LB itself).
    let mut net_driver_alm: HashMap<NetId, usize> = HashMap::new();
    for (ai, alm) in packing.alms.iter().enumerate() {
        for &net in &alm.outputs {
            net_driver_alm.insert(net, ai);
        }
    }
    let mut alm_lb: Vec<Option<usize>> = vec![None; packing.alms.len()];
    for (li, lb) in packing.lbs.iter().enumerate() {
        let loc = format!("lb {li}");
        if lb.alms.len() > arch.lb.alms as usize {
            out.push(err(
                "pack.lb-capacity",
                loc.clone(),
                format!("{} ALMs exceed the {} per-LB capacity", lb.alms.len(), arch.lb.alms),
            ));
        }
        for &ai in &lb.alms {
            if ai >= packing.alms.len() {
                out.push(err(
                    "pack.lb-capacity",
                    loc.clone(),
                    format!("member ALM index {ai} out of range"),
                ));
                continue;
            }
            if let Some(prev) = alm_lb[ai] {
                out.push(err(
                    "pack.cell-double-packed",
                    loc.clone(),
                    format!("ALM {ai} is a member of both LB {prev} and LB {li}"),
                ));
            } else {
                alm_lb[ai] = Some(li);
            }
        }
        // External input pins, recomputed: a member's gen/Z input net is an
        // LB input unless another member drives it.
        let members: Vec<usize> =
            lb.alms.iter().copied().filter(|&ai| ai < packing.alms.len()).collect();
        let mut ext: Vec<NetId> = members
            .iter()
            .flat_map(|&ai| {
                let alm = &packing.alms[ai];
                alm.gen_inputs.iter().chain(alm.z_inputs.iter()).copied()
            })
            .filter(|net| {
                !net_driver_alm.get(net).map_or(false, |d| members.contains(d))
            })
            .collect();
        ext.sort_unstable();
        ext.dedup();
        if ext.len() > arch.lb.inputs as usize {
            let chain_lb = !lb.chains.is_empty();
            out.push(v(
                if chain_lb { Severity::Warning } else { Severity::Error },
                "pack.lb-pins",
                loc.clone(),
                format!(
                    "{} external input nets exceed the {} LB input pins{}",
                    ext.len(),
                    arch.lb.inputs,
                    if chain_lb { " (tolerated: carry-macro LB)" } else { "" }
                ),
            ));
        }
        // Chain-tag cross-check: lb.chains must be exactly the chains of
        // its member ALMs.
        let mut member_chains: Vec<u32> =
            members.iter().filter_map(|&ai| packing.alms[ai].chain).collect();
        member_chains.sort_unstable();
        member_chains.dedup();
        let mut stored = lb.chains.clone();
        stored.sort_unstable();
        stored.dedup();
        if stored != member_chains {
            out.push(err(
                "pack.lb-chains",
                loc.clone(),
                format!("LB chain tags {stored:?} != member ALM chains {member_chains:?}"),
            ));
        }
    }
    for (ai, lb) in alm_lb.iter().enumerate() {
        if lb.is_none() {
            out.push(err(
                "pack.cell-unpacked",
                format!("alm {ai}"),
                "ALM belongs to no LB".to_string(),
            ));
        }
    }

    // --- Chain macros (chains ascending). --------------------------------
    // Walk each chain's ALMs in bit order; the LB sequence they visit,
    // consecutively deduped, must equal the stored macro (and never revisit
    // an LB — that would split the carry chain).
    for (ch, stored) in packing.chain_macros.iter().enumerate() {
        let mut chain_alms: Vec<(u32, usize)> = Vec::new(); // (min pos, alm)
        for (ai, alm) in packing.alms.iter().enumerate() {
            if alm.chain == Some(ch as u32) {
                let mut min_pos = u32::MAX;
                for &b in &alm.adder_bits {
                    if let Some(&CellKind::AdderBit { pos, .. }) =
                        nl.cells.get(b as usize).map(|c| &c.kind)
                    {
                        min_pos = min_pos.min(pos);
                    }
                }
                chain_alms.push((min_pos, ai));
            }
        }
        chain_alms.sort_unstable();
        let mut visited: Vec<usize> = Vec::new();
        for &(_, ai) in &chain_alms {
            if let Some(lb) = alm_lb[ai] {
                if visited.last() != Some(&lb) {
                    visited.push(lb);
                }
            }
        }
        let mut uniq = visited.clone();
        uniq.sort_unstable();
        uniq.dedup();
        if uniq.len() != visited.len() {
            out.push(err(
                "pack.chain-split",
                format!("chain {ch}"),
                format!("chain re-enters an LB it already left: visits {visited:?}"),
            ));
        }
        if &visited != stored {
            out.push(err(
                "pack.chain-macro-mismatch",
                format!("chain {ch}"),
                format!("stored macro {stored:?} != LB walk {visited:?}"),
            ));
        }
    }

    // --- Exact cell coverage. --------------------------------------------
    // Every LUT, adder bit, and FF must be packed exactly once; every
    // Input/Output cell must appear exactly once in `ios`.
    let mut slot_count: HashMap<CellId, u32> = HashMap::new();
    for alm in &packing.alms {
        for &c in alm.adder_bits.iter().chain(alm.logic_luts.iter()).chain(alm.ffs.iter()) {
            *slot_count.entry(c).or_insert(0) += 1;
        }
        for p in alm.operand_paths.iter().flatten() {
            if let OperandPath::AbsorbedLut(l) = p {
                *slot_count.entry(*l).or_insert(0) += 1;
            }
        }
    }
    for &c in &packing.ios {
        *slot_count.entry(c).or_insert(0) += 1;
    }
    for (ci, cell) in nl.cells.iter().enumerate() {
        let packable = matches!(
            cell.kind,
            CellKind::Lut { .. }
                | CellKind::AdderBit { .. }
                | CellKind::Ff
                | CellKind::Input
                | CellKind::Output
        );
        let n = slot_count.get(&(ci as CellId)).copied().unwrap_or(0);
        if packable && n == 0 {
            out.push(err(
                "pack.cell-unpacked",
                format!("cell {ci}"),
                format!("{:?} appears in no ALM slot or I/O pad", cell.kind),
            ));
        } else if n > 1 {
            out.push(err(
                "pack.cell-double-packed",
                format!("cell {ci}"),
                format!("{:?} occupies {n} packing slots", cell.kind),
            ));
        }
    }

    out
}
