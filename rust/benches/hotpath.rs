//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! placer move evaluation (incremental cost cache), router A* (serial vs
//! sharded PathFinder), the levelized wave-parallel front-end (mapper /
//! packer / STA, serial vs sharded — the PR-3 acceptance numbers), and
//! the PJRT kernel evaluation latency. No criterion offline — simple
//! timed loops with enough iterations for stable medians.
//!
//! Stage medians — front-end (map / pack / sta) *and* back-end (place /
//! route) — are also emitted as machine-readable JSON (the versioned
//! `BENCH.json` schema: version, bench, jobs, elapsed wall clock, and per
//! stage the jobs=1 / jobs=N medians and speedup) so CI can archive and
//! *gate* the perf trajectory across PRs:
//!
//! * `--out <path>` — where to write the JSON (default `BENCH.json` in
//!   the CWD; CI passes an explicit path so the artifact upload never
//!   depends on the invocation directory),
//! * `--baseline <path>` — after writing, compare against a committed
//!   baseline and exit non-zero on a perf regression,
//! * `--compare <current> <baseline>` — compare two existing JSON files
//!   without re-running anything (the CI gate step),
//! * `--emit-baseline <path>` — additionally write the same measured
//!   record shaped as a committable gate baseline: robustness counters
//!   pinned at 0 and a provenance comment with the refresh procedure.
//!   CI uploads it (`BENCH_BASELINE.measured.json` in the bench-medians
//!   artifact) so refreshing `BENCH_BASELINE.json` is a download + commit
//!   of real runner medians, never hand-typed numbers.
//!
//! The gate fails when any stage's `median_s` exceeds the baseline's by
//! more than 25% (ignoring sub-[`NOISE_FLOOR_S`] medians, which are
//! timer noise on shared runners) or when the run's wall clock exceeds
//! the baseline's `wall_clock_budget_s`.  The record also carries the
//! deterministic router work counters `route_iters` (PathFinder
//! iterations) and `astar_pops` (A* heap pops, lookahead on), gated at
//! the same 25% headroom with no noise floor — they are bit-stable per
//! (bench, arch, placement), so any growth is a real search-quality
//! regression.
//!
//! `--quick` runs a CI-smoke subset: single iterations, the router and
//! front-end determinism checks, no engine sweep.
use std::time::Instant;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::coordinator::default_workers;
use double_duty::flow::engine::{Engine, ExperimentPlan};
use double_duty::flow::FlowOpts;
use double_duty::netlist::{Netlist, NetlistIndex, PackIndex};
use double_duty::pack::{pack, pack_with, PackOpts};
use double_duty::place::cost::{IncrementalCost, NetModel};
use double_duty::place::{place, PlaceOpts};
use double_duty::route::{route, LookaheadMode, RouteOpts, Routing};
use double_duty::techmap::{map_circuit, map_circuit_with, MapOpts};
use double_duty::timing::{sta_with, TimingReport};

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    if per > 0.1 {
        println!("{name:<28} {:>10.1} ms/iter", per * 1e3);
    } else {
        println!("{name:<28} {:>10.1} us/iter", per * 1e6);
    }
}

/// Median wall-clock seconds of `iters` runs (after one warmup).
fn median_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let mut ts = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        ts.push(t0.elapsed().as_secs_f64());
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn netlists_identical(a: &Netlist, b: &Netlist) -> bool {
    a.num_chains == b.num_chains
        && a.inputs == b.inputs
        && a.outputs == b.outputs
        && a.cells.len() == b.cells.len()
        && a.nets.len() == b.nets.len()
        && a.cells.iter().zip(b.cells.iter()).all(|(x, y)| {
            x.kind == y.kind && x.name == y.name && x.ins == y.ins && x.outs == y.outs
        })
        && a.nets.iter().zip(b.nets.iter()).all(|(x, y)| {
            x.name == y.name && x.driver == y.driver && x.sinks == y.sinks
        })
}

fn packings_identical(a: &double_duty::pack::Packing, b: &double_duty::pack::Packing) -> bool {
    a.variant == b.variant
        && a.chain_macros == b.chain_macros
        && a.ios == b.ios
        && a.alms.len() == b.alms.len()
        && a.lbs.len() == b.lbs.len()
        && a.alms.iter().zip(b.alms.iter()).all(|(x, y)| {
            x.adder_bits == y.adder_bits
                && x.operand_paths == y.operand_paths
                && x.logic_luts == y.logic_luts
                && x.logic_halves == y.logic_halves
                && x.ffs == y.ffs
                && x.gen_inputs == y.gen_inputs
                && x.z_inputs == y.z_inputs
                && x.outputs == y.outputs
                && x.chain == y.chain
        })
        && a.lbs.iter().zip(b.lbs.iter()).all(|(x, y)| {
            x.alms == y.alms
                && x.inputs == y.inputs
                && x.outputs == y.outputs
                && x.chains == y.chains
        })
        && a.stats.alms == b.stats.alms
        && a.stats.lbs == b.stats.lbs
        && a.stats.adder_bits == b.stats.adder_bits
        && a.stats.luts == b.stats.luts
        && a.stats.absorbed_luts == b.stats.absorbed_luts
        && a.stats.concurrent_luts == b.stats.concurrent_luts
        && a.stats.ffs == b.stats.ffs
        && a.stats.ios == b.stats.ios
}

fn reports_identical(a: &TimingReport, b: &TimingReport) -> bool {
    a.bits_eq(b)
}

fn routing_identical(a: &Routing, b: &Routing) -> bool {
    a.success == b.success
        && a.iterations == b.iterations
        && a.wirelength == b.wirelength
        && a.sink_hops == b.sink_hops
        && a.net_nodes == b.net_nodes
        && a.channel_util == b.channel_util
        && a.astar_pops == b.astar_pops
}

/// A stage median regression beyond this factor fails the perf gate.
const REGRESS_FACTOR: f64 = 1.25;
/// Absolute median growth below this (seconds) is timer noise on shared
/// CI runners and never fails the gate on its own — the ratio check
/// alone would go red on a few ms of jitter over a near-zero baseline.
const NOISE_FLOOR_S: f64 = 0.02;
/// Wall-clock budget written into every emitted BENCH.json, so a
/// re-baselined file (`--out BENCH_BASELINE.json` or a copied CI
/// artifact) keeps the gate's budget check armed.
const WALL_BUDGET_S: f64 = 900.0;

/// Extract the number following `"key":` at or after byte `from`.  Only
/// good enough for the flat BENCH.json schema this bench itself emits —
/// deliberately not a general JSON parser (the crate is std-only).
fn json_num(text: &str, key: &str, from: usize) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = text.get(from..)?.find(&pat)? + from + pat.len();
    let rest = text[at..].trim_start();
    let end = rest
        .find(|ch: char| !(ch.is_ascii_digit() || "+-.eE".contains(ch)))
        .unwrap_or(rest.len());
    rest[..end].parse::<f64>().ok()
}

/// `median_s` of one stage entry in a BENCH.json document.
fn stage_median(text: &str, stage: &str) -> Option<f64> {
    let at = text.find(&format!("\"stage\": \"{stage}\""))?;
    json_num(text, "median_s", at)
}

/// The CI perf-trajectory gate: compare a freshly produced BENCH.json
/// against the committed baseline.  Returns the failure report, if any.
fn compare_bench(cur_path: &str, base_path: &str) -> Result<(), String> {
    let cur = std::fs::read_to_string(cur_path)
        .map_err(|e| format!("cannot read current {cur_path}: {e}"))?;
    let base = std::fs::read_to_string(base_path)
        .map_err(|e| format!("cannot read baseline {base_path}: {e}"))?;
    let mut failures: Vec<String> = Vec::new();
    for stage in ["map", "pack", "sta", "place", "route"] {
        match (stage_median(&cur, stage), stage_median(&base, stage)) {
            (Some(c), Some(b)) => {
                if c > b * REGRESS_FACTOR && c - b > NOISE_FLOOR_S {
                    failures.push(format!(
                        "stage {stage}: median {c:.4}s vs baseline {b:.4}s \
                         (> {:.0}% regression)",
                        (REGRESS_FACTOR - 1.0) * 100.0
                    ));
                } else {
                    println!("perf gate: stage {stage:<4} ok ({c:.4}s vs baseline {b:.4}s)");
                }
            }
            _ => failures.push(format!("stage {stage}: missing median_s in current or baseline")),
        }
    }
    // Deterministic router work counters (PathFinder iterations and A*
    // heap pops, lookahead on): growth means the search got genuinely
    // less focused — no timer noise involved, so no noise floor, but the
    // same 25% headroom keeps loosely seeded baselines usable.
    // `failed_seeds` / `escalations` baseline at 0: any failed or
    // ladder-rescued seed in the (fault-free) bench sweep is a real
    // robustness regression, so the 25% headroom degenerates to `> 0`.
    for key in ["route_iters", "astar_pops", "failed_seeds", "escalations"] {
        match (json_num(&cur, key, 0), json_num(&base, key, 0)) {
            (Some(c), Some(b)) => {
                if c > b * REGRESS_FACTOR {
                    failures.push(format!(
                        "counter {key}: {c:.0} vs baseline {b:.0} (> {:.0}% growth)",
                        (REGRESS_FACTOR - 1.0) * 100.0
                    ));
                } else {
                    println!("perf gate: counter {key:<11} ok ({c:.0} vs baseline {b:.0})");
                }
            }
            (Some(_), None) => {
                // Pre-counter baselines stay usable; re-baseline to arm.
                println!("perf gate: counter {key} absent from baseline (skipped)");
            }
            _ => failures.push(format!("counter {key}: missing from current BENCH.json")),
        }
    }
    if let (Some(budget), Some(elapsed)) = (
        json_num(&base, "wall_clock_budget_s", 0),
        json_num(&cur, "elapsed_s", 0),
    ) {
        if elapsed > budget {
            failures.push(format!(
                "wall clock {elapsed:.1}s exceeds baseline budget {budget:.1}s"
            ));
        } else {
            println!("perf gate: wall clock ok ({elapsed:.1}s within {budget:.1}s budget)");
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(failures.join("\n"))
    }
}

/// Value of a `--flag <value>` pair, if present.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let t_start = Instant::now();
    let args: Vec<String> = std::env::args().collect();

    // Gate-only mode: compare two existing BENCH.json files and exit.
    if let Some(i) = args.iter().position(|a| a == "--compare") {
        let (Some(cur), Some(base)) = (args.get(i + 1), args.get(i + 2)) else {
            eprintln!("--compare requires <current.json> <baseline.json>");
            std::process::exit(2);
        };
        match compare_bench(cur, base) {
            Ok(()) => {
                println!("perf gate: no regression vs {base}");
                return;
            }
            Err(msg) => {
                eprintln!("perf gate FAILED:\n{msg}");
                eprintln!(
                    "(expected on intentional perf changes: re-baseline {base} \
                     or apply the override label documented in README.md)"
                );
                std::process::exit(1);
            }
        }
    }

    let quick = args.iter().any(|a| a == "--quick");
    let out_path = flag_value(&args, "--out").unwrap_or_else(|| "BENCH.json".to_string());
    let baseline = flag_value(&args, "--baseline");
    let emit_baseline = flag_value(&args, "--emit-baseline");
    let params = BenchParams::default();
    let suite = kratos_suite(&params);
    let bench = &suite[2]; // gemmt: the hotpath representative
    let circ = bench.generate();
    let arch = Arch::coffe(ArchVariant::Dd5);
    let reps = |full: usize| if quick { 1 } else { full };

    timed("synth+map gemmt", reps(5), || {
        let c = bench.generate();
        let _ = map_circuit(&c, &MapOpts::default());
    });

    let nl = map_circuit(&circ, &MapOpts::default());
    timed("pack gemmt", reps(10), || {
        let _ = pack(&nl, &arch, &PackOpts::default());
    });

    let packing = pack(&nl, &arch, &PackOpts::default());
    timed("place gemmt (effort 0.3)", reps(3), || {
        let _ = place(&nl, &packing, &arch,
                      &PlaceOpts { effort: 0.3, ..Default::default() });
    });

    let pl = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() })
        .expect("placement");
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);

    timed("full_cost (rust)", reps(200), || {
        let _ = model.full_cost(&pl.lb_loc, &pl.io_loc);
    });
    let moved = [(0usize, double_duty::arch::device::Loc::new(2, 2))];
    timed("move_delta (scratch)", reps(20_000), || {
        let _ = model.move_delta(&pl.lb_loc, &pl.io_loc, &moved);
    });
    let inc = IncrementalCost::new(&model, &pl.lb_loc, &pl.io_loc);
    timed("move_delta (incremental)", reps(20_000), || {
        let _ = inc.move_delta(&model, &pl.lb_loc, &pl.io_loc, &moved);
    });

    match double_duty::place::kernel_accel::KernelCost::try_new(model.num_nets()) {
        Ok(mut k) => {
            timed("full_cost+congestion (PJRT)", reps(50), || {
                let _ = k.evaluate_cached(&model, &inc, &pl.device).unwrap();
            });
        }
        Err(e) => println!("PJRT kernel unavailable: {e}"),
    }

    timed("sta gemmt", reps(50), || {
        let _ = double_duty::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
    });

    // --- Router: serial vs sharded PathFinder on the largest Kratos
    // circuit (by mapped cell count).  The ISSUE-2 acceptance bar is
    // >1.5x at 4 jobs; results must be bit-identical (the rrg
    // snapshot/reduce determinism contract).
    let (big_circ, big_nl, big_name) = if quick {
        (circ.clone(), nl.clone(), bench.name.clone())
    } else {
        suite
            .iter()
            .map(|b| {
                let c = b.generate();
                let n = map_circuit(&c, &MapOpts::default());
                (c, n, b.name.clone())
            })
            .max_by_key(|(_, nl, _)| nl.cells.len())
            .expect("non-empty suite")
    };
    let big_pack = pack(&big_nl, &arch, &PackOpts::default());
    let big_pl = place(&big_nl, &big_pack, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() })
        .expect("placement");
    let mut big_model = NetModel::build(&big_nl, &big_pack);
    big_model.set_weights(&[], false);

    let route_jobs = if quick { 2 } else { 4 };
    let route_reps = reps(3);
    // Pre-build the shared RRG lookahead so the serial timing loop does
    // not pay the one-time map construction in its first rep.
    {
        let g = double_duty::rrg::RrGraph::build(&big_pl.device, &arch);
        let _ = double_duty::rrg::lookahead::shared(&g);
    }
    // Per-rep times -> median, matching the other gated stages (a mean
    // would let one scheduler hiccup fail the perf gate).
    let med = |ts: &mut Vec<f64>| {
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts[ts.len() / 2]
    };
    let mut serial_route = None;
    let mut ts = Vec::with_capacity(route_reps);
    for _ in 0..route_reps {
        let t0 = Instant::now();
        serial_route = Some(route(&big_model, &big_pl, &arch,
                                  &RouteOpts { jobs: 1, ..Default::default() }));
        ts.push(t0.elapsed().as_secs_f64());
    }
    let t_serial = med(&mut ts);
    let mut sharded_route = None;
    let mut ts = Vec::with_capacity(route_reps);
    for _ in 0..route_reps {
        let t1 = Instant::now();
        sharded_route = Some(route(&big_model, &big_pl, &arch,
                                   &RouteOpts { jobs: route_jobs, ..Default::default() }));
        ts.push(t1.elapsed().as_secs_f64());
    }
    let t_sharded = med(&mut ts);
    let (sr, pr) = (serial_route.unwrap(), sharded_route.unwrap());
    assert!(routing_identical(&sr, &pr),
            "sharded router diverged from serial on {big_name}");
    println!("route {big_name:<18} jobs=1 {:>8.1} ms", t_serial * 1e3);
    println!(
        "route {big_name:<18} jobs={route_jobs} {:>7.1} ms  ({:.2}x speedup, {} iters, bit-identical)",
        t_sharded * 1e3,
        t_serial / t_sharded.max(1e-9),
        sr.iterations
    );

    // Lookahead evidence: the same route with the legacy Manhattan
    // heuristic, so the pops/iterations reduction the lookahead buys is
    // visible in every bench log (the gated counters below come from the
    // lookahead-on run).
    let off = route(&big_model, &big_pl, &arch,
                    &RouteOpts { jobs: 1, lookahead: LookaheadMode::Off, ..Default::default() });
    println!(
        "route {big_name:<18} lookahead on  {:>9} A* pops, {:>3} iters",
        sr.astar_pops, sr.iterations
    );
    println!(
        "route {big_name:<18} lookahead off {:>9} A* pops, {:>3} iters  \
         ({:.2}x pops vs on)",
        off.astar_pops,
        off.iterations,
        off.astar_pops as f64 / sr.astar_pops.max(1) as f64
    );
    // Counters for the BENCH.json record (gated by compare_bench):
    // deterministic per (bench, arch, placement), so they track search
    // quality with zero timer noise.
    let (route_iters_ct, astar_pops_ct) = (sr.iterations, sr.astar_pops);

    // --- Front-end: levelized wave-parallel mapper / packer / STA on the
    // largest Kratos circuit, jobs=1 vs jobs=default_workers() (the PR-3
    // acceptance comparison).  Every parallel artifact is checked
    // bit-identical against its serial twin before any timing is
    // reported; medians land in the BENCH.json perf record (--out).
    let fe_jobs = default_workers().max(2);

    let map_par = map_circuit_with(&big_circ, &MapOpts::default(), fe_jobs);
    assert!(netlists_identical(&big_nl, &map_par),
            "parallel mapper diverged from serial on {big_name}");
    let map_s1 = median_secs(reps(3), || {
        let _ = map_circuit_with(&big_circ, &MapOpts::default(), 1);
    });
    let map_sn = median_secs(reps(3), || {
        let _ = map_circuit_with(&big_circ, &MapOpts::default(), fe_jobs);
    });

    let pack_par = pack_with(&big_nl, &arch, &PackOpts::default(), fe_jobs);
    assert!(packings_identical(&big_pack, &pack_par),
            "parallel packer diverged from serial on {big_name}");
    let pack_s1 = median_secs(reps(5), || {
        let _ = pack_with(&big_nl, &arch, &PackOpts::default(), 1);
    });
    let pack_sn = median_secs(reps(5), || {
        let _ = pack_with(&big_nl, &arch, &PackOpts::default(), fe_jobs);
    });

    let idx = NetlistIndex::build(&big_nl);
    let pidx = PackIndex::build(&big_nl, &big_pack);
    let sta_delay = |net: u32, _c: u32, pin: u8| 120.0 + (net % 5) as f64 + pin as f64;
    let sta_1 = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, 1);
    let sta_n = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, fe_jobs);
    assert!(reports_identical(&sta_1, &sta_n),
            "parallel STA diverged from serial on {big_name}");
    let sta_s1 = median_secs(reps(15), || {
        let _ = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, 1);
    });
    let sta_sn = median_secs(reps(15), || {
        let _ = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, fe_jobs);
    });

    // --- Placer stage (perf-gate entry): timing-driven annealing with
    // the per-sink criticality lane, sta_jobs=1 vs sharded STA refreshes.
    // The Placement must be bit-identical for any sta_jobs (the placer
    // determinism contract, also pinned by rust/tests/place_timing.rs).
    let place_popts = |sta_jobs: usize| PlaceOpts {
        effort: 0.3,
        sta_jobs,
        ..Default::default()
    };
    let pl_s1 = place(&big_nl, &big_pack, &arch, &place_popts(1)).expect("placement");
    let pl_sn = place(&big_nl, &big_pack, &arch, &place_popts(fe_jobs)).expect("placement");
    assert!(
        pl_s1.lb_loc == pl_sn.lb_loc && pl_s1.cost.to_bits() == pl_sn.cost.to_bits(),
        "placer diverged across sta_jobs on {big_name}"
    );
    let place_s1 = median_secs(reps(3), || {
        let _ = place(&big_nl, &big_pack, &arch, &place_popts(1));
    });
    let place_sn = median_secs(reps(3), || {
        let _ = place(&big_nl, &big_pack, &arch, &place_popts(fe_jobs));
    });

    let speedup = |s1: f64, sn: f64| s1 / sn.max(1e-12);
    for (stage, s1, sn) in [
        ("map", map_s1, map_sn),
        ("pack", pack_s1, pack_sn),
        ("sta", sta_s1, sta_sn),
        ("place", place_s1, place_sn),
    ] {
        println!(
            "{stage:<5} {big_name:<18} jobs=1 {:>8.2} ms | jobs={fe_jobs} {:>8.2} ms  ({:.2}x, bit-identical)",
            s1 * 1e3,
            sn * 1e3,
            speedup(s1, sn)
        );
    }

    // Versioned BENCH.json perf-trajectory record (see module docs).
    // Written to --out so the CI artifact upload and the perf gate never
    // depend on the invocation directory (the old BENCH_PR3.json landed
    // in the CWD and silently vanished when run from rust/).  Emitted at
    // the END of the run — quick or full — so elapsed_s covers
    // everything that actually ran (a full run's wall clock is dominated
    // by the engine sweep below), then gated against --baseline.
    let emit_and_gate = |elapsed_s: f64, failed_seeds: usize, escalations: usize| {
        // `comment` renders as an extra JSON field line when non-empty
        // (the baseline flavor carries its provenance inline).
        let render = |failed: usize, escalated: usize, comment: &str| {
            format!(
                "{{\n  \"version\": 1,\n  \"bench\": \"{big_name}\",\n  \"cells\": {},\n  \
                 \"jobs\": {fe_jobs},\n  \"route_iters\": {route_iters_ct},\n  \
                 \"astar_pops\": {astar_pops_ct},\n  \"failed_seeds\": {failed},\n  \
                 \"escalations\": {escalated},\n  \"elapsed_s\": {elapsed_s:.3},\n  \
                 \"wall_clock_budget_s\": {WALL_BUDGET_S:.1},\n{comment}  \"stages\": [\n    \
                 {{\"stage\": \"map\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
                 {{\"stage\": \"pack\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
                 {{\"stage\": \"sta\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
                 {{\"stage\": \"place\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
                 {{\"stage\": \"route\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}}\n  ]\n}}\n",
                big_nl.cells.len(),
                map_s1, map_sn, speedup(map_s1, map_sn),
                pack_s1, pack_sn, speedup(pack_s1, pack_sn),
                sta_s1, sta_sn, speedup(sta_s1, sta_sn),
                place_s1, place_sn, speedup(place_s1, place_sn),
                t_serial, t_sharded, speedup(t_serial, t_sharded),
            )
        };
        match std::fs::write(&out_path, render(failed_seeds, escalations, "")) {
            Ok(()) => println!("stage medians written to {out_path}"),
            Err(e) => {
                eprintln!("could not write {out_path}: {e}");
                std::process::exit(1);
            }
        }
        if let Some(bpath) = &emit_baseline {
            let note = format!(
                "  \"comment\": \"Measured perf-trajectory baseline (bench {big_name}, \
                 jobs {fe_jobs}) emitted by cargo bench --bench hotpath -- --emit-baseline. \
                 Refresh procedure (README.md): download BENCH_BASELINE.measured.json from a \
                 green main run's bench-medians artifact and commit it as BENCH_BASELINE.json \
                 — never hand-edit the medians. failed_seeds/escalations are pinned at 0: a \
                 fault-free sweep must not fail or escalate any seed. Intentional \
                 regressions: perf-regression-ok label or same-PR re-baseline.\",\n"
            );
            match std::fs::write(bpath, render(0, 0, &note)) {
                Ok(()) => println!("committable measured baseline written to {bpath}"),
                Err(e) => {
                    eprintln!("could not write {bpath}: {e}");
                    std::process::exit(1);
                }
            }
        }
        // Inline perf gate (the CI runs it as a separate --compare step
        // so an override label can skip it without skipping the bench).
        if let Some(base) = &baseline {
            if let Err(msg) = compare_bench(&out_path, base) {
                eprintln!("perf gate FAILED:\n{msg}");
                std::process::exit(1);
            }
            println!("perf gate: no regression vs {base}");
        }
    };

    if quick {
        // No engine sweep ran, so the robustness counters are zero by
        // construction — matching the committed baseline.
        emit_and_gate(t_start.elapsed().as_secs_f64(), 0, 0);
        println!("--quick: skipping engine sweep");
        return;
    }

    // Experiment-engine sweep: the paper-style grid (Kratos suite x
    // {baseline, DD5} x 3 seeds), serial vs parallel.  Both runs start
    // with a cold cache; results must match bit-for-bit (the engine's
    // determinism contract), so the wall-clock delta is pure scheduling.
    let sweep = ExperimentPlan {
        benches: kratos_suite(&params),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.15,
            route: false,
            ..Default::default()
        },
    };
    let grid_cells = sweep.benches.len() * sweep.variants.len() * sweep.flow.seeds.len();
    // Warm the process-wide COFFE sizing cache for every swept variant so
    // neither timed run pays the one-time Arch::coffe cost.
    for &v in &sweep.variants {
        let _ = Arch::coffe(v);
    }
    let t0 = Instant::now();
    let serial = Engine::new(1).run(&sweep);
    let t_serial = t0.elapsed().as_secs_f64();

    let workers = default_workers();
    let engine = Engine::new(workers);
    let t1 = Instant::now();
    let parallel = engine.run(&sweep);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.iter().flatten().zip(parallel.iter().flatten()) {
        assert!(
            a.alms == b.alms && a.cpd_ns == b.cpd_ns && a.adp == b.adp,
            "parallel engine diverged from serial on {}",
            a.name
        );
    }
    let st = &engine.cache.stats;
    use std::sync::atomic::Ordering::Relaxed;
    println!("engine sweep ({grid_cells} cells)  serial {t_serial:>8.2} s");
    println!(
        "engine sweep ({grid_cells} cells)  x{workers:<2} jobs {t_parallel:>6.2} s  ({:.2}x speedup)",
        t_serial / t_parallel.max(1e-9)
    );
    println!(
        "artifact cache: map {} misses / {} hits, pack {} misses / {} hits",
        st.map_misses.load(Relaxed),
        st.map_hits.load(Relaxed),
        st.pack_misses.load(Relaxed),
        st.pack_hits.load(Relaxed)
    );

    // Robustness counters over the fault-free sweep: any failed seed or
    // ladder rescue here is a regression (the baseline pins them at 0).
    let (sweep_failed, sweep_escalated) = parallel
        .iter()
        .flatten()
        .fold((0usize, 0usize), |acc, r| (acc.0 + r.failed_seeds, acc.1 + r.escalations));
    emit_and_gate(t_start.elapsed().as_secs_f64(), sweep_failed, sweep_escalated);
}
