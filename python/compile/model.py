"""L2: the placer's batched cost model as a JAX computation.

Wraps the L1 Pallas kernel (``kernels.hpwl``) with the pieces the rust
placer consumes per evaluation:

  * weighted HPWL total (f32[1]),
  * RUDY congestion map (f32[GRID, GRID]),
  * congestion overflow penalty (f32[1]) — total demand above a per-bin
    capacity, the placer's routability pressure term.

The rust coordinator (rust/src/place/kernel_accel.rs) feeds net bounding
boxes padded to a size bucket and reads the three outputs back.  This
module is build-time only; ``aot.py`` lowers it to HLO text per bucket and
the rust PJRT runtime executes the artifact — python is never on the
request path.
"""

import jax.numpy as jnp

from .kernels.hpwl import GRID, placement_cost_pallas

# Padded net-count buckets; rust picks the smallest bucket >= live net count.
BUCKETS = (1024, 4096, 16384)


def placement_cost(xmin, xmax, ymin, ymax, w, valid, capacity):
    """Full placement cost model.

    Args:
      xmin..ymax: f32[N] inclusive net bounding boxes in bin coordinates.
      w:          f32[N] per-net criticality weights.
      valid:      f32[N] 1.0 for live nets, 0.0 for padding.
      capacity:   f32[1] per-bin routing capacity for the overflow penalty.

    Returns (whpwl f32[1], cong f32[GRID, GRID], overflow f32[1]).
    """
    whpwl, cong = placement_cost_pallas(xmin, xmax, ymin, ymax, w, valid)
    overflow = jnp.sum(jnp.maximum(cong - capacity, 0.0))[None]
    return whpwl, cong, overflow
