//! Deterministic xoshiro256** PRNG.
//!
//! Every stochastic stage of the CAD flow (placer annealing, benchmark
//! generation, packer tie-breaking) takes an explicit seed so that runs are
//! reproducible and multi-seed averaging (the paper runs 3 seeds per
//! experiment) is well-defined.

/// xoshiro256** — fast, high-quality, dependency-free.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give unrelated
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 64-bit modulo bias over CAD-sized n (< 2^32) is negligible.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(8); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen_lo |= v == -2;
            seen_hi |= v == 2;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(2);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[r.below(10)] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
