//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! placer move evaluation (incremental cost cache), router A* (serial vs
//! sharded PathFinder), the levelized wave-parallel front-end (mapper /
//! packer / STA, serial vs sharded — the PR-3 acceptance numbers), and
//! the PJRT kernel evaluation latency. No criterion offline — simple
//! timed loops with enough iterations for stable medians.
//!
//! Front-end medians are also emitted as machine-readable
//! `BENCH_PR3.json` (stage, median seconds at jobs=1 / jobs=N, speedup)
//! so CI can archive the perf trajectory across PRs.
//!
//! `--quick` runs a CI-smoke subset: single iterations, the router and
//! front-end determinism checks, no engine sweep.
use std::time::Instant;

use double_duty::arch::{Arch, ArchVariant};
use double_duty::bench_suites::{kratos_suite, BenchParams};
use double_duty::coordinator::default_workers;
use double_duty::flow::engine::{Engine, ExperimentPlan};
use double_duty::flow::FlowOpts;
use double_duty::netlist::{Netlist, NetlistIndex, PackIndex};
use double_duty::pack::{pack, pack_with, PackOpts};
use double_duty::place::cost::{IncrementalCost, NetModel};
use double_duty::place::{place, PlaceOpts};
use double_duty::route::{route, RouteOpts, Routing};
use double_duty::techmap::{map_circuit, map_circuit_with, MapOpts};
use double_duty::timing::{sta_with, TimingReport};

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    if per > 0.1 {
        println!("{name:<28} {:>10.1} ms/iter", per * 1e3);
    } else {
        println!("{name:<28} {:>10.1} us/iter", per * 1e6);
    }
}

/// Median wall-clock seconds of `iters` runs (after one warmup).
fn median_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f();
    let mut ts = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        ts.push(t0.elapsed().as_secs_f64());
    }
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn netlists_identical(a: &Netlist, b: &Netlist) -> bool {
    a.num_chains == b.num_chains
        && a.inputs == b.inputs
        && a.outputs == b.outputs
        && a.cells.len() == b.cells.len()
        && a.nets.len() == b.nets.len()
        && a.cells.iter().zip(b.cells.iter()).all(|(x, y)| {
            x.kind == y.kind && x.name == y.name && x.ins == y.ins && x.outs == y.outs
        })
        && a.nets.iter().zip(b.nets.iter()).all(|(x, y)| {
            x.name == y.name && x.driver == y.driver && x.sinks == y.sinks
        })
}

fn packings_identical(a: &double_duty::pack::Packing, b: &double_duty::pack::Packing) -> bool {
    a.variant == b.variant
        && a.chain_macros == b.chain_macros
        && a.ios == b.ios
        && a.alms.len() == b.alms.len()
        && a.lbs.len() == b.lbs.len()
        && a.alms.iter().zip(b.alms.iter()).all(|(x, y)| {
            x.adder_bits == y.adder_bits
                && x.operand_paths == y.operand_paths
                && x.logic_luts == y.logic_luts
                && x.logic_halves == y.logic_halves
                && x.ffs == y.ffs
                && x.gen_inputs == y.gen_inputs
                && x.z_inputs == y.z_inputs
                && x.outputs == y.outputs
                && x.chain == y.chain
        })
        && a.lbs.iter().zip(b.lbs.iter()).all(|(x, y)| {
            x.alms == y.alms
                && x.inputs == y.inputs
                && x.outputs == y.outputs
                && x.chains == y.chains
        })
        && a.stats.alms == b.stats.alms
        && a.stats.lbs == b.stats.lbs
        && a.stats.adder_bits == b.stats.adder_bits
        && a.stats.luts == b.stats.luts
        && a.stats.absorbed_luts == b.stats.absorbed_luts
        && a.stats.concurrent_luts == b.stats.concurrent_luts
        && a.stats.ffs == b.stats.ffs
        && a.stats.ios == b.stats.ios
}

fn reports_identical(a: &TimingReport, b: &TimingReport) -> bool {
    a.cpd_ps.to_bits() == b.cpd_ps.to_bits()
        && a.net_crit.len() == b.net_crit.len()
        && a.arrival.len() == b.arrival.len()
        && a.net_crit
            .iter()
            .zip(b.net_crit.iter())
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && a.arrival
            .iter()
            .zip(b.arrival.iter())
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn routing_identical(a: &Routing, b: &Routing) -> bool {
    a.success == b.success
        && a.iterations == b.iterations
        && a.wirelength == b.wirelength
        && a.sink_hops == b.sink_hops
        && a.net_nodes == b.net_nodes
        && a.channel_util == b.channel_util
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let params = BenchParams::default();
    let suite = kratos_suite(&params);
    let bench = &suite[2]; // gemmt: the hotpath representative
    let circ = bench.generate();
    let arch = Arch::coffe(ArchVariant::Dd5);
    let reps = |full: usize| if quick { 1 } else { full };

    timed("synth+map gemmt", reps(5), || {
        let c = bench.generate();
        let _ = map_circuit(&c, &MapOpts::default());
    });

    let nl = map_circuit(&circ, &MapOpts::default());
    timed("pack gemmt", reps(10), || {
        let _ = pack(&nl, &arch, &PackOpts::default());
    });

    let packing = pack(&nl, &arch, &PackOpts::default());
    timed("place gemmt (effort 0.3)", reps(3), || {
        let _ = place(&nl, &packing, &arch,
                      &PlaceOpts { effort: 0.3, ..Default::default() });
    });

    let pl = place(&nl, &packing, &arch, &PlaceOpts { effort: 0.3, ..Default::default() });
    let mut model = NetModel::build(&nl, &packing);
    model.set_weights(&[], false);

    timed("full_cost (rust)", reps(200), || {
        let _ = model.full_cost(&pl.lb_loc, &pl.io_loc);
    });
    let moved = [(0usize, double_duty::arch::device::Loc::new(2, 2))];
    timed("move_delta (scratch)", reps(20_000), || {
        let _ = model.move_delta(&pl.lb_loc, &pl.io_loc, &moved);
    });
    let inc = IncrementalCost::new(&model, &pl.lb_loc, &pl.io_loc);
    timed("move_delta (incremental)", reps(20_000), || {
        let _ = inc.move_delta(&model, &pl.lb_loc, &pl.io_loc, &moved);
    });

    match double_duty::place::kernel_accel::KernelCost::try_new(model.num_nets()) {
        Ok(mut k) => {
            timed("full_cost+congestion (PJRT)", reps(50), || {
                let _ = k.evaluate_cached(&model, &inc, &pl.device).unwrap();
            });
        }
        Err(e) => println!("PJRT kernel unavailable: {e}"),
    }

    timed("sta gemmt", reps(50), || {
        let _ = double_duty::timing::sta(&nl, &packing, &arch, |_, _, _| 150.0);
    });

    // --- Router: serial vs sharded PathFinder on the largest Kratos
    // circuit (by mapped cell count).  The ISSUE-2 acceptance bar is
    // >1.5x at 4 jobs; results must be bit-identical (the rrg
    // snapshot/reduce determinism contract).
    let (big_circ, big_nl, big_name) = if quick {
        (circ.clone(), nl.clone(), bench.name.clone())
    } else {
        suite
            .iter()
            .map(|b| {
                let c = b.generate();
                let n = map_circuit(&c, &MapOpts::default());
                (c, n, b.name.clone())
            })
            .max_by_key(|(_, nl, _)| nl.cells.len())
            .expect("non-empty suite")
    };
    let big_pack = pack(&big_nl, &arch, &PackOpts::default());
    let big_pl = place(&big_nl, &big_pack, &arch,
                       &PlaceOpts { effort: 0.3, ..Default::default() });
    let mut big_model = NetModel::build(&big_nl, &big_pack);
    big_model.set_weights(&[], false);

    let route_jobs = if quick { 2 } else { 4 };
    let route_reps = reps(3);
    let mut serial_route = None;
    let t0 = Instant::now();
    for _ in 0..route_reps {
        serial_route = Some(route(&big_model, &big_pl, &arch,
                                  &RouteOpts { jobs: 1, ..Default::default() }));
    }
    let t_serial = t0.elapsed().as_secs_f64() / route_reps as f64;
    let mut sharded_route = None;
    let t1 = Instant::now();
    for _ in 0..route_reps {
        sharded_route = Some(route(&big_model, &big_pl, &arch,
                                   &RouteOpts { jobs: route_jobs, ..Default::default() }));
    }
    let t_sharded = t1.elapsed().as_secs_f64() / route_reps as f64;
    let (sr, pr) = (serial_route.unwrap(), sharded_route.unwrap());
    assert!(routing_identical(&sr, &pr),
            "sharded router diverged from serial on {big_name}");
    println!("route {big_name:<18} jobs=1 {:>8.1} ms", t_serial * 1e3);
    println!(
        "route {big_name:<18} jobs={route_jobs} {:>7.1} ms  ({:.2}x speedup, {} iters, bit-identical)",
        t_sharded * 1e3,
        t_serial / t_sharded.max(1e-9),
        sr.iterations
    );

    // --- Front-end: levelized wave-parallel mapper / packer / STA on the
    // largest Kratos circuit, jobs=1 vs jobs=default_workers() (the PR-3
    // acceptance comparison).  Every parallel artifact is checked
    // bit-identical against its serial twin before any timing is
    // reported; medians land in BENCH_PR3.json for the CI artifact.
    let fe_jobs = default_workers().max(2);

    let map_par = map_circuit_with(&big_circ, &MapOpts::default(), fe_jobs);
    assert!(netlists_identical(&big_nl, &map_par),
            "parallel mapper diverged from serial on {big_name}");
    let map_s1 = median_secs(reps(3), || {
        let _ = map_circuit_with(&big_circ, &MapOpts::default(), 1);
    });
    let map_sn = median_secs(reps(3), || {
        let _ = map_circuit_with(&big_circ, &MapOpts::default(), fe_jobs);
    });

    let pack_par = pack_with(&big_nl, &arch, &PackOpts::default(), fe_jobs);
    assert!(packings_identical(&big_pack, &pack_par),
            "parallel packer diverged from serial on {big_name}");
    let pack_s1 = median_secs(reps(5), || {
        let _ = pack_with(&big_nl, &arch, &PackOpts::default(), 1);
    });
    let pack_sn = median_secs(reps(5), || {
        let _ = pack_with(&big_nl, &arch, &PackOpts::default(), fe_jobs);
    });

    let idx = NetlistIndex::build(&big_nl);
    let pidx = PackIndex::build(&big_nl, &big_pack);
    let sta_delay = |net: u32, _c: u32, pin: u8| 120.0 + (net % 5) as f64 + pin as f64;
    let sta_1 = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, 1);
    let sta_n = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, fe_jobs);
    assert!(reports_identical(&sta_1, &sta_n),
            "parallel STA diverged from serial on {big_name}");
    let sta_s1 = median_secs(reps(15), || {
        let _ = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, 1);
    });
    let sta_sn = median_secs(reps(15), || {
        let _ = sta_with(&big_nl, &idx, &pidx, &big_pack, &arch, sta_delay, fe_jobs);
    });

    let speedup = |s1: f64, sn: f64| s1 / sn.max(1e-12);
    for (stage, s1, sn) in [
        ("map", map_s1, map_sn),
        ("pack", pack_s1, pack_sn),
        ("sta", sta_s1, sta_sn),
    ] {
        println!(
            "{stage:<5} {big_name:<18} jobs=1 {:>8.2} ms | jobs={fe_jobs} {:>8.2} ms  ({:.2}x, bit-identical)",
            s1 * 1e3,
            sn * 1e3,
            speedup(s1, sn)
        );
    }

    let json = format!(
        "{{\n  \"bench\": \"{big_name}\",\n  \"cells\": {},\n  \"jobs\": {fe_jobs},\n  \"stages\": [\n    \
         {{\"stage\": \"map\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
         {{\"stage\": \"pack\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}},\n    \
         {{\"stage\": \"sta\", \"median_s_jobs1\": {:.6}, \"median_s\": {:.6}, \"speedup\": {:.3}}}\n  ]\n}}\n",
        big_nl.cells.len(),
        map_s1, map_sn, speedup(map_s1, map_sn),
        pack_s1, pack_sn, speedup(pack_s1, pack_sn),
        sta_s1, sta_sn, speedup(sta_s1, sta_sn),
    );
    match std::fs::write("BENCH_PR3.json", &json) {
        Ok(()) => println!("front-end medians written to BENCH_PR3.json"),
        Err(e) => println!("could not write BENCH_PR3.json: {e}"),
    }

    if quick {
        println!("--quick: skipping engine sweep");
        return;
    }

    // Experiment-engine sweep: the paper-style grid (Kratos suite x
    // {baseline, DD5} x 3 seeds), serial vs parallel.  Both runs start
    // with a cold cache; results must match bit-for-bit (the engine's
    // determinism contract), so the wall-clock delta is pure scheduling.
    let sweep = ExperimentPlan {
        benches: kratos_suite(&params),
        variants: vec![ArchVariant::Baseline, ArchVariant::Dd5],
        flow: FlowOpts {
            seeds: vec![1, 2, 3],
            place_effort: 0.15,
            route: false,
            ..Default::default()
        },
    };
    let grid_cells = sweep.benches.len() * sweep.variants.len() * sweep.flow.seeds.len();
    // Warm the process-wide COFFE sizing cache for every swept variant so
    // neither timed run pays the one-time Arch::coffe cost.
    for &v in &sweep.variants {
        let _ = Arch::coffe(v);
    }
    let t0 = Instant::now();
    let serial = Engine::new(1).run(&sweep);
    let t_serial = t0.elapsed().as_secs_f64();

    let workers = default_workers();
    let engine = Engine::new(workers);
    let t1 = Instant::now();
    let parallel = engine.run(&sweep);
    let t_parallel = t1.elapsed().as_secs_f64();

    for (a, b) in serial.iter().flatten().zip(parallel.iter().flatten()) {
        assert!(
            a.alms == b.alms && a.cpd_ns == b.cpd_ns && a.adp == b.adp,
            "parallel engine diverged from serial on {}",
            a.name
        );
    }
    let st = &engine.cache.stats;
    use std::sync::atomic::Ordering::Relaxed;
    println!("engine sweep ({grid_cells} cells)  serial {t_serial:>8.2} s");
    println!(
        "engine sweep ({grid_cells} cells)  x{workers:<2} jobs {t_parallel:>6.2} s  ({:.2}x speedup)",
        t_serial / t_parallel.max(1e-9)
    );
    println!(
        "artifact cache: map {} misses / {} hits, pack {} misses / {} hits",
        st.map_misses.load(Relaxed),
        st.map_hits.load(Relaxed),
        st.pack_misses.load(Relaxed),
        st.pack_hits.load(Relaxed)
    );
}
