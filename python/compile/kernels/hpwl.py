"""L1 Pallas kernel: batched placement cost (weighted HPWL + RUDY congestion).

This is the hot spot of the timing-driven placer: given the bounding boxes of
every net in the design (padded to a fixed bucket size N), compute

  * the criticality-weighted half-perimeter wirelength (wHPWL), and
  * a RUDY-style routing-demand map over a fixed GY x GX bin grid.

The kernel is written for TPU-style tiling: the net axis is blocked with a
``BlockSpec`` grid (HBM -> VMEM streaming of net-coordinate blocks) and the
congestion map is accumulated across grid steps in an output ref that stays
resident in VMEM.  All compute is dense f32 (VPU-friendly); there is no
scatter.  ``interpret=True`` is mandatory in this environment — real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

Coordinate convention: boxes are *inclusive* bin coordinates in
``[0, GRID)``; a net confined to one bin has ``xmin == xmax``.  RUDY demand
of a net is ``w * (dx + dy) / (dx * dy)`` with ``dx = xmax - xmin + 1``,
spread uniformly over the covered bins.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed congestion-map geometry, shared with rust/src/place/kernel_accel.rs.
GRID = 64
# Net-axis block: 256 nets * (64x64 map broadcast) ~= 4 MiB VMEM per operand
# block at f32, comfortably inside a TPU core's ~16 MiB VMEM.
NET_BLOCK = 256


def _cost_kernel(xmin_ref, xmax_ref, ymin_ref, ymax_ref, w_ref, valid_ref,
                 hpwl_ref, cong_ref):
    """One net-block step: accumulate wHPWL scalar and RUDY map."""
    step = pl.program_id(0)

    xmin = xmin_ref[...]
    xmax = xmax_ref[...]
    ymin = ymin_ref[...]
    ymax = ymax_ref[...]
    w = w_ref[...] * valid_ref[...]

    # Half-perimeter wirelength, criticality-weighted.
    span = (xmax - xmin) + (ymax - ymin)
    whpwl = jnp.sum(w * span)

    # RUDY demand: net n covers inclusive bins [xmin, xmax] x [ymin, ymax].
    dx = xmax - xmin + 1.0
    dy = ymax - ymin + 1.0
    dens = w * (dx + dy) / (dx * dy)

    cells = jax.lax.iota(jnp.float32, GRID)
    # Overlap of [min, max+1) with bin [j, j+1), clipped to [0, 1].
    ox = jnp.clip(jnp.minimum(xmax[:, None] + 1.0, cells[None, :] + 1.0)
                  - jnp.maximum(xmin[:, None], cells[None, :]), 0.0, 1.0)
    oy = jnp.clip(jnp.minimum(ymax[:, None] + 1.0, cells[None, :] + 1.0)
                  - jnp.maximum(ymin[:, None], cells[None, :]), 0.0, 1.0)
    # (B,GY) x (B,GX) -> (GY,GX), scaled per net by its demand density.
    cong = jnp.einsum("by,bx->yx", oy * dens[:, None], ox,
                      preferred_element_type=jnp.float32)

    @pl.when(step == 0)
    def _init():
        hpwl_ref[...] = jnp.zeros_like(hpwl_ref)
        cong_ref[...] = jnp.zeros_like(cong_ref)

    hpwl_ref[...] += whpwl[None]
    cong_ref[...] += cong

    # `step` keeps the grid axis observably used even when n == NET_BLOCK.
    del step


@functools.partial(jax.jit, static_argnames=())
def placement_cost_pallas(xmin, xmax, ymin, ymax, w, valid):
    """Batched placement cost via the Pallas kernel.

    All inputs are f32[N] with N a multiple of NET_BLOCK (callers pad and
    mask with ``valid``).  Returns ``(whpwl f32[1], cong f32[GRID, GRID])``.
    """
    n = xmin.shape[0]
    assert n % NET_BLOCK == 0, f"net count {n} not a multiple of {NET_BLOCK}"
    steps = n // NET_BLOCK

    in_spec = pl.BlockSpec((NET_BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _cost_kernel,
        grid=(steps,),
        in_specs=[in_spec] * 6,
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((GRID, GRID), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((GRID, GRID), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(xmin, xmax, ymin, ymax, w, valid)
