//! Serve auditor: re-verifies the daemon's job bookkeeping from the raw
//! queue snapshots ([`crate::flow::engine::JobSnapshot`]), without
//! calling any queue or server code path — a scheduler bug cannot
//! self-certify.
//!
//! The resident queue promises three invariants the daemon's clients
//! rely on: job lifecycles are strictly
//! `Scheduled → Running → Done | Failed` with progress events only while
//! running, identical submissions coalesce onto one job (the
//! cache-dedup, execute-once story), and a job's terminal state agrees
//! with the result it carries.  `dd serve` runs this auditor over its
//! full job history at shutdown; `rust/tests/serve.rs` mutates snapshots
//! to prove each code fires.
//!
//! Codes (stable order of checks):
//!
//! * `serve.state-transition` — per job, replayed from the event log:
//!   the log starts at `Scheduled`, transitions only along the lifecycle
//!   edges, has no events after a terminal state, numbers its seed
//!   events `0, 1, 2, …` strictly inside `Running`, and ends in exactly
//!   the state the snapshot reports.
//! * `serve.result-consistency` — `Done` jobs carry a result with zero
//!   failed seeds and one seed event per seed; `Failed` jobs carry a
//!   result recording the failure; non-terminal jobs carry no result.
//! * `serve.dedup-key` — no two queue jobs share a submission key (a
//!   duplicate means the dedup index failed to coalesce identical
//!   submissions into one execution).

use crate::flow::engine::{JobEvent, JobSnapshot, JobState};

use super::{Severity, Stage, Violation};

fn err(code: &'static str, location: impl Into<String>, message: impl Into<String>) -> Violation {
    Violation::new(Stage::Serve, Severity::Error, code, location, message)
}

/// Audit a queue's full job history (snapshots in id order).
pub fn audit_serve(jobs: &[JobSnapshot]) -> Vec<Violation> {
    let mut vs = Vec::new();
    for j in jobs {
        let loc = || format!("job j{} ({}/{})", j.id, j.variant.name(), j.bench);
        let name = |c: Option<JobState>| c.map(JobState::name).unwrap_or("(no state yet)");

        // 1. Replay the event log through the lifecycle state machine.
        let mut cur: Option<JobState> = None;
        let mut seed_events = 0usize;
        for e in &j.events {
            match e {
                JobEvent::State(s) => {
                    let legal = matches!(
                        (cur, s),
                        (None, JobState::Scheduled)
                            | (Some(JobState::Scheduled), JobState::Running)
                            | (Some(JobState::Running), JobState::Done)
                            | (Some(JobState::Running), JobState::Failed)
                    );
                    if !legal {
                        vs.push(err(
                            "serve.state-transition",
                            loc(),
                            format!("illegal transition {} -> {}", name(cur), s.name()),
                        ));
                    }
                    cur = Some(*s);
                }
                JobEvent::Seed { index, .. } => {
                    if cur != Some(JobState::Running) {
                        vs.push(err(
                            "serve.state-transition",
                            loc(),
                            format!("seed event while {}", name(cur)),
                        ));
                    }
                    if *index != seed_events {
                        vs.push(err(
                            "serve.state-transition",
                            loc(),
                            format!("seed event index {index}, expected {seed_events}"),
                        ));
                    }
                    seed_events += 1;
                }
            }
        }
        if cur != Some(j.state) {
            vs.push(err(
                "serve.state-transition",
                loc(),
                format!("snapshot state {} but event log ends {}", j.state.name(), name(cur)),
            ));
        }

        // 2. Terminal state vs the result it carries.
        match j.state {
            JobState::Done => match &j.result {
                None => vs.push(err("serve.result-consistency", loc(), "done job has no result")),
                Some(r) => {
                    if r.failed_seeds != 0 {
                        vs.push(err(
                            "serve.result-consistency",
                            loc(),
                            format!("done job records {} failed seed(s)", r.failed_seeds),
                        ));
                    }
                    if seed_events != j.n_seeds {
                        vs.push(err(
                            "serve.result-consistency",
                            loc(),
                            format!(
                                "done job streamed {seed_events} of {} seed event(s)",
                                j.n_seeds
                            ),
                        ));
                    }
                }
            },
            JobState::Failed => match &j.result {
                None => {
                    vs.push(err("serve.result-consistency", loc(), "failed job has no result"))
                }
                Some(r) => {
                    if r.failed_seeds == 0 && r.errors.is_empty() {
                        vs.push(err(
                            "serve.result-consistency",
                            loc(),
                            "failed job carries no failure record",
                        ));
                    }
                }
            },
            JobState::Scheduled | JobState::Running => {
                if j.result.is_some() {
                    vs.push(err(
                        "serve.result-consistency",
                        loc(),
                        "non-terminal job carries a result",
                    ));
                }
            }
        }
        if seed_events > j.n_seeds {
            vs.push(err(
                "serve.result-consistency",
                loc(),
                format!("{seed_events} seed event(s) for {} seed(s)", j.n_seeds),
            ));
        }
    }

    // 3. Submission keys are unique across the whole history.  Sorted
    // scan (never a hash-order iteration), reported in (key, id) order —
    // stable for any submission interleaving.
    let mut keys: Vec<(u64, usize)> = jobs.iter().map(|j| (j.key, j.id)).collect();
    keys.sort_unstable();
    for w in keys.windows(2) {
        if w[0].0 == w[1].0 {
            vs.push(err(
                "serve.dedup-key",
                format!("jobs j{} and j{}", w[0].1, w[1].1),
                "two queue jobs share one submission key: dedup failed to coalesce them",
            ));
        }
    }
    vs
}
