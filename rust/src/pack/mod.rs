//! ALM / logic-block packing — where the Double-Duty legality lives.
//!
//! The packer turns a mapped netlist into ALM instances and clusters them
//! into logic blocks, enforcing the per-variant legality rules from §III:
//!
//! * **Baseline**: every adder operand enters through one of the ALM's
//!   4-LUTs — either *absorbing* a fanout-1 (<=4 input) driver LUT or
//!   burning a LUT as a route-through.  An ALM using its adders therefore
//!   exposes no independent LUT outputs.
//! * **DD5**: operands may bypass the LUTs through the Z1–Z4 inputs, so an
//!   ALM half whose operands both arrive via Z can host an independent
//!   <=5-input LUT on O2/O4 — the *concurrent* usage the paper enables.
//! * **DD6**: additionally, a 6-LUT (both halves) may be used concurrently
//!   with both adders when all four operands arrive via Z.
//!
//! The LB stage mirrors VPR's greedy seed-based clustering with an external
//! input-pin budget (`target_ext_pin_util` x 60) and carry-chain macros
//! that must occupy consecutive ALM slots (and consecutive LBs when a
//! chain spans blocks).
//!
//! Every legality rule above is re-verified from the artifact alone by
//! the independent [`crate::check::audit_packing`] auditor — changes to
//! the rules must land with the matching auditor + mutation-test update
//! (the check-layer contract).

pub mod cluster;

use std::collections::{HashMap, HashSet};

use crate::arch::{Arch, ArchVariant};
use crate::netlist::{CellId, CellKind, Netlist, NetId};

pub use cluster::{cluster_lbs, PackedLb};

/// Unrelated-clustering policy (VPR's `--allow_unrelated_clustering`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unrelated {
    /// Never pack unconnected cells together.
    Off,
    /// Allow when attraction finds nothing (VPR "auto"; our default).
    Auto,
    /// Aggressively pack for density, ignoring timing (Fig. 9 stress test).
    On,
}

/// Packer options.
#[derive(Clone, Copy, Debug)]
pub struct PackOpts {
    pub unrelated: Unrelated,
}

impl Default for PackOpts {
    fn default() -> Self {
        PackOpts { unrelated: Unrelated::Auto }
    }
}

/// How an adder operand reaches the adder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OperandPath {
    /// Constant operand (tied off inside the ALM).
    Const,
    /// Absorbed driver LUT (the LUT cell lives inside this ALM).
    AbsorbedLut(CellId),
    /// Route-through LUT (burns a LUT unit; baseline only).
    RouteThrough,
    /// Z-input bypass (DD variants only).
    ZBypass,
}

/// One packed ALM instance.
#[derive(Clone, Debug, Default)]
pub struct PackedAlm {
    /// Adder-bit cells hosted (0..=2, consecutive positions of one chain).
    pub adder_bits: Vec<CellId>,
    /// Operand entry paths, two per adder bit ([a, b] each).
    pub operand_paths: Vec<[OperandPath; 2]>,
    /// Independent logic LUTs (<=2 on DD5 halves, or one 6-LUT on DD6).
    pub logic_luts: Vec<CellId>,
    /// ALM halves consumed by `logic_luts`: 1 per <=5-LUT, 2 per 6-LUT
    /// (a 6-LUT fractures across both halves' 4-LUT units).
    pub logic_halves: usize,
    /// FF cells packed with this ALM.
    pub ffs: Vec<CellId>,
    /// Distinct general-input nets (A–H budget: 8).
    pub gen_inputs: HashSet<NetId>,
    /// Distinct Z-input nets (budget: 4; DD only).
    pub z_inputs: HashSet<NetId>,
    /// Nets driven by this ALM that leave it.
    pub outputs: HashSet<NetId>,
    /// Chain id if this ALM hosts adder bits.
    pub chain: Option<u32>,
}

impl PackedAlm {
    /// LUT units consumed (of 4): absorbed feeders + route-throughs + logic.
    pub fn lut_units(&self) -> usize {
        let feeders = self
            .operand_paths
            .iter()
            .flatten()
            .filter(|p| matches!(p, OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough))
            .count();
        let logic: usize = self.logic_halves * 2; // one half = two 4-LUT units
        feeders + logic
    }

    /// Which halves are free to host an independent logic LUT.
    /// Half `i` hosts adder bit `i`'s feeders; it is free iff it has no
    /// adder bit or its operands all bypass via Z/const.
    pub fn free_halves(&self) -> usize {
        let mut free = 0;
        for h in 0..2 {
            let busy = match self.operand_paths.get(h) {
                Some(paths) => paths.iter().any(|p| {
                    matches!(p, OperandPath::AbsorbedLut(_) | OperandPath::RouteThrough)
                }),
                // A half with no adder bit at all is also free.
                None => false,
            };
            if !busy {
                free += 1;
            }
        }
        free - self.logic_halves.min(free)
    }

    pub fn uses_adders(&self) -> bool {
        !self.adder_bits.is_empty()
    }
}

/// Packing statistics (the numbers Figs. 6/9 and Table IV report).
#[derive(Clone, Debug, Default)]
pub struct PackStats {
    pub alms: usize,
    pub lbs: usize,
    pub adder_bits: usize,
    pub luts: usize,
    /// LUTs absorbed as adder feeders.
    pub absorbed_luts: usize,
    /// Independent LUTs packed into adder-using ALMs (impossible on
    /// baseline) — the paper's "Concurrent 5-LUTs".
    pub concurrent_luts: usize,
    pub ffs: usize,
    pub ios: usize,
}

/// A fully packed design.
#[derive(Clone, Debug)]
pub struct Packing {
    pub variant: ArchVariant,
    pub alms: Vec<PackedAlm>,
    pub lbs: Vec<PackedLb>,
    /// Per chain: ordered list of LB indices it spans (placement macro).
    pub chain_macros: Vec<Vec<usize>>,
    /// I/O cells (Input/Output cells of the netlist), each its own pad.
    pub ios: Vec<CellId>,
    pub stats: PackStats,
}

/// Entry point: pack `nl` for `arch` (serial convenience wrapper over
/// [`pack_with`]).
pub fn pack(nl: &Netlist, arch: &Arch, opts: &PackOpts) -> Packing {
    pack_with(nl, arch, opts, 1)
}

/// [`pack`] with the clusterer's candidate-attraction scoring sharded over
/// `jobs` workers (commits stay serial and in fixed order, so the packing
/// is bit-identical for any `jobs` value — see
/// [`cluster::cluster_lbs`]).
pub fn pack_with(nl: &Netlist, arch: &Arch, opts: &PackOpts, jobs: usize) -> Packing {
    let dd = arch.variant.concurrent_lut5();

    // --- Identify absorbable feeder LUTs. --------------------------------
    // A LUT can be absorbed into an adder ALM when it has <= 4 inputs and
    // its only sink is that single adder operand.
    let mut absorbed: HashMap<CellId, CellId> = HashMap::new(); // lut -> adder bit
    let absorbable = |net: NetId| -> Option<CellId> {
        let netref = &nl.nets[net as usize];
        let (drv, _) = netref.driver?;
        if netref.sinks.len() != 1 {
            return None;
        }
        match nl.cells[drv as usize].kind {
            CellKind::Lut { k, .. } if k <= 4 => Some(drv),
            _ => None,
        }
    };

    // --- Build adder ALMs from chains. -----------------------------------
    let mut alms: Vec<PackedAlm> = Vec::new();
    let mut cell_alm: HashMap<CellId, usize> = HashMap::new();
    // Per chain: list of ALM indices in chain order.
    let mut chain_alms: Vec<Vec<usize>> = vec![Vec::new(); nl.num_chains as usize];

    for chain in 0..nl.num_chains {
        let bits = nl.chain_cells(chain);
        for pair in bits.chunks(2) {
            let mut alm = PackedAlm { chain: Some(chain), ..Default::default() };
            let alm_idx = alms.len();
            for &bit in pair {
                alm.adder_bits.push(bit);
                cell_alm.insert(bit, alm_idx);
                let cell = &nl.cells[bit as usize];
                let mut paths = [OperandPath::Const, OperandPath::Const];
                for (oi, &net) in cell.ins.iter().take(2).enumerate() {
                    let driver_kind = nl.nets[net as usize]
                        .driver
                        .map(|(c, _)| &nl.cells[c as usize].kind);
                    if matches!(driver_kind, Some(CellKind::Const(_))) {
                        paths[oi] = OperandPath::Const;
                        continue;
                    }
                    if let Some(lut) = absorbable(net) {
                        // Absorb the driver LUT into this ALM.
                        paths[oi] = OperandPath::AbsorbedLut(lut);
                        absorbed.insert(lut, bit);
                        cell_alm.insert(lut, alm_idx);
                        for &inet in &nl.cells[lut as usize].ins {
                            alm.gen_inputs.insert(inet);
                        }
                    } else if dd {
                        paths[oi] = OperandPath::ZBypass;
                        alm.z_inputs.insert(net);
                    } else {
                        paths[oi] = OperandPath::RouteThrough;
                        alm.gen_inputs.insert(net);
                    }
                }
                alm.operand_paths.push(paths);
                // Sum output leaves the ALM if it has external sinks.
                let sum = cell.outs[0];
                if !nl.nets[sum as usize].sinks.is_empty() {
                    alm.outputs.insert(sum);
                }
            }
            // Enforce the 8-general-input budget: spill absorbed feeders
            // (largest first) back to the LUT pool as route-through/Z.
            while alm.gen_inputs.len() > 8 {
                let spill = alm
                    .operand_paths
                    .iter()
                    .flatten()
                    .filter_map(|p| match p {
                        OperandPath::AbsorbedLut(l) => Some(*l),
                        _ => None,
                    })
                    .max_by_key(|&l| nl.cells[l as usize].ins.len());
                let Some(lut) = spill else { break };
                absorbed.remove(&lut);
                cell_alm.remove(&lut);
                // Recompute this ALM's operand paths and inputs.
                alm.gen_inputs.clear();
                alm.z_inputs.clear();
                for (bi, &bit) in alm.adder_bits.iter().enumerate() {
                    let cell = &nl.cells[bit as usize];
                    for (oi, &net) in cell.ins.iter().take(2).enumerate() {
                        match alm.operand_paths[bi][oi] {
                            OperandPath::AbsorbedLut(l) if l == lut => {
                                alm.operand_paths[bi][oi] = if dd {
                                    alm.z_inputs.insert(net);
                                    OperandPath::ZBypass
                                } else {
                                    alm.gen_inputs.insert(net);
                                    OperandPath::RouteThrough
                                };
                            }
                            OperandPath::AbsorbedLut(l) => {
                                for &inet in &nl.cells[l as usize].ins {
                                    alm.gen_inputs.insert(inet);
                                }
                            }
                            OperandPath::RouteThrough => {
                                alm.gen_inputs.insert(net);
                            }
                            OperandPath::ZBypass => {
                                alm.z_inputs.insert(net);
                            }
                            OperandPath::Const => {}
                        }
                    }
                }
            }
            chain_alms[chain as usize].push(alm_idx);
            alms.push(alm);
        }
    }

    // --- LUT pool: everything not absorbed. -------------------------------
    let lut_pool: Vec<CellId> = nl
        .cells
        .iter()
        .enumerate()
        .filter_map(|(i, c)| match c.kind {
            CellKind::Lut { .. } if !absorbed.contains_key(&(i as CellId)) => {
                Some(i as CellId)
            }
            _ => None,
        })
        .collect();

    let lut_k = |cell: CellId| -> u8 {
        match nl.cells[cell as usize].kind {
            CellKind::Lut { k, .. } => k,
            _ => unreachable!(),
        }
    };

    // Net -> pool LUT index for attraction lookups.
    let mut net_users: HashMap<NetId, Vec<CellId>> = HashMap::new();
    for &lut in &lut_pool {
        for &net in &nl.cells[lut as usize].ins {
            net_users.entry(net).or_default().push(lut);
        }
    }

    let mut placed: HashSet<CellId> = HashSet::new();
    let mut concurrent_luts = 0usize;

    // --- DD variants: fill free halves of adder ALMs. ---------------------
    if dd {
        let max_k_concurrent = if arch.variant.concurrent_lut6() { 6 } else { 5 };
        // Chains spanning multiple LBs become placement macros; stuffing
        // unrelated logic into them stretches that logic's nets across the
        // macro column and inflates CPD, so unrelated fill is restricted
        // to single-LB chains (attraction-based fill stays allowed).
        let chain_len: Vec<usize> = chain_alms.iter().map(|v| v.len()).collect();
        for alm_idx in 0..alms.len() {
            if !alms[alm_idx].uses_adders() {
                continue;
            }
            let in_macro = alms[alm_idx]
                .chain
                .map(|ch| chain_len[ch as usize] > arch.lb.alms as usize)
                .unwrap_or(false);
            loop {
                let free = alms[alm_idx].free_halves();
                if free == 0 {
                    break;
                }
                // Gather attracted candidates: LUTs sharing a net with this
                // ALM's current inputs/outputs.
                let mut cand: Option<CellId> = None;
                let mut best_shared = 0usize;
                let mut nets: Vec<NetId> = alms[alm_idx]
                    .gen_inputs
                    .iter()
                    .chain(alms[alm_idx].z_inputs.iter())
                    .chain(alms[alm_idx].outputs.iter())
                    .copied()
                    .collect();
                // HashSet iteration order is nondeterministic; sort so the
                // candidate scan (and its tie-breaks) is reproducible.
                nets.sort_unstable();
                for &net in &nets {
                    if let Some(users) = net_users.get(&net) {
                        for &lut in users {
                            if placed.contains(&lut) || absorbed.contains_key(&lut) {
                                continue;
                            }
                            let k = lut_k(lut);
                            let needs_halves = if k == 6 { 2 } else { 1 };
                            if k > max_k_concurrent || needs_halves > free {
                                continue;
                            }
                            let ins: HashSet<NetId> = nl.cells[lut as usize]
                                .ins
                                .iter()
                                .copied()
                                .collect();
                            let union: HashSet<NetId> = alms[alm_idx]
                                .gen_inputs
                                .union(&ins)
                                .copied()
                                .collect();
                            if union.len() > 8 {
                                continue;
                            }
                            let shared = ins
                                .iter()
                                .filter(|n| alms[alm_idx].gen_inputs.contains(n))
                                .count()
                                + 1;
                            if shared > best_shared {
                                best_shared = shared;
                                cand = Some(lut);
                            }
                        }
                    }
                }
                let unrelated_ok = match opts.unrelated {
                    Unrelated::On => true,
                    Unrelated::Auto => !in_macro,
                    Unrelated::Off => false,
                };
                if cand.is_none() && unrelated_ok {
                    // Unrelated fill (VPR's auto behaviour): take any
                    // fitting LUT — this is what converts DD5's free
                    // halves into the paper's concurrent-usage density.
                    cand = lut_pool.iter().copied().find(|&l| {
                        if placed.contains(&l) || absorbed.contains_key(&l) {
                            return false;
                        }
                        let k = lut_k(l);
                        let needs = if k == 6 { 2 } else { 1 };
                        if k > max_k_concurrent || needs > free {
                            return false;
                        }
                        let ins: HashSet<NetId> =
                            nl.cells[l as usize].ins.iter().copied().collect();
                        let union: HashSet<NetId> = alms[alm_idx]
                            .gen_inputs
                            .union(&ins)
                            .copied()
                            .collect();
                        union.len() <= 8
                    });
                }
                let Some(lut) = cand else { break };
                placed.insert(lut);
                cell_alm.insert(lut, alm_idx);
                for &inet in &nl.cells[lut as usize].ins {
                    alms[alm_idx].gen_inputs.insert(inet);
                }
                alms[alm_idx].outputs.insert(nl.cells[lut as usize].outs[0]);
                alms[alm_idx].logic_luts.push(lut);
                alms[alm_idx].logic_halves += if lut_k(lut) == 6 { 2 } else { 1 };
                concurrent_luts += 1;
            }
        }
    }

    // --- Remaining LUTs: pair into logic ALMs. ----------------------------
    let mut remaining: Vec<CellId> = lut_pool
        .iter()
        .copied()
        .filter(|l| !placed.contains(l))
        .collect();
    // Pair by shared inputs: sort by (first input net, k) so related LUTs
    // are adjacent, then greedily pair.
    remaining.sort_by_key(|&l| {
        let c = &nl.cells[l as usize];
        (c.ins.first().copied().unwrap_or(0), std::cmp::Reverse(c.ins.len()))
    });
    let mut i = 0;
    while i < remaining.len() {
        let a = remaining[i];
        let ka = lut_k(a);
        let mut alm = PackedAlm::default();
        let alm_idx = alms.len();
        for &inet in &nl.cells[a as usize].ins {
            alm.gen_inputs.insert(inet);
        }
        alm.outputs.insert(nl.cells[a as usize].outs[0]);
        alm.logic_luts.push(a);
        alm.logic_halves += if ka == 6 { 2 } else { 1 };
        cell_alm.insert(a, alm_idx);
        i += 1;
        if ka <= 5 {
            // Try to add a second <=5-LUT within the 8-input budget.
            let mut j = i;
            let limit = (i + 24).min(remaining.len()); // bounded lookahead
            while j < limit {
                let b = remaining[j];
                if lut_k(b) <= 5 {
                    let ins_b: HashSet<NetId> =
                        nl.cells[b as usize].ins.iter().copied().collect();
                    let union: HashSet<NetId> =
                        alm.gen_inputs.union(&ins_b).copied().collect();
                    let ok_unrelated = opts.unrelated != Unrelated::Off
                        || ins_b.iter().any(|n| alm.gen_inputs.contains(n));
                    if union.len() <= 8 && ok_unrelated {
                        alm.gen_inputs = union;
                        alm.outputs.insert(nl.cells[b as usize].outs[0]);
                        alm.logic_luts.push(b);
                        alm.logic_halves += 1; // partner is a <=5-LUT
                        cell_alm.insert(b, alm_idx);
                        remaining.remove(j);
                        break;
                    }
                }
                j += 1;
            }
        }
        alms.push(alm);
    }

    // --- FFs: pack with the ALM driving d when possible. -------------------
    let mut ff_overflow: Vec<CellId> = Vec::new();
    for (i, cell) in nl.cells.iter().enumerate() {
        if !matches!(cell.kind, CellKind::Ff) {
            continue;
        }
        let d_net = cell.ins[0];
        let host = nl.nets[d_net as usize]
            .driver
            .and_then(|(c, _)| cell_alm.get(&c).copied());
        match host {
            Some(a) if alms[a].ffs.len() < 4 => {
                alms[a].ffs.push(i as CellId);
                alms[a].outputs.insert(cell.outs[0]);
                cell_alm.insert(i as CellId, a);
            }
            _ => ff_overflow.push(i as CellId),
        }
    }
    for group in ff_overflow.chunks(4) {
        let mut alm = PackedAlm::default();
        let alm_idx = alms.len();
        for &ff in group {
            alm.ffs.push(ff);
            alm.gen_inputs.insert(nl.cells[ff as usize].ins[0]);
            alm.outputs.insert(nl.cells[ff as usize].outs[0]);
            cell_alm.insert(ff, alm_idx);
        }
        alms.push(alm);
    }

    // --- Cluster ALMs into LBs. -------------------------------------------
    let (lbs, chain_macros) = cluster::cluster_lbs(nl, arch, &alms, &chain_alms, opts, jobs);

    // --- I/Os. -------------------------------------------------------------
    let ios: Vec<CellId> = nl
        .cells
        .iter()
        .enumerate()
        .filter_map(|(i, c)| {
            matches!(c.kind, CellKind::Input | CellKind::Output).then_some(i as CellId)
        })
        .collect();

    let stats = PackStats {
        alms: alms.len(),
        lbs: lbs.len(),
        adder_bits: nl.num_adders(),
        luts: nl.num_luts(),
        absorbed_luts: absorbed.len(),
        concurrent_luts,
        ffs: nl.num_ffs(),
        ios: ios.len(),
    };

    Packing { variant: arch.variant, alms, lbs, chain_macros, ios, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::circuit::Circuit;
    use crate::synth::multiplier::{soft_mul, AdderAlgo};
    use crate::techmap::{map_circuit, MapOpts};

    fn mul_netlist(w: usize) -> Netlist {
        let mut c = Circuit::new("m");
        let x = c.pi_bus("x", w);
        let y = c.pi_bus("y", w);
        let p = soft_mul(&mut c, &x, &y, AdderAlgo::Wallace);
        c.po_bus("p", &p);
        map_circuit(&c, &MapOpts::default())
    }

    #[test]
    fn baseline_has_no_concurrent_luts() {
        let nl = mul_netlist(6);
        let arch = Arch::paper(ArchVariant::Baseline);
        let p = pack(&nl, &arch, &PackOpts::default());
        assert_eq!(p.stats.concurrent_luts, 0);
        assert!(p.stats.alms > 0);
        assert!(p.stats.lbs > 0);
    }

    #[test]
    fn dd5_packs_concurrent_luts_and_fewer_alms() {
        let nl = mul_netlist(6);
        let base = pack(&nl, &Arch::paper(ArchVariant::Baseline), &PackOpts::default());
        let dd5 = pack(&nl, &Arch::paper(ArchVariant::Dd5), &PackOpts::default());
        assert!(dd5.stats.alms <= base.stats.alms,
                "dd5 {} vs base {}", dd5.stats.alms, base.stats.alms);
    }

    #[test]
    fn alm_respects_input_budget() {
        let nl = mul_netlist(8);
        for v in [ArchVariant::Baseline, ArchVariant::Dd5, ArchVariant::Dd6] {
            let p = pack(&nl, &Arch::paper(v), &PackOpts::default());
            for alm in &p.alms {
                assert!(alm.gen_inputs.len() <= 8,
                        "{} gen inputs on {v:?}", alm.gen_inputs.len());
                assert!(alm.z_inputs.len() <= 4);
                assert!(alm.lut_units() <= 4, "units {}", alm.lut_units());
                if v == ArchVariant::Baseline {
                    assert!(alm.z_inputs.is_empty());
                    if alm.uses_adders() {
                        assert!(alm.logic_luts.is_empty(),
                                "baseline adder ALM hosts logic LUTs");
                    }
                }
            }
        }
    }

    #[test]
    fn every_cell_is_packed_exactly_once() {
        let nl = mul_netlist(6);
        let p = pack(&nl, &Arch::paper(ArchVariant::Dd5), &PackOpts::default());
        let mut seen: HashSet<CellId> = HashSet::new();
        for alm in &p.alms {
            for &c in alm
                .adder_bits
                .iter()
                .chain(alm.logic_luts.iter())
                .chain(alm.ffs.iter())
            {
                assert!(seen.insert(c), "cell {c} packed twice");
            }
            for paths in &alm.operand_paths {
                for p in paths {
                    if let OperandPath::AbsorbedLut(l) = p {
                        assert!(seen.insert(*l), "feeder {l} packed twice");
                    }
                }
            }
        }
        let packable = nl
            .cells
            .iter()
            .filter(|c| {
                matches!(c.kind,
                         CellKind::Lut { .. } | CellKind::AdderBit { .. } | CellKind::Ff)
            })
            .count();
        assert_eq!(seen.len(), packable);
    }

    #[test]
    fn chains_occupy_consecutive_alm_pairs() {
        let nl = mul_netlist(6);
        let p = pack(&nl, &Arch::paper(ArchVariant::Baseline), &PackOpts::default());
        for alm in &p.alms {
            if alm.adder_bits.len() == 2 {
                let (c0, p0, c1, p1) = match (&nl.cells[alm.adder_bits[0] as usize].kind,
                                              &nl.cells[alm.adder_bits[1] as usize].kind) {
                    (CellKind::AdderBit { chain: c0, pos: p0 },
                     CellKind::AdderBit { chain: c1, pos: p1 }) => (*c0, *p0, *c1, *p1),
                    _ => unreachable!(),
                };
                assert_eq!(c0, c1);
                assert_eq!(p1, p0 + 1);
            }
        }
    }
}
